"""CI perf-regression gate for the benchmark trajectory.

Compares a freshly generated ``BENCH_pyramid.json`` (``benchmarks.run
--dry-run`` is enough: the gated quantities are all analytic) against the
committed baseline ``benchmarks/baseline.json`` and fails when any gated
metric *regresses* by more than ``--tolerance`` (default 10%):

* ``kernel_dataflow.launches.<workload>``: ``hbm_bytes_total``,
  ``modeled_cycles``, ``input_bytes_halo``, ``slice_bytes`` — per-launch
  off-chip traffic, pipeline-aware modeled latency, and the streamed
  weight-DMA granule of each tracked kernel workload (``slice_bytes`` is 0
  resident, the last level's whole tensor when untiled, and shrinks by
  ``c_tiles`` on channel-tiled launches — a regression back to the untiled
  blocking regime multiplies it and fails the gate).  Each workload has a
  bf16 twin row (``<workload>_bf16``) gated on the same metrics, so losing
  the low-precision plan re-tiering (e.g. a bf16 launch regressing from
  resident back to streamed) fails CI just like an f32 regression;
* ``partition.<model>.<strategy>`` for ``auto`` and ``auto_bf16``:
  ``hbm_bytes``, ``modeled_latency_us`` — the auto-partitioner's
  whole-network plan quality for every zoo model at both compute dtypes;
* ``serving.<model>.buckets.bucket<N>``: ``modeled_cycles``, ``slo_us`` —
  the batch-aware plan cost and published cold-latency SLO of every
  serving bucket (DESIGN.md §14), so a ladder change that slows a bucket's
  plan fails CI even though the measured sweep never gates;
* ``serving.<model>.modeled_batch_efficiency_b8`` — a *higher-is-better
  floor* (``EFFICIENCY_FLOORS``): resnet18's bucket-8 modeled batch
  efficiency must stay >= 3.0x.  This is the serving acceptance for big
  models — the measured interpret-mode wall clock (~0.87x for resnet18)
  reflects CPU emulation scaling with rows, not the TPU dataflow the cycle
  model gates, so it stays ungated context.

The launch rows also carry ungated context columns (``c_tiles``,
``k_pipeline_cycles_saved``, ``pipeline_cycles_saved``) so the committed
baseline documents the schedule each number was produced under.

Lower is better for every gated metric, so improvements always pass; a
genuine improvement should be locked in by refreshing the baseline with
``--update`` and committing the result.  On failure the gate prints the
*full* diff table of every gated metric (baseline vs current vs allowed
threshold, with per-row status) so one bad number never hides the rest of
the picture.

Usage::

    PYTHONPATH=src python -m benchmarks.run --dry-run
    python -m benchmarks.check_regression            # gate (CI)
    python -m benchmarks.check_regression --update   # reseed the baseline
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

BASELINE = pathlib.Path(__file__).with_name("baseline.json")

LAUNCH_METRICS = (
    "hbm_bytes_total", "modeled_cycles", "input_bytes_halo", "slice_bytes",
)
PARTITION_METRICS = ("hbm_bytes", "modeled_latency_us")
PARTITION_STRATEGIES = ("auto", "auto_bf16")
SERVING_METRICS = ("modeled_cycles", "slo_us")

# higher-is-better minimums, gated against an absolute floor rather than the
# baseline: the modeled batch efficiency is the serving acceptance for big
# models (the measured interpret-mode wall clock never gates — see module
# docstring), so a plan change that erodes batching below the floor fails CI
# even if it erodes slowly enough to slip the 10% relative gate.
EFFICIENCY_FLOORS = {
    "serving/resnet18/modeled_batch_efficiency_b8": 3.0,
}


def gated_metrics(bench: dict) -> dict[str, float]:
    """Flatten the gated (name -> lower-is-better value) metric map."""
    out: dict[str, float] = {}
    for name, row in bench["kernel_dataflow"]["launches"].items():
        for m in LAUNCH_METRICS:
            if m in row:  # absent gated metrics surface as MISSING rows
                out[f"kernel_dataflow/{name}/{m}"] = float(row[m])
    for model, rows in bench["partition"].items():
        for strategy in PARTITION_STRATEGIES:
            for m in PARTITION_METRICS:
                if strategy in rows and m in rows[strategy]:
                    out[f"partition/{model}/{strategy}/{m}"] = float(
                        rows[strategy][m]
                    )
    for model, rows in bench.get("serving", {}).items():
        for bname, row in rows.get("buckets", {}).items():
            for m in SERVING_METRICS:
                if m in row:
                    out[f"serving/{model}/{bname}/{m}"] = float(row[m])
    return out


def floor_metrics(bench: dict) -> dict[str, float]:
    """Flatten the floor-gated (name -> higher-is-better value) map."""
    out: dict[str, float] = {}
    for model, rows in bench.get("serving", {}).items():
        if "modeled_batch_efficiency_b8" in rows:
            out[f"serving/{model}/modeled_batch_efficiency_b8"] = float(
                rows["modeled_batch_efficiency_b8"]
            )
    return out


def diff_table(current: dict, baseline: dict, tolerance: float) -> list[dict]:
    """One row per gated metric: baseline vs current vs allowed threshold.

    ``status`` is ``FAIL`` (above threshold), ``MISSING`` (gated metric
    absent from the current output), ``improved`` (below baseline) or
    ``ok``.  Every metric gets a row so a failing gate prints the complete
    picture, not just the first offender."""
    cur, base = gated_metrics(current), gated_metrics(baseline)
    rows = []
    for key, base_val in sorted(base.items()):
        threshold = base_val * (1.0 + tolerance)
        cur_val = cur.get(key)
        if cur_val is None:
            status = "MISSING"
        elif cur_val > threshold:
            status = "FAIL"
        elif cur_val < base_val:
            status = "improved"
        else:
            status = "ok"
        rows.append(
            {
                "metric": key,
                "baseline": base_val,
                "current": cur_val,
                "threshold": threshold,
                "delta": (
                    cur_val / base_val - 1.0
                    if cur_val is not None and base_val
                    else None
                ),
                "status": status,
            }
        )
    # absolute higher-is-better floors: gated against EFFICIENCY_FLOORS, not
    # the baseline, so the acceptance bar cannot drift with reseeds
    floors = floor_metrics(current)
    base_floors = floor_metrics(baseline)
    for key, floor in sorted(EFFICIENCY_FLOORS.items()):
        if key not in floors and key not in base_floors:
            # neither side tracks this section (e.g. a unit-test fixture
            # bench with no serving rows) — the committed baseline carries
            # every floored metric, so a real bench that drops one still
            # surfaces below as MISSING
            continue
        cur_val = floors.get(key)
        if cur_val is None:
            status = "MISSING"
        elif cur_val < floor:
            status = "FAIL"
        else:
            status = "ok"
        rows.append(
            {
                "metric": f"{key} (floor)",
                "baseline": floor,
                "current": cur_val,
                "threshold": floor,
                "delta": (cur_val / floor - 1.0) if cur_val is not None else None,
                "status": status,
            }
        )
    return rows


def format_diff_table(rows: list[dict], out=print) -> None:
    out(
        f"{'metric':<58} {'baseline':>14} {'current':>14} "
        f"{'threshold':>14} {'delta':>8}  status"
    )
    for r in rows:
        cur = "—" if r["current"] is None else f"{r['current']:,.6g}"
        delta = "—" if r["delta"] is None else f"{r['delta']:+.1%}"
        out(
            f"{r['metric']:<58} {r['baseline']:>14,.6g} {cur:>14} "
            f"{r['threshold']:>14,.6g} {delta:>8}  {r['status']}"
        )


def compare(current: dict, baseline: dict, tolerance: float) -> list[str]:
    """Regressions (worse than baseline by > tolerance) as report lines."""
    failures = []
    for r in diff_table(current, baseline, tolerance):
        if r["status"] == "MISSING":
            failures.append(
                f"{r['metric']}: missing from current benchmark output"
            )
        elif r["status"] == "FAIL" and r["metric"].endswith(" (floor)"):
            failures.append(
                f"{r['metric']}: {r['current']:g} below required floor "
                f"{r['baseline']:g}"
            )
        elif r["status"] == "FAIL":
            failures.append(
                f"{r['metric']}: {r['current']:g} vs baseline "
                f"{r['baseline']:g} (+{r['delta']:.1%} > {tolerance:.0%})"
            )
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", default="BENCH_pyramid.json",
                    help="freshly generated benchmark JSON to gate")
    ap.add_argument("--baseline", default=str(BASELINE),
                    help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional regression (default 0.10)")
    ap.add_argument("--update", action="store_true",
                    help="reseed the baseline from --bench instead of gating")
    args = ap.parse_args(argv)

    with open(args.bench) as f:
        bench = json.load(f)

    if args.update:
        slim = {
            # launches only: the wallclock subsection is machine-dependent
            # timing noise and is never gated
            "kernel_dataflow": {
                "launches": bench["kernel_dataflow"]["launches"]
            },
            "partition": {
                model: {s: rows[s] for s in PARTITION_STRATEGIES}
                for model, rows in bench["partition"].items()
            },
            # analytic bucket rows + modeled efficiency only: the measured
            # sweep is wall-clock noise and never gates
            "serving": {
                model: {
                    k: rows[k]
                    for k in ("buckets", "modeled_batch_efficiency_b8")
                    if k in rows
                }
                for model, rows in bench.get("serving", {}).items()
            },
        }
        with open(args.baseline, "w") as f:
            json.dump(slim, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"baseline reseeded: {args.baseline} "
              f"({len(gated_metrics(slim))} gated metrics)")
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = compare(bench, baseline, args.tolerance)
    if failures:
        print(f"PERF REGRESSION vs {args.baseline} "
              f"(tolerance {args.tolerance:.0%}):")
        for line in failures:
            print(f"  {line}")
        print("\nfull gated-metric diff:")
        format_diff_table(diff_table(bench, baseline, args.tolerance))
        return 1
    n = len(gated_metrics(baseline))
    print(f"perf gate OK: {n} metrics within {args.tolerance:.0%} of baseline,"
          f" {len(EFFICIENCY_FLOORS)} floor(s) held")
    return 0


if __name__ == "__main__":
    sys.exit(main())
