"""Figs. 12-14: END detection rates, energy savings, ResNet-18 cycle savings.

Digit-level END simulation over conv-layer SOP windows.  The paper measures
trained filters on dataset images; offline we use He-initialized filters over
1/f-correlated synthetic images (natural-image second-order statistics), the
determinant of SOP sign rates.  Expected regime: ~40-55% negatives caught
within the digit budget, ~2% undetermined (paper: 43.1%/41.08% detected,
~2.1-2.4% undetermined).

Energy model (documented): bit-serial PPU energy ~ active digit cycles, so
energy saving == mean fraction of cycles terminated (paper Fig. 13 reports
46.8%/48.5%/42.6% on the same basis).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cnn_models import (
    ALEXNET_FUSION,
    LENET5_FUSION,
    VGG_FUSION,
    resnet18_fusions,
)
from repro.core.end_detect import end_statistics
from repro.core.executor import conv_windows, init_pyramid_params


N_DIGITS = 16
PAPER_DETECTED = {"alexnet": 43.1, "vgg": 41.08}
PAPER_ENERGY = {"lenet": 46.8, "alexnet": 48.5, "vgg": 42.6}


def natural_images(key, n, size, channels):
    """1/f-spectrum images: natural second-order statistics."""
    white = jax.random.normal(key, (n, size, size, channels))
    f = jnp.fft.fftfreq(size)
    rad = jnp.sqrt(f[:, None] ** 2 + f[None, :] ** 2) + 1.0 / size
    spec = jnp.fft.fft2(white, axes=(1, 2)) / rad[None, :, :, None]
    img = jnp.real(jnp.fft.ifft2(spec, axes=(1, 2)))
    img = img / (jnp.std(img, axis=(1, 2, 3), keepdims=True) + 1e-6)
    return img.astype(jnp.float32)


def conv1_end_stats(spec, *, n_filters=10, n_images=8, max_windows=512,
                    seed=0):
    """END statistics for the first conv layer (Fig. 12 protocol).

    SOP values are range-normalized to ~(-1, 1) (x4 sigma), exactly the
    fixed-point scaling a deployed bit-serial accelerator applies; scaling
    never changes signs, so detection rates are scale-faithful while the
    termination cycle reflects a correctly-provisioned dynamic range.
    The digit stream used is the fast path validated against the full
    multiplier + adder-tree pipeline in tests/test_online_arith.py.
    """
    from repro.core.online_arith import to_digits

    key = jax.random.PRNGKey(seed)
    params = init_pyramid_params(spec, key)
    imgs = natural_images(
        jax.random.PRNGKey(seed + 1), n_images, spec.input_size,
        spec.levels[0].n_in,
    )
    win, _ = conv_windows(imgs, spec, level=0, max_windows=max_windows)
    w = params.weights[0].reshape(-1, params.weights[0].shape[-1])
    per_filter = []
    for f in range(n_filters):
        vals = win @ w[:, f]
        scale = 1.0 / (4.0 * float(jnp.std(vals)) + 1e-9)
        vn = jnp.clip(vals * scale, -0.999, 0.999)
        digits = to_digits(vn, N_DIGITS)
        per_filter.append(end_statistics(digits, vn))
    return per_filter


def fused_cycle_savings(spec, *, seed=0, n_images=4, max_windows=256):
    """Fig. 14 protocol on a fusion pyramid: END cycle savings for its convs."""
    stats = conv1_end_stats(spec, n_filters=8, n_images=n_images,
                            max_windows=max_windows, seed=seed)
    savings = [s.cycle_savings for s in stats]
    return float(np.mean(savings))


def run(csv=print):
    csv("fig,net,metric,ours,paper")
    for net, spec in [("lenet", LENET5_FUSION), ("alexnet", ALEXNET_FUSION),
                      ("vgg", VGG_FUSION)]:
        stats = conv1_end_stats(spec)
        det = 100 * float(np.mean([s.detected_frac for s in stats]))
        und = 100 * float(np.mean([s.undetermined_frac for s in stats]))
        sav = 100 * float(np.mean([s.cycle_savings for s in stats]))
        csv(f"F12_detected_pct,{net},conv1,{det:.1f},"
            f"{PAPER_DETECTED.get(net, '-')}")
        csv(f"F12_undetermined_pct,{net},conv1,{und:.2f},~2.2")
        csv(f"F13_energy_saving_pct,{net},conv1,{sav:.1f},{PAPER_ENERGY[net]}")
    # Fig. 14: ResNet-18 fusion pyramids (2-conv blocks)
    sav = []
    for i, spec in enumerate(resnet18_fusions()[:4]):
        s = 100 * fused_cycle_savings(spec, seed=i)
        sav.append(s)
        csv(f"F14_resnet18_cycle_saving_pct,block{i},fused,{s:.1f},~50.1")
    csv(f"F14_resnet18_cycle_saving_pct,mean,fused,{np.mean(sav):.1f},50.1")


if __name__ == "__main__":
    run()
