"""Figs. 10-11: performance vs operational intensity.

Points per network: unfused layer-by-layer, fused naive-stride (Baselines
1-2), fused uniform-stride (Baseline-3 + proposed), each with the DS-1 /
conventional durations from the cycle models.  The paper's headline — the
uniform-stride OI improvement (8.2x / 17.8x / 279.4x) — is reproduced by
``intensity_improvement`` (LeNet exact; AlexNet/VGG same order, the paper's
byte accounting is under-specified — EXPERIMENTS.md §Intensity).
"""

from __future__ import annotations

from repro.core.cnn_models import NETWORKS, PAPER_OPS, PAPER_OUT_REGION
from repro.core.cycle_model import evaluate_design
from repro.core.fusion import plan_fusion
from repro.core.intensity import (
    IntensityPoint,
    fused_bytes,
    intensity_improvement,
    unfused_bytes,
)

PAPER_OI_IMPROVEMENT = {"lenet": 8.2, "alexnet": 17.8, "vgg": 279.4}


def points(net: str) -> list[IntensityPoint]:
    spec = NETWORKS[net]
    plan = plan_fusion(spec, out_region=PAPER_OUT_REGION[net])
    ops = PAPER_OPS[(net, "Fused")]
    ds1_uni = evaluate_design("ds1", spec, plan, ops)
    ds1_naive = evaluate_design("ds1", spec, plan, ops, uniform_stride=False)
    conv_uni = evaluate_design("baseline_spatial", spec, plan, ops)
    return [
        IntensityPoint("unfused_conventional", ops, unfused_bytes(spec),
                       conv_uni.duration_us),
        IntensityPoint("fused_naive_stride(B1/B2)", ops,
                       fused_bytes(spec, plan, uniform=False),
                       ds1_naive.duration_us),
        IntensityPoint("fused_uniform_B3", ops, fused_bytes(spec, plan),
                       conv_uni.duration_us),
        IntensityPoint("fused_uniform_DS1", ops, fused_bytes(spec, plan),
                       ds1_uni.duration_us),
    ]


def run(csv=print):
    csv("fig,net,design,ops_per_byte,gops")
    for net in NETWORKS:
        for p in points(net):
            csv(f"F11_intensity,{net},{p.design},{p.intensity:.2f},{p.gops:.2f}")
        spec = NETWORKS[net]
        plan = plan_fusion(spec, out_region=PAPER_OUT_REGION[net])
        imp = intensity_improvement(spec, plan)
        csv(
            f"F11_oi_improvement,{net},uniform_vs_naive,{imp:.1f}x,"
            f"paper={PAPER_OI_IMPROVEMENT[net]}x"
        )


if __name__ == "__main__":
    run()
