"""Tables 1-4: DS-1 / DS-2 durations & performance vs paper-printed values.

Our Eq. (3)/(4) cycle models with the paper-consistent parameters
(n=8, delta_OLM=delta_OLA=2, MP=2, Acc=1, 100 MHz).  Fused DS-1 rows
reproduce the paper EXACTLY; DS-2 within ~2%; baseline durations are
paper-quoted (their RTL-level formulas are not given) next to our
documented baseline model.
"""

from __future__ import annotations

from repro.core.cnn_models import NETWORKS, PAPER_OPS, PAPER_OUT_REGION
from repro.core.cycle_model import evaluate_design
from repro.core.fusion import plan_fusion

# paper-printed values: (duration_us, ...) from Tables 1-4
PAPER_DS1_FUSED_US = {"lenet": 13.75, "alexnet": 63.99, "vgg": 11.79}
PAPER_DS2_FUSED_US = {"lenet": 128.25, "alexnet": 1210.0, "vgg": 39.40}
PAPER_B3_SPATIAL_US = {"lenet": 25.75, "alexnet": 101.25, "vgg": 16.83}
PAPER_B3_TEMPORAL_US = {"lenet": 214.25, "alexnet": 2020.14, "vgg": 57.51}
PAPER_SPEEDUP_DS1 = {"lenet": 1.87, "alexnet": 1.58, "vgg": 1.43}
PAPER_SPEEDUP_DS2 = {"lenet": 1.67, "alexnet": 1.68, "vgg": 1.46}


def rows():
    out = []
    for net, spec in NETWORKS.items():
        plan = plan_fusion(spec, out_region=PAPER_OUT_REGION[net])
        ops = PAPER_OPS[(net, "Fused")]
        ds1 = evaluate_design("ds1", spec, plan, ops)
        ds2 = evaluate_design("ds2", spec, plan, ops)
        b_sp = evaluate_design("baseline_spatial", spec, plan, ops)
        b_tmp = evaluate_design("baseline_temporal", spec, plan, ops)
        naive1 = evaluate_design("ds1", spec, plan, ops, uniform_stride=False)
        out.append(
            dict(
                net=net,
                alpha=plan.alpha,
                ds1_us=ds1.duration_us,
                ds1_paper_us=PAPER_DS1_FUSED_US[net],
                ds1_gops=ds1.gops,
                ds2_us=ds2.duration_us,
                ds2_paper_us=PAPER_DS2_FUSED_US[net],
                b3_spatial_model_us=b_sp.duration_us,
                b3_spatial_paper_us=PAPER_B3_SPATIAL_US[net],
                b3_temporal_model_us=b_tmp.duration_us,
                b3_temporal_paper_us=PAPER_B3_TEMPORAL_US[net],
                naive_stride_us=naive1.duration_us,
                paper_speedup_ds1=PAPER_SPEEDUP_DS1[net],
                paper_speedup_ds2=PAPER_SPEEDUP_DS2[net],
                stride_speedup=naive1.duration_us / ds1.duration_us,
            )
        )
    return out


def run(csv=print):
    csv("table,net,alpha,ours_us,paper_us,rel_err")
    for r in rows():
        csv(
            f"T1_ds1_fused,{r['net']},{r['alpha']},{r['ds1_us']:.2f},"
            f"{r['ds1_paper_us']:.2f},"
            f"{abs(r['ds1_us'] - r['ds1_paper_us']) / r['ds1_paper_us']:.4f}"
        )
        csv(
            f"T2_ds2_fused,{r['net']},{r['alpha']},{r['ds2_us']:.2f},"
            f"{r['ds2_paper_us']:.2f},"
            f"{abs(r['ds2_us'] - r['ds2_paper_us']) / r['ds2_paper_us']:.4f}"
        )
        csv(
            f"T1_uniform_vs_naive_stride,{r['net']},{r['alpha']},"
            f"{r['stride_speedup']:.2f}x,>2x,-"
        )
    return rows()


if __name__ == "__main__":
    run()
