"""Benchmark harness: one section per paper table/figure, plus the
whole-network partition comparison, with machine-readable output.

``PYTHONPATH=src python -m benchmarks.run``  prints ``name,...`` CSV rows and
writes ``BENCH_pyramid.json`` (``--out`` to relocate) holding the per-workload
HBM bytes, wall-clock numbers (each recorded as its median plus a
``{p50_ms, p95_ms, reps}`` stats dict over :data:`WALLCLOCK_REPS` timed reps
after one warm-up), END skip fractions, and the auto-partition vs
paper-fusion vs layer-by-layer comparison for every zoo model — the rows the
perf trajectory tracks.  Wall clocks are never gated; the analytic rows are
(see ``check_regression``).

Sections:

* Tables 1-4 — DS-1/DS-2 cycle-model durations vs the paper (paper_tables)
* Figs 10-11 — performance vs operational intensity (intensity)
* Figs 12-14 — END detection / energy / ResNet-18 cycle savings (end_savings)
* Whole-network partitions — modeled HBM/latency of auto vs baselines
* Kernel wall-time sanity (interpret mode; TPU timing is the dry-run's job)

``--dry-run`` keeps only the analytic sections (no kernel launches, no
digit-level simulation) so the CI smoke job finishes in seconds on CPU.
"""

from __future__ import annotations

import argparse
import json

FREQ_MHZ = 100.0

# every wall-clock number in the JSON is the median of this many timed reps
# (after one untimed warm-up call); the rep count rides along in the output
# so check_regression-style consumers compare like with like
WALLCLOCK_REPS = 5


def _percentile_ms(times: list[float], q: float) -> float:
    """Linear-interpolated q-th percentile (times already in ms) — the
    shared :func:`repro.obs.stats.percentile`, kept under its historical
    name for callers and tests."""
    from repro.obs.stats import percentile

    return percentile(times, q)


def _timed_stats_ms(fn, reps: int = WALLCLOCK_REPS) -> dict:
    """Wall-clock stats over ``reps`` timed calls of ``fn`` — the shared
    :func:`repro.obs.stats.timed_stats_ms` (warm-up + reps, returns
    ``{"p50_ms", "p95_ms", "reps"}``).  Every wall-clock metric in
    BENCH_pyramid.json records this dict alongside its median scalar so the
    trajectory carries tail latency too.  Wall clocks are never gated by
    check_regression, so the extra keys do not widen the gate."""
    from repro.obs.stats import timed_stats_ms

    return timed_stats_ms(fn, reps)


def _timed_median_ms(fn, reps: int = WALLCLOCK_REPS) -> float:
    """Median-only convenience wrapper around :func:`_timed_stats_ms`."""
    return _timed_stats_ms(fn, reps)["p50_ms"]


def _partition_comparison(csv=print) -> dict:
    """Auto vs paper vs layer-by-layer for every zoo model: modeled HBM
    traffic and DS-1 latency of all pyramid launches (batch 1).  The
    ``auto_bf16`` strategy re-runs the DP with 2-byte operands so the JSON
    records how the halved working set re-tiers the plan ladder (regime
    flips and cut-point moves) alongside the ~2x HBM reduction."""
    from repro.net.graph import MODELS
    from repro.net.partition import (
        auto_partition,
        layerwise_partition,
        paper_partition,
    )

    out: dict = {}
    csv("partition,model,strategy,hbm_bytes,launches,modeled_latency_us")
    for model in MODELS:
        graph = MODELS[model]()
        rows = {}
        for strategy, plan in (
            ("auto", auto_partition(graph)),
            ("auto_bf16", auto_partition(graph, compute_dtype="bfloat16")),
            ("paper", paper_partition(graph)),
            ("layerwise", layerwise_partition(graph)),
        ):
            lat_us = plan.modeled_cycles() / FREQ_MHZ
            rows[strategy] = {
                "hbm_bytes": plan.hbm_bytes(),
                "launches": plan.n_launches(),
                "modeled_latency_us": lat_us,
                "pyramids": [
                    {
                        "nodes": list(p.node_names),
                        "q_convs": p.q_convs,
                        "out_region": p.launch.out_region,
                        "streamed": p.launch.streamed,
                        "regime": p.launch.regime,
                        "c_tiles": p.launch.c_tiles,
                        "hbm_bytes": p.launch.hbm_bytes(),
                    }
                    for p in plan.pyramids
                ],
            }
            csv(
                f"partition,{model},{strategy},{rows[strategy]['hbm_bytes']},"
                f"{rows[strategy]['launches']},{lat_us:.1f}"
            )
        auto, layer = rows["auto"]["hbm_bytes"], rows["layerwise"]["hbm_bytes"]
        paper = rows["paper"]["hbm_bytes"]
        csv(
            f"partition_savings,{model},auto_vs_layerwise,"
            f"{(layer - auto) / layer:.1%},auto_vs_paper,"
            f"{(paper - auto) / paper:.1%}"
        )
        bf16 = rows["auto_bf16"]
        flips = sum(
            1
            for p32, p16 in zip(rows["auto"]["pyramids"], bf16["pyramids"])
            if p32["regime"] != p16["regime"]
        ) if rows["auto"]["launches"] == bf16["launches"] else None
        csv(
            f"partition_dtype,{model},bf16_hbm_ratio,"
            f"{auto / bf16['hbm_bytes']:.2f}x,launches,"
            f"{rows['auto']['launches']}->{bf16['launches']},regime_flips,"
            f"{'resegmented' if flips is None else flips}"
        )
        out[model] = rows
    return out


def _kernel_dataflow(csv=print, dry_run: bool = True) -> dict:
    """Per-launch HBM dataflow of the fused-pyramid kernel: the retired
    whole-image-resident input model vs the halo-tile model (what the kernel
    now actually moves), per regime, the fully-blocking vs software-pipelined
    modeled latency delta (cross-cell input prefetch *and* the k-axis weight
    slice pipeline of channel-tiled launches), plus compiled-vs-interpret
    wall clock when kernels may run.  The analytic rows are emitted even
    under ``--dry-run`` so the CI smoke job can assert the section exists
    and the bench trajectory has comparable numbers."""
    import dataclasses

    import jax

    from repro.core.cnn_models import (
        LENET5_FUSION,
        VGG_FUSION,
        resnet18_fusions,
    )
    from repro.core.intensity import launch_dataflow
    from repro.core.program import plan_launch

    out: dict = {"launches": {}}
    csv(
        "kernel_dataflow,workload,input_model,input_bytes,weight_bytes,"
        "output_bytes,regime"
    )
    specs = {
        "lenet_q2": LENET5_FUSION,
        "vgg_blocks12_q4_224": VGG_FUSION,
        "resnet18_b7_streamed": resnet18_fusions()[7],
    }
    # every workload is planned twice: at f32 and at bf16.  The bf16 twin
    # rides as ``<name>_bf16`` so the regression gate tracks both ladders;
    # the dtype row below reports the HBM ratio and any plan-tier flip the
    # halved bytes buy (e.g. streamed -> resident, fewer c_tiles).
    for name, spec in specs.items():
        for dtype in ("float32", "bfloat16"):
            lp = plan_launch(spec, compute_dtype=dtype)
            flow = launch_dataflow(lp.program, streamed=lp.streamed)
            # the fully-blocking schedule: serial input fetch AND blocking
            # weight DMA, at the launched c_tiles — what every DMA/MXU
            # overlap (cross-cell x pipeline + k-axis slice pipeline) is
            # measured against
            cycles_serial = dataclasses.replace(
                lp, x_slots=1, w_slots=1
            ).modeled_cycles()
            # only advertise the pipelined latency when the x_slots=2 kernel
            # is actually buildable (the planner's own ladder rule) —
            # otherwise the row reports the launched regime
            cycles_pipe = lp.with_input_pipeline().modeled_cycles()
            # the k-axis share alone: the launched plan vs its blocking-slice
            # (w_slots=1) twin — 0 for resident launches, > 0 exactly when
            # the weight pipeline (channel-tiled or whole-level) overlaps
            cycles_w1 = dataclasses.replace(lp, w_slots=1).modeled_cycles()
            row = {
                **flow,
                "compute_dtype": dtype,
                "regime": lp.regime,
                "alpha": lp.program.alpha,
                "out_region": lp.out_region,
                "tile0": lp.program.tile0,
                "streamed": lp.streamed,
                "w_slots": lp.w_slots,
                "x_slots": lp.x_slots,
                "c_tiles": lp.c_tiles,
                "slice_bytes": lp.slice_bytes(),
                "hbm_bytes_total": lp.hbm_bytes(),
                "input_reduction": (
                    flow["input_bytes_whole_image"] / flow["input_bytes_halo"]
                ),
                "modeled_cycles": lp.modeled_cycles(),
                "modeled_cycles_serial": cycles_serial,
                "modeled_cycles_pipelined": cycles_pipe,
                "pipeline_cycles_saved": cycles_serial - cycles_pipe,
                "k_pipeline_cycles_saved": cycles_w1 - lp.modeled_cycles(),
            }
            key = name if dtype == "float32" else f"{name}_bf16"
            out["launches"][key] = row
            for model in ("whole_image", "halo"):
                csv(
                    f"kernel_dataflow,{key},{model},"
                    f"{flow[f'input_bytes_{model}']},{flow['weight_bytes']},"
                    f"{flow['output_bytes']},{lp.regime}"
                )
            csv(
                f"kernel_dataflow_reduction,{key},input,"
                f"{row['input_reduction']:.1f}x,alpha,{row['alpha']}"
            )
            csv(
                f"kernel_dataflow_pipeline,{key},serial,{cycles_serial},"
                f"pipelined,{cycles_pipe},saved,{row['pipeline_cycles_saved']},"
                f"x_slots,{lp.x_slots},c_tiles,{lp.c_tiles},"
                f"slice_bytes,{row['slice_bytes']},"
                f"k_saved,{row['k_pipeline_cycles_saved']}"
            )
        f32, b16 = out["launches"][name], out["launches"][f"{name}_bf16"]
        csv(
            f"kernel_dataflow_dtype,{name},bf16_hbm_ratio,"
            f"{f32['hbm_bytes_total'] / b16['hbm_bytes_total']:.2f}x,"
            f"cycles_ratio,"
            f"{f32['modeled_cycles'] / b16['modeled_cycles']:.2f}x,"
            f"regime,{f32['regime']}->{b16['regime']},"
            f"c_tiles,{f32['c_tiles']}->{b16['c_tiles']}"
        )

    if not dry_run:
        from repro.core import resolve_interpret
        from repro.core.executor import init_pyramid_params
        from repro.kernels.fused_conv.ops import fused_pyramid

        spec = LENET5_FUSION
        params = init_pyramid_params(spec, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 1))
        wall: dict = {
            "backend": jax.default_backend(),
            "reps": WALLCLOCK_REPS,
        }
        modes = [("interpret", True)]
        if not resolve_interpret(None):  # compiled mode available (TPU)
            modes.append(("compiled", False))
        for label, interp in modes:
            def call(interp=interp):
                y, _ = fused_pyramid(
                    x, params.weights, params.biases, spec=spec,
                    out_region=1, interpret=interp,
                )
                jax.block_until_ready(y)

            stats = _timed_stats_ms(call)
            wall[f"{label}_ms"] = stats["p50_ms"]
            wall[f"{label}_stats"] = stats
            csv(
                f"kernel_dataflow_wallclock,lenet_q2,{label},"
                f"{stats['p50_ms']:.1f},ms_per_call_median{WALLCLOCK_REPS},"
                f"p95,{stats['p95_ms']:.1f}"
            )
        if "compiled_ms" not in wall:
            wall["compiled_ms"] = None  # no TPU on this host
        out["wallclock"] = wall
    return out


def _serving(csv=print, dry_run: bool = True) -> dict:
    """Serving engine (DESIGN.md §14): per-bucket modeled rows — launches,
    HBM bytes, batch-aware modeled cycles, and the published SLO (cold:
    host staging + compute; steady: the double-buffered ``max`` bound) —
    for LeNet and ResNet-18 at paper scale.  The analytic rows are emitted
    under ``--dry-run`` too and are regression-gated (``modeled_cycles``
    and ``slo_us`` per bucket), so a plan ladder change that slows a
    serving bucket fails CI even though no kernel ran.

    When kernels may run (``not dry_run``), a measured sweep drives 8
    single-image requests through a :class:`~repro.net.serve.ServingEngine`
    at each bucket size and through a sequential batch-1 ``run_network``
    baseline (ResNet-18 at the reduced interpret scale).  The acceptance
    row is ``bucket8_beats_sequential``: continuous batching at bucket 8
    must out-throughput one-at-a-time calls."""
    from repro.core.cycle_model import host_staging_cycles, serve_stream_cycles
    from repro.core.dtypes import DTYPE_BYTES
    from repro.net.graph import MODELS
    from repro.net.partition import auto_partition

    buckets = (1, 2, 4, 8)
    out: dict = {}
    csv(
        "serving,model,bucket,launches,hbm_bytes,modeled_cycles,"
        "slo_us,steady_us,us_per_img"
    )
    for model in ("lenet", "resnet18"):
        graph = MODELS[model]()
        rows: dict = {}
        for bucket in buckets:
            plan = auto_partition(graph, batch=bucket)
            compute = plan.modeled_cycles()
            in_bytes = DTYPE_BYTES[plan.compute_dtype] * bucket * (
                graph.input_size ** 2 * graph.in_channels
            )
            staging = host_staging_cycles(in_bytes)
            slo_us = serve_stream_cycles(
                1, compute, staging, double_buffered=False
            ) / FREQ_MHZ
            steady_us = max(compute, staging) / FREQ_MHZ
            rows[f"bucket{bucket}"] = {
                "bucket": bucket,
                "launches": plan.n_launches(),
                "hbm_bytes": plan.hbm_bytes(),
                "modeled_cycles": compute,
                "staging_cycles": staging,
                "slo_us": slo_us,
                "steady_us": steady_us,
                "us_per_img": slo_us / bucket,
            }
            csv(
                f"serving,{model},{bucket},{plan.n_launches()},"
                f"{plan.hbm_bytes()},{compute},{slo_us:.1f},"
                f"{steady_us:.1f},{slo_us / bucket:.1f}"
            )
        b1, b8 = rows["bucket1"], rows["bucket8"]
        efficiency = 8 * b1["slo_us"] / b8["slo_us"]
        csv(
            f"serving_batch_efficiency,{model},bucket8_vs_1x8,"
            f"{efficiency:.2f}x_modeled,launches,"
            f"{b1['launches']}->{b8['launches']}"
        )
        # the serving acceptance for big models: modeled batch efficiency
        # (8 cold batch-1 SLOs vs one cold bucket-8 SLO).  The measured
        # interpret-mode wall clock is NOT the acceptance — CPU kernel
        # emulation scales with rows, so batching shows ~1x there (0.87x
        # for resnet18) while the TPU-model claim is >3x; the floor gate
        # lives in check_regression.EFFICIENCY_FLOORS.
        out[model] = {
            "buckets": rows,
            "modeled_batch_efficiency_b8": efficiency,
        }

    if not dry_run:
        measured = _serving_measured(csv)
        for model, rows in measured.items():
            out[model]["measured"] = rows
    return out


def _serving_measured(csv=print) -> dict:
    """Measured half of the serving section: 8 single-image requests per
    bucket through the engine vs sequential batch-1 calls, interpret mode.
    The sequential baseline blocks per call — request-response semantics:
    a one-at-a-time server must return each result before dispatching the
    next forward, which is exactly the sync overhead continuous batching
    amortizes.  Wall clocks are never gated; ``bucket8_beats_sequential``
    records the acceptance row for LeNet (the only zoo model whose
    interpret-mode wall clock is not dominated by per-image kernel
    emulation — for ResNet-18 the rows ride as ungated context next to
    its modeled batch efficiency, which is the TPU-model claim)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.net.graph import MODELS
    from repro.net.partition import auto_partition
    from repro.net.runner import (
        init_network_params,
        prepare_network_params,
        run_network,
    )
    from repro.net.serve import ServeConfig, ServingEngine

    n_imgs = 8
    sizes = {"lenet": None, "resnet18": 32}  # interpret-friendly scales
    out: dict = {}
    for model, size in sizes.items():
        kwargs = {"input_size": size} if size else {}
        graph = MODELS[model](**kwargs)
        params = init_network_params(graph, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        imgs = [
            rng.standard_normal(
                (1, graph.input_size, graph.input_size, graph.in_channels)
            ).astype(np.float32)
            for _ in range(n_imgs)
        ]

        # sequential baseline: one batch-1 run_network call per image,
        # host->device copy included and blocking per call (a one-request-
        # at-a-time server returns each result before the next dispatch)
        plan1 = auto_partition(graph, batch=1)
        prep1 = prepare_network_params(plan1, params)

        def sequential():
            for x in imgs:
                logits, _ = run_network(
                    jax.device_put(jnp.asarray(x)), prep1, plan=plan1
                )
                jax.block_until_ready(logits)

        seq_stats = _timed_stats_ms(sequential)
        seq_imgs_per_s = n_imgs / (seq_stats["p50_ms"] / 1e3)
        csv(
            f"serving_measured,{model},sequential_b1,"
            f"{seq_stats['p50_ms']:.1f},ms_per_{n_imgs}imgs,imgs_per_s,"
            f"{seq_imgs_per_s:.1f}"
        )
        rows: dict = {
            "input_size": graph.input_size,
            "n_imgs": n_imgs,
            "wallclock_reps": WALLCLOCK_REPS,
            "sequential_b1": {
                "wallclock_ms": seq_stats["p50_ms"],
                "wallclock_stats": seq_stats,
                "imgs_per_s": seq_imgs_per_s,
            },
        }

        # engine sweep: a single-bucket engine per size so every batch pads
        # to exactly that bucket (the warm-up rep absorbs plan + jit trace)
        for bucket in (1, 2, 4, 8):
            eng = ServingEngine(
                graph, params, ServeConfig(buckets=(bucket,))
            )

            def call(eng=eng):
                eng.serve(imgs)

            stats = _timed_stats_ms(call)
            entry = eng._entry(bucket)  # cached by the warm-up
            imgs_per_s = n_imgs / (stats["p50_ms"] / 1e3)
            rows[f"bucket{bucket}"] = {
                "wallclock_ms": stats["p50_ms"],
                "wallclock_stats": stats,
                "imgs_per_s": imgs_per_s,
                "slo_us": entry.slo_us,
                "steady_us": entry.steady_us,
            }
            csv(
                f"serving_measured,{model},bucket{bucket},"
                f"{stats['p50_ms']:.1f},ms_per_{n_imgs}imgs,imgs_per_s,"
                f"{imgs_per_s:.1f},slo_us,{entry.slo_us:.1f}"
            )
        speedup = rows["bucket8"]["imgs_per_s"] / seq_imgs_per_s
        rows["bucket8_speedup_vs_sequential"] = speedup
        # the acceptance row: only meaningful where interpret-mode wall
        # clock reflects batching (LeNet); big-model rows are context
        rows["bucket8_beats_sequential"] = bool(speedup > 1.0)
        csv(
            f"serving_measured_speedup,{model},bucket8_vs_sequential,"
            f"{speedup:.2f}x,beats_sequential,"
            f"{rows['bucket8_beats_sequential']}"
        )
        out[model] = rows
    return out


def _lenet_e2e(csv=print) -> dict:
    """End-to-end LeNet-5 through run_network: wall clock + skip fractions
    (the only zoo model cheap enough to execute at paper scale in interpret
    mode), then the same network at bf16 — wall clock, modeled HBM, and the
    max-abs logit error against the f32 run, alongside the documented
    tolerance (``bf16_logit_tol``) the CI smoke job enforces."""
    import jax
    import jax.numpy as jnp

    from repro.net.graph import lenet5
    from repro.net.partition import auto_partition
    from repro.net.runner import (
        bf16_logit_tol,
        init_network_params,
        prepare_network_params,
        run_network,
        skip_fractions,
    )

    graph = lenet5()
    raw = init_network_params(graph, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32, 1))

    plan = auto_partition(graph, batch=4)
    params = prepare_network_params(plan, raw)
    logits_f32, skips = run_network(x, params, plan=plan)  # + jit warm

    def call():
        logits, _ = run_network(x, params, plan=plan)
        jax.block_until_ready(logits)

    stats = _timed_stats_ms(call)
    dt_ms = stats["p50_ms"]
    frac = skip_fractions(skips)
    csv(f"lenet_e2e,auto_plan,interpret,{dt_ms:.1f},ms_per_batch4,"
        f"p95,{stats['p95_ms']:.1f}")

    plan16 = auto_partition(graph, batch=4, compute_dtype="bfloat16")
    params16 = prepare_network_params(plan16, raw)
    logits_b16, _ = run_network(x, params16, plan=plan16)  # jit warm

    def call16():
        logits, _ = run_network(x, params16, plan=plan16)
        jax.block_until_ready(logits)

    stats16 = _timed_stats_ms(call16)
    dt16_ms = stats16["p50_ms"]
    err = float(jnp.max(jnp.abs(
        logits_b16.astype(jnp.float32) - logits_f32
    )))
    tol = bf16_logit_tol(logits_f32)
    csv(f"lenet_e2e_bf16,auto_plan,interpret,{dt16_ms:.1f},ms_per_batch4,"
        f"max_abs_err,{err:.4f},tol,{tol:.4f}")
    # modeled_cycles rides alongside the wall clock so obs.report can join
    # this workload into the model-vs-measured drift table
    return {
        "hbm_bytes": plan.hbm_bytes(),
        "modeled_cycles": plan.modeled_cycles(),
        "wallclock_ms": dt_ms,
        "wallclock_stats": stats,
        "wallclock_reps": WALLCLOCK_REPS,
        "batch": 4,
        "skip_fractions": frac,
        "bf16": {
            "hbm_bytes": plan16.hbm_bytes(),
            "modeled_cycles": plan16.modeled_cycles(),
            "wallclock_ms": dt16_ms,
            "wallclock_stats": stats16,
            "max_abs_err": err,
            "logit_tol": tol,
        },
    }


def _guard_overhead(csv=print) -> dict:
    """Guarded-runtime cost (DESIGN.md §13): the LeNet e2e workload run
    unguarded (jit fast path) vs under ``guarding()`` — the wall-clock
    delta is ``guard_overhead_pct`` — plus the fallback counts of the
    clean guarded run (all-clean expected) and of a squeezed run that
    forces the replan rung.  All rows are ungated stats context: wall
    clocks are never part of the regression gate."""
    import jax

    from repro.net.graph import lenet5
    from repro.net.partition import auto_partition
    from repro.net.runner import (
        init_network_params,
        prepare_network_params,
        run_network,
    )
    from repro.robust import GuardConfig, guarding, inject

    graph = lenet5()
    master = init_network_params(graph, jax.random.PRNGKey(0))
    plan = auto_partition(graph, batch=4)
    params = prepare_network_params(plan, master)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32, 1))

    def plain():
        logits, _ = run_network(x, params, plan=plan)
        jax.block_until_ready(logits)

    def guarded():
        with guarding(GuardConfig(), source_params=master):
            logits, _ = run_network(x, params, plan=plan)
        jax.block_until_ready(logits)

    stats_plain = _timed_stats_ms(plain)
    stats_guard = _timed_stats_ms(guarded)
    overhead_pct = (
        (stats_guard["p50_ms"] - stats_plain["p50_ms"])
        / stats_plain["p50_ms"] * 100.0
    )

    # clean-run fallback counts (expected empty) ...
    with guarding(GuardConfig(), source_params=master) as guard:
        logits, _ = run_network(x, params, plan=plan)
        jax.block_until_ready(logits)
    clean_counts = guard.last_report.fallback_counts()
    clean = guard.last_report.clean_launches
    launches = guard.last_report.launches

    # ... and a squeezed run demonstrating the replan rung end to end
    with guarding(GuardConfig(), source_params=master) as guard:
        with inject(seed=0) as inj:
            inj.squeeze_budget(0.002)
            logits, _ = run_network(x, params, plan=plan)
            jax.block_until_ready(logits)
    squeezed_counts = guard.last_report.fallback_counts()

    csv(
        f"guard_overhead,lenet_e2e,plain,{stats_plain['p50_ms']:.1f},"
        f"guarded,{stats_guard['p50_ms']:.1f},ms_per_batch4,"
        f"overhead_pct,{overhead_pct:.1f}"
    )
    csv(
        f"guard_fallbacks,lenet_e2e,clean,{clean}/{launches},"
        f"counts,{clean_counts},squeezed_counts,{squeezed_counts}"
    )
    return {
        "guard_overhead_pct": overhead_pct,
        "plain_ms": stats_plain["p50_ms"],
        "plain_stats": stats_plain,
        "guarded_ms": stats_guard["p50_ms"],
        "guarded_stats": stats_guard,
        "wallclock_reps": WALLCLOCK_REPS,
        "batch": 4,
        "clean_launches": clean,
        "launches": launches,
        "fallback_counts": clean_counts,
        "squeezed": {"factor": 0.002, "fallback_counts": squeezed_counts},
    }


def _kernel_micro(csv=print) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.cnn_models import LENET5_FUSION
    from repro.core.executor import init_pyramid_params
    from repro.kernels.fused_conv.ops import fused_conv2
    from repro.kernels.online_sop.ops import online_sop_end

    out = {"wallclock_reps": WALLCLOCK_REPS}
    params = init_pyramid_params(LENET5_FUSION, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 1))
    args = (x, params.weights[0], params.biases[0], params.weights[1],
            params.biases[1])

    def call_conv():
        res, _ = fused_conv2(*args, spec=LENET5_FUSION, out_region=1)
        jax.block_until_ready(res)

    stats = _timed_stats_ms(call_conv)
    us = stats["p50_ms"] * 1e3
    csv(f"kernel_fused_conv_lenet,interpret,{us:.0f},us_per_call,"
        f"p95,{stats['p95_ms'] * 1e3:.0f}")
    out["fused_conv_lenet_us"] = us
    out["fused_conv_lenet_stats"] = stats

    xs = jnp.asarray(np.random.default_rng(0).uniform(-0.03, 0.03, (512, 25)),
                     jnp.float32)
    y = jnp.asarray(np.random.default_rng(1).uniform(-0.5, 0.5, (25,)),
                    jnp.float32) / 4

    def call_sop():
        s, _, _ = online_sop_end(xs, y, 16)
        jax.block_until_ready(s)

    stats = _timed_stats_ms(call_sop)
    us = stats["p50_ms"] * 1e3
    csv(f"kernel_online_sop_512x25,interpret,{us:.0f},us_per_call,"
        f"p95,{stats['p95_ms'] * 1e3:.0f}")
    out["online_sop_512x25_us"] = us
    out["online_sop_512x25_stats"] = stats
    return out


def _vgg_q4_fusion_delta(csv=print) -> dict:
    """Single-kernel VGG Q=4 (the variadic pyramid) vs the historical 2+2
    chained path: analytic HBM traffic at paper scale (224^2) and interpret-
    mode wall clock at reduced scale.  The chained path round-trips the
    block-1 output feature map through HBM; the single launch does not."""
    import dataclasses
    import jax

    from repro.core.cnn_models import VGG_FUSION
    from repro.core.executor import init_pyramid_params
    from repro.core.program import compile_program, pick_out_region
    from repro.kernels.fused_conv.ops import fused_pyramid_chain, plan_chunks

    out: dict = {}
    modes = [("single", {}), ("chained2", {"max_convs_per_chunk": 2})]
    traffic = {}
    for label, kwargs in modes:
        chunks = plan_chunks(VGG_FUSION, **kwargs)
        total = 0
        for ch in chunks:
            prog = compile_program(ch, pick_out_region(ch))
            total += prog.hbm_bytes(1)
        traffic[label] = total
        out[f"hbm_bytes_{label}"] = total
        csv(
            f"vgg_q4_hbm_traffic,{label},{len(chunks)}_launches,"
            f"{total},bytes"
        )
    saved = traffic["chained2"] - traffic["single"]
    csv(
        f"vgg_q4_hbm_traffic_delta,single_vs_chained2,{saved},bytes_saved,"
        f"{saved / traffic['chained2']:.1%},of_chained"
    )

    spec = dataclasses.replace(VGG_FUSION, input_size=32)
    params = init_pyramid_params(spec, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3))
    wall = {}
    out["wallclock_reps"] = WALLCLOCK_REPS
    for label, kwargs in modes:
        def call(kwargs=kwargs):
            y, _ = fused_pyramid_chain(
                x, params.weights, params.biases, spec=spec, **kwargs
            )
            jax.block_until_ready(y)

        stats = _timed_stats_ms(call)
        wall[label] = stats["p50_ms"]
        out[f"wallclock_ms_{label}"] = wall[label]
        out[f"wallclock_stats_{label}"] = stats
        csv(f"vgg_q4_wallclock,{label},interpret,{wall[label]:.1f},ms_per_call,"
            f"p95,{stats['p95_ms']:.1f}")
    csv(
        f"vgg_q4_wallclock_delta,single_vs_chained2,"
        f"{wall['chained2'] - wall['single']:.1f},ms_saved_per_call"
    )
    return out


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="analytic sections only: no kernel launches, no "
                         "digit-level simulation (CI smoke mode)")
    ap.add_argument("--out", default="BENCH_pyramid.json",
                    help="where to write the machine-readable results")
    args = ap.parse_args(argv)

    from benchmarks import intensity, paper_tables

    bench: dict = {"dry_run": args.dry_run, "workloads": {}}

    print("== Tables 1-4: cycle models vs paper ==")
    paper_tables.run()
    print("== Figs 10-11: operational intensity ==")
    intensity.run()
    print("== whole-network partitions: auto vs paper vs layer-by-layer ==")
    bench["partition"] = _partition_comparison()
    print("== kernel dataflow: whole-image vs halo-tile HBM traffic ==")
    bench["kernel_dataflow"] = _kernel_dataflow(dry_run=args.dry_run)
    print("== serving: bucketed batching SLOs"
          + ("" if args.dry_run else " + measured throughput sweep") + " ==")
    bench["serving"] = _serving(dry_run=args.dry_run)

    if not args.dry_run:
        from benchmarks import end_savings

        print("== Figs 12-14: END savings ==")
        end_savings.run()
        print("== LeNet-5 end-to-end (run_network, interpret mode) ==")
        bench["workloads"]["lenet_e2e"] = _lenet_e2e()
        print("== guarded runtime: overhead + fallback counts ==")
        bench["workloads"]["guard_overhead"] = _guard_overhead()
        print("== kernels (interpret-mode wall time; TPU perf comes from the"
              " dry-run roofline) ==")
        bench["workloads"]["kernel_micro"] = _kernel_micro()
        print("== VGG Q=4: single-kernel fusion vs 2+2 chained (HBM traffic +"
              " latency) ==")
        bench["workloads"]["vgg_q4"] = _vgg_q4_fusion_delta()

    with open(args.out, "w") as f:
        json.dump(bench, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
