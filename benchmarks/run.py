"""Benchmark harness: one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``  prints ``name,...`` CSV rows:

* Tables 1-4 — DS-1/DS-2 cycle-model durations vs the paper (paper_tables)
* Figs 10-11 — performance vs operational intensity (intensity)
* Figs 12-14 — END detection / energy / ResNet-18 cycle savings (end_savings)
* Kernel wall-time sanity (interpret mode; TPU timing is the dry-run's job)
"""

from __future__ import annotations

import time


def _kernel_micro():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.cnn_models import LENET5_FUSION
    from repro.core.executor import init_pyramid_params
    from repro.kernels.fused_conv.ops import fused_conv2
    from repro.kernels.online_sop.ops import online_sop_end

    params = init_pyramid_params(LENET5_FUSION, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 1))
    args = (x, params.weights[0], params.biases[0], params.weights[1],
            params.biases[1])
    out, _ = fused_conv2(*args, spec=LENET5_FUSION, out_region=1)
    t0 = time.perf_counter()
    for _ in range(3):
        out, _ = fused_conv2(*args, spec=LENET5_FUSION, out_region=1)
        jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / 3
    print(f"kernel_fused_conv_lenet,interpret,{dt * 1e6:.0f},us_per_call")

    xs = jnp.asarray(np.random.default_rng(0).uniform(-0.03, 0.03, (512, 25)),
                     jnp.float32)
    y = jnp.asarray(np.random.default_rng(1).uniform(-0.5, 0.5, (25,)),
                    jnp.float32) / 4
    s, _, _ = online_sop_end(xs, y, 16)
    t0 = time.perf_counter()
    for _ in range(3):
        s, _, _ = online_sop_end(xs, y, 16)
        jax.block_until_ready(s)
    dt = (time.perf_counter() - t0) / 3
    print(f"kernel_online_sop_512x25,interpret,{dt * 1e6:.0f},us_per_call")


def _vgg_q4_fusion_delta():
    """Single-kernel VGG Q=4 (the variadic pyramid) vs the historical 2+2
    chained path: analytic HBM traffic at paper scale (224^2) and interpret-
    mode wall clock at reduced scale.  The chained path round-trips the
    block-1 output feature map through HBM; the single launch does not."""
    import dataclasses
    import jax

    from repro.core.cnn_models import VGG_FUSION
    from repro.core.executor import init_pyramid_params
    from repro.core.program import compile_program, pick_out_region
    from repro.kernels.fused_conv.ops import fused_pyramid_chain, plan_chunks

    modes = [("single", {}), ("chained2", {"max_convs_per_chunk": 2})]
    traffic = {}
    for label, kwargs in modes:
        chunks = plan_chunks(VGG_FUSION, **kwargs)
        total = 0
        for ch in chunks:
            prog = compile_program(ch, pick_out_region(ch))
            total += prog.hbm_bytes(1)
        traffic[label] = total
        print(
            f"vgg_q4_hbm_traffic,{label},{len(chunks)}_launches,"
            f"{total},bytes"
        )
    saved = traffic["chained2"] - traffic["single"]
    print(
        f"vgg_q4_hbm_traffic_delta,single_vs_chained2,{saved},bytes_saved,"
        f"{saved / traffic['chained2']:.1%},of_chained"
    )

    spec = dataclasses.replace(VGG_FUSION, input_size=32)
    params = init_pyramid_params(spec, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3))
    wall = {}
    for label, kwargs in modes:
        y, _ = fused_pyramid_chain(
            x, params.weights, params.biases, spec=spec, **kwargs
        )  # warm the jit caches
        jax.block_until_ready(y)
        t0 = time.perf_counter()
        for _ in range(3):
            y, _ = fused_pyramid_chain(
                x, params.weights, params.biases, spec=spec, **kwargs
            )
            jax.block_until_ready(y)
        wall[label] = (time.perf_counter() - t0) / 3
        print(f"vgg_q4_wallclock,{label},interpret,{wall[label] * 1e3:.1f},ms_per_call")
    print(
        f"vgg_q4_wallclock_delta,single_vs_chained2,"
        f"{(wall['chained2'] - wall['single']) * 1e3:.1f},ms_saved_per_call"
    )


def main() -> None:
    from benchmarks import end_savings, intensity, paper_tables

    print("== Tables 1-4: cycle models vs paper ==")
    paper_tables.run()
    print("== Figs 10-11: operational intensity ==")
    intensity.run()
    print("== Figs 12-14: END savings ==")
    end_savings.run()
    print("== kernels (interpret-mode wall time; TPU perf comes from the"
          " dry-run roofline) ==")
    _kernel_micro()
    print("== VGG Q=4: single-kernel fusion vs 2+2 chained (HBM traffic +"
          " latency) ==")
    _vgg_q4_fusion_delta()


if __name__ == "__main__":
    main()
