"""Fused CNN inference with the Pallas kernel (TPU-target, interpret on CPU).

Runs AlexNet's first fused block (conv1+pool1+conv2+pool2) through the
fused_conv Pallas kernel — the whole pyramid executes per tile with the
intermediate feature map resident in VMEM — and verifies against the
monolithic reference.  Also demonstrates the END tile-skip firing on
spatially sparse input.

Run:  PYTHONPATH=src python examples/fused_cnn_inference.py
"""

import time

import jax
import jax.numpy as jnp

from repro.core.cnn_models import ALEXNET_FUSION
from repro.core.executor import init_pyramid_params
from repro.kernels.fused_conv.ops import fused_conv2
from repro.kernels.fused_conv.ref import fused_conv2_ref

spec = ALEXNET_FUSION
params = init_pyramid_params(spec, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (1, 227, 227, 3))

t0 = time.time()
out, skip = fused_conv2(
    x, params.weights[0], params.biases[0], params.weights[1], params.biases[1],
    spec=spec, out_region=1,
)
print(f"fused kernel: out {out.shape} in {time.time() - t0:.1f}s (interpret mode)")
ref = fused_conv2_ref(
    x, spec, params.weights[0], params.biases[0], params.weights[1], params.biases[1]
)
print("max err vs monolithic reference:", float(jnp.abs(out - ref).max()))
print("END tile-skips on dense input:", int(skip.sum()), "/", skip.size)

# sparse input: most tiles dead after ReLU -> kernel skips their conv2
xs = jnp.zeros_like(x).at[:, :40, :40, :].set(
    jax.random.normal(jax.random.PRNGKey(2), (1, 40, 40, 3)) * 3
)
b1 = params.biases[0] - 0.3
out2, skip2 = fused_conv2(
    xs, params.weights[0], b1, params.weights[1], params.biases[1],
    spec=spec, out_region=1,
)
ref2 = fused_conv2_ref(xs, spec, params.weights[0], b1, params.weights[1],
                       params.biases[1])
print("sparse input: END skipped", int(skip2.sum()), "/", skip2.size,
      "tiles; err", float(jnp.abs(out2 - ref2).max()))
