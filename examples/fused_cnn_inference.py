"""Fused CNN inference with the Pallas kernel (TPU-target, interpret on CPU).

Runs AlexNet's first fused block (conv1+pool1+conv2+pool2) through the
fused_conv Pallas kernel — the whole pyramid executes per tile with the
intermediate feature maps resident in VMEM — and verifies against the
monolithic reference.  Also demonstrates the END tile-skip cascade firing on
spatially sparse input, and VGG blocks 1-2 (Q=4 convs + 2 pools) running as
a *single* variadic kernel launch: no intermediate map ever touches HBM.

Run:  PYTHONPATH=src python examples/fused_cnn_inference.py
"""

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core.cnn_models import ALEXNET_FUSION, VGG_FUSION
from repro.core.executor import init_pyramid_params
from repro.kernels.fused_conv.ops import fused_conv2, fused_pyramid
from repro.kernels.fused_conv.ref import fused_conv2_ref, fused_pyramid_ref

spec = ALEXNET_FUSION
params = init_pyramid_params(spec, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (1, 227, 227, 3))

t0 = time.time()
out, skip = fused_conv2(
    x, params.weights[0], params.biases[0], params.weights[1], params.biases[1],
    spec=spec, out_region=1,
)
print(f"fused kernel: out {out.shape} in {time.time() - t0:.1f}s (interpret mode)")
ref = fused_conv2_ref(
    x, spec, params.weights[0], params.biases[0], params.weights[1], params.biases[1]
)
print("max err vs monolithic reference:", float(jnp.abs(out - ref).max()))
print("END tile-skips on dense input:", int(skip.sum()), "/", skip.size)

# sparse input: most tiles dead after ReLU -> kernel skips their conv2
xs = jnp.zeros_like(x).at[:, :40, :40, :].set(
    jax.random.normal(jax.random.PRNGKey(2), (1, 40, 40, 3)) * 3
)
b1 = params.biases[0] - 0.3
out2, skip2 = fused_conv2(
    xs, params.weights[0], b1, params.weights[1], params.biases[1],
    spec=spec, out_region=1,
)
ref2 = fused_conv2_ref(xs, spec, params.weights[0], b1, params.weights[1],
                       params.biases[1])
print("sparse input: END skipped", int(skip2.sum()), "/", skip2.size,
      "tiles; err", float(jnp.abs(out2 - ref2).max()))

# --- VGG blocks 1-2 as ONE kernel launch (Q=4 fusion pyramid) --------------
# Reduced spatial size keeps interpret mode quick; the level structure (four
# 3x3 convs + two 2x2 pools) is VGG's.  skip3 carries one END-cascade flag
# per conv level per tile.
vgg = dataclasses.replace(VGG_FUSION, input_size=32)
vp = init_pyramid_params(vgg, jax.random.PRNGKey(3))
xv = jax.random.normal(jax.random.PRNGKey(4), (1, 32, 32, 3))
t0 = time.time()
out3, skip3 = fused_pyramid(xv, vp.weights, vp.biases, spec=vgg, out_region=4)
print(f"VGG Q=4 single launch: out {out3.shape} skip {skip3.shape} "
      f"in {time.time() - t0:.1f}s (interpret mode)")
ref3 = fused_pyramid_ref(xv, vgg, vp.weights, vp.biases)
print("max err vs monolithic reference:", float(jnp.abs(out3 - ref3).max()))
