"""End-to-end fused CNN inference with machine-chosen fusion boundaries.

Builds a zoo model as a graph (`repro.net.graph`), lets the memory-aware
auto-partitioner pick the pyramid cuts (`repro.net.partition`), executes the
whole network through the fused Pallas kernels (`repro.net.runner`) and
verifies the logits against the monolithic JAX reference.  Also demonstrates
the END tile-skip cascade firing on spatially sparse input.

Run:  PYTHONPATH=src python examples/fused_cnn_inference.py --model lenet
      PYTHONPATH=src python examples/fused_cnn_inference.py --model resnet18

Big models default to reduced spatial scale so interpret mode (CPU) stays
quick; pass --input-size to override (the partitioner and kernels are the
same code that handles paper scale — see benchmarks/run.py for the analytic
224^2 numbers).
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.net.graph import MODELS, infer_shapes
from repro.net.partition import auto_partition, layerwise_partition
from repro.net.runner import (
    bf16_logit_tol,
    init_network_params,
    reference_network,
    run_network,
    skip_fractions,
)
from repro.obs import tracing

# interpret-friendly default scales (paper scale for LeNet only)
DEFAULT_SIZE = {"lenet": 32, "alexnet": 67, "vgg16": 32, "resnet18": 32}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", choices=sorted(MODELS), default="lenet")
    ap.add_argument("--input-size", type=int, default=None)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--dtype", choices=("float32", "bfloat16"),
                    default="float32",
                    help="compute dtype for activations/weights; "
                         "accumulation stays f32 either way (DESIGN.md #11)")
    args = ap.parse_args()

    size = args.input_size or DEFAULT_SIZE[args.model]
    graph = MODELS[args.model](input_size=size, num_classes=10,
                               compute_dtype=args.dtype)
    shapes = infer_shapes(graph)
    print(f"{graph.name}: {len(graph.nodes)} nodes, input {size}x{size}, "
          f"logits {shapes[graph.output.name].channels}, "
          f"compute dtype {graph.compute_dtype}")

    plan = auto_partition(graph, batch=args.batch)
    layer = layerwise_partition(graph, batch=args.batch)
    print(plan.summary())
    print(f"layer-by-layer baseline: {layer.hbm_bytes():,}B over "
          f"{layer.n_launches()} launches -> auto saves "
          f"{1 - plan.hbm_bytes() / layer.hbm_bytes():.1%} modeled HBM traffic")

    params = init_network_params(graph, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (args.batch, size, size,
                                                  graph.in_channels))
    t0 = time.time()
    logits, skips = run_network(x, params, plan=plan)
    jax.block_until_ready(logits)
    print(f"run_network: logits {logits.shape} in {time.time() - t0:.1f}s "
          "(interpret mode, includes compile)")
    ref = reference_network(x, graph, params)
    err = float(jnp.abs(logits.astype(jnp.float32) - ref).max())
    print("max |err| vs monolithic f32 reference:", err)
    if args.dtype == "bfloat16":
        # the documented low-precision contract (DESIGN.md #11): bf16
        # operands, f32 accumulation, error relative to logit magnitude
        tol = bf16_logit_tol(ref)
        print(f"bf16 logit tolerance: {tol:.4f}")
        assert err <= tol, f"bf16 error {err} exceeds tolerance {tol}"

    # sparse input: most tiles die after level 0, the END cascade skips the
    # deeper convs of each pyramid.  Re-partition with the paper's
    # smallest-region preference: maximal tile grids even at reduced scale,
    # so the per-tile skips become visible.
    tight = auto_partition(graph, batch=args.batch, prefer_region="smallest")
    blob = max(4, size // 4)
    xs = jnp.zeros_like(x).at[:, :blob, :blob, :].set(
        jax.random.normal(jax.random.PRNGKey(2),
                          (args.batch, blob, blob, graph.in_channels)) * 3
    )
    sparse_params = {
        k: (w, b - 0.3) if graph.node(k).op == "conv" else (w, b)
        for k, (w, b) in params.items()
    }
    # run the sparse forward traced (DESIGN.md #12): one measured+modeled
    # span per fused launch, recorded launch-by-launch
    with tracing() as collector:
        logits_s, skips_s = run_network(xs, sparse_params, plan=tight)
    ref_s = reference_network(xs, graph, sparse_params)
    print("sparse input: max |err|", float(jnp.abs(logits_s - ref_s).max()))
    for name, frac in skip_fractions(skips_s).items():
        if any(f > 0 for f in frac):
            print(f"  END skips {name}: "
                  + ", ".join(f"L{i}={f:.0%}" for i, f in enumerate(frac)))
    print("traced launches (modeled cycle-model time vs measured wall clock):")
    for s in collector.spans:
        print(f"  {s.name:<24} {s.regime:<16} modeled {s.modeled_us:>9,.1f}us"
              f"   measured {s.duration_ms:>9,.1f}ms")
    print(f"  (python -m repro.obs.explain --model {args.model} "
          "--trace t.json renders the full plan table + Perfetto timeline)")


if __name__ == "__main__":
    main()
