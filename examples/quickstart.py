"""Quickstart: the USEFUSE core in five minutes.

Plans a fusion pyramid for LeNet-5 (Algorithms 3-4), runs the fused executor
against the monolithic reference, reproduces the paper's Table-1 duration via
Eq. (3), and shows END early-termination statistics on the first conv layer.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (
    end_statistics,
    evaluate_design,
    fused_forward,
    init_pyramid_params,
    lockstep_plan,
    plan_fusion,
    reference_forward,
    to_digits,
)
from repro.core.cnn_models import LENET5_FUSION, PAPER_OPS
from repro.core.executor import conv_windows

# --- 1. plan the fusion pyramid (Eq. (1) + Algorithms 3-4) -----------------
plan = plan_fusion(LENET5_FUSION, out_region=1)
print("uniform alpha:", plan.alpha, " (paper: 5)")
for lvl, ls in zip(LENET5_FUSION.levels, plan.levels):
    print(f"  {lvl.name}: tile {ls.tile}x{ls.tile}  stride S^T={ls.stride}")

# --- 2. fused execution == monolithic reference ----------------------------
params = init_pyramid_params(LENET5_FUSION, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 1))
ref = reference_forward(x, LENET5_FUSION, params)
fused = fused_forward(x, LENET5_FUSION, params, lockstep_plan(LENET5_FUSION, 1))
print("fused vs reference max err:", float(jnp.abs(ref - fused).max()))

# --- 3. Eq. (3) cycle model reproduces Table 1 ------------------------------
res = evaluate_design("ds1", LENET5_FUSION, plan, PAPER_OPS[("lenet", "Fused")])
print(f"DS-1 fused duration: {res.duration_us} us (paper: 13.75 us), "
      f"{res.gops:.1f} GOPS (paper: 86.10)")

# --- 4. END early negative detection ----------------------------------------
win, _ = conv_windows(x, LENET5_FUSION, level=0, max_windows=256)
vals = win[0] @ params.weights[0].reshape(-1, 6)[:, 0]
vn = jnp.clip(vals / (4 * jnp.std(vals)), -0.999, 0.999)
st = end_statistics(to_digits(vn, 16), vn)
print(f"END: {100 * st.detected_frac:.1f}% detected negative early, "
      f"{100 * st.cycle_savings:.1f}% digit cycles saved")
