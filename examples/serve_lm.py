"""Batched serving example (deliverable b): greedy decode with KV caches on
every architecture family.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

from repro.launch.serve import serve

for arch in ("deepseek_7b", "mamba2_780m", "qwen2_moe_a2_7b"):
    gen, tps = serve(arch, batch=2, new_tokens=12)
    print(f"{arch:18s} generated {gen.shape[1]} tokens/seq at {tps:.1f} tok/s")
