"""End-to-end LM training driver (deliverable b): a ~100M-param dense model
trained for a few hundred steps with the full production stack — data
pipeline, sharded AdamW, checkpoint/restart.

Run:  PYTHONPATH=src python examples/train_lm.py  [--steps 300]

Runtime note: each step is ~0.6 TFLOP; seconds on any accelerator, ~30 s
on this 1-core CPU container (use --steps 10 for a smoke pass; the loop,
checkpointing and restart logic are covered by tests/test_integration.py).
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    # ~100M params: deepseek-7b family scaled to 12 layers x 768
    import repro.configs.deepseek_7b as ds

    cfg = dataclasses.replace(
        ds.CONFIG,
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
        d_ff=2048, vocab=32000, remat="none",
    )
    import repro.configs.base as base

    # register as a transient config the trainer can resolve
    import repro.launch.train as T

    orig = T.get_config
    T.get_config = lambda name: cfg if name == "lm100m" else orig(name)
    losses = train(
        "lm100m",
        steps=args.steps,
        reduced=False,
        seq_len=args.seq_len,
        global_batch=args.global_batch,
        ckpt_dir=args.ckpt_dir,
        log_every=20,
    )
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
