"""Checkpointing: async snapshot, manifest + content hashes, elastic restore.

Fault-tolerance contract (DESIGN.md §6):

* ``save`` snapshots device arrays to host (blocking only for the copy),
  then writes shards + a manifest (tree structure, shapes, dtypes, sha256
  per shard, step) on a background thread — the training loop keeps going.
* ``restore`` verifies hashes, rebuilds the tree, and **re-shards to the
  current mesh** (elastic: a 512-chip checkpoint restores onto 256 chips or
  vice versa — jax.device_put with the target sharding does the resharding).
* Partial/corrupt checkpoints are detected via the manifest hash set and the
  newest *complete* step wins (``latest_complete``).
"""

from __future__ import annotations

import hashlib
import json
import os
import threading
from dataclasses import dataclass
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def _sanitize(p: str) -> str:
    return p.replace("[", "_").replace("]", "").replace("'", "").replace("/", "__")


@dataclass
class Checkpointer:
    directory: str
    keep: int = 3

    def __post_init__(self):
        Path(self.directory).mkdir(parents=True, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, *, blocking: bool = False):
        """Snapshot to host, then write in the background."""
        self.wait()  # one in-flight save at a time
        paths, leaves, _ = _flatten_with_paths(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]

        def write():
            d = Path(self.directory) / f"step_{step:010d}.tmp"
            d.mkdir(parents=True, exist_ok=True)
            manifest = {"step": step, "shards": {}}
            for p, arr in zip(paths, host):
                fn = _sanitize(p) + ".npy"
                # non-native dtypes (bfloat16) round-trip as uint16 views;
                # the manifest records the true dtype
                to_save = arr.view(np.uint16) if arr.dtype.name == "bfloat16" else arr
                np.save(d / fn, to_save)
                h = hashlib.sha256((d / fn).read_bytes()).hexdigest()
                manifest["shards"][p] = {
                    "file": fn,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sha256": h,
                }
            (d / "manifest.json").write_text(json.dumps(manifest))
            final = Path(self.directory) / f"step_{step:010d}"
            os.rename(d, final)  # atomic completion marker
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        done = sorted(Path(self.directory).glob("step_??????????"))
        for old in done[: -self.keep]:
            for f in old.iterdir():
                f.unlink()
            old.rmdir()

    # -- restore --------------------------------------------------------------

    def latest_complete(self) -> int | None:
        steps = []
        for d in Path(self.directory).glob("step_??????????"):
            if (d / "manifest.json").exists():
                steps.append(int(d.name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, step: int, like: Any, shardings: Any | None = None) -> Any:
        """Restore into the structure of ``like``; reshard onto ``shardings``
        (a matching NamedSharding tree) if given — the elastic path."""
        d = Path(self.directory) / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        paths, like_leaves, treedef = _flatten_with_paths(like)
        shard_leaves = (
            jax.tree.leaves(shardings) if shardings is not None else [None] * len(paths)
        )
        out = []
        for p, leaf, shard in zip(paths, like_leaves, shard_leaves):
            meta = manifest["shards"][p]
            fn = d / meta["file"]
            blob = fn.read_bytes()
            if hashlib.sha256(blob).hexdigest() != meta["sha256"]:
                raise IOError(f"checkpoint shard corrupt: {p}")
            arr = np.load(fn)
            if meta["dtype"] == "bfloat16":
                import ml_dtypes

                arr = arr.view(ml_dtypes.bfloat16)
            if list(arr.shape) != list(leaf.shape):
                raise ValueError(
                    f"shape mismatch for {p}: ckpt {arr.shape} vs model {leaf.shape}"
                )
            if shard is not None:
                out.append(jax.device_put(arr, shard))
            else:
                out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, out)
