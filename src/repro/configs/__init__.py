"""Architecture configs: one module per assigned architecture.

``get_config("<id>")`` resolves the registry; shapes live in
:mod:`repro.configs.shapes`.
"""

from .base import ARCH_IDS, ArchConfig, all_configs, get_config
from .shapes import SHAPES, ShapeConfig, cells

__all__ = [
    "ARCH_IDS",
    "ArchConfig",
    "SHAPES",
    "ShapeConfig",
    "all_configs",
    "cells",
    "get_config",
]
