"""Snowflake Arctic 480B base — MoE 128e top-2 + dense residual MLP.

[hf:Snowflake/snowflake-arctic-base; hf]  35L d_model=7168 56H (GQA kv=8)
expert d_ff=4864 vocab=32000.  Arctic is a "dense-MoE hybrid": every layer
sums a dense residual MLP and a 128-expert top-2 MoE.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,          # dense residual MLP width
    vocab=32000,
    n_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_residual=True,
    rope_theta=1e4,
    moment_dtype="bfloat16",  # 480B params: fp32 moments exceed single-pod HBM
    moe_group_tokens=512,  # keeps (G,T,E,C) dispatch temps ~tens of MB/device
    source="hf:Snowflake/snowflake-arctic-base",
)
