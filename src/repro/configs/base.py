"""ArchConfig: the framework's architecture description + registry.

One config file per assigned architecture lives next to this module; each
exposes ``CONFIG``.  ``get_config(name)`` resolves from the registry,
``--arch <id>`` in the launchers goes through it.  ``cfg.reduced()`` builds
the family-preserving small config used by the CPU smoke tests.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass, replace
from typing import Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # defaults to d_model // n_heads

    # attention
    attn_kind: str = "gqa"  # gqa | mla
    rope_theta: float = 1e4
    window: int = 0  # sliding-window size for local-attn layers (hybrid)
    global_layers: Tuple[int, ...] = ()  # full-attn layer ids among sliding

    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    d_nope: int = 0
    d_rope: int = 0
    d_v: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.0
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE

    # SSM / hybrid
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_state: int = 0
    ssm_conv: int = 4
    ssd_chunk: int = 256

    # structure
    kind: str = "decoder"  # decoder | encdec
    enc_layers: int = 0
    enc_seq: int = 0  # stub frontend sequence length (whisper frames)
    cross_every: int = 0  # vlm: a cross-attn layer every N layers
    vis_seq: int = 0  # stub vision tokens
    act: str = "swiglu"  # swiglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False

    # numerics / distribution
    dtype: str = "bfloat16"
    remat: str = "full"  # none | full | dots
    moment_dtype: str = "float32"  # adam moment dtype (bf16 for huge MoE)
    attn_chunk: int = 1024  # flash chunk (prefill)
    moe_group_tokens: int = 4096  # target tokens per dispatch group

    # provenance
    source: str = ""

    def __post_init__(self):
        if self.d_head == 0 and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_heads * self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: attention-free or windowed-attention."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs have a decoder

    def param_count(self) -> int:
        """Approximate parameter count (embedding + layers)."""
        from repro.models.model import build_param_specs
        from repro.models.params import P
        import numpy as np
        import jax

        specs = build_param_specs(self)
        leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        return int(sum(np.prod(l.shape) for l in leaves))

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed experts count top_k/E)."""
        total = self.param_count()
        if not self.n_experts:
            return total
        expert_p = (
            self.n_layers * self.n_experts * 3 * self.d_model * self.moe_d_ff
        )
        active_expert_p = expert_p * self.top_k / self.n_experts
        return int(total - expert_p + active_expert_p)

    def reduced(self) -> "ArchConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        return replace(
            self,
            n_layers=2,
            enc_layers=min(self.enc_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_head=16,
            d_ff=128,
            vocab=256,
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=16 if self.kv_lora_rank else 0,
            d_nope=8 if self.d_nope else 0,
            d_rope=8 if self.d_rope else 0,
            d_v=16 if self.d_v else 0,
            n_experts=min(self.n_experts, 8),
            n_shared_experts=min(self.n_shared_experts, 2),
            top_k=min(self.top_k, 2),
            moe_d_ff=32 if self.moe_d_ff else 0,
            ssm_heads=4 if self.ssm_heads else 0,
            ssm_head_dim=8 if self.ssm_head_dim else 0,
            ssm_state=8 if self.ssm_state else 0,
            ssd_chunk=8,
            window=16 if self.window else 0,
            global_layers=(0,) if self.global_layers else (),
            enc_seq=min(self.enc_seq, 16),
            vis_seq=min(self.vis_seq, 16),
            cross_every=2 if self.cross_every else 0,
            attn_chunk=16,
            moe_group_tokens=32,
            remat="none",
        )


ARCH_IDS = (
    "arctic_480b",
    "qwen2_moe_a2_7b",
    "minicpm3_4b",
    "deepseek_7b",
    "glm4_9b",
    "phi4_mini_3_8b",
    "llama32_vision_11b",
    "hymba_1_5b",
    "mamba2_780m",
    "whisper_large_v3",
)


def get_config(name: str) -> ArchConfig:
    """Resolve an architecture id (dashes or underscores) to its config."""
    key = name.replace("-", "_").replace(".", "_")
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
