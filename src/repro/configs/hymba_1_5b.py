"""Hymba-1.5B — hybrid: parallel attention + mamba heads per layer.

[arXiv:2411.13676; hf]  32L d_model=1600 25H (kv=5) d_ff=5504 ssm_state=16
vocab=32001.  Sliding-window attention everywhere except 3 global layers
(first / middle / last), mamba heads in parallel within every layer.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab=32001,
    window=1024,
    global_layers=(0, 15, 31),
    ssm_heads=50,
    ssm_head_dim=64,   # d_inner = 3200 = 2 * d_model
    ssm_state=16,
    source="arXiv:2411.13676",
)
