"""Llama-3.2-11B-Vision — dense decoder + gated cross-attn image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]  40L d_model=4096 32H (kv=8)
d_ff=14336 vocab=128256.  A gated cross-attention layer every 5 layers (8
total); the vision tower is a STUB per the assignment: ``input_specs``
provides precomputed patch embeddings (vis_seq x d_model).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    cross_every=5,
    vis_seq=1601,  # 1 tile x (40x40 patches + cls)
    rope_theta=5e5,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
