"""Mamba2-780m — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060; unverified]  48L d_model=1536 ssm_state=128 vocab=50280.
d_inner = 2*d_model = 3072, head_dim 64 -> 48 ssm heads.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_head=0,
    d_ff=0,
    vocab=50280,
    ssm_heads=48,
    ssm_head_dim=64,
    ssm_state=128,
    source="arXiv:2405.21060",
)
