"""MiniCPM3-4B — dense with MLA (multi-head latent attention).

[hf:openbmb/MiniCPM3-4B; hf]  62L d_model=2560 40H d_ff=6400 vocab=73448.
MLA dims from the HF config: q_lora 768, kv_lora 256, qk_nope 64, qk_rope 32,
v_head 64.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    attn_kind="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    d_nope=64,
    d_rope=32,
    d_v=64,
    d_head=96,  # nope + rope
    source="hf:openbmb/MiniCPM3-4B",
)
