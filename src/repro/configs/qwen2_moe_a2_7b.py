"""Qwen1.5-MoE-A2.7B — 60 routed experts top-4 + 4 shared experts.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]  24L d_model=2048 16H (kv=16) moe d_ff=1408
vocab=151936.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5632,           # shared-expert aggregate width (4 x 1408)
    vocab=151936,
    n_experts=60,
    n_shared_experts=4,
    top_k=4,
    moe_d_ff=1408,
    rope_theta=1e6,
    moe_group_tokens=512,  # keeps (G,T,E,C) dispatch temps ~tens of MB/device
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
