"""Assigned input shapes and the (arch x shape) cell matrix.

Four shapes per LM arch (seq_len x global_batch):

* ``train_4k``    — 4,096 x 256, lowers ``train_step``
* ``prefill_32k`` — 32,768 x 32, lowers ``prefill_step`` (forward, causal)
* ``decode_32k``  — one new token against a 32,768 KV cache, batch 128,
                    lowers ``serve_step``
* ``long_500k``   — one new token against a 524,288 cache, batch 1, lowers
                    ``serve_step``; requires sub-quadratic attention — run for
                    SSM/hybrid archs only, skipped (and recorded) for pure
                    full-attention archs per the assignment.

Whisper (enc-dec) decodes against its audio cross-context; its ``seq_len``
applies to the self-attention KV cache of the decoder, which is the shape's
intent (the 448-token product limit is a checkpoint property, not an
architecture one) — noted in EXPERIMENTS.md.
"""

from __future__ import annotations

from dataclasses import dataclass

from .base import ArchConfig


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    step: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_supported(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(supported, reason-if-not) for one (arch x shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (assignment: skip + record)"
        )
    return True, ""


def cells(configs: dict[str, ArchConfig]):
    """Yield (arch_id, cfg, shape, supported, reason) for the full matrix."""
    for arch_id, cfg in configs.items():
        for shape in SHAPES.values():
            ok, why = cell_supported(cfg, shape)
            yield arch_id, cfg, shape, ok, why
