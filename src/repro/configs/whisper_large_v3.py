"""Whisper-large-v3 — encoder-decoder audio transformer.

[arXiv:2212.04356; unverified]  32L encoder + 32L decoder, d_model=1280
20H (kv=20) d_ff=5120 vocab=51866.  GELU MLP + LayerNorm (whisper family).
The conv frame frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (1500 x d_model).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    kind="encdec",
    n_layers=32,
    enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    enc_seq=1500,
    act="gelu",
    norm="layernorm",
    source="arXiv:2212.04356",
)
