"""USEFUSE core: the paper's contribution as composable JAX modules.

Public API:

* fusion planning — :mod:`repro.core.fusion` (Eq. (1), Algorithms 3-4)
* tile-program compiler — :mod:`repro.core.program` (the single lowering
  shared by the executor and the variadic Pallas kernel)
* online arithmetic — :mod:`repro.core.online_arith` (Algorithm 1, adders)
* early negative detection — :mod:`repro.core.end_detect` (Algorithm 2)
* cycle / performance models — :mod:`repro.core.cycle_model` (Eqs. (2)-(4))
* operational intensity — :mod:`repro.core.intensity` (Figs. 10-11)
* fused execution — :mod:`repro.core.executor`
* backend dispatch — :func:`resolve_interpret` (compiled on TPU, interpreted
  elsewhere), shared by every kernel entry point
"""

import jax

from .fusion import (
    FusedLevel,
    FusionPlan,
    FusionSpec,
    LockstepPlan,
    lockstep_plan,
    plan_fusion,
    receptive_window,
    tile_sizes,
    uniform_tile_stride,
)
from .program import (
    ConvLevelProg,
    LevelWindow,
    TileProgram,
    WindowProgram,
    compile_program,
    compile_windows,
    pick_out_region,
)
from .cycle_model import ArithParams, DesignResult, evaluate_design
from .end_detect import EndStats, end_scan, end_statistics
from .executor import (
    PyramidParams,
    fused_forward,
    init_pyramid_params,
    reference_forward,
)
from .online_arith import (
    from_digits,
    online_add,
    online_mul_sp,
    online_sop,
    sop_digits_fast,
    to_digits,
)


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Resolve a kernel entry point's ``interpret`` argument.

    ``None`` (the default everywhere) auto-detects: compiled Mosaic on a real
    TPU backend, the Pallas interpreter on CPU/GPU (CI, laptops, autodiff
    debugging).  An explicit bool is honored unchanged, so tests can pin
    either mode.
    """
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


__all__ = [
    "ArithParams",
    "ConvLevelProg",
    "DesignResult",
    "EndStats",
    "LevelWindow",
    "TileProgram",
    "WindowProgram",
    "compile_program",
    "compile_windows",
    "pick_out_region",
    "FusedLevel",
    "FusionPlan",
    "FusionSpec",
    "LockstepPlan",
    "PyramidParams",
    "end_scan",
    "end_statistics",
    "evaluate_design",
    "from_digits",
    "fused_forward",
    "init_pyramid_params",
    "lockstep_plan",
    "online_add",
    "online_mul_sp",
    "online_sop",
    "plan_fusion",
    "receptive_window",
    "reference_forward",
    "resolve_interpret",
    "sop_digits_fast",
    "tile_sizes",
    "to_digits",
    "uniform_tile_stride",
]
