"""CNN workload definitions used by the paper (§4.1), derived from the
graph IR.

The full networks live as graphs in :mod:`repro.net.graph` (the model zoo);
this module derives the paper's *hand-picked fusion choices* from them:
LeNet-5 / AlexNet fuse the first two conv layers (+ their pools); VGG-16
fuses the first two blocks (four convs + two pools); ResNet-18 fuses the
conv pair inside each residual block (stem conv excluded).  The raw tuple
tables that used to define these stacks are gone — the graphs are the single
source of truth, and the specs here are prefixes/segments of them.
"""

from __future__ import annotations

from .fusion import FusedLevel, FusionSpec


def _zoo():
    # deferred: repro.net.graph imports repro.core.fusion at module load
    from repro.net import graph

    return graph


# ---------------------------------------------------------------------------
# Paper fusion groups, derived from the zoo graphs
# ---------------------------------------------------------------------------

LENET5_INPUT = 32
LENET5_FUSION = _zoo().backbone_prefix(_zoo().lenet5(LENET5_INPUT), 2)
LENET5_LEVELS = LENET5_FUSION.levels

ALEXNET_INPUT = 227
ALEXNET_FUSION = _zoo().backbone_prefix(_zoo().alexnet(ALEXNET_INPUT), 2)
ALEXNET_LEVELS = ALEXNET_FUSION.levels

VGG_INPUT = 224
VGG_FUSION = _zoo().backbone_prefix(_zoo().vgg16(VGG_INPUT), 4)
VGG_BLOCK12_LEVELS = VGG_FUSION.levels


# ---------------------------------------------------------------------------
# ResNet-18 (224x224x3) — §4.3 END experiment: fuse conv pairs per block
# ---------------------------------------------------------------------------


def resnet18_fusions(input_size: int = 224) -> list[FusionSpec]:
    """Fusion pyramid per residual block (convA -> convB), derived from the
    ResNet-18 graph's body segments; stem and projection shortcuts excluded
    per the paper."""
    g = _zoo().resnet18(input_size)
    return [
        seg.spec()
        for seg in _zoo().fusable_segments(g)
        if seg.nodes[0].name.endswith("_convA")
    ]


def resnet18_block_fusion(n_in: int, n_out: int, ifm: int, s1: int) -> FusionSpec:
    """Fusion pyramid for one residual block: conv3x3(s1) -> conv3x3(1)."""
    return FusionSpec(
        levels=(
            FusedLevel("conv", K=3, S=s1, pad=1, n_in=n_in, n_out=n_out, name="convA"),
            FusedLevel("conv", K=3, S=1, pad=1, n_in=n_out, n_out=n_out, name="convB"),
        ),
        input_size=ifm,
    )


# ---------------------------------------------------------------------------
# Paper Table 1/2 "Number of Operations" (as printed; see EXPERIMENTS.md for
# the internal inconsistencies in the paper's own 2*M*N*R*C*K*K accounting)
# ---------------------------------------------------------------------------

PAPER_OPS = {
    ("lenet", "CONV1"): 235_200,
    ("lenet", "CONV2"): 940_800,
    ("lenet", "Fused"): 1_183_880,
    ("alexnet", "CONV1"): 105_415_200,
    ("alexnet", "CONV2"): 223_948_800,
    ("alexnet", "Fused"): 329_659_136,
    ("vgg", "CONV1"): 173_408_256,
    ("vgg", "CONV2"): 3_699_376_128,
    ("vgg", "CONV3"): 1_849_688_064,
    ("vgg", "CONV4"): 3_699_376_128,
    ("vgg", "Fused"): 9_429_625_856,
}


def conv_ops(level: FusedLevel, out_size: int) -> int:
    """2*M*N*R*C*K*K (Eq. 2's numerator) for one conv level."""
    return 2 * level.n_out * level.n_in * out_size * out_size * level.K * level.K


NETWORKS = {
    "lenet": LENET5_FUSION,
    "alexnet": ALEXNET_FUSION,
    "vgg": VGG_FUSION,
}

# Paper-matching output-region pins (derived in DESIGN.md / validated in
# tests): these yield alpha = 5 / 9 / 3 respectively via Algorithm 4.
PAPER_OUT_REGION = {"lenet": 1, "alexnet": 1, "vgg": None}  # vgg: scan smallest
