"""CNN workload definitions used by the paper (§4.1).

LeNet-5, AlexNet, VGG-16 and ResNet-18 convolution/pool stacks, expressed as
:class:`~repro.core.fusion.FusedLevel` chains, plus the paper's fusion
choices: LeNet-5 / AlexNet fuse the first two conv layers (+ their pools);
VGG-16 fuses the first two blocks (four convs + two pools); ResNet-18 fuses
consecutive conv pairs inside each residual block (first conv excluded).
"""

from __future__ import annotations

from .fusion import FusedLevel, FusionSpec

# ---------------------------------------------------------------------------
# LeNet-5 (32x32x1 input) — paper's running example (§3.3.1)
# ---------------------------------------------------------------------------

LENET5_INPUT = 32
LENET5_LEVELS = (
    FusedLevel("conv", K=5, S=1, pad=0, n_in=1, n_out=6, name="CL1"),
    FusedLevel("pool", K=2, S=2, pad=0, n_in=6, n_out=6, name="MPL1"),
    FusedLevel("conv", K=5, S=1, pad=0, n_in=6, n_out=16, name="CL2"),
    FusedLevel("pool", K=2, S=2, pad=0, n_in=16, n_out=16, name="MPL2"),
)
LENET5_FUSION = FusionSpec(levels=LENET5_LEVELS, input_size=LENET5_INPUT)

# ---------------------------------------------------------------------------
# AlexNet (227x227x3 input) — first two conv layers + pools fused
# ---------------------------------------------------------------------------

ALEXNET_INPUT = 227
ALEXNET_LEVELS = (
    FusedLevel("conv", K=11, S=4, pad=0, n_in=3, n_out=96, name="CONV1"),
    FusedLevel("pool", K=3, S=2, pad=0, n_in=96, n_out=96, name="POOL1"),
    FusedLevel("conv", K=5, S=1, pad=2, n_in=96, n_out=256, name="CONV2"),
    FusedLevel("pool", K=3, S=2, pad=0, n_in=256, n_out=256, name="POOL2"),
)
ALEXNET_FUSION = FusionSpec(levels=ALEXNET_LEVELS, input_size=ALEXNET_INPUT)

# ---------------------------------------------------------------------------
# VGG-16 (224x224x3) — blocks 1-2 (four convs, two pools) fused
# ---------------------------------------------------------------------------

VGG_INPUT = 224
VGG_BLOCK12_LEVELS = (
    FusedLevel("conv", K=3, S=1, pad=1, n_in=3, n_out=64, name="CONV1"),
    FusedLevel("conv", K=3, S=1, pad=1, n_in=64, n_out=64, name="CONV2"),
    FusedLevel("pool", K=2, S=2, pad=0, n_in=64, n_out=64, name="POOL1"),
    FusedLevel("conv", K=3, S=1, pad=1, n_in=64, n_out=128, name="CONV3"),
    FusedLevel("conv", K=3, S=1, pad=1, n_in=128, n_out=128, name="CONV4"),
    FusedLevel("pool", K=2, S=2, pad=0, n_in=128, n_out=128, name="POOL2"),
)
VGG_FUSION = FusionSpec(levels=VGG_BLOCK12_LEVELS, input_size=VGG_INPUT)

# Full VGG-16 conv stack (for end-to-end §4.4 comparisons).
VGG16_ALL_CONVS = (
    # (K, S, pad, n_in, n_out, ifm)
    (3, 1, 1, 3, 64, 224),
    (3, 1, 1, 64, 64, 224),
    (3, 1, 1, 64, 128, 112),
    (3, 1, 1, 128, 128, 112),
    (3, 1, 1, 128, 256, 56),
    (3, 1, 1, 256, 256, 56),
    (3, 1, 1, 256, 256, 56),
    (3, 1, 1, 256, 512, 28),
    (3, 1, 1, 512, 512, 28),
    (3, 1, 1, 512, 512, 28),
    (3, 1, 1, 512, 512, 14),
    (3, 1, 1, 512, 512, 14),
    (3, 1, 1, 512, 512, 14),
)

# ---------------------------------------------------------------------------
# ResNet-18 (224x224x3) — §4.3 END experiment: fuse conv pairs per block
# ---------------------------------------------------------------------------

# (n_in, n_out, ifm, stride_of_first_conv) per residual block; two 3x3 convs
# each.  conv1 (7x7/2) excluded from fusion per the paper.
RESNET18_BLOCKS = (
    (64, 64, 56, 1),
    (64, 64, 56, 1),
    (64, 128, 56, 2),
    (128, 128, 28, 1),
    (128, 256, 28, 2),
    (256, 256, 14, 1),
    (256, 512, 14, 2),
    (512, 512, 7, 1),
)


def resnet18_block_fusion(n_in: int, n_out: int, ifm: int, s1: int) -> FusionSpec:
    """Fusion pyramid for one residual block: conv3x3(s1) -> conv3x3(1)."""
    return FusionSpec(
        levels=(
            FusedLevel("conv", K=3, S=s1, pad=1, n_in=n_in, n_out=n_out, name="convA"),
            FusedLevel("conv", K=3, S=1, pad=1, n_in=n_out, n_out=n_out, name="convB"),
        ),
        input_size=ifm,
    )


def resnet18_fusions() -> list[FusionSpec]:
    return [resnet18_block_fusion(*blk) for blk in RESNET18_BLOCKS]


# ---------------------------------------------------------------------------
# Paper Table 1/2 "Number of Operations" (as printed; see EXPERIMENTS.md for
# the internal inconsistencies in the paper's own 2*M*N*R*C*K*K accounting)
# ---------------------------------------------------------------------------

PAPER_OPS = {
    ("lenet", "CONV1"): 235_200,
    ("lenet", "CONV2"): 940_800,
    ("lenet", "Fused"): 1_183_880,
    ("alexnet", "CONV1"): 105_415_200,
    ("alexnet", "CONV2"): 223_948_800,
    ("alexnet", "Fused"): 329_659_136,
    ("vgg", "CONV1"): 173_408_256,
    ("vgg", "CONV2"): 3_699_376_128,
    ("vgg", "CONV3"): 1_849_688_064,
    ("vgg", "CONV4"): 3_699_376_128,
    ("vgg", "Fused"): 9_429_625_856,
}


def conv_ops(level: FusedLevel, out_size: int) -> int:
    """2*M*N*R*C*K*K (Eq. 2's numerator) for one conv level."""
    return 2 * level.n_out * level.n_in * out_size * out_size * level.K * level.K


NETWORKS = {
    "lenet": LENET5_FUSION,
    "alexnet": ALEXNET_FUSION,
    "vgg": VGG_FUSION,
}

# Paper-matching output-region pins (derived in DESIGN.md / validated in
# tests): these yield alpha = 5 / 9 / 3 respectively via Algorithm 4.
PAPER_OUT_REGION = {"lenet": 1, "alexnet": 1, "vgg": None}  # vgg: scan smallest
