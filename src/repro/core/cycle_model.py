"""USEFUSE cycle / performance models (paper §4.2, Eqs. (2)-(4)).

Reproduces the paper's *proposed-design* durations exactly (validated in
tests): with ``n=8, delta_olm=2, delta_ola=2, mp_cycles=2`` Eq. (3) yields the
Table-1 fused durations 13.75 us (LeNet-5, alpha=5), 63.99 us (AlexNet,
alpha=9) and 11.79 us (VGG blocks 1-2, alpha=3) at 100 MHz.

Baseline models: the paper specifies its conventional-bit-serial baselines
only structurally (UNPU-style AND-gate partial-product WPUs, Figs. 8-9); the
printed baseline durations are not derivable from any formula given in the
paper.  We therefore implement principled baseline models with explicit,
documented assumptions (below) and report *both* our modeled speedups and the
paper's printed ones in the benchmark tables.

Baseline assumptions (conventional bit-serial, spatial):
  * serial-parallel multiplier (UNPU PE): n cycles to produce a full product
    (one weight bit per cycle into an AND-array + shift-accumulate);
  * adder trees are pipelined, 1 cycle per level (ceil(log2 K^2) +
    ceil(log2 N) levels);
  * NO cross-layer digit overlap: a fused level cannot start until the
    previous level's tile is fully computed and buffered, so the n-cycle
    serial phase is paid per level (this is the structural disadvantage the
    paper attributes to conventional arithmetic: it "fails to process the
    generated data immediately");
  * per-level tile buffering costs one extra pass of the level's output
    region through the activation buffer (R_l cycles, bandwidth 1 row/cycle).
Temporal baselines re-use one multiplier per window: K*K * (n + acc) cycles.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from .dtypes import mxu_throughput
from .fusion import FusionPlan, FusionSpec


def _log2c(x: int) -> int:
    return math.ceil(math.log2(x)) if x > 1 else 0


@dataclass(frozen=True)
class ArithParams:
    """Arithmetic/unit parameters (paper's symbols)."""

    n: int = 8  # input precision (bits)
    delta_olm: int = 2  # online multiplier delay
    delta_ola: int = 2  # online adder delay
    acc: int = 1  # accumulator cycles per add (DS-2, Eq. 4)
    mp_cycles: int = 2  # cycles per maxpool stage (MP term)
    freq_mhz: float = 100.0


DEFAULT_PARAMS = ArithParams()


# ---------------------------------------------------------------------------
# Proposed designs — Eq. (3) (DS-1 spatial) and Eq. (4) (DS-2 temporal)
# ---------------------------------------------------------------------------


def _levels_with_pools(spec: FusionSpec):
    """Group conv levels with their trailing pool (for the MP term)."""
    groups = []
    for lvl in spec.levels:
        if lvl.kind == "conv":
            groups.append([lvl, None])
        else:
            if groups and groups[-1][1] is None:
                groups[-1][1] = lvl
            else:  # leading pool (not in the paper's configs)
                groups.append([None, lvl])
    return groups


def ds1_cycles_per_movement(spec: FusionSpec, p: ArithParams = DEFAULT_PARAMS,
                            *, include_pool: bool = True) -> int:
    """Per-movement cycles of Eq. (3), without the alpha^2 factor.

    Per conv level q: delta_OLM + delta_OLA*ceil(log2 K_q^2)
    + delta_OLA*ceil(log2 N_q) + ceil(log2 K_q^2) + ceil(log2 N_q) + MP_q,
    then a single trailing ``n`` — the digit stream is pipelined across the
    whole fusion pyramid, so working precision is paid once.
    """
    total = 0
    for conv, pool in _levels_with_pools(spec):
        if conv is None:
            total += p.mp_cycles if include_pool else 0
            continue
        lk = _log2c(conv.K * conv.K)
        ln = _log2c(conv.n_in)
        total += p.delta_olm + p.delta_ola * lk + p.delta_ola * ln + lk + ln
        if pool is not None and include_pool:
            total += p.mp_cycles
    return total + p.n


def ds2_cycles_per_movement(spec: FusionSpec, p: ArithParams = DEFAULT_PARAMS,
                            *, include_pool: bool = True) -> int:
    """Per-movement cycles of Eq. (4) (temporal design, one OLM per window).

    Per conv level: (delta_OLM + (n-1) + Acc) * K^2  — the single online
    multiplier is drained K^2 times into the accumulation buffer — plus the
    channel adder tree terms and MP; single trailing ``n``.
    """
    total = 0
    for conv, pool in _levels_with_pools(spec):
        if conv is None:
            total += p.mp_cycles if include_pool else 0
            continue
        ln = _log2c(conv.n_in)
        total += (p.delta_olm + (p.n - 1) + p.acc) * conv.K * conv.K
        total += p.delta_ola * ln + ln
        if pool is not None and include_pool:
            total += p.mp_cycles
    return total + p.n


def ds1_split_cycles_per_movement(
    spec: FusionSpec, p: ArithParams = DEFAULT_PARAMS
) -> tuple[int, int]:
    """Eq. (3) per-movement cycles split at the last conv group:
    ``(mid, last)`` with ``mid`` the levels before the final conv (+ its
    trailing pool) and ``last`` the final conv group plus the single
    trailing ``n`` (working precision is paid once, at the pyramid's end, so
    it belongs to the last level's share).  ``mid + last`` equals
    :func:`ds1_cycles_per_movement`; ``mid == 0`` for Q=1 chains.

    This is the compute split the channel-tiled cost model needs: the mid
    share runs once per grid cell (``k == 0``), the last share is divided
    across the ``c_tiles`` output-channel steps."""
    groups = _levels_with_pools(spec)
    terms = []
    for conv, pool in groups:
        if conv is None:
            terms.append(p.mp_cycles)
            continue
        lk = _log2c(conv.K * conv.K)
        ln = _log2c(conv.n_in)
        t = p.delta_olm + p.delta_ola * lk + p.delta_ola * ln + lk + ln
        if pool is not None:
            t += p.mp_cycles
        terms.append(t)
    last_conv = max(
        (gi for gi, (conv, _) in enumerate(groups) if conv is not None),
        default=0,
    )
    mid = sum(terms[:last_conv])
    last = sum(terms[last_conv:]) + p.n
    return mid, last


def mxu_scaled_cycles(cycles: int, compute_dtype) -> int:
    """Compute cycles at ``compute_dtype``: an Eq. (3)/(4) cycle count —
    calibrated at the float32 rate — divided by the dtype's relative MXU
    throughput (:func:`repro.core.dtypes.mxu_throughput`; bf16 operands
    double the systolic array's effective rate, int8 quadruples it), ceil'd
    so a movement never rounds to free.  The compute side of the dtype-aware
    overlap model: DMA terms scale with ``bytes_per_val``, compute divides
    by this factor."""
    return -(-cycles // mxu_throughput(compute_dtype))


def channel_tiled_body_cycles(
    compute_mid: int,
    compute_last: int,
    dma_mid: int,
    dma_slice: int,
    c_tiles: int,
    *,
    pipelined: bool,
) -> int:
    """Per-grid-cell cycles of the channel-tiled schedule (``c_tiles`` > 1).

    ``compute_mid`` / ``dma_mid`` are the once-per-cell (``k == 0``) mid
    pyramid's compute and blocking weight-DMA cycles; ``compute_last`` is
    the whole last level's compute, split evenly over the ``c_tiles`` steps;
    ``dma_slice`` is one ``(Cin, Cout / c_tiles)`` weight slice's DMA.

    Blocking (``w_slots=1``): every slice fetch is exposed —
    ``dma_mid + compute_mid + c_tiles * (dma_slice + ck)``.

    Pipelined (``w_slots=2``): slice 0's fetch starts at the top of the
    kernel body and fills behind the mid pyramid
    (``max(compute_mid, dma_slice)`` exposed), each later slice's fetch
    hides behind the previous slice's MXU pass (steady state
    ``max(ck, dma_slice)``), and the final slice's compute drains exposed:
    ``dma_mid + max(compute_mid, dma_slice) + ck
    + (c_tiles - 1) * max(ck, dma_slice)``.  The saving over blocking is
    ``min(compute_mid, dma_slice) + (c_tiles - 1) * min(ck, dma_slice)``
    >= 0 — never worse.
    """
    ck = -(-compute_last // c_tiles)
    if not pipelined:
        return dma_mid + compute_mid + c_tiles * (dma_slice + ck)
    return (
        dma_mid
        + max(compute_mid, dma_slice)
        + ck
        + (c_tiles - 1) * max(ck, dma_slice)
    )


@dataclass(frozen=True)
class TimelineSegment:
    """One bar of a modeled launch timeline: ``lane`` is ``"mxu"`` (compute)
    or ``"dma"`` (HBM transfer), ``start``/``duration`` are cycles from
    launch start.  Segments are produced by the ``*_timeline`` twins of the
    cycle formulas below; the end of the last segment always equals the
    corresponding ``*_cycles`` total (enforced in ``tests/test_obs.py``), so
    a rendered timeline can never disagree with the cost the planner
    optimized."""

    lane: str
    label: str
    start: int
    duration: int

    @property
    def end(self) -> int:
        return self.start + self.duration


def timeline_end(segments: list[TimelineSegment]) -> int:
    """Cycle at which the last segment of a timeline finishes."""
    return max((s.end for s in segments), default=0)


def channel_tiled_body_timeline(
    compute_mid: int,
    compute_last: int,
    dma_mid: int,
    dma_slice: int,
    c_tiles: int,
    *,
    pipelined: bool,
) -> list[TimelineSegment]:
    """The DMA-vs-MXU bars of one channel-tiled grid cell — the timeline twin
    of :func:`channel_tiled_body_cycles` (same arguments, and the timeline
    ends exactly at that cycle count).

    Blocking: every slice fetch is exposed before its MXU pass.  Pipelined:
    slice 0's fetch fills behind the mid pyramid, slice ``k+1``'s fetch hides
    behind slice ``k``'s pass, the last slice's compute drains exposed.
    """
    ck = -(-compute_last // c_tiles)
    segs: list[TimelineSegment] = []
    if dma_mid:
        segs.append(TimelineSegment("dma", "mid weights", 0, dma_mid))
    if not pipelined:
        t = dma_mid
        if compute_mid:
            segs.append(TimelineSegment("mxu", "mid pyramid", t, compute_mid))
            t += compute_mid
        for k in range(c_tiles):
            segs.append(TimelineSegment("dma", f"w slice {k}", t, dma_slice))
            segs.append(
                TimelineSegment("mxu", f"last conv k={k}", t + dma_slice, ck)
            )
            t += dma_slice + ck
        return segs
    if compute_mid:
        segs.append(TimelineSegment("mxu", "mid pyramid", dma_mid, compute_mid))
    segs.append(TimelineSegment("dma", "w slice 0 (fill)", dma_mid, dma_slice))
    s = dma_mid + max(compute_mid, dma_slice)
    for k in range(c_tiles):
        segs.append(TimelineSegment("mxu", f"last conv k={k}", s, ck))
        if k + 1 < c_tiles:
            segs.append(TimelineSegment("dma", f"w slice {k + 1}", s, dma_slice))
            s += max(ck, dma_slice)
    return segs


def grid_pipeline_timeline(
    cells: int,
    body: int,
    input_dma: int,
    *,
    pipelined: bool,
    max_cells: int = 64,
) -> list[TimelineSegment]:
    """The DMA-vs-MXU bars of one batch element's movement grid — the
    timeline twin of :func:`grid_pipeline_cycles` (same arguments; the
    timeline ends exactly at that cycle count).

    Serial: each cell's halo fetch is exposed before its pyramid.  Pipelined
    (the revolving ``x_slots=2`` landing buffer): cell 0's fetch is the
    warm-up fill, cell ``n`` starts cell ``n+1``'s fetch alongside its own
    pyramid, the last cell's compute drains exposed.  Grids beyond
    ``max_cells`` render the leading cells individually and fold the steady-
    state remainder into one labelled segment so a VGG-scale ``alpha^2``
    never explodes the trace — the elided segment keeps the end exact.
    """
    segs: list[TimelineSegment] = []
    shown = cells if cells <= max_cells else max(1, max_cells - 1)
    if not pipelined or cells <= 1:
        t = 0
        for n in range(shown):
            segs.append(TimelineSegment("dma", f"halo tile {n}", t, input_dma))
            segs.append(
                TimelineSegment("mxu", f"pyramid cell {n}", t + input_dma, body)
            )
            t += input_dma + body
        if shown < cells:
            rest = cells - shown
            segs.append(
                TimelineSegment(
                    "mxu",
                    f"cells {shown}..{cells - 1} x{rest} (elided)",
                    t,
                    rest * (input_dma + body),
                )
            )
        return segs
    step = max(body, input_dma)
    segs.append(TimelineSegment("dma", "halo tile 0 (fill)", 0, input_dma))
    s = input_dma
    for n in range(shown):
        segs.append(TimelineSegment("mxu", f"pyramid cell {n}", s, body))
        if n + 1 < cells:
            segs.append(TimelineSegment("dma", f"halo tile {n + 1}", s, input_dma))
        if n + 1 < shown:
            s += step
    if shown < cells:
        rest = cells - shown  # steady-state cells folded into one bar
        segs.append(
            TimelineSegment(
                "mxu",
                f"cells {shown}..{cells - 1} x{rest} (elided)",
                s + step,
                (rest - 1) * step + body,
            )
        )
    return segs


# Modeled host->HBM staging rate of the serving input stage, in bytes per
# cycle of the 100 MHz model (1.6 GB/s — PCIe-class, a quarter of
# program.HBM_BYTES_PER_CYCLE).  Only the ratio to compute matters: it sets
# how large a bucket's host->device input copy is relative to the pyramid
# cycles the double-buffered stage hides it behind.
HOST_BYTES_PER_CYCLE = 16


def host_staging_cycles(nbytes: int) -> int:
    """Cycles one bucket's host->device input copy occupies the staging
    interface (:data:`HOST_BYTES_PER_CYCLE`) — the quantity the serving
    engine's double-buffered input stage overlaps with the previous
    bucket's compute."""
    return -(-nbytes // HOST_BYTES_PER_CYCLE)


def serve_stream_cycles(
    batches: int, compute: int, staging: int, *, double_buffered: bool
) -> int:
    """Latency of a stream of ``batches`` equal buckets through the serving
    engine given per-bucket ``compute`` cycles and host->device input
    ``staging`` cycles — the serving-level twin of
    :func:`grid_pipeline_cycles`.

    Serial (``double_buffered=False``): every bucket blocks on its own input
    copy — ``(staging + compute) * batches``.

    Double-buffered: bucket ``n+1``'s ``device_put`` is issued while bucket
    ``n`` computes, so after bucket 0's exposed fill the stream runs at the
    steady-state period ``max(compute, staging)``:
    ``staging + compute + (batches - 1) * max(compute, staging)``.  The
    saving over serial is ``(batches - 1) * min(compute, staging)`` >= 0.
    """
    if batches <= 0:
        return 0
    if not double_buffered or batches == 1:
        return batches * (staging + compute)
    return staging + compute + (batches - 1) * max(compute, staging)


def queue_delay_cycles(batches: int, compute: int, staging: int) -> int:
    """Modeled cycles a newly admitted request waits behind ``batches``
    already-queued bucket executions before its own bucket can start.

    Under the double-buffered steady state each queued bucket occupies the
    engine for ``max(compute, staging)`` cycles (the stream period of
    :func:`serve_stream_cycles`), so the wait is ``batches`` periods.  The
    serving front end's admission control compares this (plus the request's
    own bucket SLO) against the request's deadline: when the modeled wait
    already blows the deadline, admitting the request only wastes a launch
    on a result nobody can use — shed it at the door instead.
    """
    if batches <= 0:
        return 0
    return batches * max(compute, staging)


def grid_pipeline_cycles(
    cells: int, body: int, input_dma: int, *, pipelined: bool
) -> int:
    """Latency of one batch element's ``alpha^2``-cell movement grid given
    per-cell compute(+weight-DMA) cycles ``body`` and per-cell input
    halo-tile DMA cycles ``input_dma``.

    Serial (``pipelined=False``): every cell blocks on its own input fetch —
    ``(input_dma + body) * cells``.

    Pipelined (``x_slots=2``, the revolving cross-cell landing buffer): cell
    ``n`` starts cell ``n+1``'s fetch before its own pyramid, so the timeline
    is warm-up fill, then ``cells - 1`` steady-state steps where the fetch
    hides behind compute, then the drain cell's exposed compute:
    ``input_dma + body + (cells - 1) * max(body, input_dma)``.  The saving
    over serial is exactly ``(cells - 1) * min(body, input_dma)`` >= 0, zero
    at ``cells == 1`` (a 1x1 grid has no successor to prefetch).
    """
    if not pipelined or cells <= 1:
        return cells * (body + input_dma)
    return input_dma + body + (cells - 1) * max(body, input_dma)


# ---------------------------------------------------------------------------
# Baseline models (documented assumptions in module docstring)
# ---------------------------------------------------------------------------


def conv_baseline_spatial_cycles_per_movement(
    spec: FusionSpec, p: ArithParams = DEFAULT_PARAMS, *, include_pool: bool = True
) -> int:
    """Conventional bit-serial, spatial WPU (Fig. 8): n paid per level."""
    total = 0
    for conv, pool in _levels_with_pools(spec):
        if conv is None:
            total += p.mp_cycles if include_pool else 0
            continue
        lk = _log2c(conv.K * conv.K)
        ln = _log2c(conv.n_in)
        total += p.n + lk + ln
        if pool is not None and include_pool:
            total += p.mp_cycles
    return total


def conv_baseline_temporal_cycles_per_movement(
    spec: FusionSpec, p: ArithParams = DEFAULT_PARAMS, *, include_pool: bool = True
) -> int:
    """Conventional bit-serial, temporal WPU (Fig. 9)."""
    total = 0
    for conv, pool in _levels_with_pools(spec):
        if conv is None:
            total += p.mp_cycles if include_pool else 0
            continue
        ln = _log2c(conv.n_in)
        total += (p.n + p.acc) * conv.K * conv.K + ln
        if pool is not None and include_pool:
            total += p.mp_cycles
    return total


# ---------------------------------------------------------------------------
# End-to-end duration / performance (Eq. (2))
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DesignResult:
    name: str
    cycles: int
    duration_us: float
    ops: int
    gops: float
    alpha: int


_PER_MOVEMENT = {
    "ds1": ds1_cycles_per_movement,
    "ds2": ds2_cycles_per_movement,
    "baseline_spatial": conv_baseline_spatial_cycles_per_movement,
    "baseline_temporal": conv_baseline_temporal_cycles_per_movement,
}


def naive_alpha(plan: FusionPlan) -> int:
    """Movements when the tile stride equals the conv stride (Baselines 1-2).

    The fusion tile of the FIRST level advances by that level's conv stride,
    so the pyramid is evaluated once per first-level output position that the
    tile plan must cover; this is the paper's "tile stride matching the
    convolution stride" configuration (massively overlapping tiles).
    """
    first = plan.spec.levels[0]
    lvl = plan.levels[0]
    span = lvl.ifm - lvl.tile
    return math.ceil(span / first.S) + 1


def evaluate_design(
    design: str,
    spec: FusionSpec,
    plan: FusionPlan,
    ops: int,
    p: ArithParams = DEFAULT_PARAMS,
    *,
    uniform_stride: bool = True,
) -> DesignResult:
    """Duration & performance for a design over a fusion plan (Eq. (2))."""
    per_mv = _PER_MOVEMENT[design](spec, p)
    alpha = plan.alpha if uniform_stride else naive_alpha(plan)
    cycles = alpha * alpha * per_mv
    dur_us = cycles / p.freq_mhz
    return DesignResult(
        name=design,
        cycles=cycles,
        duration_us=dur_us,
        ops=ops,
        gops=ops / (dur_us * 1e3) if dur_us else float("inf"),
        alpha=alpha,
    )


def single_layer_result(
    design: str,
    spec: FusionSpec,
    plan: FusionPlan,
    conv_index: int,
    ops: int,
    p: ArithParams = DEFAULT_PARAMS,
) -> DesignResult:
    """Per-layer rows of Tables 1-2: one conv level evaluated standalone
    (no pooling epilogue — validated against the paper's CONV1 rows), still
    executed with the fusion plan's alpha movements.
    """
    convs = [l for l in spec.levels if l.kind == "conv"]
    conv = convs[conv_index]
    sub = FusionSpec(levels=(conv,), input_size=spec.input_size)
    per_mv = _PER_MOVEMENT[design](sub, p, include_pool=False)
    cycles = plan.alpha * plan.alpha * per_mv
    dur_us = cycles / p.freq_mhz
    return DesignResult(
        name=f"{design}/conv{conv_index + 1}",
        cycles=cycles,
        duration_us=dur_us,
        ops=ops,
        gops=ops / (dur_us * 1e3) if dur_us else float("inf"),
        alpha=plan.alpha,
    )
