"""The single source of per-value byte widths and compute dtypes.

Every byte model in the repo — :class:`~repro.core.program.TileProgram`'s
VMEM/HBM accounting, the paper-level operational-intensity helpers in
:mod:`repro.core.intensity`, the DMA terms of
:meth:`~repro.core.program.LaunchPlan.modeled_cycles` — derives its
``bytes_per_val`` from :data:`DTYPE_BYTES` so the planner, the kernels, and
the benchmarks can never disagree about how wide a value is.

Dtypes are carried as canonical *name strings* (``"float32"``,
``"bfloat16"``, ``"int8"``): programs and plans are frozen hashable
dataclasses used as jit static arguments, and a string keeps them that way
across pickling/caching while :func:`jnp_dtype` recovers the jnp dtype at
kernel-launch time.

``int8`` is modeled (byte accounting, MXU throughput) but not yet executable
by the fused kernels — the quantized pyramid is the documented stretch; see
:data:`EXEC_DTYPES`.
"""

from __future__ import annotations

import jax.numpy as jnp

# bytes per value of every dtype the byte models understand
DTYPE_BYTES: dict[str, int] = {
    "float32": 4,
    "bfloat16": 2,
    "int8": 1,
    "int32": 4,
}

# dtypes the fused kernels can actually run (bf16 operands accumulate f32
# via preferred_element_type; int8 needs the quantized-pyramid epilogue)
EXEC_DTYPES: tuple[str, ...] = ("float32", "bfloat16")

# relative MXU throughput vs float32: bf16 operands double the systolic
# array's effective rate, int8 quadruples it (the paper's low-precision SOP
# premise mapped onto the TPU's native mixed-precision modes)
MXU_THROUGHPUT: dict[str, int] = {
    "float32": 1,
    "bfloat16": 2,
    "int8": 4,
}

# working precision in bits — the trailing digit-stream term of Eq. (3)
DTYPE_BITS: dict[str, int] = {k: 8 * v for k, v in DTYPE_BYTES.items()}


def canonical_dtype(dtype) -> str:
    """Canonical name string of ``dtype`` (name, jnp dtype, or np dtype).

    Raises ``KeyError`` with the known table on anything the byte models
    don't understand, so a typo'd dtype fails at plan time, not mid-kernel.
    """
    name = dtype if isinstance(dtype, str) else jnp.dtype(dtype).name
    if name not in DTYPE_BYTES:
        raise KeyError(
            f"unknown compute dtype {name!r}; known: {sorted(DTYPE_BYTES)}"
        )
    return name


def dtype_bytes(dtype) -> int:
    """Bytes per value, via :data:`DTYPE_BYTES` — the only place a byte
    width may come from."""
    return DTYPE_BYTES[canonical_dtype(dtype)]


def jnp_dtype(dtype) -> jnp.dtype:
    """The jnp dtype for a canonical name (kernel-launch side of the
    name-string convention)."""
    return jnp.dtype(canonical_dtype(dtype))


def mxu_throughput(dtype) -> int:
    """Relative MXU throughput factor vs float32 (>= 1)."""
    return MXU_THROUGHPUT[canonical_dtype(dtype)]
