"""Early Negative Detection (END) — paper §3.2, Algorithm 2.

The END unit watches the MSDF digit stream of a SOP headed into a ReLU.  In
redundant form the prefix after ``j`` digits is ``N_j = sum_k d_k 2**(j-k)``
(an integer in units of ``2**-j``, equal to ``Z+ - Z-`` of the paper's
positive/negative bit registers).  The remaining tail can add at most
``2**-j - 2**-T < 2**-j``, so

    ``N_j <= -1``  (the paper's ``Z+ < Z-`` comparison)

proves the final SOP is strictly negative: the computation is terminated and
ReLU outputs zero — bit-exact, no accuracy loss (§3.2's claim, verified in
tests).  Activations that are negative but never trip the test within the
digit budget are the paper's "undetermined" residue (its Fig. 12 reports
~2.1-2.4%); they fall through to full-length computation.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit)
def end_scan(digits: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Run Algorithm 2 over digit streams ``(..., T)``.

    Returns ``(detected, cycle)``: ``detected`` bool — the negative-detect
    condition fired; ``cycle`` int32 — 1-based digit index at which it fired
    (== T when it never fired; that stream runs to completion).
    """
    T = digits.shape[-1]
    d = jnp.moveaxis(digits, -1, 0)  # (T, ...)

    def step(carry, dj):
        n_prefix, det, cyc, j = carry
        n_prefix = 2 * n_prefix + dj.astype(jnp.int32)
        hit = (n_prefix <= -1) & (~det)
        det = det | hit
        cyc = jnp.where(hit, j, cyc)
        # clamp the latched prefix so int32 never overflows on long streams
        n_prefix = jnp.clip(n_prefix, -(2 ** 24), 2 ** 24)
        return (n_prefix, det, cyc, j + 1), None

    batch = d.shape[1:]
    carry0 = (
        jnp.zeros(batch, jnp.int32),
        jnp.zeros(batch, bool),
        jnp.full(batch, T, jnp.int32),
        jnp.int32(1),
    )
    (_, det, cyc, _), _ = jax.lax.scan(step, carry0, d)
    return det, cyc


@dataclass(frozen=True)
class EndStats:
    """Aggregate END statistics for a batch of SOP streams (Figs. 12-14)."""

    total: int
    negative: int  # truly negative final SOPs
    detected: int  # flagged early by Algorithm 2
    undetermined: int  # negative but never flagged within the digit budget
    mean_detect_cycle: float  # mean firing digit among detected
    cycles_no_end: int  # total digit cycles without END
    cycles_with_end: int  # total digit cycles with END termination

    @property
    def detected_frac(self) -> float:
        return self.detected / max(self.total, 1)

    @property
    def undetermined_frac(self) -> float:
        return self.undetermined / max(self.total, 1)

    @property
    def cycle_savings(self) -> float:
        return 1.0 - self.cycles_with_end / max(self.cycles_no_end, 1)


def end_statistics(digits: jnp.ndarray, values: jnp.ndarray) -> EndStats:
    """Evaluate END over streams with known exact values."""
    det, cyc = end_scan(digits)
    det = jax.device_get(det).reshape(-1)
    cyc = jax.device_get(cyc).reshape(-1)
    vals = jax.device_get(values).reshape(-1)
    T = digits.shape[-1]
    neg = vals < 0
    undet = neg & ~det
    total = vals.size
    eff = cyc.copy()
    eff[~det] = T
    return EndStats(
        total=int(total),
        negative=int(neg.sum()),
        detected=int(det.sum()),
        undetermined=int(undet.sum()),
        mean_detect_cycle=float(cyc[det].mean()) if det.any() else float(T),
        cycles_no_end=int(total * T),
        cycles_with_end=int(eff.sum()),
    )
