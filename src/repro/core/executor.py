"""Fused-pyramid executor: value-level JAX execution of a fusion plan.

Demonstrates the paper's layer-fusion dataflow at tensor level: every output
tile of the fused chain is computed **only from tile-local buffers** (the
on-chip working set), never from whole intermediate feature maps.  The
monolithic reference (:func:`reference_forward`) materializes every
intermediate map; :func:`fused_forward` must match it exactly — this is the
correctness contract for the fusion-plan math (Eq. (1) windows, lockstep
movement, edge handling).

Hardware note: USEFUSE *reuses* overlapping tile outputs from on-chip buffers
("output pixel reuse instead of recompute", §3.4); value-wise reuse and
recompute are identical, so the executor recomputes halos per tile while the
intensity/cycle models charge the plan's actual buffer traffic.

Layout: NHWC.  Conv weights: (K, K, Cin, Cout) + bias (Cout,).  Conv levels
apply ReLU (the paper's pyramids are conv+ReLU[+pool] stacks).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .fusion import FusionSpec, LockstepPlan, lockstep_plan
from .program import compile_windows


@dataclass
class PyramidParams:
    """Weights for the conv levels of a fusion spec (index-aligned to convs)."""

    weights: list[jnp.ndarray]
    biases: list[jnp.ndarray]


def init_pyramid_params(
    spec: FusionSpec, key: jax.Array, scale: float = 1.0
) -> PyramidParams:
    ws, bs = [], []
    for lvl in spec.levels:
        if lvl.kind != "conv":
            continue
        key, k1, k2 = jax.random.split(key, 3)
        fan_in = lvl.K * lvl.K * lvl.n_in
        w = jax.random.normal(k1, (lvl.K, lvl.K, lvl.n_in, lvl.n_out)) * (
            scale * (2.0 / fan_in) ** 0.5
        )
        b = jax.random.normal(k2, (lvl.n_out,)) * 0.01
        ws.append(w.astype(jnp.float32))
        bs.append(b.astype(jnp.float32))
    return PyramidParams(ws, bs)


def _conv2d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, stride: int,
            pad: int) -> jnp.ndarray:
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + b


def _maxpool(x: jnp.ndarray, k: int, s: int) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, k, k, 1),
        window_strides=(1, s, s, 1),
        padding="VALID",
    )


def reference_forward(
    x: jnp.ndarray, spec: FusionSpec, params: PyramidParams, *, relu: bool = True
) -> jnp.ndarray:
    """Layer-by-layer execution with full intermediate maps (the baseline
    dataflow whose off-chip traffic fusion eliminates)."""
    ci = 0
    for lvl in spec.levels:
        if lvl.kind == "conv":
            x = _conv2d(x, params.weights[ci], params.biases[ci], lvl.S, lvl.pad)
            if relu:
                x = jax.nn.relu(x)
            ci += 1
        else:
            x = _maxpool(x, lvl.K, lvl.S)
    return x




def fused_forward(
    x: jnp.ndarray,
    spec: FusionSpec,
    params: PyramidParams,
    plan: LockstepPlan | None = None,
    *,
    out_region: int | None = None,
    relu: bool = True,
) -> jnp.ndarray:
    """Execute the fused pyramid tile-by-tile per the lockstep plan.

    The alpha x alpha tile grid covers the final output; each tile's chain is
    traced back through the compiled Eq. (1) windows
    (:func:`repro.core.program.compile_windows` — the same tile-program
    lowering the Pallas kernel consumes) and computed from tile-local data.
    """
    if plan is None:
        plan = lockstep_plan(spec, out_region or 1)
    wprog = compile_windows(spec, plan.out_region)
    out = jnp.zeros(
        (x.shape[0], wprog.out_size, wprog.out_size, wprog.n_out), jnp.float32
    )
    for si in plan.starts:
        wins_i = wprog.level_windows(si)
        for sj in plan.starts:
            wins_j = wprog.level_windows(sj)
            # first-level slice (row window from si, col window from sj)
            (lo_i, size_i), (lo_j, size_j) = wins_i[0], wins_j[0]
            p0 = spec.levels[0].pad
            ga_i, ga_j = lo_i - p0, lo_j - p0
            ai, bi = max(ga_i, 0), min(ga_i + size_i, x.shape[1])
            aj, bj = max(ga_j, 0), min(ga_j + size_j, x.shape[2])
            tile = x[:, ai:bi, aj:bj, :]
            tile = jnp.pad(
                tile,
                (
                    (0, 0),
                    (ai - ga_i, ga_i + size_i - bi),
                    (aj - ga_j, ga_j + size_j - bj),
                    (0, 0),
                ),
            )
            tile = _tile_chain_2d(tile, (lo_i, lo_j), spec, params,
                                  (wins_i, wins_j), relu)
            out = out.at[:, si : si + plan.out_region, sj : sj + plan.out_region, :].set(
                tile
            )
    return out


def _tile_chain_2d(tile, g_pad, spec, params, windows, relu):
    """Run one tile through the fused chain using only tile-local buffers.

    ``tile`` holds a window of the level-0 *unpadded* input starting at
    ``g = g_pad - pad_0`` (negative = overlaps the pad border; those rows are
    zero-filled by the caller).  At each level the requested Eq. (1) window is
    cut from the local buffer; any deficit is zero — it is exactly this
    level's padding (interior requests always fit, by construction).  After
    the level executes, rows outside the level's valid output range are
    cropped: a deeper level that asks for them receives zeros (its own pad),
    never values convolved out of thin air.
    """
    wins_i, wins_j = windows
    sizes = spec.feature_sizes()
    gi = g_pad[0] - spec.levels[0].pad
    gj = g_pad[1] - spec.levels[0].pad
    ci = 0
    for l, lvl in enumerate(spec.levels):
        (loi_pad, size_i), (loj_pad, size_j) = wins_i[l], wins_j[l]
        loi, loj = loi_pad - lvl.pad, loj_pad - lvl.pad
        ai, aj = loi - gi, loj - gj
        bi, bj = ai + size_i, aj + size_j
        pli, phi = max(0, -ai), max(0, bi - tile.shape[1])
        plj, phj = max(0, -aj), max(0, bj - tile.shape[2])
        if pli or phi or plj or phj:
            tile = jnp.pad(tile, ((0, 0), (pli, phi), (plj, phj), (0, 0)))
            ai += pli
            bi += pli
            aj += plj
            bj += plj
        tile = tile[:, ai:bi, aj:bj, :]
        if lvl.kind == "conv":
            tile = _conv2d(tile, params.weights[ci], params.biases[ci], lvl.S, 0)
            if relu:
                tile = jax.nn.relu(tile)
            ci += 1
        else:
            tile = _maxpool(tile, lvl.K, lvl.S)
        gi, gj = loi_pad // lvl.S, loj_pad // lvl.S
        # crop to the level's valid output range [0, out_size)
        out_size = sizes[l + 1]
        ci_lo, cj_lo = max(0, -gi), max(0, -gj)
        ci_hi = min(tile.shape[1], out_size - gi)
        cj_hi = min(tile.shape[2], out_size - gj)
        tile = tile[:, ci_lo:ci_hi, cj_lo:cj_hi, :]
        gi += ci_lo
        gj += cj_lo
    return tile


def conv_windows(
    x: jnp.ndarray, spec: FusionSpec, level: int = 0, max_windows: int | None = None
) -> tuple[jnp.ndarray, int]:
    """Extract flattened K*K*N input windows of a conv level (END stats).

    Returns ``(windows, n_windows_per_image)`` with windows shaped
    ``(B, P, K*K*N)`` where P = number of spatial output positions (possibly
    subsampled to ``max_windows``).
    """
    lvl = spec.levels[level]
    assert lvl.kind == "conv"
    xp = jnp.pad(x, ((0, 0), (lvl.pad, lvl.pad), (lvl.pad, lvl.pad), (0, 0)))
    B, H, W, C = xp.shape
    out = (H - lvl.K) // lvl.S + 1
    patches = jax.lax.conv_general_dilated_patches(
        xp,
        (lvl.K, lvl.K),
        (lvl.S, lvl.S),
        "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (B, out, out, K*K*C)
    flat = patches.reshape(B, out * out, -1)
    if max_windows is not None and flat.shape[1] > max_windows:
        idx = np.linspace(0, flat.shape[1] - 1, max_windows).astype(int)
        flat = flat[:, idx, :]
    return flat, out * out
