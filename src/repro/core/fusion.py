"""USEFUSE fusion-pyramid planning.

Implements the paper's layer-fusion math:

* Eq. (1): ``D_l = (D_o - 1) * S_l + K_l`` — receptive-field recurrence used to
  derive per-level tile sizes from a chosen output region (Algorithm 3).
* Algorithm 4: *uniform tile stride* — per level, enumerate integer movement
  counts ``alpha = (IFM - H)/p + 1`` and intersect across levels so every level
  of the pyramid moves the same number of times (no synchronization stalls,
  no ragged execution rounds).

Two layers of fidelity are provided (see DESIGN.md §2):

``tile_sizes`` / ``uniform_tile_stride`` / ``plan_fusion``
    The paper's algorithms, literally.  These reproduce the paper's alpha
    values (LeNet-5 -> 5, AlexNet -> 9, VGG-16 first two blocks -> 3).

``lockstep_plan``
    The physically-exact tile schedule used by the executor / Pallas kernel:
    tiles at every level move in lockstep (movement at level l is the final
    output-region stride times the cumulative downsampling), with exact ragged
    edge tiles.  Algorithm 4 as printed guarantees *per-level* coverage but not
    inter-level lockstep when inner layers are padded; the executor must be
    exact, so it uses this plan.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


# ---------------------------------------------------------------------------
# Layer / network description
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FusedLevel:
    """One level of the fusion pyramid: a conv or pooling stage.

    Attributes mirror the paper's symbols: kernel ``K``, stride ``S``; ``pad``
    is symmetric spatial padding (the paper's examples are pad-0; AlexNet /
    VGG need it).  ``kind`` is ``"conv"`` or ``"pool"``.  ``n_in``/``n_out``
    are channel counts (N and M in the paper) used by cycle/intensity models.
    """

    kind: str
    K: int
    S: int
    pad: int = 0
    n_in: int = 1
    n_out: int = 1
    name: str = ""

    def out_size(self, in_size: int) -> int:
        """Spatial output size for a (padded) input of ``in_size``."""
        return (in_size + 2 * self.pad - self.K) // self.S + 1


@dataclass(frozen=True)
class FusionSpec:
    """A chain of levels to fuse plus the network input size.

    Construction validates the channel chain (level *l+1* must consume what
    level *l* produces; pools preserve channels) so that a malformed chain
    fails here with a named level instead of deep inside the kernel wrapper
    with a shape error.
    """

    levels: tuple[FusedLevel, ...]
    input_size: int  # unpadded spatial size of the first level's input

    def __post_init__(self) -> None:
        if not self.levels:
            raise ValueError("FusionSpec needs at least one level")
        carried: int | None = None
        for l, lvl in enumerate(self.levels):
            label = lvl.name or f"level {l} ({lvl.kind})"
            if lvl.kind not in ("conv", "pool"):
                raise ValueError(f"{label}: unknown level kind {lvl.kind!r}")
            if lvl.kind == "pool" and lvl.n_in != lvl.n_out:
                raise ValueError(
                    f"{label}: pools preserve channels, got "
                    f"n_in={lvl.n_in} != n_out={lvl.n_out}"
                )
            if carried is not None and lvl.n_in != carried:
                raise ValueError(
                    f"{label}: n_in={lvl.n_in} does not chain with the "
                    f"{carried} channels produced by the previous level"
                )
            carried = lvl.n_out

    @property
    def q_convs(self) -> int:
        return sum(1 for l in self.levels if l.kind == "conv")

    def feature_sizes(self) -> list[int]:
        """Unpadded input spatial size of every level, plus the final output.

        ``sizes[l]`` is the *unpadded* input to level ``l``;  ``sizes[-1]`` is
        the final output size of the fused chain.
        """
        sizes = [self.input_size]
        cur = self.input_size
        for lvl in self.levels:
            cur = lvl.out_size(cur)
            sizes.append(cur)
        return sizes


# ---------------------------------------------------------------------------
# Algorithm 3 — tile sizes from Eq. (1)
# ---------------------------------------------------------------------------


def tile_sizes(spec: FusionSpec, out_region: int) -> list[int]:
    """Eq. (1) chained from the last level to the first (Algorithm 3).

    Returns ``T`` with ``T[l]`` = tile size in level ``l``'s input coordinates
    (``T[-1] == out_region``, the selected square region of the final output
    feature map).  ``len(T) == len(levels) + 1``.
    """
    T = [out_region]
    cur = out_region
    for lvl in reversed(spec.levels):
        cur = (cur - 1) * lvl.S + lvl.K  # Eq. (1)
        T.append(cur)
    T.reverse()
    return T


def all_tile_configs(spec: FusionSpec) -> dict[int, list[int]]:
    """Algorithm 3's full H matrix: tile sizes for every feasible out_region.

    Bounded by ``H <= IFM`` (padded input size) per the paper's Ensure clause.
    """
    sizes = spec.feature_sizes()
    configs: dict[int, list[int]] = {}
    for r in range(1, sizes[-1] + 1):
        T = tile_sizes(spec, r)
        ok = all(
            T[l] <= sizes[l] + 2 * spec.levels[l].pad for l in range(len(spec.levels))
        )
        if ok:
            configs[r] = T
    return configs


# ---------------------------------------------------------------------------
# Algorithm 4 — uniform tile stride
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LevelStride:
    """Chosen tile stride for one level (paper's S^T) and its movement count."""

    tile: int  # H_l, tile size in this level's (padded) input coords
    stride: int  # S^T_l
    alpha: int  # movements per spatial dim; uniform across levels
    ifm: int  # padded input size this level tiles over


@dataclass(frozen=True)
class FusionPlan:
    """Result of the paper's planning pipeline (Fig. 2)."""

    spec: FusionSpec
    out_region: int
    alpha: int
    levels: tuple[LevelStride, ...]

    @property
    def movements(self) -> int:
        """Total tile executions: alpha^2 (square maps, square tiles)."""
        return self.alpha * self.alpha


def _level_candidates(
    ifm: int, tile: int, K: int, S: int, *, require_alignment: bool
) -> dict[int, int]:
    """Feasible {alpha: max stride} for one level (inner loop of Algorithm 4).

    A stride ``p`` is feasible when:
      * ``alpha = (ifm - tile)/p + 1`` is a positive integer (exact coverage,
        the paper's ``alpha in Z`` test);
      * ``p <= tile - K + S`` so consecutive tiles leave no uncomputed output
        between them (the paper's "do not skip computation of some regions");
      * optionally ``p % S == 0`` so every tile start lands on the conv/pool
        grid.  The paper does not state this check (its examples are stride-1
        convs where it is vacuous); default off for fidelity.
    """
    span = ifm - tile
    out: dict[int, int] = {}
    if span == 0:
        return {1: 0}  # single tile covers the level
    noskip = tile - K + S
    for p in range(1, tile + 1):
        if span % p != 0:
            continue
        if p > noskip:
            continue
        if require_alignment and p % S != 0:
            continue
        alpha = span // p + 1
        # max stride per alpha (larger stride == less overlap, paper's pick)
        if alpha not in out or p > out[alpha]:
            out[alpha] = p
    return out


def uniform_tile_stride(
    spec: FusionSpec,
    out_region: int,
    *,
    require_alignment: bool = False,
) -> FusionPlan | None:
    """Algorithm 4 + the paper's selection rule.

    Intersects each *conv* level's feasible alpha set and picks the minimum
    uniform alpha (fewest movements -> largest strides -> least overlap
    growth), then the maximum stride per level for that alpha.

    Pooling levels contribute to the Eq.(1) tile-size chain but are excluded
    from the stride constraints: in the paper's architecture (Fig. 4) pooling
    is an epilogue block applied to each conv tile's output region, so its
    traversal is slaved to the conv tile rather than independently strided.
    (This is the only reading under which the paper's own alpha values —
    LeNet-5: 5, AlexNet: 9, VGG blocks 1-2: 3 — are reproducible; validated
    in tests/test_fusion.py.)

    Returns ``None`` when no uniform integer alpha exists for this region.
    """
    T = tile_sizes(spec, out_region)
    sizes = spec.feature_sizes()
    per_level: list[dict[int, int] | None] = []
    for l, lvl in enumerate(spec.levels):
        ifm = sizes[l] + 2 * lvl.pad
        if T[l] > ifm:
            return None
        if lvl.kind != "conv":
            per_level.append(None)  # slaved to the preceding conv level
            continue
        per_level.append(
            _level_candidates(
                ifm, T[l], lvl.K, lvl.S, require_alignment=require_alignment
            )
        )
    conv_cands = [c for c in per_level if c is not None]
    if not conv_cands:
        # degenerate chain with no conv levels: constrain on every level
        per_level = [
            _level_candidates(
                sizes[l] + 2 * lvl.pad, T[l], lvl.K, lvl.S,
                require_alignment=require_alignment,
            )
            for l, lvl in enumerate(spec.levels)
        ]
        conv_cands = per_level
    common = set(conv_cands[0])
    for cand in conv_cands[1:]:
        common &= set(cand)
    if not common:
        return None
    alpha = min(common)
    chosen = []
    for l, lvl in enumerate(spec.levels):
        ifm = sizes[l] + 2 * lvl.pad
        if per_level[l] is not None:
            stride = per_level[l][alpha]
        else:
            # slaved pool level: exact movement if the span divides, else the
            # executor handles it with ragged/clamped windows (stride 0 flag).
            span = ifm - T[l]
            stride = span // (alpha - 1) if alpha > 1 and span % (alpha - 1) == 0 else 0
        chosen.append(LevelStride(tile=T[l], stride=stride, alpha=alpha, ifm=ifm))
    return FusionPlan(
        spec=spec, out_region=out_region, alpha=alpha, levels=tuple(chosen)
    )


def plan_fusion(
    spec: FusionSpec,
    *,
    out_region: int | None = None,
    require_alignment: bool = False,
) -> FusionPlan:
    """The paper's design pipeline (Fig. 2): pick the smallest output region
    admitting a uniform integer alpha, then the minimum such alpha.

    ``out_region`` pins the region explicitly (used when matching a paper
    configuration); otherwise regions are scanned smallest-first, per the
    paper's goal of "the smallest possible tile sizes ... maintaining a
    uniform tile movement".
    """
    if out_region is not None:
        plan = uniform_tile_stride(
            spec, out_region, require_alignment=require_alignment
        )
        if plan is None:
            raise ValueError(
                f"no uniform tile stride exists for out_region={out_region}"
            )
        return plan
    last = spec.feature_sizes()[-1]
    for r in range(1, last + 1):
        plan = uniform_tile_stride(spec, r, require_alignment=require_alignment)
        if plan is not None:
            return plan
    raise ValueError("no uniform tile stride exists for any output region")


# ---------------------------------------------------------------------------
# Lockstep (executor-exact) plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LockstepPlan:
    """Exact tile schedule: all levels move together.

    ``starts`` are the final-output region start indices (1-D; the 2-D grid is
    the cross product).  The executor derives every level's window from these
    via the receptive-field chain, clamping at the edges (ragged tiles), so
    composition is exact regardless of inner padding.
    """

    spec: FusionSpec
    out_region: int
    out_stride: int
    starts: tuple[int, ...]

    @property
    def alpha(self) -> int:
        return len(self.starts)


def lockstep_plan(
    spec: FusionSpec, out_region: int, out_stride: int | None = None
) -> LockstepPlan:
    """Build the exact schedule for a chosen output region and stride.

    Defaults to ``out_stride = out_region`` (non-overlapping output tiles —
    every output pixel computed exactly once, overlap exists only in inputs).
    The last start is clamped so the union of regions covers the output.
    """
    out_size = spec.feature_sizes()[-1]
    s = out_region if out_stride is None else out_stride
    if out_region >= out_size:
        return LockstepPlan(spec, out_size, s, (0,))
    starts = list(range(0, out_size - out_region, s))
    starts.append(out_size - out_region)  # clamp final tile
    return LockstepPlan(spec, out_region, s, tuple(starts))


def receptive_window(
    spec: FusionSpec, start: int, size: int
) -> list[tuple[int, int]]:
    """Map a final-output interval [start, start+size) back through the chain.

    Returns per-level ``(start, size)`` in each level's *padded* input
    coordinates, first level first; the paper's Fig. 2 "start and end indices
    of the feature maps intended for each layer".
    """
    windows: list[tuple[int, int]] = []
    lo, hi = start, start + size - 1  # inclusive range, this level's OUTPUT coords
    for lvl in reversed(spec.levels):
        lo_in = lo * lvl.S  # this level's PADDED input coords
        hi_in = hi * lvl.S + lvl.K - 1
        windows.append((lo_in, hi_in - lo_in + 1))
        # previous level's output coords = this level's unpadded input coords
        lo = lo_in - lvl.pad
        hi = hi_in - lvl.pad
    windows.reverse()
    return windows
