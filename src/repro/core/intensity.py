"""Operational-intensity model (paper Figs. 10-11, roofline x-axis).

Off-chip traffic accounting (the default ``bytes_per_val`` flows from the
:data:`~repro.core.dtypes.DTYPE_BYTES` table at the paper's n=8-bit SOP
precision, i.e. int8's 1 byte/value — the kernel-level byte models in
:mod:`repro.core.program` use the same table at their program's
``compute_dtype``, so paper-level and launch-level accounting can no longer
silently disagree about value width):

* ``unfused``  — layer-by-layer dataflow: every level reads its input map
  from off-chip and writes its output map back, plus weights once.
* ``fused_naive`` — fusion pyramid whose tile stride equals the convolution
  stride (Baselines 1-2): the first-level tile is re-read per movement with
  massive overlap: ``alpha_naive^2 * H1^2 * C_in`` input bytes.
* ``fused_uniform`` — the proposed uniform tile stride (and Baseline-3):
  ``alpha^2 * H1^2 * C_in`` input bytes — overlap bounded by the planner's
  maximal-stride selection.

Both fused variants write only the final output map off-chip and load weights
once (input/output channel tiling, §3.3.1).  Validated against the paper:
LeNet-5 OI improvement 8.2x reproduces exactly; AlexNet / VGG land at the
same order (paper's per-network byte accounting is not fully specified; see
EXPERIMENTS.md).
"""

from __future__ import annotations

from dataclasses import dataclass

from .cycle_model import naive_alpha
from .dtypes import DTYPE_BYTES
from .fusion import FusionPlan, FusionSpec

# the paper's figures account one byte per value (n=8-bit SOP precision);
# pass bytes_per_val=DTYPE_BYTES[...] explicitly to account other dtypes
PAPER_BYTES_PER_VAL = DTYPE_BYTES["int8"]


def weight_bytes(
    spec: FusionSpec, bytes_per_val: int = PAPER_BYTES_PER_VAL
) -> int:
    return sum(
        lvl.K * lvl.K * lvl.n_in * lvl.n_out * bytes_per_val
        for lvl in spec.levels
        if lvl.kind == "conv"
    )


def unfused_bytes(
    spec: FusionSpec, bytes_per_val: int = PAPER_BYTES_PER_VAL
) -> int:
    sizes = spec.feature_sizes()
    total = 0
    for l, lvl in enumerate(spec.levels):
        total += sizes[l] ** 2 * lvl.n_in * bytes_per_val  # read input map
        total += sizes[l + 1] ** 2 * lvl.n_out * bytes_per_val  # write output
    return total + weight_bytes(spec, bytes_per_val)


def fused_bytes(
    spec: FusionSpec,
    plan: FusionPlan,
    *,
    uniform: bool = True,
    bytes_per_val: int = PAPER_BYTES_PER_VAL,
) -> int:
    sizes = spec.feature_sizes()
    h1 = plan.levels[0].tile
    alpha = plan.alpha if uniform else naive_alpha(plan)
    in_bytes = alpha * alpha * h1 * h1 * spec.levels[0].n_in * bytes_per_val
    out_bytes = sizes[-1] ** 2 * spec.levels[-1].n_out * bytes_per_val
    return in_bytes + out_bytes + weight_bytes(spec, bytes_per_val)


@dataclass(frozen=True)
class IntensityPoint:
    """One point of the performance-vs-OI plots (Figs. 10-11)."""

    design: str
    ops: int
    bytes_offchip: int
    duration_us: float

    @property
    def intensity(self) -> float:  # ops / byte
        return self.ops / self.bytes_offchip

    @property
    def gops(self) -> float:
        return self.ops / (self.duration_us * 1e3)


def intensity_improvement(spec: FusionSpec, plan: FusionPlan) -> float:
    """OI(proposed uniform-stride fusion) / OI(naive-stride fusion)."""
    return fused_bytes(spec, plan, uniform=False) / fused_bytes(spec, plan)


def launch_dataflow(program, batch: int = 1, *, streamed: bool = False) -> dict:
    """Per-launch HBM byte breakdown of one kernel launch.

    The bridge between the paper-level OI accounting above and the kernel's
    :class:`~repro.core.program.TileProgram` model: the same halo-tile input
    term (``alpha^2 * tile0^2 * C``, Algorithm 4's uniform minimal movement)
    that :meth:`TileProgram.hbm_bytes` charges and the partitioner DP
    minimizes.  ``input_bytes_whole_image`` is the retired
    whole-image-resident dataflow (every grid cell re-read the padded image),
    reported so the benchmark trajectory has a before/after column.  Input,
    weight, and output bytes are charged at the program's ``compute_dtype``
    width; skip flags stay int32 regardless.  The components sum to
    ``program.hbm_bytes(batch, streamed=streamed)`` (asserted in
    ``tests/test_dataflow.py``).
    """
    a2 = batch * program.alpha ** 2
    bpv = program.bytes_per_val
    return {
        "input_bytes_whole_image": program.input_hbm_bytes(
            batch, whole_image=True
        ),
        "input_bytes_halo": program.input_hbm_bytes(batch),
        "weight_bytes": bpv * (a2 if streamed else 1) * program.weight_floats(),
        "output_bytes": bpv * batch * program.out_size ** 2 * program.n_out,
        "skip_bytes": DTYPE_BYTES["int32"] * a2 * program.q_convs,
    }
