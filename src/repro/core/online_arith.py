"""Online (MSDF) arithmetic over the signed-digit radix-2 set {-1, 0, 1}.

Faithful, vectorized JAX simulation of the paper's compute substrate (§3.1):

* :func:`to_digits` / :func:`from_digits` — SD radix-2 encode/decode.  Values
  are normalized fractions in (-1, 1); digit ``j`` (0-based) has weight
  ``2**-(j+1)``, most significant digit first.
* :func:`online_mul_sp` — Algorithm 1, the serial-parallel online multiplier
  (serial MSDF input ``x``, parallel constant ``Y``, online delay delta=2).
* :func:`online_add` — online adder on two digit streams (delta=2).
* :func:`online_sop` — the WPU: per-window products reduced through a binary
  tree of online adders, producing the sum-of-products digit stream that the
  END unit observes (§3.2).

Scaling convention: hardware online adders absorb precision growth by
emitting extra leading digits (the ``ceil(log2 .)`` growth-cycle terms in
Eqs. (3)-(4)).  In simulation each adder computes ``(a+b)/2`` so every stream
stays in (-1, 1); a depth-``d`` tree therefore yields ``sop / 2**d``.  Signs
(hence END semantics) are unaffected, and the cycle model accounts for the
growth cycles explicitly.

All recurrences follow the single residual form (derivation in DESIGN.md):
``v_t = 2*w_{t-1} + (new digit contribution) * 2**-delta``;
``z_t = SEL(v_t)``; ``w_t = v_t - z_t``;
with SEL(v) = sign(v) when ``|v| >= 0.5`` else 0, keeping ``|w|`` bounded
(<= 0.75 for the multiplier, <= 0.5 for the adder) so every output digit is
in {-1, 0, 1}.  Selection uses the exact residual; hardware truncates to
t=2 fractional bits, which changes digit choices only within the redundancy
of the SD representation (same represented value), not END decisions.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

DELTA_OLM = 2  # online delay of the serial-parallel multiplier (paper §3.1.1)
DELTA_OLA = 2  # online delay of the online adder


def _select(v: jnp.ndarray) -> jnp.ndarray:
    """SELM: output digit in {-1, 0, 1} from the (exact) residual estimate."""
    return jnp.where(v >= 0.5, 1.0, jnp.where(v <= -0.5, -1.0, 0.0))


# ---------------------------------------------------------------------------
# Encode / decode
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("n",))
def to_digits(x: jnp.ndarray, n: int) -> jnp.ndarray:
    """SD radix-2 encode: ``x`` in (-1, 1) -> digits ``(..., n)`` MSDF."""

    def step(w, _):
        v = 2.0 * w
        d = _select(v)
        return v - d, d

    _, digits = jax.lax.scan(step, jnp.asarray(x, jnp.float32), None, length=n)
    return jnp.moveaxis(digits, 0, -1)


def from_digits(d: jnp.ndarray) -> jnp.ndarray:
    """Decode digit streams ``(..., n)`` back to values."""
    n = d.shape[-1]
    weights = 2.0 ** -(jnp.arange(1, n + 1, dtype=jnp.float32))
    return jnp.sum(d * weights, axis=-1)


def prefix_values(d: jnp.ndarray) -> jnp.ndarray:
    """Running prefix value after each digit: ``(..., n)``."""
    n = d.shape[-1]
    weights = 2.0 ** -(jnp.arange(1, n + 1, dtype=jnp.float32))
    return jnp.cumsum(d * weights, axis=-1)


# ---------------------------------------------------------------------------
# Algorithm 1 — serial-parallel online multiplier
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("n_out",))
def online_mul_sp(x_digits: jnp.ndarray, y: jnp.ndarray, n_out: int) -> jnp.ndarray:
    """Serial-parallel online multiplication (Algorithm 1).

    ``x_digits``: (..., n) MSDF digit stream of the serial operand.
    ``y``: (...,) parallel operand, |y| < 1.
    Returns the product's digit stream ``(..., n_out)``; digit ``j`` of the
    output is produced at hardware cycle ``j + DELTA_OLM`` (cycle accounting
    lives in :mod:`repro.core.cycle_model`).
    """
    n_in = x_digits.shape[-1]
    total = n_out + DELTA_OLM
    xs = jnp.moveaxis(x_digits, -1, 0)  # (n, ...)
    pad = jnp.zeros((total - n_in,) + xs.shape[1:], xs.dtype)
    xs = jnp.concatenate([xs, pad], axis=0) if total > n_in else xs[:total]
    y = jnp.asarray(y, jnp.float32)
    scale = 2.0 ** -DELTA_OLM

    def step(carry, xt):
        w, t = carry
        v = 2.0 * w + xt * y * scale
        # initialization phase (Algorithm 1 lines 1-5): collect delta digits,
        # no output selection, w <- v.
        z = jnp.where(t >= DELTA_OLM, _select(v), 0.0)
        return (v - z, t + 1), z

    w0 = jnp.zeros(jnp.broadcast_shapes(xs.shape[1:], y.shape), jnp.float32)
    (_, _), zs = jax.lax.scan(step, (w0, jnp.int32(0)), xs)
    return jnp.moveaxis(zs[DELTA_OLM:], 0, -1)


# ---------------------------------------------------------------------------
# Online adder
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("scale_half",))
def online_add(
    a: jnp.ndarray, b: jnp.ndarray, *, scale_half: bool = True
) -> jnp.ndarray:
    """Online addition of two MSDF digit streams (delta = 2).

    With ``scale_half`` (default) computes ``(a + b) / 2`` so the output stays
    in (-1, 1) — the simulation's stand-in for the hardware's extra leading
    digit (see module docstring).
    """
    n = a.shape[-1]
    total = n + DELTA_OLA
    ax = jnp.moveaxis(a, -1, 0)
    bx = jnp.moveaxis(b, -1, 0)
    zpad = jnp.zeros((DELTA_OLA,) + ax.shape[1:], ax.dtype)
    ax = jnp.concatenate([ax, zpad], axis=0)
    bx = jnp.concatenate([bx, zpad], axis=0)
    scale = (0.5 if scale_half else 1.0) * 2.0 ** -DELTA_OLA

    def step(carry, ab):
        w, t = carry
        at, bt = ab
        v = 2.0 * w + (at + bt) * scale
        z = jnp.where(t >= DELTA_OLA, _select(v), 0.0)  # init: no selection
        return (v - z, t + 1), z

    w0 = jnp.zeros(jnp.broadcast_shapes(ax.shape[1:], bx.shape[1:]), jnp.float32)
    (_, _), zs = jax.lax.scan(step, (w0, jnp.int32(0)), (ax, bx))
    return jnp.moveaxis(zs[DELTA_OLA:], 0, -1)


# ---------------------------------------------------------------------------
# WPU: sum-of-products via multiplier bank + online adder tree
# ---------------------------------------------------------------------------


def online_sop(
    x_digits: jnp.ndarray, y: jnp.ndarray, n_out: int
) -> tuple[jnp.ndarray, int]:
    """Window processing unit: SOP of ``m`` serialxparallel products.

    ``x_digits``: (..., m, n) digit streams; ``y``: (..., m) parallel weights.
    Returns ``(digits, depth)`` where ``digits`` is the (..., n_out) MSDF
    stream of ``sop / 2**depth`` and ``depth = ceil(log2 m)`` (the adder-tree
    depth, whose growth cycles Eq. (3) charges explicitly).
    """
    prods = online_mul_sp(x_digits, y, n_out)  # (..., m, n_out)
    streams = [prods[..., i, :] for i in range(prods.shape[-2])]
    depth = 0
    while len(streams) > 1:
        nxt = []
        for i in range(0, len(streams) - 1, 2):
            nxt.append(online_add(streams[i], streams[i + 1]))
        if len(streams) % 2:
            # odd element passes through scaled by 1/2 to stay aligned
            nxt.append(online_add(streams[-1], jnp.zeros_like(streams[-1])))
        streams = nxt
        depth += 1
    return streams[0], depth


def sop_digits_fast(x: jnp.ndarray, y: jnp.ndarray, n_out: int) -> tuple[jnp.ndarray, int]:
    """Fast path for large-scale END statistics: digit stream of the exact
    SOP value, scaled like :func:`online_sop`'s tree output.

    Any valid SD stream of the same value has prefix error <= 2**-j at digit
    j, so END decisions agree with the composed pipeline to within one digit
    cycle (asserted in tests/test_online_arith.py).
    """
    import math

    m = x.shape[-1]
    depth = max(1, math.ceil(math.log2(m))) if m > 1 else 0
    val = jnp.sum(x * y, axis=-1) / (2.0 ** depth)
    return to_digits(val, n_out), depth
