"""Tile-program compiler: one lowering pass shared by planner, executor, and
the variadic Pallas kernel.

``FusionSpec`` + a chosen output region lower to a static *tile program*:

* **Eq. (1) windows** — the per-level receptive windows of an output tile,
  expressed affinely in the tile's final-output start coordinate
  (:class:`LevelWindow`: ``lo(start) = base + step * start``, constant
  ``size``).  This is the only place window/offset math is derived; the
  executor (:mod:`repro.core.executor`) and the kernel wrapper
  (:mod:`repro.kernels.fused_conv.ops`) both consume it.
* **Uniform-stride grid** — Algorithm 4 realized as an ``alpha x alpha``
  movement grid: every level moves the same number of times, the level-0 tile
  stride is ``stride0`` (:class:`TileProgram`).
* **Validity-mask ranges** — per conv level, the affine global output
  coordinate (``o_base + i * o_step``) and the valid extent used to zero
  rows that fall in a level's padding; ditto for the pool epilogue
  (:class:`ConvLevelProg`).
* **Pool epilogues** — each pool level is folded into the preceding conv
  level's program (the paper's Fig. 4 pooling block is slaved to the conv
  tile; see DESIGN.md §3).
* **VMEM-budget accounting** — :meth:`TileProgram.vmem_bytes` models the
  kernel's resident working set; :func:`pick_out_region` scans output regions
  against the budget and :meth:`TileProgram.hbm_bytes` models the per-launch
  off-chip traffic (the quantity fusion minimizes).

The compiler is pure Python over static shapes: programs are frozen,
hashable dataclasses suitable as jit static arguments.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

from .fusion import FusionSpec, receptive_window

VMEM_BUDGET_BYTES = 16 * 1024 * 1024  # v5e per-core VMEM

# Modeled HBM service rate of the cycle model's 100 MHz accelerator, in bytes
# per cycle (6.4 GB/s).  Only ratios matter: the constant sets how expensive a
# streamed-weight DMA is relative to the DS-1 compute cycles it overlaps with.
HBM_BYTES_PER_CYCLE = 64


# ---------------------------------------------------------------------------
# Eq. (1) windows, affine in the output start coordinate
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LevelWindow:
    """Eq. (1) window of one spec level, affine in the final-output start.

    A final-output interval ``[s, s + out_region)`` needs this level's padded
    input rows ``[base + step * s, base + step * s + size)``; ``step`` is the
    cumulative stride of this level and everything below it.
    """

    base: int
    step: int
    size: int

    def at(self, start: int) -> tuple[int, int]:
        return (self.base + self.step * start, self.size)


@dataclass(frozen=True)
class WindowProgram:
    """Per-level Eq. (1) windows plus output geometry.

    The contract consumed by the value-level executor: it needs windows for
    *arbitrary* (possibly ragged/clamped) output starts, so offsets stay
    affine in the start coordinate rather than in a grid index.
    """

    spec: FusionSpec
    out_region: int
    windows: tuple[LevelWindow, ...]
    out_size: int
    n_out: int

    def level_windows(self, start: int) -> list[tuple[int, int]]:
        """Per-level ``(lo, size)`` in padded input coords for one start."""
        return [w.at(start) for w in self.windows]


def chain_channels(spec: FusionSpec) -> int:
    """Channel count leaving the chain (pools are channel-preserving)."""
    c = spec.levels[0].n_in
    for lvl in spec.levels:
        if lvl.kind == "conv":
            c = lvl.n_out
    return c


def compile_windows(spec: FusionSpec, out_region: int) -> WindowProgram:
    """Lower the Eq. (1) receptive-window chain to affine per-level windows.

    ``receptive_window`` is exact but pointwise; every level's window start is
    affine in the output start (each level applies ``lo -> lo * S`` and a
    constant pad shift), so two evaluations recover ``(base, step)``.
    """
    wins0 = receptive_window(spec, 0, out_region)
    wins1 = receptive_window(spec, 1, out_region)
    windows = tuple(
        LevelWindow(base=w0[0], step=w1[0] - w0[0], size=w0[1])
        for w0, w1 in zip(wins0, wins1)
    )
    return WindowProgram(
        spec=spec,
        out_region=out_region,
        windows=windows,
        out_size=spec.feature_sizes()[-1],
        n_out=chain_channels(spec),
    )


# ---------------------------------------------------------------------------
# Kernel-level program: per-conv-level static offsets + the uniform grid
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ConvLevelProg:
    """Static per-conv-level kernel program (offsets affine in tile index).

    ``o_base + i * o_step`` is the global output coordinate of tile row 0 at
    grid index ``i``; rows outside ``[0, valid)`` are this level's padding and
    get masked to zero.  A trailing pool level is folded in as an epilogue
    with its own offset/valid triple.
    """

    K: int
    S: int
    n_in: int
    n_out: int
    in_size: int  # tile spatial size entering this level
    out_size: int  # tile spatial size leaving the conv
    o_base: int  # global output coord of tile row 0 at tile index 0
    o_step: int  # global output coord step per tile index
    valid: int  # level's valid output extent (mask range)
    pool: tuple[int, int] | None  # (K, S) of trailing pool, if any
    pool_out: int  # tile spatial size after pool (== out_size if no pool)
    pool_o_base: int = 0
    pool_o_step: int = 0
    pool_valid: int = 0


@dataclass(frozen=True)
class TileProgram:
    """Complete static program for one variadic fusion-pyramid launch.

    ``levels`` holds one :class:`ConvLevelProg` per conv level (any Q >= 1),
    pools folded in.  ``tile0``/``stride0`` cut level-0 tiles out of the
    pre-padded input; the grid is ``(batch, alpha, alpha)``.
    """

    spec: FusionSpec
    out_region: int
    alpha: int
    levels: tuple[ConvLevelProg, ...]
    tile0: int
    stride0: int
    pad_lo: int
    pad_hi: int
    out_size: int
    n_out: int

    @property
    def q_convs(self) -> int:
        return len(self.levels)

    @property
    def padded_input(self) -> int:
        return self.pad_lo + self.spec.input_size + self.pad_hi

    def weight_floats(self) -> int:
        return sum(p.K * p.K * p.n_in * p.n_out + p.n_out for p in self.levels)

    def level_weight_counts(self) -> tuple[int, ...]:
        """Flattened float count of each level's weight tensor (bias excluded)
        — the slice table for streamed-weight launches."""
        return tuple(p.K * p.K * p.n_in * p.n_out for p in self.levels)

    def _tile_floats(self, x_slots: int = 1) -> int:
        """Per-grid-cell pyramid tile buffers: ``x_slots`` level-0 halo-tile
        landing buffers (DMA destinations; 2 = the revolving cross-cell
        prefetch pipeline), the live level-0 tile value, and every level's
        conv/pool output tile."""
        c0 = self.levels[0].n_in
        floats = (1 + x_slots) * self.tile0 ** 2 * c0
        for p in self.levels:
            floats += p.out_size ** 2 * p.n_out
            if p.pool is not None:
                floats += p.pool_out ** 2 * p.n_out
        return floats

    def vmem_bytes(self, x_slots: int = 1) -> int:
        """Resident working set of one kernel instance, in bytes.

        The input stays in HBM; only the level-0 halo tile (``tile0 x tile0``,
        DMA'd per grid cell into one of ``x_slots`` landing slots) is
        VMEM-resident, plus all weights ("filters are loaded into the kernel
        buffers only once", §3.3.1) and the per-level tile buffers of the
        pyramid.
        """
        return 4 * (self._tile_floats(x_slots) + self.weight_floats())

    def vmem_stream_bytes(self, slots: int = 1, x_slots: int = 1) -> int:
        """Working set with per-level weight streaming: only ``slots`` copies
        of the largest single level's weights are VMEM-resident at once
        (DMA'd from HBM level by level; ``slots=2`` is the double-buffered
        pipeline that overlaps level ``l+1``'s fetch with level ``l``'s
        compute); biases stay resident.  The fallback when
        :meth:`vmem_bytes` busts the budget — e.g. ResNet-18's last block,
        whose two 512x512 3x3 weight tensors alone exceed 16 MiB.
        ``x_slots`` counts input landing buffers as in :meth:`vmem_bytes`."""
        floats = self._tile_floats(x_slots)
        floats += slots * max(self.level_weight_counts())
        floats += sum(p.n_out for p in self.levels)  # biases
        return 4 * floats

    def input_dma_cycles(self) -> int:
        """Cycles one grid cell's halo-tile DMA occupies the HBM interface
        (``tile0^2 * C`` floats at :data:`HBM_BYTES_PER_CYCLE`) — the
        quantity the cross-cell prefetch pipeline hides behind compute."""
        c0 = self.levels[0].n_in
        return -(-4 * self.tile0 ** 2 * c0 // HBM_BYTES_PER_CYCLE)

    def input_hbm_bytes(self, batch: int = 1, *, whole_image: bool = False) -> int:
        """Per-launch input read traffic.  The halo-tile dataflow fetches one
        ``tile0 x tile0`` tile per grid cell — ``alpha^2 * tile0^2 * C`` total,
        overlap bounded by the pyramid halo (the uniform-stride minimum of
        Algorithm 4).  ``whole_image=True`` is the retired whole-image-resident
        model (every grid cell re-reads the padded image: ``alpha^2 * Hp * Wp *
        C``), kept for before/after benchmark comparisons."""
        c0 = self.levels[0].n_in
        tile = self.padded_input ** 2 if whole_image else self.tile0 ** 2
        return 4 * batch * self.alpha ** 2 * tile * c0

    def hbm_bytes(self, batch: int = 1, *, streamed: bool = False) -> int:
        """Off-chip traffic of one launch: read halo tiles + weights, write
        output map + skip flags.  Chained launches pay this per chunk — the
        intermediate maps crossing HBM are exactly what fusion removes.
        Streamed-weight launches re-read the weights once per grid cell."""
        w_reads = batch * self.alpha ** 2 if streamed else 1
        write = (
            batch * self.out_size ** 2 * self.n_out
            + batch * self.alpha ** 2 * self.q_convs  # int32 skip flags
        )
        return (
            self.input_hbm_bytes(batch)
            + 4 * (w_reads * self.weight_floats() + write)
        )


def compile_program(spec: FusionSpec, out_region: int) -> TileProgram:
    """Lower a fusion spec + output region to the kernel's static program.

    Requires the final output to be exactly tiled by ``out_region`` (the
    uniform-stride grid — every level moves ``alpha`` times per dim).  Every
    pool level must directly follow a conv level: pools execute as epilogues
    of the preceding conv tile (Fig. 4), so a leading or doubled pool has no
    conv program to fold into.
    """
    levels = spec.levels
    assert levels and levels[0].kind == "conv", (
        "chain must start with a conv level"
    )
    for l, lvl in enumerate(levels):
        if lvl.kind == "pool":
            assert levels[l - 1].kind == "conv", (
                "each pool level must directly follow a conv level"
            )
    sizes = spec.feature_sizes()
    out_size = sizes[-1]
    assert out_size % out_region == 0, (
        f"out_region {out_region} must tile the {out_size} output exactly"
    )
    alpha = out_size // out_region

    win = compile_windows(spec, out_region).windows
    progs = []
    for l, lvl in enumerate(levels):
        if lvl.kind != "conv":
            continue
        in_size = win[l].size
        out_sz = (in_size - lvl.K) // lvl.S + 1
        pool = None
        pool_out = out_sz
        pool_ob = pool_os = pool_valid = 0
        if l + 1 < len(levels) and levels[l + 1].kind == "pool":
            pk, ps = levels[l + 1].K, levels[l + 1].S
            pool = (pk, ps)
            pool_out = (out_sz - pk) // ps + 1
            pool_ob = win[l + 1].base // ps
            pool_os = (win[l + 1].step * out_region) // ps
            pool_valid = sizes[l + 2]
        progs.append(
            ConvLevelProg(
                K=lvl.K,
                S=lvl.S,
                n_in=lvl.n_in,
                n_out=lvl.n_out,
                in_size=in_size,
                out_size=out_sz,
                o_base=win[l].base // lvl.S,
                o_step=(win[l].step * out_region) // lvl.S,
                valid=sizes[l + 1],
                pool=pool,
                pool_out=pool_out,
                pool_o_base=pool_ob,
                pool_o_step=pool_os,
                pool_valid=pool_valid,
            )
        )
    for prev, cur in zip(progs, progs[1:]):
        assert prev.pool_out == cur.in_size, "window chain is inconsistent"

    tile0 = win[0].size
    lo0 = win[0].base - levels[0].pad  # unpadded coords; <= 0 by construction
    assert lo0 <= 0, "level-0 window cannot start inside the image"
    stride0 = win[0].step * out_region
    pad_lo = -lo0
    last_end = lo0 + (alpha - 1) * stride0 + tile0
    pad_hi = max(0, last_end - spec.input_size)
    return TileProgram(
        spec=spec,
        out_region=out_region,
        alpha=alpha,
        levels=tuple(progs),
        tile0=tile0,
        stride0=stride0,
        pad_lo=pad_lo,
        pad_hi=pad_hi,
        out_size=out_size,
        n_out=chain_channels(spec),
    )


@dataclass(frozen=True)
class LaunchPlan:
    """A costed, VMEM-feasible single-launch configuration of one pyramid.

    The plan-costing hook consumed by the auto-partitioner
    (:mod:`repro.net.partition`) and the kernel wrapper
    (:mod:`repro.kernels.fused_conv.ops`): region choice *and* weight regime
    (resident vs streamed, and with how many stream slots) are decided here,
    once, so planner cost and launched kernel can never disagree.

    ``w_slots`` only matters when ``streamed``: 2 is the double-buffered
    weight pipeline (level ``l+1``'s DMA overlaps level ``l``'s compute), 1
    the blocking start();wait() fallback when two copies of the largest
    level's weights bust VMEM.

    ``x_slots`` is the input landing-buffer count: 2 is the revolving
    cross-cell prefetch pipeline (grid cell ``n`` starts cell ``n+1``'s
    halo-tile DMA before running its own pyramid, so after the per-image
    warm-up fill the input DMA hides behind the MXU cascade), 1 the serial
    start();wait() path.  The chain is confined to one batch element — the
    batch grid axis is declared ``parallel`` and may be partitioned across
    TensorCores, so a prefetch must never cross a batch boundary.
    """

    program: TileProgram
    streamed: bool
    w_slots: int = 1
    x_slots: int = 2

    @property
    def spec(self) -> FusionSpec:
        return self.program.spec

    @property
    def out_region(self) -> int:
        return self.program.out_region

    def vmem_bytes(self) -> int:
        if self.streamed:
            return self.program.vmem_stream_bytes(self.w_slots, self.x_slots)
        return self.program.vmem_bytes(self.x_slots)

    def hbm_bytes(self, batch: int = 1) -> int:
        return self.program.hbm_bytes(batch, streamed=self.streamed)

    def with_input_pipeline(
        self, vmem_budget: int = VMEM_BUDGET_BYTES
    ) -> LaunchPlan:
        """The ``x_slots=2`` variant of this plan when buildable — the
        planner's ladder rule: the grid has a successor cell (``alpha > 1``)
        and the extra landing slot fits the budget — else this plan
        unchanged.  The single source of the buildability predicate for
        consumers (benchmarks) comparing serial vs pipelined latency."""
        cand = replace(self, x_slots=2)
        if self.program.alpha > 1 and cand.vmem_bytes() <= vmem_budget:
            return cand
        return self

    def modeled_cycles(self, batch: int = 1) -> int:
        """Overlap-aware cycle cost over the launch's uniform-stride grid —
        the latency tiebreaker of the partitioner's dynamic program.

        Per movement: DS-1 compute cycles (Eq. 3), plus the streamed-weight
        DMA cost at :data:`HBM_BYTES_PER_CYCLE`.  With a double-buffered
        weight pipeline (``w_slots=2``) only level 0's DMA (the pipeline
        ``fill``) is exposed and the rest hides behind compute —
        ``fill + max(compute, dma - fill)``, never worse than the
        single-slot fallback's serialized ``compute + dma``.  Resident
        weights pay no per-movement DMA.

        The input halo-tile DMA is then composed per batch element by
        :func:`~repro.core.cycle_model.grid_pipeline_cycles`: serial
        (``x_slots=1``) pays ``(input_dma + body) * cells``; the revolving
        cross-cell prefetch (``x_slots=2``) pays
        ``warmup_fill + body + (cells - 1) * max(body, input_dma)`` — never
        worse than serial, equal at ``alpha == 1`` (no successor cell)."""
        from .cycle_model import ds1_cycles_per_movement, grid_pipeline_cycles

        compute = ds1_cycles_per_movement(self.spec)
        body = compute
        if self.streamed:
            cnts = self.program.level_weight_counts()
            dma = -(-4 * sum(cnts) // HBM_BYTES_PER_CYCLE)
            if self.w_slots > 1:
                fill = -(-4 * cnts[0] // HBM_BYTES_PER_CYCLE)
                body = fill + max(compute, dma - fill)
            else:
                body = compute + dma
        per_image = grid_pipeline_cycles(
            self.program.alpha ** 2,
            body,
            self.program.input_dma_cycles(),
            pipelined=self.x_slots > 1,
        )
        return batch * per_image


def plan_launch(
    spec: FusionSpec,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    *,
    allow_stream: bool = True,
    prefer_region: str = "largest",
) -> LaunchPlan | None:
    """Pick the launch configuration for one pyramid: an exactly-tiling
    output region whose program fits the VMEM budget, preferring
    fully-resident weights over per-level streaming (which re-reads weights
    once per grid cell), and double-buffered streaming (DMA overlapped with
    compute) over the blocking single-slot fallback.  Within each weight
    regime the two-slot input landing buffer (cross-cell halo prefetch,
    ``x_slots=2``) is preferred over the serial single slot; a 1x1 grid has
    no successor cell to prefetch, so ``alpha == 1`` pins ``x_slots=1``.
    ``prefer_region="largest"`` (default) minimizes grid overhead;
    ``"smallest"`` is the paper's smallest-tile preference — maximal tile
    grids, i.e. END skipping at its finest granularity.
    Returns ``None`` when no single launch fits."""
    assert prefer_region in ("largest", "smallest")
    out_size = spec.feature_sizes()[-1]
    regions = [r for r in range(out_size, 0, -1) if out_size % r == 0]
    if prefer_region == "smallest":
        regions.reverse()

    def x_options(prog: TileProgram) -> tuple[int, ...]:
        return (1,) if prog.alpha == 1 else (2, 1)

    for r in regions:
        prog = compile_program(spec, r)
        for xs in x_options(prog):
            if prog.vmem_bytes(xs) <= vmem_budget:
                return LaunchPlan(program=prog, streamed=False, x_slots=xs)
    if allow_stream:
        # region preference stays primary (a smaller region multiplies the
        # alpha^2 streamed weight re-reads); within a region prefer the
        # double-buffered two-slot weight pipeline over the blocking single
        # slot, and within a weight regime the pipelined input buffer
        for r in regions:
            prog = compile_program(spec, r)
            for slots in (2, 1):
                for xs in x_options(prog):
                    if prog.vmem_stream_bytes(slots, xs) <= vmem_budget:
                        return LaunchPlan(
                            program=prog, streamed=True, w_slots=slots,
                            x_slots=xs,
                        )
    return None


def pick_out_region(
    spec: FusionSpec,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    *,
    allow_stream: bool = True,
) -> int | None:
    """Largest output region that tiles the output exactly and whose program
    fits the VMEM budget — the TPU analogue of the paper's ``H <= IFM``
    feasibility bound (DESIGN.md §2 assumption change #2).

    Fully-resident weights are preferred; when no region fits that way and
    ``allow_stream``, regions feasible under per-level weight streaming are
    considered.  Returns ``None`` when nothing fits (the chain must then be
    chunked).
    """
    plan = plan_launch(spec, vmem_budget, allow_stream=allow_stream)
    return None if plan is None else plan.out_region
