"""Tile-program compiler: one lowering pass shared by planner, executor, and
the variadic Pallas kernel.

``FusionSpec`` + a chosen output region lower to a static *tile program*:

* **Eq. (1) windows** — the per-level receptive windows of an output tile,
  expressed affinely in the tile's final-output start coordinate
  (:class:`LevelWindow`: ``lo(start) = base + step * start``, constant
  ``size``).  This is the only place window/offset math is derived; the
  executor (:mod:`repro.core.executor`) and the kernel wrapper
  (:mod:`repro.kernels.fused_conv.ops`) both consume it.
* **Uniform-stride grid** — Algorithm 4 realized as an ``alpha x alpha``
  movement grid: every level moves the same number of times, the level-0 tile
  stride is ``stride0`` (:class:`TileProgram`).
* **Validity-mask ranges** — per conv level, the affine global output
  coordinate (``o_base + i * o_step``) and the valid extent used to zero
  rows that fall in a level's padding; ditto for the pool epilogue
  (:class:`ConvLevelProg`).
* **Pool epilogues** — each pool level is folded into the preceding conv
  level's program (the paper's Fig. 4 pooling block is slaved to the conv
  tile; see DESIGN.md §3).
* **VMEM-budget accounting** — :meth:`TileProgram.vmem_bytes` models the
  kernel's resident working set; :func:`pick_out_region` scans output regions
  against the budget and :meth:`TileProgram.hbm_bytes` models the per-launch
  off-chip traffic (the quantity fusion minimizes).

The compiler is pure Python over static shapes: programs are frozen,
hashable dataclasses suitable as jit static arguments.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

from .dtypes import DTYPE_BYTES, canonical_dtype
from .fusion import FusionSpec, receptive_window

VMEM_BUDGET_BYTES = 16 * 1024 * 1024  # v5e per-core VMEM

# Modeled HBM service rate of the cycle model's 100 MHz accelerator, in bytes
# per cycle (6.4 GB/s).  Only ratios matter: the constant sets how expensive a
# streamed-weight DMA is relative to the DS-1 compute cycles it overlaps with.
HBM_BYTES_PER_CYCLE = 64


# ---------------------------------------------------------------------------
# Eq. (1) windows, affine in the output start coordinate
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LevelWindow:
    """Eq. (1) window of one spec level, affine in the final-output start.

    A final-output interval ``[s, s + out_region)`` needs this level's padded
    input rows ``[base + step * s, base + step * s + size)``; ``step`` is the
    cumulative stride of this level and everything below it.
    """

    base: int
    step: int
    size: int

    def at(self, start: int) -> tuple[int, int]:
        return (self.base + self.step * start, self.size)


@dataclass(frozen=True)
class WindowProgram:
    """Per-level Eq. (1) windows plus output geometry.

    The contract consumed by the value-level executor: it needs windows for
    *arbitrary* (possibly ragged/clamped) output starts, so offsets stay
    affine in the start coordinate rather than in a grid index.
    """

    spec: FusionSpec
    out_region: int
    windows: tuple[LevelWindow, ...]
    out_size: int
    n_out: int

    def level_windows(self, start: int) -> list[tuple[int, int]]:
        """Per-level ``(lo, size)`` in padded input coords for one start."""
        return [w.at(start) for w in self.windows]


def chain_channels(spec: FusionSpec) -> int:
    """Channel count leaving the chain (pools are channel-preserving)."""
    c = spec.levels[0].n_in
    for lvl in spec.levels:
        if lvl.kind == "conv":
            c = lvl.n_out
    return c


def compile_windows(spec: FusionSpec, out_region: int) -> WindowProgram:
    """Lower the Eq. (1) receptive-window chain to affine per-level windows.

    ``receptive_window`` is exact but pointwise; every level's window start is
    affine in the output start (each level applies ``lo -> lo * S`` and a
    constant pad shift), so two evaluations recover ``(base, step)``.
    """
    wins0 = receptive_window(spec, 0, out_region)
    wins1 = receptive_window(spec, 1, out_region)
    windows = tuple(
        LevelWindow(base=w0[0], step=w1[0] - w0[0], size=w0[1])
        for w0, w1 in zip(wins0, wins1)
    )
    return WindowProgram(
        spec=spec,
        out_region=out_region,
        windows=windows,
        out_size=spec.feature_sizes()[-1],
        n_out=chain_channels(spec),
    )


# ---------------------------------------------------------------------------
# Kernel-level program: per-conv-level static offsets + the uniform grid
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ConvLevelProg:
    """Static per-conv-level kernel program (offsets affine in tile index).

    ``o_base + i * o_step`` is the global output coordinate of tile row 0 at
    grid index ``i``; rows outside ``[0, valid)`` are this level's padding and
    get masked to zero.  A trailing pool level is folded in as an epilogue
    with its own offset/valid triple.
    """

    K: int
    S: int
    n_in: int
    n_out: int
    in_size: int  # tile spatial size entering this level
    out_size: int  # tile spatial size leaving the conv
    o_base: int  # global output coord of tile row 0 at tile index 0
    o_step: int  # global output coord step per tile index
    valid: int  # level's valid output extent (mask range)
    pool: tuple[int, int] | None  # (K, S) of trailing pool, if any
    pool_out: int  # tile spatial size after pool (== out_size if no pool)
    pool_o_base: int = 0
    pool_o_step: int = 0
    pool_valid: int = 0


@dataclass(frozen=True)
class TileProgram:
    """Complete static program for one variadic fusion-pyramid launch.

    ``levels`` holds one :class:`ConvLevelProg` per conv level (any Q >= 1),
    pools folded in.  ``tile0``/``stride0`` cut level-0 tiles out of the
    pre-padded input; the grid is ``(batch, alpha, alpha)``.
    """

    spec: FusionSpec
    out_region: int
    alpha: int
    levels: tuple[ConvLevelProg, ...]
    tile0: int
    stride0: int
    pad_lo: int
    pad_hi: int
    out_size: int
    n_out: int
    # canonical dtype name of activations/weights moving through the launch
    # (a string keeps the program hashable for jit); mid-level dot products
    # always accumulate float32 regardless — see DESIGN.md §11
    compute_dtype: str = "float32"

    @property
    def q_convs(self) -> int:
        return len(self.levels)

    @property
    def bytes_per_val(self) -> int:
        """Bytes per activation/weight value, from the one DTYPE_BYTES
        table — every byte quantity below scales with this."""
        return DTYPE_BYTES[self.compute_dtype]

    @property
    def padded_input(self) -> int:
        return self.pad_lo + self.spec.input_size + self.pad_hi

    def weight_floats(self) -> int:
        return sum(p.K * p.K * p.n_in * p.n_out + p.n_out for p in self.levels)

    def level_weight_counts(self) -> tuple[int, ...]:
        """Flattened float count of each level's weight tensor (bias excluded)
        — the slice table for streamed-weight launches."""
        return tuple(p.K * p.K * p.n_in * p.n_out for p in self.levels)

    def c_tile_options(self) -> tuple[int, ...]:
        """Legal output-channel tile counts of the last level, ascending and
        excluding the untiled 1: the divisors of the final conv's ``n_out``
        (a ``Cout`` block must tile the channel axis exactly so the per-``k``
        out BlockSpec stays uniform) that keep at least **two** channels per
        slice.  Single-channel slices are excluded on principle (they waste
        the 128-lane MXU) and on contract: XLA lowers the degenerate
        ``(P, Cin) @ (Cin, 1)`` dot through its matrix-vector special case,
        whose contraction order differs from the sliced-out column of the
        full dot — breaking the bitwise-parity guarantee every other slice
        width keeps."""
        m = self.levels[-1].n_out
        return tuple(c for c in range(2, m // 2 + 1) if m % c == 0)

    def _tile_floats(self, x_slots: int = 1, c_tiles: int = 1) -> int:
        """Per-grid-cell pyramid tile buffers: ``x_slots`` level-0 halo-tile
        landing buffers (DMA destinations; 2 = the revolving cross-cell
        prefetch pipeline), the live level-0 tile value, and every level's
        conv/pool output tile.  With ``c_tiles > 1`` the last level's
        conv/pool tiles hold one ``Cout / c_tiles`` channel block at a time
        (the per-``k`` working tile of the channel-tiled grid), and a Q > 1
        chain additionally carries the *persistent* mid-pyramid scratch the
        kernel re-reads at ``k > 0`` — live alongside the transient mid
        tiles at ``k == 0``, so it is counted on top of them."""
        c0 = self.levels[0].n_in
        floats = (1 + x_slots) * self.tile0 ** 2 * c0
        for li, p in enumerate(self.levels):
            n_out = p.n_out
            if li == len(self.levels) - 1:
                n_out = -(-n_out // c_tiles)
            floats += p.out_size ** 2 * n_out
            if p.pool is not None:
                floats += p.pool_out ** 2 * n_out
        if c_tiles > 1 and len(self.levels) > 1:
            last = self.levels[-1]
            floats += last.in_size ** 2 * last.n_in  # mid_scratch carry
        return floats

    def vmem_bytes(self, x_slots: int = 1, c_tiles: int = 1) -> int:
        """Resident working set of one kernel instance, in bytes.

        The input stays in HBM; only the level-0 halo tile (``tile0 x tile0``,
        DMA'd per grid cell into one of ``x_slots`` landing slots) is
        VMEM-resident, plus all weights ("filters are loaded into the kernel
        buffers only once", §3.3.1) and the per-level tile buffers of the
        pyramid.  ``c_tiles`` only shrinks the last level's working tile —
        resident weights stay whole, so channel tiling is a streamed-regime
        tool (the planner never picks it resident); the resident kernel still
        accepts it for parity testing.  Every buffer holds ``compute_dtype``
        values (the per-level f32 dot accumulator is compiler-managed vector
        state, not declared scratch), so the whole set scales with
        ``bytes_per_val`` — halving it is what flips streamed plans back to
        resident under bf16.
        """
        return self.bytes_per_val * (
            self._tile_floats(x_slots, c_tiles) + self.weight_floats()
        )

    def vmem_stream_bytes(
        self, slots: int = 1, x_slots: int = 1, c_tiles: int = 1
    ) -> int:
        """Working set with per-level weight streaming: only ``slots`` copies
        of the largest single level's weights are VMEM-resident at once
        (DMA'd from HBM level by level; ``slots=2`` is the double-buffered
        pipeline that overlaps level ``l+1``'s fetch with level ``l``'s
        compute); biases stay resident.  The fallback when
        :meth:`vmem_bytes` busts the budget — e.g. ResNet-18's last block,
        whose two 512x512 3x3 weight tensors alone exceed 16 MiB.
        ``x_slots`` counts input landing buffers as in :meth:`vmem_bytes`.

        With ``c_tiles > 1`` (the channel-tiled grid) the last level streams
        per-``k`` ``(Cin, Cout / c_tiles)`` slices through ``slots`` scratch
        slots while the mid levels fall back to one blocking slot sized for
        the largest mid level — streamed slices shrink by ``c_tiles``, which
        is what lets ResNet-18 b7 afford the double-buffered ``slots=2``
        regime its untiled weights bust."""
        cnts = self.level_weight_counts()
        floats = self._tile_floats(x_slots, c_tiles)
        if c_tiles > 1:
            if len(cnts) > 1:
                floats += max(cnts[:-1])  # one blocking mid-level slot
            floats += slots * -(-cnts[-1] // c_tiles)  # per-k slice slots
        else:
            floats += slots * max(cnts)
        floats += sum(p.n_out for p in self.levels)  # biases
        return self.bytes_per_val * floats

    def resolve_stream_regime(
        self,
        vmem_budget: int,
        x_slots: int = 1,
        w_slots: int | None = None,
        c_tiles: int | None = None,
    ) -> tuple[int, int]:
        """Resolve ``(w_slots, c_tiles)`` for a streamed launch along
        :func:`plan_launch`'s rung order — double-buffered untiled >
        channel-tiled double-buffered (smallest feasible ``c_tiles``) >
        blocking single slot — honouring whichever knobs the caller already
        pinned.  The kernel-entry fallback used by
        :func:`repro.kernels.fused_conv.ops.fused_pyramid`, so the single
        rung order lives here and in :func:`plan_launch` only.  Never
        raises: a jointly-infeasible pin surfaces at the caller's VMEM
        assert."""
        if w_slots is None and c_tiles is None:
            if self.vmem_stream_bytes(2, x_slots) <= vmem_budget:
                return 2, 1
            for ct in self.c_tile_options():
                if self.vmem_stream_bytes(2, x_slots, ct) <= vmem_budget:
                    return 2, ct
            return 1, 1
        if w_slots is None:
            fits2 = self.vmem_stream_bytes(2, x_slots, c_tiles) <= vmem_budget
            return (2 if fits2 else 1), c_tiles
        if c_tiles is None:
            if (
                w_slots > 1
                and self.vmem_stream_bytes(w_slots, x_slots) > vmem_budget
            ):
                for ct in self.c_tile_options():
                    if (
                        self.vmem_stream_bytes(w_slots, x_slots, ct)
                        <= vmem_budget
                    ):
                        return w_slots, ct
            return w_slots, 1
        return w_slots, c_tiles

    def input_dma_cycles(self) -> int:
        """Cycles one grid cell's halo-tile DMA occupies the HBM interface
        (``tile0^2 * C`` floats at :data:`HBM_BYTES_PER_CYCLE`) — the
        quantity the cross-cell prefetch pipeline hides behind compute."""
        c0 = self.levels[0].n_in
        return -(
            -self.bytes_per_val * self.tile0 ** 2 * c0 // HBM_BYTES_PER_CYCLE
        )

    def input_hbm_bytes(self, batch: int = 1, *, whole_image: bool = False) -> int:
        """Per-launch input read traffic.  The halo-tile dataflow fetches one
        ``tile0 x tile0`` tile per grid cell — ``alpha^2 * tile0^2 * C`` total,
        overlap bounded by the pyramid halo (the uniform-stride minimum of
        Algorithm 4).  ``whole_image=True`` is the retired whole-image-resident
        model (every grid cell re-reads the padded image: ``alpha^2 * Hp * Wp *
        C``), kept for before/after benchmark comparisons."""
        c0 = self.levels[0].n_in
        tile = self.padded_input ** 2 if whole_image else self.tile0 ** 2
        return self.bytes_per_val * batch * self.alpha ** 2 * tile * c0

    def hbm_bytes(
        self, batch: int = 1, *, streamed: bool = False, c_tiles: int = 1
    ) -> int:
        """Off-chip traffic of one launch: read halo tiles + weights, write
        output map + skip flags.  Chained launches pay this per chunk — the
        intermediate maps crossing HBM are exactly what fusion removes.
        Streamed-weight launches re-read the weights once per grid cell.

        ``c_tiles`` is accepted for symmetry with the VMEM models but leaves
        the total unchanged: the channel-tiled grid reads ``1 / c_tiles`` of
        the last level's weights per ``k`` step across ``c_tiles`` steps
        (same per-cell total), writes each output channel block exactly once,
        and emits one flag vector per cell — channel tiling re-schedules the
        movement, it does not add traffic."""
        del c_tiles  # traffic-invariant; see docstring
        w_reads = batch * self.alpha ** 2 if streamed else 1
        vals = w_reads * self.weight_floats() + batch * self.out_size ** 2 * self.n_out
        # skip flags stay int32 whatever the compute dtype
        flag_bytes = (
            DTYPE_BYTES["int32"] * batch * self.alpha ** 2 * self.q_convs
        )
        return (
            self.input_hbm_bytes(batch)
            + self.bytes_per_val * vals
            + flag_bytes
        )


def compile_program(
    spec: FusionSpec, out_region: int, *, compute_dtype="float32"
) -> TileProgram:
    """Lower a fusion spec + output region to the kernel's static program.

    Requires the final output to be exactly tiled by ``out_region`` (the
    uniform-stride grid — every level moves ``alpha`` times per dim).  Every
    pool level must directly follow a conv level: pools execute as epilogues
    of the preceding conv tile (Fig. 4), so a leading or doubled pool has no
    conv program to fold into.  ``compute_dtype`` (name string or jnp dtype)
    sets the byte width of every activation/weight the program accounts —
    window math is dtype-invariant, the byte and cycle models are not.
    """
    from repro.robust.errors import PlanError

    levels = spec.levels
    if not (levels and levels[0].kind == "conv"):
        raise PlanError(
            "chain must start with a conv level",
            levels=[lvl.kind for lvl in levels],
        )
    for l, lvl in enumerate(levels):
        if lvl.kind == "pool" and levels[l - 1].kind != "conv":
            raise PlanError(
                "each pool level must directly follow a conv level",
                level=l, node=lvl.name,
            )
    sizes = spec.feature_sizes()
    out_size = sizes[-1]
    if out_size % out_region != 0:
        raise PlanError(
            f"out_region {out_region} must tile the {out_size} output"
            " exactly",
            out_region=out_region, out_size=out_size,
        )
    alpha = out_size // out_region

    win = compile_windows(spec, out_region).windows
    progs = []
    for l, lvl in enumerate(levels):
        if lvl.kind != "conv":
            continue
        in_size = win[l].size
        out_sz = (in_size - lvl.K) // lvl.S + 1
        pool = None
        pool_out = out_sz
        pool_ob = pool_os = pool_valid = 0
        if l + 1 < len(levels) and levels[l + 1].kind == "pool":
            pk, ps = levels[l + 1].K, levels[l + 1].S
            pool = (pk, ps)
            pool_out = (out_sz - pk) // ps + 1
            pool_ob = win[l + 1].base // ps
            pool_os = (win[l + 1].step * out_region) // ps
            pool_valid = sizes[l + 2]
        progs.append(
            ConvLevelProg(
                K=lvl.K,
                S=lvl.S,
                n_in=lvl.n_in,
                n_out=lvl.n_out,
                in_size=in_size,
                out_size=out_sz,
                o_base=win[l].base // lvl.S,
                o_step=(win[l].step * out_region) // lvl.S,
                valid=sizes[l + 1],
                pool=pool,
                pool_out=pool_out,
                pool_o_base=pool_ob,
                pool_o_step=pool_os,
                pool_valid=pool_valid,
            )
        )
    for prev, cur in zip(progs, progs[1:]):
        assert prev.pool_out == cur.in_size, "window chain is inconsistent"

    tile0 = win[0].size
    lo0 = win[0].base - levels[0].pad  # unpadded coords; <= 0 by construction
    assert lo0 <= 0, "level-0 window cannot start inside the image"
    stride0 = win[0].step * out_region
    pad_lo = -lo0
    last_end = lo0 + (alpha - 1) * stride0 + tile0
    pad_hi = max(0, last_end - spec.input_size)
    return TileProgram(
        spec=spec,
        out_region=out_region,
        alpha=alpha,
        levels=tuple(progs),
        tile0=tile0,
        stride0=stride0,
        pad_lo=pad_lo,
        pad_hi=pad_hi,
        out_size=out_size,
        n_out=chain_channels(spec),
        compute_dtype=canonical_dtype(compute_dtype),
    )


@dataclass(frozen=True)
class LaunchPlan:
    """A costed, VMEM-feasible single-launch configuration of one pyramid.

    The plan-costing hook consumed by the auto-partitioner
    (:mod:`repro.net.partition`) and the kernel wrapper
    (:mod:`repro.kernels.fused_conv.ops`): region choice *and* weight regime
    (resident vs streamed, and with how many stream slots) are decided here,
    once, so planner cost and launched kernel can never disagree.

    ``w_slots`` only matters when ``streamed``: 2 is the double-buffered
    weight pipeline (level ``l+1``'s DMA overlaps level ``l``'s compute), 1
    the blocking start();wait() fallback when two copies of the largest
    level's weights bust VMEM.

    ``x_slots`` is the input landing-buffer count: 2 is the revolving
    cross-cell prefetch pipeline (grid cell ``n`` starts cell ``n+1``'s
    halo-tile DMA before running its own pyramid, so after the per-image
    warm-up fill the input DMA hides behind the MXU cascade), 1 the serial
    start();wait() path.  The chain is confined to one batch element — the
    batch grid axis is declared ``parallel`` and may be partitioned across
    TensorCores, so a prefetch must never cross a batch boundary.

    ``c_tiles > 1`` is the channel-tiled grid: a fourth sequential grid axis
    ``k`` over ``Cout / c_tiles`` output-channel tiles of the *last* level
    (the column-parallel axis of the paper's Fig. 5 WPU array).  Levels
    ``0..Q-2`` are computed once per cell at ``k == 0`` into a persistent
    VMEM scratch and reused for ``k > 0``; level ``Q-1`` runs per ``k`` on a
    ``(Cin, Cout / c_tiles)`` streamed weight slice, so with ``w_slots=2``
    the next slice's DMA overlaps the current slice's MXU pass — the regime
    that restores pipelining to ``alpha == 1`` launches the cross-cell input
    prefetch cannot touch (no successor cell).
    """

    program: TileProgram
    streamed: bool
    w_slots: int = 1
    x_slots: int = 2
    c_tiles: int = 1

    @property
    def spec(self) -> FusionSpec:
        return self.program.spec

    @property
    def out_region(self) -> int:
        return self.program.out_region

    @property
    def regime(self) -> str:
        """Display label: ``resident``, ``streamed_w<slots>``, with a
        ``_c<tiles>`` suffix on channel-tiled launches."""
        if not self.streamed:
            return "resident"
        label = f"streamed_w{self.w_slots}"
        if self.c_tiles > 1:
            label += f"_c{self.c_tiles}"
        return label

    def vmem_bytes(self) -> int:
        if self.streamed:
            return self.program.vmem_stream_bytes(
                self.w_slots, self.x_slots, self.c_tiles
            )
        return self.program.vmem_bytes(self.x_slots, self.c_tiles)

    def hbm_bytes(self, batch: int = 1) -> int:
        return self.program.hbm_bytes(
            batch, streamed=self.streamed, c_tiles=self.c_tiles
        )

    def slice_bytes(self) -> int:
        """Bytes of one per-``k`` streamed weight slice of the last level —
        the DMA granule the channel-tiled pipeline hides behind the MXU
        (0 for resident launches, the whole last level at ``c_tiles == 1``)."""
        if not self.streamed:
            return 0
        cnt = self.program.level_weight_counts()[-1]
        return self.program.bytes_per_val * -(-cnt // self.c_tiles)

    def with_input_pipeline(
        self, vmem_budget: int = VMEM_BUDGET_BYTES
    ) -> LaunchPlan:
        """The ``x_slots=2`` variant of this plan when buildable — the
        planner's ladder rule: the grid has a successor cell (``alpha > 1``)
        and the extra landing slot fits the budget — else this plan
        unchanged.  The single source of the buildability predicate for
        consumers (benchmarks) comparing serial vs pipelined latency."""
        cand = replace(self, x_slots=2)
        if self.program.alpha > 1 and cand.vmem_bytes() <= vmem_budget:
            return cand
        return self

    def body_cycles(self) -> int:
        """Per-grid-cell compute(+weight-DMA) cycles — the ``body`` argument
        of :func:`~repro.core.cycle_model.grid_pipeline_cycles`, shared by
        :meth:`modeled_cycles` and the modeled timelines so cost and
        rendering can never disagree.

        Per movement: DS-1 compute cycles (Eq. 3), plus the streamed-weight
        DMA cost at :data:`HBM_BYTES_PER_CYCLE`.  With a double-buffered
        weight pipeline (``w_slots=2``) only level 0's DMA (the pipeline
        ``fill``) is exposed and the rest hides behind compute —
        ``fill + max(compute, dma - fill)``, never worse than the
        single-slot fallback's serialized ``compute + dma``.  Resident
        weights pay no per-movement DMA.

        With the channel-tiled grid (``c_tiles > 1``, streamed) the body is
        :func:`~repro.core.cycle_model.channel_tiled_body_cycles`: blocking
        mid-level weight DMA + mid compute, then the k-axis pipeline — slice
        0's fetch overlaps the mid pyramid (fill), each later slice's fetch
        overlaps the previous slice's MXU pass (steady), the last slice's
        compute drains exposed.

        Both sides of the overlap are dtype-aware: every weight-DMA term
        scales with the program's ``bytes_per_val``, and the MXU compute
        cycles divide by :func:`~repro.core.dtypes.mxu_throughput` (bf16
        operands double the systolic rate) — so narrowing the dtype shrinks
        the DMA *and* the compute it hides behind."""
        from .cycle_model import channel_tiled_body_cycles

        compute, stream = self._body_terms()
        if stream is None:
            return compute
        kind = stream["kind"]
        if kind == "channel_tiled":
            return channel_tiled_body_cycles(
                stream["compute_mid"],
                stream["compute_last"],
                stream["dma_mid"],
                stream["dma_slice"],
                self.c_tiles,
                pipelined=self.w_slots > 1,
            )
        if kind == "pipelined":
            fill, dma = stream["fill"], stream["dma"]
            return fill + max(compute, dma - fill)
        return compute + stream["dma"]

    def _body_terms(self) -> tuple[int, dict | None]:
        """The raw compute/DMA cycle terms of one grid cell: ``(compute,
        stream)`` with ``stream`` None for resident launches, else a dict
        naming the weight-DMA regime and its terms — consumed by both
        :meth:`body_cycles` and :meth:`body_detail_timeline`."""
        from .cycle_model import (
            ds1_cycles_per_movement,
            ds1_split_cycles_per_movement,
            mxu_scaled_cycles,
        )

        bpv = self.program.bytes_per_val
        cdt = self.program.compute_dtype
        compute = mxu_scaled_cycles(ds1_cycles_per_movement(self.spec), cdt)
        if not self.streamed:
            return compute, None
        cnts = self.program.level_weight_counts()
        if self.c_tiles > 1:
            compute_mid, compute_last = ds1_split_cycles_per_movement(self.spec)
            return compute, {
                "kind": "channel_tiled",
                "compute_mid": mxu_scaled_cycles(compute_mid, cdt),
                "compute_last": mxu_scaled_cycles(compute_last, cdt),
                "dma_mid": -(-bpv * sum(cnts[:-1]) // HBM_BYTES_PER_CYCLE),
                "dma_slice": -(
                    -bpv * -(-cnts[-1] // self.c_tiles) // HBM_BYTES_PER_CYCLE
                ),
            }
        dma = -(-bpv * sum(cnts) // HBM_BYTES_PER_CYCLE)
        if self.w_slots > 1:
            fill = -(-bpv * cnts[0] // HBM_BYTES_PER_CYCLE)
            return compute, {"kind": "pipelined", "dma": dma, "fill": fill}
        return compute, {"kind": "blocking", "dma": dma}

    def body_detail_timeline(self):
        """DMA-vs-MXU bars *inside* one grid cell — weight movement against
        the conv cascade (:class:`~repro.core.cycle_model.TimelineSegment`
        list ending exactly at :meth:`body_cycles`): a single compute bar for
        resident launches, exposed-then-compute for blocking streams, the
        fill-overlap shape for the double-buffered weight pipeline, and the
        k-axis fill/steady/drain for channel-tiled launches."""
        from .cycle_model import TimelineSegment, channel_tiled_body_timeline

        compute, stream = self._body_terms()
        if stream is None:
            return [TimelineSegment("mxu", "pyramid (resident)", 0, compute)]
        kind = stream["kind"]
        if kind == "channel_tiled":
            return channel_tiled_body_timeline(
                stream["compute_mid"],
                stream["compute_last"],
                stream["dma_mid"],
                stream["dma_slice"],
                self.c_tiles,
                pipelined=self.w_slots > 1,
            )
        dma = stream["dma"]
        segs = [TimelineSegment("dma", "weights", 0, dma)]
        if kind == "pipelined":
            # compute starts once level 0's weights (the fill) have landed;
            # later levels' DMA hides behind the cascade
            segs.append(
                TimelineSegment("mxu", "pyramid", stream["fill"], compute)
            )
        else:
            segs.append(TimelineSegment("mxu", "pyramid", dma, compute))
        return segs

    def modeled_timeline(self, *, max_cells: int = 64):
        """The launch's modeled DMA-vs-MXU timeline for one batch element
        (:class:`~repro.core.cycle_model.TimelineSegment` list): the
        uniform-stride grid's input halo-tile stream against the per-cell
        pyramid bodies, serial or software-pipelined per ``x_slots``, ending
        exactly at ``modeled_cycles(batch=1)``.  The Chrome-trace exporter
        (:mod:`repro.obs.timeline`) renders this next to measured spans."""
        from .cycle_model import grid_pipeline_timeline

        return grid_pipeline_timeline(
            self.program.alpha ** 2,
            self.body_cycles(),
            self.program.input_dma_cycles(),
            pipelined=self.x_slots > 1,
            max_cells=max_cells,
        )

    def describe(
        self, batch: int = 1, vmem_budget: int | None = None
    ) -> dict:
        """The launch as one observability row: every plan knob plus the
        modeled byte/cycle quantities the planner optimized, in one flat
        JSON-safe dict (the span schema of DESIGN.md §12 and the row format
        of ``repro.obs.explain``).  ``vmem_budget`` adds the headroom column
        (budget minus modeled working set)."""
        prog = self.program
        row = {
            "q_convs": prog.q_convs,
            "out_region": self.out_region,
            "alpha": prog.alpha,
            "regime": self.regime,
            "streamed": self.streamed,
            "x_slots": self.x_slots,
            "w_slots": self.w_slots,
            "c_tiles": self.c_tiles,
            "compute_dtype": prog.compute_dtype,
            "batch": batch,
            "hbm_bytes": self.hbm_bytes(batch),
            "vmem_bytes": self.vmem_bytes(),
            "slice_bytes": self.slice_bytes(),
            "modeled_cycles": self.modeled_cycles(batch),
            "body_cycles": self.body_cycles(),
            "input_dma_cycles": prog.input_dma_cycles(),
        }
        if vmem_budget is not None:
            row["vmem_headroom_bytes"] = vmem_budget - row["vmem_bytes"]
        return row

    def modeled_cycles(self, batch: int = 1) -> int:
        """Pipeline-aware cycle cost of the whole launch — the latency
        tiebreaker of the partitioner's dynamic program.

        The per-cell :meth:`body_cycles` is composed per batch element by
        :func:`~repro.core.cycle_model.grid_pipeline_cycles`: serial
        (``x_slots=1``) pays ``(input_dma + body) * cells``; the revolving
        cross-cell prefetch (``x_slots=2``) pays
        ``warmup_fill + body + (cells - 1) * max(body, input_dma)`` — never
        worse than serial, equal at ``alpha == 1`` (no successor cell).

        ``batch`` multiplies the per-image grid (the batch grid axis is
        ``parallel`` across cores but sequential within one, and the
        prefetch chain resets at batch boundaries, so each element pays its
        own warm-up fill).  The byte models scale differently in batch —
        resident weights are read once per launch, streamed weights once per
        cell per element — which is why the partitioner's cut points shift
        with the serving bucket (see :func:`plan_launch` and DESIGN.md §14).
        """
        from .cycle_model import grid_pipeline_cycles

        per_image = grid_pipeline_cycles(
            self.program.alpha ** 2,
            self.body_cycles(),
            self.program.input_dma_cycles(),
            pipelined=self.x_slots > 1,
        )
        return batch * per_image

    def modeled_us(self, batch: int = 1) -> float:
        """:meth:`modeled_cycles` at the cycle model's reference frequency —
        the per-launch share of a serving bucket's latency SLO estimate."""
        from .cycle_model import DEFAULT_PARAMS

        return self.modeled_cycles(batch) / DEFAULT_PARAMS.freq_mhz


def plan_launch(
    spec: FusionSpec,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    *,
    batch: int = 1,
    allow_stream: bool = True,
    prefer_region: str = "largest",
    compute_dtype="float32",
) -> LaunchPlan | None:
    """Pick the launch configuration for one pyramid: an exactly-tiling
    output region whose program fits the VMEM budget, preferring
    fully-resident weights over per-level streaming (which re-reads weights
    once per grid cell), and double-buffered streaming (DMA overlapped with
    compute) over the blocking single-slot fallback.  Between those two
    streamed rungs sits the **channel-tiled** regime: when two whole copies
    of the largest level's weights bust VMEM, tiling the last level's Cout
    across a fourth sequential grid axis shrinks the streamed slice by
    ``c_tiles`` so the double-buffered pipeline fits after all — the ladder
    is resident > streamed x2 > channel-tiled streamed x2 > streamed x1,
    with the smallest (coarsest-slice) feasible ``c_tiles`` preferred.
    Within each weight regime the two-slot input landing buffer (cross-cell
    halo prefetch, ``x_slots=2``) is preferred over the serial single slot;
    a 1x1 grid has no successor cell to prefetch, so ``alpha == 1`` pins
    ``x_slots=1``.  ``prefer_region="largest"`` (default) minimizes grid
    overhead; ``"smallest"`` is the paper's smallest-tile preference —
    maximal tile grids, i.e. END skipping at its finest granularity.
    ``compute_dtype`` re-tiers the whole ladder: the rungs are walked with
    that dtype's byte widths, so a chain that busts VMEM resident at float32
    may climb back to resident (or from channel-tiled to plain streamed x2)
    at bfloat16 — the launched kernel then moves that dtype end to end.

    ``batch`` is the costing scale: within a rung the plan knobs are chosen
    by ``modeled_cycles(batch)`` at the batch the launch will actually run
    (the serving engine plans per bucket).  The rung *order* needs no batch
    argument — resident weights are read once per launch while streamed
    re-reads scale with ``batch * alpha^2``, so the ladder is cost-monotone
    at every batch — but the batch still decides plans globally through the
    partitioner, which compares whole cut points at the bucket batch and
    shifts toward fewer, weight-resident launches as batch grows (weight
    loads amortize across the batch; activation traffic does not).
    Returns ``None`` when no single launch fits."""
    if prefer_region not in ("largest", "smallest"):
        from repro.robust.errors import PreflightError

        raise PreflightError(
            f"prefer_region must be 'largest' or 'smallest',"
            f" got {prefer_region!r}"
        )
    compute_dtype = canonical_dtype(compute_dtype)
    out_size = spec.feature_sizes()[-1]
    regions = [r for r in range(out_size, 0, -1) if out_size % r == 0]
    if prefer_region == "smallest":
        regions.reverse()

    def x_options(prog: TileProgram) -> tuple[int, ...]:
        return (1,) if prog.alpha == 1 else (2, 1)

    def pick_x(prog: TileProgram, build) -> LaunchPlan | None:
        """Cheapest feasible input-buffer knob of one rung, costed at
        ``batch``: ``build(xs)`` returns the rung's plan at ``x_slots=xs``
        or None when it busts VMEM.  The prefetch pipeline is never modeled
        slower than serial at any batch; on a tie keep the extra landing
        slot (the historical ladder's preference)."""
        cands = [p for p in (build(xs) for xs in x_options(prog)) if p]
        if not cands:
            return None
        return min(cands, key=lambda p: (p.modeled_cycles(batch), -p.x_slots))

    def feasible(plan: LaunchPlan) -> LaunchPlan | None:
        return plan if plan.vmem_bytes() <= vmem_budget else None

    for r in regions:
        prog = compile_program(spec, r, compute_dtype=compute_dtype)
        plan = pick_x(
            prog,
            lambda xs, prog=prog: feasible(
                LaunchPlan(program=prog, streamed=False, x_slots=xs)
            ),
        )
        if plan is not None:
            return plan
    if allow_stream:
        # region preference stays primary (a smaller region multiplies the
        # alpha^2 streamed weight re-reads); within a region prefer the
        # double-buffered two-slot weight pipeline over channel-tiled
        # double buffering over the blocking single slot, and within a
        # weight regime the cheapest feasible input buffer at ``batch``
        for r in regions:
            prog = compile_program(spec, r, compute_dtype=compute_dtype)
            rungs = [dict(w_slots=2)]
            rungs += [dict(w_slots=2, c_tiles=ct) for ct in prog.c_tile_options()]
            rungs += [dict(w_slots=1)]
            for knobs in rungs:
                plan = pick_x(
                    prog,
                    lambda xs, prog=prog, knobs=knobs: feasible(
                        LaunchPlan(
                            program=prog, streamed=True, x_slots=xs, **knobs
                        )
                    ),
                )
                if plan is not None:
                    return plan
    return None


def pick_out_region(
    spec: FusionSpec,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    *,
    allow_stream: bool = True,
    compute_dtype="float32",
) -> int | None:
    """Largest output region that tiles the output exactly and whose program
    fits the VMEM budget — the TPU analogue of the paper's ``H <= IFM``
    feasibility bound (DESIGN.md §2 assumption change #2).

    Fully-resident weights are preferred; when no region fits that way and
    ``allow_stream``, regions feasible under per-level weight streaming are
    considered.  Returns ``None`` when nothing fits (the chain must then be
    chunked).
    """
    plan = plan_launch(
        spec, vmem_budget, allow_stream=allow_stream,
        compute_dtype=compute_dtype,
    )
    return None if plan is None else plan.out_region
