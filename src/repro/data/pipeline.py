"""Deterministic synthetic data pipeline with host sharding + prefetch.

Production posture without external datasets: token streams are generated
from a counter-based PRNG (reproducible across restarts and elastic
rescales — shard i of N always sees the same stream), packed to fixed
``(batch, seq)`` blocks, and double-buffered so host generation overlaps the
device step.  Restart semantics: the pipeline is a pure function of
``(seed, step)`` — checkpoint stores only the step counter.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from queue import Queue
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def _batch_at(cfg: DataConfig, step: int) -> dict:
    """Pure function (seed, step, host) -> host-local batch.

    Zipfian token draws (natural-language-like marginals) + a next-token
    structure (shifted mixing) so the LM loss is learnable, not pure noise.
    """
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id])
    )
    b, s = cfg.host_batch, cfg.seq_len
    # zipf marginals clipped to vocab
    raw = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
    toks = (raw - 1) % cfg.vocab
    # inject learnable bigram structure: with p=0.5, t[i+1] = f(t[i]);
    # applied sequentially so the rule chains through rewritten positions
    mask = rng.random((b, s)) < 0.5
    for i in range(s):
        sel = mask[:, i]
        toks[sel, i + 1] = (toks[sel, i] * 31 + 7) % cfg.vocab
    return {"tokens": toks.astype(np.int32)}


class Pipeline:
    """Prefetching iterator over deterministic batches."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, prefetch: int = 2):
        self.cfg = cfg
        self.step = start_step
        self._q: Queue = Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            batch = _batch_at(self.cfg, step)
            self._q.put((step, batch))
            step += 1

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except Exception:
            pass


def batch_at(cfg: DataConfig, step: int) -> dict:
    """Random-access batch (restart / straggler re-issue path)."""
    return _batch_at(cfg, step)
