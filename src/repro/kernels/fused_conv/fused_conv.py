"""Pallas TPU kernel: variadic USEFUSE fusion pyramid (conv+ReLU[+pool] x Q).

The paper's fused-layer dataflow, adapted to the TPU memory hierarchy
(DESIGN.md §2): one grid cell computes one fusion-pyramid tile end to end —
every intermediate level stays in VMEM (the TPU analogue of "no off-chip
intermediate traffic") for *any* pyramid depth Q >= 1, including odd Q and
ResNet-style conv-only pairs.  The grid is the uniform-stride tile plan: the
``alpha x alpha`` movement grid with identical movement counts at every level
is exactly Algorithm 4's uniform stride, realized as a Pallas grid.

The kernel is compiled from a :class:`~repro.core.program.TileProgram` — the
single tile-program lowering shared with the value-level executor — and
receives one ``ConvLevelProg`` per conv level (pool epilogues folded in).

Per grid cell (b, i, j):
  * the image block (whole padded image of batch b) is VMEM-resident; the
    level-0 tile is cut with dynamic slices at ``i*stride0`` (tile stride S^T
    from the plan);
  * conv levels run as K*K unrolled strided-slice + MXU dot-general
    (``(P, Cin) @ (Cin, Cout)``) accumulations — the WPU array of Fig. 5 maps
    onto MXU tiles;
  * inner-layer padding is realized by *validity masking*: rows whose global
    coordinate falls outside a level's valid output range are zeroed — zeros
    are exactly the next level's pad value, and post-ReLU zeros are neutral
    for maxpool (the executor's crop logic, branch-free for SIMD);
  * END tile-skip (the paper's §3.2 insight at TPU-feasible granularity)
    generalizes to a **cascade**: at every level l >= 1, if the incoming
    post-ReLU tile is all zero the level's K^2 MXU pass is skipped and its
    output collapses to the closed form ``epilogue(relu(b_l))``; the constant
    tile feeds the next level, which applies the same test — so a dead tile
    with non-positive downstream biases short-circuits the whole remaining
    pyramid.  A per-level skip flag is emitted for energy/cycle statistics.

Weights live whole in VMEM ("filters are loaded into the kernel buffers only
once", §3.3.1); the VMEM working set is accounted by
:meth:`~repro.core.program.TileProgram.vmem_bytes` and asserted in ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.program import ConvLevelProg, TileProgram  # noqa: F401 (re-export)


def _conv_tile(x, w, b, K: int, S: int, out: int):
    """Valid conv on a (h, w, Cin) tile via K*K strided-slice MXU dots."""
    cin, cout = w.shape[2], w.shape[3]
    acc = jnp.zeros((out * out, cout), jnp.float32)
    hi = (out - 1) * S + 1
    for ki in range(K):
        for kj in range(K):
            patch = x[ki : ki + hi : S, kj : kj + hi : S, :]
            acc = acc + jnp.dot(
                patch.reshape(out * out, cin),
                w[ki, kj],
                preferred_element_type=jnp.float32,
            )
    return acc.reshape(out, out, cout) + b


def _pool_tile(x, K: int, S: int):
    out = (x.shape[0] - K) // S + 1
    hi = (out - 1) * S + 1
    r = None
    for pi in range(K):
        for pj in range(K):
            v = x[pi : pi + hi : S, pj : pj + hi : S, :]
            r = v if r is None else jnp.maximum(r, v)
    return r


def _mask(t, idx, o_base: int, o_step: int, valid: int):
    """Zero rows/cols whose global coordinate is outside [0, valid)."""
    g0 = o_base + idx[0] * o_step
    g1 = o_base + idx[1] * o_step
    rows = jnp.arange(t.shape[0])
    cols = jnp.arange(t.shape[1])
    mrow = (rows + g0 >= 0) & (rows + g0 < valid)
    mcol = (cols + g1 >= 0) & (cols + g1 < valid)
    return t * (mrow[:, None, None] & mcol[None, :, None])


def _level_epilogue(t, idx, prog: ConvLevelProg):
    """Mask conv output to its valid range, pool, mask the pool output."""
    t = _mask(t, idx, prog.o_base, prog.o_step, prog.valid)
    if prog.pool is not None:
        t = _pool_tile(t, *prog.pool)
        t = _mask(t, idx, prog.pool_o_base, prog.pool_o_step, prog.pool_valid)
    return t


def _const_level(idx, prog: ConvLevelProg, b, relu: bool):
    """Closed form of a level whose input tile is all zero: the conv output
    is the bias everywhere, so the tile is ``epilogue(relu(b))``."""
    c = jnp.maximum(b, 0.0) if relu else b
    t = jnp.broadcast_to(c, (prog.out_size, prog.out_size, c.shape[-1]))
    return _level_epilogue(t, idx, prog)


def _pyramid_kernel(
    *refs,
    progs: tuple[ConvLevelProg, ...],
    tile0: int,
    stride0: int,
    relu: bool,
    end_skip: bool,
    stream: bool,
):
    q = len(progs)
    x_ref = refs[0]
    if stream:
        # weights arrive as one flat HBM-space array; each level's slice is
        # DMA'd into the shared VMEM scratch just before it is needed.
        wflat_ref = refs[1]
        b_refs = refs[2 : 2 + q]
        out_ref, skip_ref = refs[2 + q], refs[3 + q]
        w_scratch, w_sem = refs[4 + q], refs[5 + q]
    else:
        w_refs = refs[1 : 1 + 2 * q : 2]
        b_refs = refs[2 : 2 + 2 * q : 2]
        out_ref, skip_ref = refs[1 + 2 * q], refs[2 + 2 * q]
    i = pl.program_id(1)
    j = pl.program_id(2)
    idx = (i, j)

    # ---- level-0 tile from the VMEM-resident image block ----
    t = x_ref[0, pl.ds(i * stride0, tile0), pl.ds(j * stride0, tile0), :]

    skips = []
    w_off = 0
    for l, prog in enumerate(progs):
        cnt = prog.K * prog.K * prog.n_in * prog.n_out
        if stream:
            # fetch lazily inside the live branch: an END-skipped level must
            # not pay its HBM weight read either
            def fetch_w(w_off=w_off, cnt=cnt, prog=prog):
                dma = pltpu.make_async_copy(
                    wflat_ref.at[pl.ds(w_off, cnt)],
                    w_scratch.at[pl.ds(0, cnt)],
                    w_sem,
                )
                dma.start()
                dma.wait()
                return w_scratch[0:cnt].reshape(
                    prog.K, prog.K, prog.n_in, prog.n_out
                )

            w_off += cnt
        else:
            def fetch_w(l=l):
                return w_refs[l][...]

        b = b_refs[l][...]

        def run_level(t_in, fetch_w=fetch_w, b=b, prog=prog):
            tl = _conv_tile(t_in, fetch_w(), b, prog.K, prog.S, prog.out_size)
            if relu:
                tl = jnp.maximum(tl, 0.0)
            return _level_epilogue(tl, idx, prog)

        if l == 0 or not (end_skip and relu):
            # level 0 always computes; without ReLU the all-zero test is not
            # a sound skip predicate (negatives would survive).
            skips.append(jnp.int32(0))
            t = run_level(t)
        else:
            # END cascade: post-ReLU tiles are >= 0, so max == 0 proves the
            # whole tile (masked halo included) is zero and the conv input is
            # literally the zero tensor — @cond skips the K^2 MXU pass and
            # emits the closed form instead, bit-exactly.
            live = jnp.max(t) > 0.0
            skips.append(jnp.where(live, 0, 1).astype(jnp.int32))
            t = jax.lax.cond(
                live,
                run_level,
                lambda t_in, b=b, prog=prog: _const_level(idx, prog, b, relu),
                t,
            )

    out_ref[0, :, :, :] = t
    skip_ref[0, 0, 0, :] = jnp.stack(skips)


def fused_pyramid_pallas(
    x_padded: jnp.ndarray,  # (B, Hp, Wp, C) pre-padded input
    weights: list[jnp.ndarray],
    biases: list[jnp.ndarray],
    *,
    program: TileProgram,
    relu: bool = True,
    end_skip: bool = True,
    interpret: bool = True,
    stream_weights: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Launch the variadic fused pyramid over the (B, alpha, alpha) grid.

    Weights/biases are flat per-conv-level lists, index-aligned with
    ``program.levels``.  With ``stream_weights`` the weights stay in HBM
    (memory space ANY) and each level's tensor is DMA'd into a shared VMEM
    scratch on demand — the fallback when the fully-resident working set
    busts the VMEM budget (see ``TileProgram.vmem_stream_bytes``).

    Returns ``(out, skip)`` with ``skip`` shaped ``(B, alpha, alpha, Q)`` —
    ``skip[..., l] == 1`` where level ``l``'s conv was short-circuited by the
    END cascade (level 0 never skips).
    """
    B, Hp, Wp, C = x_padded.shape
    q = program.q_convs
    assert len(weights) == len(biases) == q, "one (w, b) pair per conv level"
    alpha, out_region = program.alpha, program.out_region
    m_out = program.n_out
    kernel = functools.partial(
        _pyramid_kernel,
        progs=program.levels,
        tile0=program.tile0,
        stride0=program.stride0,
        relu=relu,
        end_skip=end_skip,
        stream=stream_weights,
    )
    in_specs = [pl.BlockSpec((1, Hp, Wp, C), lambda b, i, j: (b, 0, 0, 0))]
    operands: list[jnp.ndarray] = [x_padded]
    scratch_shapes: list = []
    if stream_weights:
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
        operands.append(jnp.concatenate([w.reshape(-1) for w in weights]))
        for bias in biases:
            in_specs.append(pl.BlockSpec(bias.shape, lambda b, i, j: (0,)))
            operands.append(bias)
        scratch_shapes = [
            pltpu.VMEM((max(program.level_weight_counts()),), jnp.float32),
            pltpu.SemaphoreType.DMA,
        ]
    else:
        for w, bias in zip(weights, biases):
            in_specs.append(pl.BlockSpec(w.shape, lambda b, i, j: (0,) * 4))
            in_specs.append(pl.BlockSpec(bias.shape, lambda b, i, j: (0,)))
            operands += [w, bias]
    out, skip = pl.pallas_call(
        kernel,
        grid=(B, alpha, alpha),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec(
                (1, out_region, out_region, m_out), lambda b, i, j: (b, i, j, 0)
            ),
            pl.BlockSpec((1, 1, 1, q), lambda b, i, j: (b, i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(
                (B, alpha * out_region, alpha * out_region, m_out), jnp.float32
            ),
            jax.ShapeDtypeStruct((B, alpha, alpha, q), jnp.int32),
        ],
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(*operands)
    return out, skip
