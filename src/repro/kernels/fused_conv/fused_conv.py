"""Pallas TPU kernel: variadic USEFUSE fusion pyramid (conv+ReLU[+pool] x Q).

The paper's fused-layer dataflow, adapted to the TPU memory hierarchy
(DESIGN.md §2, §8): one grid cell computes one fusion-pyramid tile end to
end — every intermediate level stays in VMEM (the TPU analogue of "no
off-chip intermediate traffic") for *any* pyramid depth Q >= 1, including odd
Q and ResNet-style conv-only pairs.  The grid is the uniform-stride tile
plan: the ``alpha x alpha`` movement grid with identical movement counts at
every level is exactly Algorithm 4's uniform stride, realized as a Pallas
grid.

The kernel is compiled from a :class:`~repro.core.program.TileProgram` — the
single tile-program lowering shared with the value-level executor — and
receives one ``ConvLevelProg`` per conv level (pool epilogues folded in).

Per grid cell (b, i, j):
  * the input stays in HBM (memory space ANY); the level-0 halo tile
    (``tile0 x tile0``, neighbours overlapping by the pyramid halo) is DMA'd
    into a VMEM landing buffer with ``make_async_copy`` at offset
    ``(i*stride0, j*stride0)`` — per-cell input traffic is ``tile0^2 * C``
    (Algorithm 4's uniform minimal movement), not the whole padded image;
  * with ``x_slots=2`` the landing buffer is a *revolving two-slot pipeline
    across grid cells*: before running its own pyramid, cell ``n`` (row-major
    within its batch element) starts the halo DMA for cell ``n+1`` — next
    ``j``, wrapping to the next ``i`` — into the idle slot, so after the
    per-image warm-up fill the input stream hides behind the Q-level MXU
    cascade (§3.3's tile movement).  The chain deliberately resets at every
    batch boundary: the batch grid axis is declared ``parallel`` in
    ``dimension_semantics`` and may be partitioned across TensorCores, and a
    prefetch crossing a batch boundary would land in another core's scratch.
    END-skipped cells still issue their successor's prefetch (the input
    prefetch precedes the cascade, outside every liveness branch), so a dead
    region never stalls the pipeline.  ``x_slots=1`` is the serial
    start();wait() path — bit-identical, only the movement schedule differs;
  * conv levels run as K*K unrolled strided-slice + MXU dot-general
    (``(P, Cin) @ (Cin, Cout)``) accumulations — the WPU array of Fig. 5 maps
    onto MXU tiles;
  * inner-layer padding is realized by *validity masking*: rows whose global
    coordinate falls outside a level's valid output range are zeroed — zeros
    are exactly the next level's pad value, and post-ReLU zeros are neutral
    for maxpool (the executor's crop logic, branch-free for SIMD);
  * END tile-skip (the paper's §3.2 insight at TPU-feasible granularity)
    generalizes to a **cascade**: at every level l >= 1, if the incoming
    post-ReLU tile is all zero the level's K^2 MXU pass is skipped and its
    output collapses to the closed form ``epilogue(relu(b_l))``; the constant
    tile feeds the next level, which applies the same test — so a dead tile
    with non-positive downstream biases short-circuits the whole remaining
    pyramid.  A per-level skip flag is emitted for energy/cycle statistics.

Weight regimes ("filters are loaded into the kernel buffers only once",
§3.3.1, vs the VMEM-busting fallback):
  * resident — all weights live whole in VMEM for the launch;
  * streamed, double-buffered (``w_slots=2``) — weights stay in HBM as one
    flat array; level ``l+1``'s slice is DMA'd into the idle scratch slot
    before level ``l``'s MXU pass so the transfer hides behind compute
    (START-wait-flip).  The prefetch for level ``l+1`` is issued inside level
    ``l``'s *live* branch, so a cascade of END-skipped levels issues no
    weight DMAs at all; the one speculative case (level ``l`` live but its
    output all zero) drains the already-started DMA in the skip branch to
    keep semaphores balanced;
  * streamed, single-slot (``w_slots=1``) — blocking start();wait() per live
    level, when even two copies of the largest level's weights bust VMEM
    (e.g. ResNet-18's 512-channel block).

The VMEM working set of each regime is accounted by
:meth:`~repro.core.program.TileProgram.vmem_bytes` /
:meth:`~repro.core.program.TileProgram.vmem_stream_bytes` and asserted in
ops.py; the regime itself is chosen once by
:func:`~repro.core.program.plan_launch` so planner cost and launched kernel
can never disagree.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import resolve_interpret
from repro.core.dtypes import EXEC_DTYPES, jnp_dtype
from repro.core.program import ConvLevelProg, TileProgram  # noqa: F401 (re-export)


def _conv_tile(x, w, b, K: int, S: int, out: int):
    """Valid conv on a (h, w, Cin) tile via K*K strided-slice MXU dots.

    Operands may be any compute dtype (f32 or bf16); the accumulator is
    always float32 via ``preferred_element_type`` — DESIGN.md §11's
    "low-precision operands, full-precision accumulation" contract, the MXU's
    native mixed-precision mode.  The bias add also runs in f32 (the f32
    accumulator promotes a bf16 ``b``)."""
    cin, cout = w.shape[2], w.shape[3]
    acc = jnp.zeros((out * out, cout), jnp.float32)
    hi = (out - 1) * S + 1
    for ki in range(K):
        for kj in range(K):
            patch = x[ki : ki + hi : S, kj : kj + hi : S, :]
            acc = acc + jnp.dot(
                patch.reshape(out * out, cin),
                w[ki, kj],
                preferred_element_type=jnp.float32,
            )
    return acc.reshape(out, out, cout) + b


def _pool_tile(x, K: int, S: int):
    out = (x.shape[0] - K) // S + 1
    hi = (out - 1) * S + 1
    r = None
    for pi in range(K):
        for pj in range(K):
            v = x[pi : pi + hi : S, pj : pj + hi : S, :]
            r = v if r is None else jnp.maximum(r, v)
    return r


def _mask(t, idx, o_base: int, o_step: int, valid: int):
    """Zero rows/cols whose global coordinate is outside [0, valid)."""
    g0 = o_base + idx[0] * o_step
    g1 = o_base + idx[1] * o_step
    rows = jnp.arange(t.shape[0])
    cols = jnp.arange(t.shape[1])
    mrow = (rows + g0 >= 0) & (rows + g0 < valid)
    mcol = (cols + g1 >= 0) & (cols + g1 < valid)
    return t * (mrow[:, None, None] & mcol[None, :, None])


def _level_epilogue(t, idx, prog: ConvLevelProg):
    """Mask conv output to its valid range, pool, mask the pool output."""
    t = _mask(t, idx, prog.o_base, prog.o_step, prog.valid)
    if prog.pool is not None:
        t = _pool_tile(t, *prog.pool)
        t = _mask(t, idx, prog.pool_o_base, prog.pool_o_step, prog.pool_valid)
    return t


def _const_level(idx, prog: ConvLevelProg, b, relu: bool, out_dtype):
    """Closed form of a level whose input tile is all zero: the conv output
    is the bias everywhere, so the tile is ``epilogue(relu(b))``.

    Bit-identical to the live path at every compute dtype: the live path
    accumulates ``0 + b`` in f32 then casts after the epilogue, and relu /
    validity masks / maxpool all commute exactly with the f32->bf16
    round-trip of a bf16-representable ``b`` (monotone or multiply-by-{0,1}
    ops on exactly-representable values)."""
    c = jnp.maximum(b, 0.0) if relu else b
    t = jnp.broadcast_to(c, (prog.out_size, prog.out_size, c.shape[-1]))
    return _level_epilogue(t, idx, prog).astype(out_dtype)


def _pyramid_kernel(
    *refs,
    progs: tuple[ConvLevelProg, ...],
    tile0: int,
    stride0: int,
    alpha: int,
    relu: bool,
    end_skip: bool,
    stream: bool,
    w_slots: int,
    x_slots: int,
    cnts: tuple[int, ...],
    out_dtype,
):
    q = len(progs)
    x_hbm = refs[0]
    if stream:
        # weights arrive as one flat HBM-space array; each level's slice is
        # DMA'd into one of the w_slots VMEM scratch slots.
        wflat_ref = refs[1]
        b_refs = refs[2 : 2 + q]
        out_ref, skip_ref = refs[2 + q], refs[3 + q]
        x_scratch, x_sem = refs[4 + q], refs[5 + q]
        w_scratch, w_sem = refs[6 + q], refs[7 + q]
    else:
        w_refs = refs[1 : 1 + 2 * q : 2]
        b_refs = refs[2 : 2 + 2 * q : 2]
        out_ref, skip_ref = refs[1 + 2 * q], refs[2 + 2 * q]
        x_scratch, x_sem = refs[3 + 2 * q], refs[4 + 2 * q]
    bi = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    idx = (i, j)

    offs = [sum(cnts[:l]) for l in range(q)]

    def w_dma(l):
        """DMA descriptor for level l's weight slice into its scratch slot."""
        return pltpu.make_async_copy(
            wflat_ref.at[pl.ds(offs[l], cnts[l])],
            w_scratch.at[l % w_slots, pl.ds(0, cnts[l])],
            w_sem.at[l % w_slots],
        )

    def x_dma(ii, jj, slot):
        """DMA descriptor for cell (bi, ii, jj)'s halo tile into one landing
        slot.  All cells of the chain share ``bi``: the batch axis is
        ``parallel`` (possibly core-partitioned), so the prefetch chain must
        never cross a batch boundary."""
        return pltpu.make_async_copy(
            x_hbm.at[
                bi, pl.ds(ii * stride0, tile0), pl.ds(jj * stride0, tile0), :
            ],
            x_scratch.at[slot],
            x_sem.at[slot],
        )

    # ---- halo tile fetch: HBM -> VMEM landing buffer(s), overlapped with
    # the level-0 weight DMA in the double-buffered streamed regime ----
    if x_slots > 1:
        # revolving cross-cell pipeline: cell n's tile was prefetched by cell
        # n-1 into slot n % 2; this cell starts cell n+1's fetch into the
        # idle slot (just vacated by cell n-1) before waiting on its own.
        cell = i * alpha + j
        slot = jax.lax.rem(cell, x_slots)

        @pl.when(cell == 0)
        def _():  # warm-up: each batch element's first cell self-fetches
            x_dma(i, j, slot).start()

        ni = jnp.where(j == alpha - 1, i + 1, i)
        nj = jnp.where(j == alpha - 1, 0, j + 1)

        @pl.when(cell + 1 < alpha * alpha)
        def _():  # issued unconditionally w.r.t. the END cascade
            x_dma(ni, nj, 1 - slot).start()

        if stream and w_slots > 1:
            w_dma(0).start()  # pipeline warm-up: level 0 always computes
        x_dma(i, j, slot).wait()
        t = x_scratch[slot]
    else:
        serial_dma = x_dma(i, j, 0)
        serial_dma.start()
        if stream and w_slots > 1:
            w_dma(0).start()  # pipeline warm-up: level 0 always computes
        serial_dma.wait()
        t = x_scratch[0]

    skips = []
    # per level: None = statically live (always computed), else the traced
    # liveness predicate — the prefetch-bookkeeping contract: level l+1's
    # weight DMA was issued iff level l ran its live branch.
    live_flags: list = []
    for l, prog in enumerate(progs):
        prev_live = live_flags[l - 1] if l else None
        statically_live = l == 0 or not (end_skip and relu)
        if stream:
            def fetch_w(l=l, prog=prog, cnt=cnts[l], prev_live=prev_live):
                # called inside level l's live branch only
                if w_slots > 1:
                    if l > 0 and prev_live is not None:
                        # predecessor skipped => no prefetch: fetch on demand
                        @pl.when(jnp.logical_not(prev_live))
                        def _():
                            w_dma(l).start()
                else:
                    w_dma(l).start()
                w_dma(l).wait()
                return w_scratch[l % w_slots, 0:cnt].reshape(
                    prog.K, prog.K, prog.n_in, prog.n_out
                )
        else:
            def fetch_w(l=l):
                return w_refs[l][...]

        b = b_refs[l][...]

        def run_level(t_in, fetch_w=fetch_w, b=b, prog=prog, l=l):
            w = fetch_w()
            if stream and w_slots > 1 and l + 1 < q:
                # double-buffer flip: start the next level's weight DMA into
                # the idle slot before this level's K^2 MXU pass
                w_dma(l + 1).start()
            tl = _conv_tile(t_in, w, b, prog.K, prog.S, prog.out_size)
            if relu:
                tl = jnp.maximum(tl, 0.0)
            # relu/mask/pool run in the f32 accumulator dtype; the cast to
            # the compute dtype happens once, after the epilogue, so every
            # inter-level tile (VMEM and HBM alike) is compute-dtype wide
            return _level_epilogue(tl, idx, prog).astype(out_dtype)

        if statically_live:
            # level 0 always computes; without ReLU the all-zero test is not
            # a sound skip predicate (negatives would survive).
            live_flags.append(None)
            skips.append(jnp.int32(0))
            t = run_level(t)
        else:
            # END cascade: post-ReLU tiles are >= 0, so max == 0 proves the
            # whole tile (masked halo included) is zero and the conv input is
            # literally the zero tensor — @cond skips the K^2 MXU pass and
            # emits the closed form instead, bit-exactly.
            live = jnp.max(t) > 0.0
            live_flags.append(live)
            skips.append(jnp.where(live, 0, 1).astype(jnp.int32))

            def skip_level(t_in, b=b, prog=prog, l=l, prev_live=prev_live):
                if stream and w_slots > 1:
                    # drain the speculative prefetch (issued iff the previous
                    # level ran live) so the semaphore stays balanced
                    if prev_live is None:
                        w_dma(l).wait()
                    else:
                        @pl.when(prev_live)
                        def _():
                            w_dma(l).wait()
                return _const_level(idx, prog, b, relu, out_dtype)

            t = jax.lax.cond(live, run_level, skip_level, t)

    out_ref[0, :, :, :] = t
    skip_ref[0, 0, 0, :] = jnp.stack(skips)


def _ktiled_kernel(
    *refs,
    progs: tuple[ConvLevelProg, ...],
    tile0: int,
    stride0: int,
    alpha: int,
    relu: bool,
    end_skip: bool,
    stream: bool,
    w_slots: int,
    x_slots: int,
    c_tiles: int,
    cnts: tuple[int, ...],
    out_dtype,
):
    """Channel-tiled variant over the (B, alpha, alpha, c_tiles) grid.

    The fourth grid axis ``k`` walks ``Cout / c_tiles`` output-channel tiles
    of the *last* level (the column-parallel axis of the paper's Fig. 5 WPU
    array).  Levels ``0..Q-2`` run once per cell, at ``k == 0``, into a
    persistent VMEM scratch (Pallas TPU scratch survives sequential grid
    iterations — the same property the revolving landing buffer relies on);
    ``k > 0`` re-reads the scratch and computes only the last level's k-th
    channel block, written through a channel-indexed out BlockSpec.

    Streamed weights split in two: mid levels fetch their whole tensor from
    the flat HBM array through one *blocking* scratch slot inside their live
    branch (the double-buffer budget belongs to the slices), while the last
    level DMAs per-``k`` ``(K, K, Cin, Cout/c_tiles)`` slices from its
    natural 4D HBM ref through ``w_slots`` revolving slots — slice 0 starts
    at the top of the ``k == 0`` body so it fills behind the mid pyramid,
    slice ``k+1`` starts before slice ``k``'s MXU pass.  Slice DMAs are
    issued and drained *unconditionally* with respect to the END cascade
    (only the MXU pass is gated), so the semaphores stay balanced with no
    speculative drain paths; the END flag vector is written once, at
    ``k == 0`` (the last level's liveness predicate is k-invariant: every k
    reads the same mid tile)."""
    q = len(progs)
    last = progs[-1]
    ct_out = last.n_out // c_tiles
    if stream:
        x_hbm, wflat_ref, wlast_ref = refs[0], refs[1], refs[2]
        b_refs = refs[3 : 3 + q]
        out_ref, skip_ref = refs[3 + q], refs[4 + q]
        scratch = list(refs[5 + q :])
    else:
        x_hbm = refs[0]
        w_refs = refs[1 : 1 + 2 * q : 2]
        b_refs = refs[2 : 2 + 2 * q : 2]
        out_ref, skip_ref = refs[1 + 2 * q], refs[2 + 2 * q]
        scratch = list(refs[3 + 2 * q :])
    x_scratch, x_sem = scratch.pop(0), scratch.pop(0)
    mid_scratch = scratch.pop(0) if q > 1 else None
    if stream:
        if q > 1:
            wm_scratch, wm_sem = scratch.pop(0), scratch.pop(0)
        wk_scratch, wk_sem = scratch.pop(0), scratch.pop(0)

    bi = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)
    k = pl.program_id(3)
    idx = (i, j)

    def x_dma(ii, jj, slot):
        return pltpu.make_async_copy(
            x_hbm.at[
                bi, pl.ds(ii * stride0, tile0), pl.ds(jj * stride0, tile0), :
            ],
            x_scratch.at[slot],
            x_sem.at[slot],
        )

    if stream:
        offs = [sum(cnts[:l]) for l in range(q)]

        def wm_dma(l):
            """Blocking mid-level fetch: level l's whole slice of the flat
            HBM weight array into the single mid scratch slot."""
            return pltpu.make_async_copy(
                wflat_ref.at[pl.ds(offs[l], cnts[l])],
                wm_scratch.at[0, pl.ds(0, cnts[l])],
                wm_sem,
            )

        def wk_dma(kk):
            """Per-k slice fetch: the last level's kk-th Cout block, a
            strided read of the natural 4D HBM ref."""
            return pltpu.make_async_copy(
                wlast_ref.at[:, :, :, pl.ds(kk * ct_out, ct_out)],
                wk_scratch.at[kk % w_slots],
                wk_sem.at[kk % w_slots],
            )

    if x_slots > 1:
        cell = i * alpha + j
        slot = jax.lax.rem(cell, x_slots)
    else:
        slot = 0

    # ---- k == 0: input halo fetch (+ cross-cell prefetch chain) and the
    # mid pyramid, persisted into mid_scratch for k > 0 ----
    @pl.when(k == 0)
    def _():
        if x_slots > 1:
            @pl.when(cell == 0)
            def _():  # warm-up: each batch element's first cell self-fetches
                x_dma(i, j, slot).start()

            ni = jnp.where(j == alpha - 1, i + 1, i)
            nj = jnp.where(j == alpha - 1, 0, j + 1)

            @pl.when(cell + 1 < alpha * alpha)
            def _():  # successor prefetch, unconditional w.r.t. END
                x_dma(ni, nj, 1 - slot).start()

            if stream and w_slots > 1:
                wk_dma(0).start()  # slice 0 fills behind the mid pyramid
            x_dma(i, j, slot).wait()
        else:
            serial_dma = x_dma(i, j, 0)
            serial_dma.start()
            if stream and w_slots > 1:
                wk_dma(0).start()  # slice 0 fills behind the mid pyramid
            serial_dma.wait()
        t = x_scratch[slot]

        skips = []
        for l, prog in enumerate(progs[:-1]):
            b = b_refs[l][...]

            def run_level(t_in, l=l, prog=prog, b=b):
                if stream:
                    wm_dma(l).start()
                    wm_dma(l).wait()
                    w = wm_scratch[0, 0 : cnts[l]].reshape(
                        prog.K, prog.K, prog.n_in, prog.n_out
                    )
                else:
                    w = w_refs[l][...]
                tl = _conv_tile(t_in, w, b, prog.K, prog.S, prog.out_size)
                if relu:
                    tl = jnp.maximum(tl, 0.0)
                # cast after the epilogue, exactly as the untiled kernel, so
                # mid_scratch (and hence every k's input) is compute dtype
                return _level_epilogue(tl, idx, prog).astype(out_dtype)

            if l == 0 or not (end_skip and relu):
                skips.append(jnp.int32(0))
                t = run_level(t)
            else:
                live = jnp.max(t) > 0.0
                skips.append(jnp.where(live, 0, 1).astype(jnp.int32))
                t = jax.lax.cond(
                    live,
                    run_level,
                    lambda t_in, b=b, prog=prog: _const_level(
                        idx, prog, b, relu, out_dtype
                    ),
                    t,
                )
        if q > 1:
            mid_scratch[...] = t
            skip_ref[0, 0, 0, 0 : q - 1] = jnp.stack(skips)

    # ---- every k: the last level's k-th output-channel block ----
    t_in = mid_scratch[...] if q > 1 else x_scratch[slot]
    b_full = b_refs[q - 1][...]
    bk = jax.lax.dynamic_slice_in_dim(b_full, k * ct_out, ct_out, 0)

    if stream:
        if w_slots > 1:
            @pl.when(k + 1 < c_tiles)
            def _():  # revolving flip: next slice behind this MXU pass
                wk_dma(k + 1).start()
        else:
            wk_dma(k).start()  # blocking single-slot fallback
        wk_dma(k).wait()  # unconditional: doubles as the END drain
        w_k = wk_scratch[k % w_slots]
    else:
        w_k = jax.lax.dynamic_slice_in_dim(w_refs[q - 1][...], k * ct_out,
                                           ct_out, 3)

    def run_last(t_mid):
        tl = _conv_tile(t_mid, w_k, bk, last.K, last.S, last.out_size)
        if relu:
            tl = jnp.maximum(tl, 0.0)
        return _level_epilogue(tl, idx, last).astype(out_dtype)

    if q == 1 or not (end_skip and relu):
        last_flag = jnp.int32(0)
        res = run_last(t_in)
    else:
        live = jnp.max(t_in) > 0.0  # k-invariant: same mid tile every k
        last_flag = jnp.where(live, 0, 1).astype(jnp.int32)
        res = jax.lax.cond(
            live,
            run_last,
            lambda t_mid: _const_level(idx, last, bk, relu, out_dtype),
            t_in,
        )

    out_ref[0, :, :, :] = res

    @pl.when(k == 0)
    def _():
        skip_ref[0, 0, 0, q - 1 :] = last_flag.reshape(1)


def fused_pyramid_pallas(
    x_padded: jnp.ndarray,  # (B, Hp, Wp, C) pre-padded input
    weights: list[jnp.ndarray] | None,
    biases: list[jnp.ndarray],
    *,
    program: TileProgram,
    relu: bool = True,
    end_skip: bool = True,
    interpret: bool | None = None,
    stream_weights: bool = False,
    w_slots: int = 2,
    x_slots: int = 2,
    c_tiles: int = 1,
    weights_flat: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Launch the variadic fused pyramid over the (B, alpha, alpha) grid.

    The input stays in HBM; each grid cell DMAs its ``tile0 x tile0`` halo
    tile into VMEM.  With ``x_slots=2`` (default) the landing buffer
    revolves across grid cells: each cell prefetches its successor's halo
    tile into the idle slot before running its own pyramid, hiding the input
    stream behind compute after the per-image warm-up; ``x_slots=1`` is the
    serial fetch-then-compute path (bit-identical output).  The grid is
    launched with ``dimension_semantics=("parallel", "arbitrary",
    "arbitrary")`` so the compiler may partition the batch axis across
    TensorCores — the prefetch chain never crosses a batch boundary, so the
    partitioning is safe.

    Weights/biases are flat per-conv-level lists, index-aligned with
    ``program.levels``.  With ``stream_weights`` the weights stay in HBM
    (memory space ANY) and each level's tensor is DMA'd into one of
    ``w_slots`` shared VMEM scratch slots — double-buffered (prefetch
    overlapping compute) when ``w_slots == 2`` — the fallback when the
    fully-resident working set busts the VMEM budget (see
    ``TileProgram.vmem_stream_bytes``).  ``weights_flat`` supplies the
    pre-flattened concatenated weights (see
    :func:`repro.kernels.fused_conv.ops.flatten_weights`) so plan-driven
    callers don't re-concatenate per step; streamed callers holding only the
    flat form may pass ``weights=None``.  ``interpret=None`` auto-resolves
    to compiled on TPU, interpreted elsewhere.

    With ``c_tiles > 1`` the launch runs the channel-tiled grid
    ``(B, alpha, alpha, c_tiles)``: a fourth sequential axis over
    ``Cout / c_tiles`` output-channel tiles of the last level, the mid
    pyramid computed once per cell at ``k == 0`` into persistent VMEM
    scratch, and (when streamed) per-``k`` weight-slice DMAs revolving
    through ``w_slots`` scratch slots — the regime that restores DMA/MXU
    overlap to ``alpha == 1`` launches (see ``_ktiled_kernel``).
    ``c_tiles`` must divide the last level's ``Cout``; output and skip
    shapes are unchanged, and the result is bit-identical to ``c_tiles=1``.

    All operands must arrive in ``program.compute_dtype`` (DESIGN.md §11):
    halo tiles, weight slices, inter-level tiles, and the output all move at
    that width — matching the byte model byte for byte — while every conv
    accumulates in f32 (``preferred_element_type``) and casts once after the
    level epilogue.  The int32 skip map is dtype-invariant.

    Returns ``(out, skip)`` with ``skip`` shaped ``(B, alpha, alpha, Q)`` —
    ``skip[..., l] == 1`` where level ``l``'s conv was short-circuited by the
    END cascade (level 0 never skips).
    """
    B = x_padded.shape[0]
    q = program.q_convs
    if program.compute_dtype not in EXEC_DTYPES:
        raise NotImplementedError(
            f"compute dtype {program.compute_dtype!r} is modeled but not"
            f" executable; the kernels run {EXEC_DTYPES}"
        )
    cdt = jnp_dtype(program.compute_dtype)
    assert x_padded.dtype == cdt, (
        f"x_padded dtype {x_padded.dtype} != program compute dtype {cdt}"
    )
    assert all(b.dtype == cdt for b in biases), (
        f"bias dtypes must match the program compute dtype {cdt}"
    )
    assert weights is None or all(w.dtype == cdt for w in weights), (
        f"weight dtypes must match the program compute dtype {cdt}"
    )
    assert weights_flat is None or weights_flat.dtype == cdt, (
        f"weights_flat dtype {weights_flat.dtype} != compute dtype {cdt}"
    )
    assert x_slots in (1, 2), "x_slots: 1 (serial) or 2 (revolving pipeline)"
    assert len(biases) == q, "one bias per conv level"
    if not stream_weights and weights_flat is not None:
        raise ValueError(
            "weights_flat was passed with stream_weights=False: the resident"
            " kernel reads per-level weight tensors and would silently"
            " ignore it — pass stream_weights=True (or drop weights_flat)"
        )
    if weights is None:
        assert stream_weights and weights_flat is not None, (
            "weights=None requires stream_weights=True and weights_flat"
        )
    elif weights_flat is None:
        assert len(weights) == q, "one weight tensor per conv level"
    if weights_flat is not None:
        assert weights_flat.size == sum(program.level_weight_counts()), (
            "weights_flat does not match the program's level weight counts"
        )
    assert c_tiles >= 1 and program.levels[-1].n_out % c_tiles == 0, (
        f"c_tiles {c_tiles} must divide the last level's Cout"
        f" {program.levels[-1].n_out}"
    )
    assert c_tiles == 1 or program.levels[-1].n_out // c_tiles >= 2, (
        "channel slices must keep >= 2 channels: the degenerate one-column"
        " dot reassociates the Cin contraction (see"
        " TileProgram.c_tile_options) and would break bitwise parity"
    )
    assert x_padded.shape[1] == x_padded.shape[2] == program.padded_input, (
        "x_padded spatial dims must equal the program's padded input"
    )
    if c_tiles > 1:
        return _launch_ktiled(
            x_padded,
            weights,
            biases,
            program=program,
            relu=relu,
            end_skip=end_skip,
            interpret=interpret,
            stream_weights=stream_weights,
            w_slots=w_slots,
            x_slots=x_slots,
            c_tiles=c_tiles,
            weights_flat=weights_flat,
        )
    c0 = program.levels[0].n_in
    alpha, out_region = program.alpha, program.out_region
    m_out = program.n_out
    kernel = functools.partial(
        _pyramid_kernel,
        progs=program.levels,
        tile0=program.tile0,
        stride0=program.stride0,
        alpha=alpha,
        relu=relu,
        end_skip=end_skip,
        stream=stream_weights,
        w_slots=w_slots,
        x_slots=x_slots,
        cnts=program.level_weight_counts(),
        out_dtype=cdt,
    )
    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY)]
    operands: list[jnp.ndarray] = [x_padded]
    scratch_shapes: list = [
        pltpu.VMEM((x_slots, program.tile0, program.tile0, c0), cdt),
        pltpu.SemaphoreType.DMA((x_slots,)),
    ]
    if stream_weights:
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
        if weights_flat is None:
            weights_flat = jnp.concatenate([w.reshape(-1) for w in weights])
        operands.append(weights_flat)
        for bias in biases:
            in_specs.append(pl.BlockSpec(bias.shape, lambda b, i, j: (0,)))
            operands.append(bias)
        scratch_shapes += [
            pltpu.VMEM((w_slots, max(program.level_weight_counts())), cdt),
            pltpu.SemaphoreType.DMA((w_slots,)),
        ]
    else:
        for w, bias in zip(weights, biases):
            in_specs.append(pl.BlockSpec(w.shape, lambda b, i, j: (0,) * 4))
            in_specs.append(pl.BlockSpec(bias.shape, lambda b, i, j: (0,)))
            operands += [w, bias]
    out, skip = pl.pallas_call(
        kernel,
        grid=(B, alpha, alpha),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec(
                (1, out_region, out_region, m_out), lambda b, i, j: (b, i, j, 0)
            ),
            pl.BlockSpec((1, 1, 1, q), lambda b, i, j: (b, i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(
                (B, alpha * out_region, alpha * out_region, m_out), cdt
            ),
            jax.ShapeDtypeStruct((B, alpha, alpha, q), jnp.int32),
        ],
        scratch_shapes=scratch_shapes,
        # the batch axis is embarrassingly parallel: every cross-cell chain
        # (input prefetch) is confined to one batch element, so the compiler
        # may partition dim 0 across cores; the movement grid dims stay
        # sequential (the revolving landing buffer is carried cell to cell)
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")
        ),
        interpret=resolve_interpret(interpret),
    )(*operands)
    return out, skip


def _launch_ktiled(
    x_padded: jnp.ndarray,
    weights: list[jnp.ndarray] | None,
    biases: list[jnp.ndarray],
    *,
    program: TileProgram,
    relu: bool,
    end_skip: bool,
    interpret: bool | None,
    stream_weights: bool,
    w_slots: int,
    x_slots: int,
    c_tiles: int,
    weights_flat: jnp.ndarray | None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Launch the channel-tiled ``(B, alpha, alpha, c_tiles)`` grid.

    Streamed launches keep the flat concatenated weight array for the mid
    levels (its last-level tail is simply never read) and additionally need
    the last level's tensor in its natural 4D shape for the strided per-k
    slice DMA — taken from ``weights`` when available, else sliced and
    reshaped out of ``weights_flat`` (a one-off device-side copy per call,
    tiny next to the per-cell streamed traffic)."""
    B = x_padded.shape[0]
    q = program.q_convs
    cnts = program.level_weight_counts()
    last = program.levels[-1]
    ct_out = last.n_out // c_tiles
    c0 = program.levels[0].n_in
    alpha, out_region = program.alpha, program.out_region
    m_out = program.n_out
    cdt = jnp_dtype(program.compute_dtype)
    kernel = functools.partial(
        _ktiled_kernel,
        progs=program.levels,
        tile0=program.tile0,
        stride0=program.stride0,
        alpha=alpha,
        relu=relu,
        end_skip=end_skip,
        stream=stream_weights,
        w_slots=w_slots,
        x_slots=x_slots,
        c_tiles=c_tiles,
        cnts=cnts,
        out_dtype=cdt,
    )
    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY)]
    operands: list[jnp.ndarray] = [x_padded]
    scratch_shapes: list = [
        pltpu.VMEM((x_slots, program.tile0, program.tile0, c0), cdt),
        pltpu.SemaphoreType.DMA((x_slots,)),
    ]
    if q > 1:
        scratch_shapes.append(
            pltpu.VMEM((last.in_size, last.in_size, last.n_in), cdt)
        )
    if stream_weights:
        if weights_flat is None:
            weights_flat = jnp.concatenate([w.reshape(-1) for w in weights])
        if weights is not None:
            w_last = weights[-1]
        else:
            w_last = jax.lax.dynamic_slice_in_dim(
                weights_flat, sum(cnts[:-1]), cnts[-1], 0
            ).reshape(last.K, last.K, last.n_in, last.n_out)
        in_specs += [
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ]
        operands += [weights_flat, w_last]
        for bias in biases:
            in_specs.append(pl.BlockSpec(bias.shape, lambda b, i, j, k: (0,)))
            operands.append(bias)
        if q > 1:
            scratch_shapes += [
                pltpu.VMEM((1, max(cnts[:-1])), cdt),
                pltpu.SemaphoreType.DMA(()),
            ]
        scratch_shapes += [
            pltpu.VMEM((w_slots, last.K, last.K, last.n_in, ct_out), cdt),
            pltpu.SemaphoreType.DMA((w_slots,)),
        ]
    else:
        for w, bias in zip(weights, biases):
            in_specs.append(
                pl.BlockSpec(w.shape, lambda b, i, j, k: (0,) * 4)
            )
            in_specs.append(pl.BlockSpec(bias.shape, lambda b, i, j, k: (0,)))
            operands += [w, bias]
    out, skip = pl.pallas_call(
        kernel,
        grid=(B, alpha, alpha, c_tiles),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec(
                (1, out_region, out_region, ct_out),
                lambda b, i, j, k: (b, i, j, k),
            ),
            pl.BlockSpec((1, 1, 1, q), lambda b, i, j, k: (b, i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(
                (B, alpha * out_region, alpha * out_region, m_out), cdt
            ),
            jax.ShapeDtypeStruct((B, alpha, alpha, q), jnp.int32),
        ],
        scratch_shapes=scratch_shapes,
        # batch stays embarrassingly parallel; the movement grid AND the
        # channel axis are sequential — mid_scratch is carried k to k, and
        # the revolving landing/slice buffers are carried cell to cell
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=(
                "parallel", "arbitrary", "arbitrary", "arbitrary",
            )
        ),
        interpret=resolve_interpret(interpret),
    )(*operands)
    return out, skip
