"""Pallas TPU kernel: USEFUSE fusion pyramid (conv+ReLU[+pool] x2) in VMEM.

The paper's fused-layer dataflow, adapted to the TPU memory hierarchy
(DESIGN.md §2): one grid cell computes one fusion-pyramid tile end to end —
the level-1 intermediate never leaves VMEM (the TPU analogue of "no off-chip
intermediate traffic").  The grid is the uniform-stride tile plan: the
``alpha x alpha`` movement grid with identical movement counts at every level
is exactly Algorithm 4's uniform stride, realized as a Pallas grid.

Per grid cell (b, i, j):
  * the image block (whole padded image of batch b) is VMEM-resident; the
    level-0 tile is cut with dynamic slices at ``i*stride0`` (tile stride S^T
    from the plan);
  * conv levels run as K*K unrolled strided-slice + MXU dot-general
    (``(P, Cin) @ (Cin, Cout)``) accumulations — the WPU array of Fig. 5 maps
    onto MXU tiles;
  * inner-layer padding is realized by *validity masking*: rows whose global
    coordinate falls outside a level's valid output range are zeroed — zeros
    are exactly the next level's pad value, and post-ReLU zeros are neutral
    for maxpool (the executor's crop logic, branch-free for SIMD);
  * END tile-skip (the paper's §3.2 insight at TPU-feasible granularity):
    when the entire level-1 post-ReLU tile is zero, ``@pl.when`` skips the
    level-2 convolution and emits its closed form ``pool(relu(b2))``; a skip
    flag per tile is emitted for the energy/cycle statistics.

Weights live whole in VMEM ("filters are loaded into the kernel buffers only
once", §3.3.1).  VMEM budget: image block (<=227^2*3*4B = 618 KiB) + weights
(AlexNet fused: <=2.5 MiB) + tiles -- < 4 MiB, comfortably inside 16 MiB/core
(v5e); asserted in ops.py.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@dataclass(frozen=True)
class ConvLevelProg:
    """Static per-conv-level program (offsets are affine in the tile index)."""

    K: int
    S: int
    in_size: int  # tile spatial size entering this level
    out_size: int  # tile spatial size leaving the conv
    o_base: int  # global output coord of tile row 0 at tile index 0
    o_step: int  # global output coord step per tile index
    valid: int  # level's valid output extent (mask range)
    pool: tuple[int, int] | None  # (K, S) of trailing pool, if any
    pool_out: int  # tile spatial size after pool (== out_size if no pool)
    # pool-output masking (pool windows straddling the valid boundary mix
    # real data into rows the next level expects to be padding)
    pool_o_base: int = 0
    pool_o_step: int = 0
    pool_valid: int = 0


def _conv_tile(x, w, b, K: int, S: int, out: int):
    """Valid conv on a (h, w, Cin) tile via K*K strided-slice MXU dots."""
    cin, cout = w.shape[2], w.shape[3]
    acc = jnp.zeros((out * out, cout), jnp.float32)
    hi = (out - 1) * S + 1
    for ki in range(K):
        for kj in range(K):
            patch = x[ki : ki + hi : S, kj : kj + hi : S, :]
            acc = acc + jnp.dot(
                patch.reshape(out * out, cin),
                w[ki, kj],
                preferred_element_type=jnp.float32,
            )
    return acc.reshape(out, out, cout) + b


def _pool_tile(x, K: int, S: int):
    out = (x.shape[0] - K) // S + 1
    hi = (out - 1) * S + 1
    r = None
    for pi in range(K):
        for pj in range(K):
            v = x[pi : pi + hi : S, pj : pj + hi : S, :]
            r = v if r is None else jnp.maximum(r, v)
    return r


def _mask(t, idx, o_base: int, o_step: int, valid: int):
    """Zero rows/cols whose global coordinate is outside [0, valid)."""
    g0 = o_base + idx[0] * o_step
    g1 = o_base + idx[1] * o_step
    rows = jnp.arange(t.shape[0])
    cols = jnp.arange(t.shape[1])
    mrow = (rows + g0 >= 0) & (rows + g0 < valid)
    mcol = (cols + g1 >= 0) & (cols + g1 < valid)
    return t * (mrow[:, None, None] & mcol[None, :, None])


def _level_epilogue(t, idx, prog: ConvLevelProg):
    """Mask conv output to its valid range, pool, mask the pool output."""
    t = _mask(t, idx, prog.o_base, prog.o_step, prog.valid)
    if prog.pool is not None:
        t = _pool_tile(t, *prog.pool)
        t = _mask(t, idx, prog.pool_o_base, prog.pool_o_step, prog.pool_valid)
    return t


def _fused2_kernel(
    x_ref,
    w1_ref,
    b1_ref,
    w2_ref,
    b2_ref,
    out_ref,
    skip_ref,
    *,
    p1: ConvLevelProg,
    p2: ConvLevelProg,
    tile0: int,
    stride0: int,
    relu: bool,
    end_skip: bool,
):
    i = pl.program_id(1)
    j = pl.program_id(2)
    idx = (i, j)

    # ---- level-0 tile from the VMEM-resident image block ----
    x = x_ref[0, pl.ds(i * stride0, tile0), pl.ds(j * stride0, tile0), :]

    # ---- level 1: conv + ReLU (+ pool), masked to valid range ----
    t1 = _conv_tile(x, w1_ref[...], b1_ref[...], p1.K, p1.S, p1.out_size)
    if relu:
        t1 = jnp.maximum(t1, 0.0)
    t1 = _level_epilogue(t1, idx, p1)

    def level2(t1_in):
        t2 = _conv_tile(t1_in, w2_ref[...], b2_ref[...], p2.K, p2.S, p2.out_size)
        if relu:
            t2 = jnp.maximum(t2, 0.0)
        return _level_epilogue(t2, idx, p2)

    if end_skip and relu:
        # END at tile granularity: an all-zero post-ReLU level-1 tile makes
        # conv2's output the closed form relu(b2) everywhere (then pooled) —
        # @pl.when skips the K^2 MXU pass entirely on the dead branch.
        live = jnp.max(t1) > 0.0
        skip_ref[0, 0, 0] = jnp.where(live, 0, 1).astype(jnp.int32)

        @pl.when(live)
        def _compute():
            out_ref[0, :, :, :] = level2(t1)

        @pl.when(jnp.logical_not(live))
        def _skip():
            const = jnp.maximum(b2_ref[...], 0.0)
            const_tile = _level_epilogue(
                jnp.broadcast_to(
                    const, (p2.out_size, p2.out_size, const.shape[-1])
                ),
                idx,
                p2,
            )
            out_ref[0, :, :, :] = const_tile
    else:
        skip_ref[0, 0, 0] = jnp.int32(0)
        out_ref[0, :, :, :] = level2(t1)


def fused_conv2_pallas(
    x_padded: jnp.ndarray,  # (B, Hp, Wp, C) pre-padded input
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
    *,
    p1: ConvLevelProg,
    p2: ConvLevelProg,
    tile0: int,
    stride0: int,
    alpha: int,
    out_region: int,
    relu: bool = True,
    end_skip: bool = True,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Launch the fused 2-conv pyramid over the (B, alpha, alpha) grid."""
    B, Hp, Wp, C = x_padded.shape
    m2 = w2.shape[-1]
    kernel = functools.partial(
        _fused2_kernel,
        p1=p1,
        p2=p2,
        tile0=tile0,
        stride0=stride0,
        relu=relu,
        end_skip=end_skip,
    )
    out, skip = pl.pallas_call(
        kernel,
        grid=(B, alpha, alpha),
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, C), lambda b, i, j: (b, 0, 0, 0)),
            pl.BlockSpec(w1.shape, lambda b, i, j: (0,) * 4),
            pl.BlockSpec(b1.shape, lambda b, i, j: (0,)),
            pl.BlockSpec(w2.shape, lambda b, i, j: (0,) * 4),
            pl.BlockSpec(b2.shape, lambda b, i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec(
                (1, out_region, out_region, m2), lambda b, i, j: (b, i, j, 0)
            ),
            pl.BlockSpec((1, 1, 1), lambda b, i, j: (b, i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(
                (B, alpha * out_region, alpha * out_region, m2), jnp.float32
            ),
            jax.ShapeDtypeStruct((B, alpha, alpha), jnp.int32),
        ],
        interpret=interpret,
    )(x_padded, w1, b1, w2, b2)
    return out, skip
