"""Public wrapper for the fused conv-pyramid Pallas kernel.

Compiles a :class:`~repro.core.fusion.FusionSpec` (exactly two conv levels,
each with an optional trailing pool) into the kernel's static program:

* tile sizes / window offsets from :func:`receptive_window` (Eq. (1));
* the uniform tile grid: ``alpha`` movements of stride ``S^T`` per dim —
  Algorithm 4 realized as the Pallas grid (requires the final output to be
  exactly tiled by ``out_region``; callers pick a region from the planner);
* input pre-padding that folds the level-0 conv pad plus any halo the
  Eq. (1) chain demands at the borders.

Deeper pyramids (e.g. VGG's Q=4 block) chain 2-conv kernel calls — the
fusion granularity USEFUSE itself deploys on its FPGA (§4.4 fuses Q=2).

A VMEM-budget assert mirrors the paper's "H <= IFM" feasibility bound with
the TPU's real constraint (DESIGN.md §2 assumption change #2).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.fusion import FusionSpec, receptive_window
from .fused_conv import ConvLevelProg, fused_conv2_pallas

VMEM_BUDGET_BYTES = 16 * 1024 * 1024  # v5e per-core VMEM


def _build_programs(spec: FusionSpec, out_region: int):
    """Static kernel program from the fusion spec + chosen output region."""
    levels = spec.levels
    convs = [l for l, lvl in enumerate(levels) if lvl.kind == "conv"]
    assert len(convs) == 2, "kernel fuses exactly 2 conv levels"
    sizes = spec.feature_sizes()
    out_size = sizes[-1]
    assert out_size % out_region == 0, (
        f"out_region {out_region} must tile the {out_size} output exactly"
    )
    alpha = out_size // out_region

    wins0 = [w for w, _ in zip(receptive_window(spec, 0, out_region), levels)]
    wins1 = receptive_window(spec, out_region, out_region)
    win_sizes = [w[1] for w in receptive_window(spec, 0, out_region)]

    progs = []
    for ci, l in enumerate(convs):
        lvl = levels[l]
        in_size = win_sizes[l]
        out_sz = (in_size - lvl.K) // lvl.S + 1
        o_base = wins0[l][0] // lvl.S  # output coord of tile row 0, tile 0
        o_step = (wins1[l][0] - wins0[l][0]) // lvl.S
        pool = None
        pool_out = out_sz
        pool_ob = pool_os = pool_valid = 0
        if l + 1 < len(levels) and levels[l + 1].kind == "pool":
            pk, ps = levels[l + 1].K, levels[l + 1].S
            pool = (pk, ps)
            pool_out = (out_sz - pk) // ps + 1
            pool_ob = wins0[l + 1][0] // ps
            pool_os = (wins1[l + 1][0] - wins0[l + 1][0]) // ps
            pool_valid = sizes[l + 2]
        progs.append(
            ConvLevelProg(
                K=lvl.K,
                S=lvl.S,
                in_size=in_size,
                out_size=out_sz,
                o_base=o_base,
                o_step=o_step,
                valid=sizes[l + 1],
                pool=pool,
                pool_out=pool_out,
                pool_o_base=pool_ob,
                pool_o_step=pool_os,
                pool_valid=pool_valid,
            )
        )

    tile0 = win_sizes[0]
    lo0 = wins0[0][0] - levels[0].pad  # unpadded coords, typically negative
    stride0 = wins1[0][0] - wins0[0][0]
    # left pad so tile 0 starts at array index 0; right pad so the last tile fits
    pad_lo = -lo0
    last_end = lo0 + (alpha - 1) * stride0 + tile0
    pad_hi = max(0, last_end - spec.input_size)
    return progs, tile0, stride0, alpha, pad_lo, pad_hi


def fused_pyramid_chain(
    x: jnp.ndarray,
    weights: list,
    biases: list,
    *,
    spec: FusionSpec,
    out_regions: list[int] | None = None,
    relu: bool = True,
    end_skip: bool = True,
    interpret: bool = True,
):
    """Q>2 fusion (the paper's §4 VGG Q=4 experiment): consecutive 2-conv
    chunks each run as one fused kernel; only chunk boundaries touch HBM —
    the deployment granularity USEFUSE itself uses on its FPGA (Q=2 per
    pyramid, pyramids chained).

    Returns (y, [skip maps per chunk]).
    """
    # split the level chain into chunks of 2 convs (+ their trailing pools)
    chunks: list[list] = [[]]
    convs_in_chunk = 0
    for lvl in spec.levels:
        if lvl.kind == "conv":
            if convs_in_chunk == 2:
                chunks.append([])
                convs_in_chunk = 0
            convs_in_chunk += 1
        chunks[-1].append(lvl)
    assert all(sum(l.kind == "conv" for l in ch) == 2 for ch in chunks), (
        "chain requires an even conv count; pad with identity or use the"
        " executor for odd Q"
    )
    y = x
    size = spec.input_size
    skips = []
    wi = 0
    for ci, ch in enumerate(chunks):
        sub = FusionSpec(levels=tuple(ch), input_size=size)
        region = (
            out_regions[ci]
            if out_regions is not None
            else sub.feature_sizes()[-1]
        )
        y, skip = fused_conv2(
            y, weights[wi], biases[wi], weights[wi + 1], biases[wi + 1],
            spec=sub, out_region=region, relu=relu, end_skip=end_skip,
            interpret=interpret,
        )
        skips.append(skip)
        size = sub.feature_sizes()[-1]
        wi += 2
    return y, skips


@partial(
    jax.jit,
    static_argnames=("spec", "out_region", "relu", "end_skip", "interpret"),
)
def fused_conv2(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
    *,
    spec: FusionSpec,
    out_region: int,
    relu: bool = True,
    end_skip: bool = True,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused 2-conv pyramid forward.  Returns (output map, skip map).

    ``x``: (B, H, W, C) NHWC; weights (K, K, Cin, Cout), biases (Cout,).
    ``skip``: (B, alpha, alpha) int32 — 1 where END tile-skip fired.
    """
    (p1, p2), tile0, stride0, alpha, pad_lo, pad_hi = _build_programs(
        spec, out_region
    )
    xp = jnp.pad(
        x.astype(jnp.float32),
        ((0, 0), (pad_lo, pad_hi), (pad_lo, pad_hi), (0, 0)),
    )
    vmem = (
        xp.shape[1] * xp.shape[2] * xp.shape[3]
        + w1.size + b1.size + w2.size + b2.size
        + tile0 * tile0 * xp.shape[3]
        + p1.out_size ** 2 * w1.shape[-1]
        + p2.out_size ** 2 * w2.shape[-1]
    ) * 4
    assert vmem < VMEM_BUDGET_BYTES, f"working set {vmem} exceeds VMEM"
    return fused_conv2_pallas(
        xp,
        w1.astype(jnp.float32),
        b1.astype(jnp.float32),
        w2.astype(jnp.float32),
        b2.astype(jnp.float32),
        p1=p1,
        p2=p2,
        tile0=tile0,
        stride0=stride0,
        alpha=alpha,
        out_region=out_region,
        relu=relu,
        end_skip=end_skip,
        interpret=interpret,
    )
