"""Public wrappers for the variadic fused conv-pyramid Pallas kernel.

All window/offset math comes from the tile-program compiler
(:mod:`repro.core.program`); this module only pads inputs, checks the VMEM
budget, and launches:

* :func:`fused_pyramid` — any Q >= 1 conv levels (odd Q and conv-only pairs
  included) as **one** kernel launch; LeNet's Q=2, VGG blocks 1-2's Q=4, and
  every ResNet-18 block each fit a single launch.
* :func:`fused_conv2` — thin compatibility wrapper for the historical 2-conv
  entry point (returns the old ``(B, alpha, alpha)`` skip map).
* :func:`fused_pyramid_chain` — chunks a chain into multiple launches *only*
  when the VMEM budget forces it (or an explicit per-chunk conv cap is given,
  e.g. to reproduce USEFUSE's FPGA deployment granularity of Q=2 per pyramid).

The VMEM-budget check mirrors the paper's "H <= IFM" feasibility bound with
the TPU's real constraint (DESIGN.md §2 assumption change #2).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.dtypes import canonical_dtype, jnp_dtype
from repro.core.fusion import FusionSpec
from repro.core.program import (
    VMEM_BUDGET_BYTES,
    compile_program,
    pick_out_region,
    plan_launch,
)
from .fused_conv import fused_pyramid_pallas


def flatten_weights(weights: list, dtype="float32") -> jnp.ndarray:
    """Concatenate per-level weight tensors into the flat compute-dtype
    array the streamed-weight kernel DMAs from.  Plan-driven callers (the
    network runner) call this once per model instead of once per launch;
    ``dtype`` must match the launch's compute dtype so each streamed byte is
    exactly as wide as the byte model charges."""
    dt = jnp_dtype(dtype)
    return jnp.concatenate([jnp.asarray(w, dt).reshape(-1) for w in weights])


@partial(
    jax.jit,
    static_argnames=(
        "spec", "out_region", "streamed", "w_slots", "x_slots", "c_tiles",
        "relu", "end_skip", "interpret", "vmem_budget", "compute_dtype",
    ),
)
def fused_pyramid(
    x: jnp.ndarray,
    weights: list | None,
    biases: list,
    *,
    spec: FusionSpec,
    out_region: int | None = None,
    streamed: bool | None = None,
    w_slots: int | None = None,
    x_slots: int | None = None,
    c_tiles: int | None = None,
    relu: bool = True,
    end_skip: bool = True,
    interpret: bool | None = None,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    weights_flat: jnp.ndarray | None = None,
    compute_dtype: str = "float32",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused Q-conv pyramid forward as a single kernel launch.

    ``x``: (B, H, W, C) NHWC; ``weights[l]``: (K, K, Cin, Cout) and
    ``biases[l]``: (Cout,) per conv level, in chain order.  ``out_region``
    must tile the final output exactly; ``None`` picks the largest region
    fitting the VMEM budget.  ``streamed`` / ``w_slots`` / ``x_slots`` /
    ``c_tiles`` pin the weight regime, the input landing-buffer depth, and
    the last level's output-channel tile count (the plan-driven entry used
    by :mod:`repro.net.runner`, whose
    :class:`~repro.core.program.LaunchPlan` already decided them); ``None``
    derives them from the budget along ``plan_launch``'s ladder
    (double-buffered weight streaming preferred over channel-tiled double
    buffering over the blocking single slot; the revolving cross-cell input
    prefetch preferred over the serial fetch whenever the grid has a
    successor cell and the extra landing slot fits).  ``weights_flat``
    optionally supplies the pre-flattened streamed weights
    (:func:`flatten_weights`) to keep the concatenation out of the per-call
    path — streamed callers holding only the flat form may pass
    ``weights=None`` (its dtype must match ``compute_dtype``).
    ``compute_dtype`` (name string or jnp dtype; static) selects the value
    width of every tile/weight moved by the launch — activations and weights
    are cast on entry, accumulation stays f32 inside the kernel (DESIGN.md
    §11) — and re-tiers the regime ladder, since halved bytes let plans that
    streamed at f32 go resident or double-buffered at bf16.
    ``interpret=None`` resolves to compiled on TPU, interpreted on CPU/GPU.
    Returns ``(out, skip)`` with ``skip``: (B, alpha, alpha, Q) int32
    END-cascade flags (level 0 never skips, and skip flags are
    dtype-invariant).
    """
    compute_dtype = canonical_dtype(compute_dtype)
    cdt = jnp_dtype(compute_dtype)
    if out_region is None:
        lp = plan_launch(
            spec, vmem_budget=vmem_budget, compute_dtype=compute_dtype
        )
        if lp is None:
            from repro.robust.errors import BudgetError

            raise BudgetError(
                "no output region fits VMEM; chunk via fused_pyramid_chain",
                vmem_budget=vmem_budget,
            )
        out_region = lp.out_region
        if streamed is None:
            streamed = lp.streamed
            if w_slots is None:
                w_slots = lp.w_slots
                if c_tiles is None:
                    c_tiles = lp.c_tiles
        if x_slots is None:
            x_slots = lp.x_slots
    prog = compile_program(spec, out_region, compute_dtype=compute_dtype)
    # a caller-pinned x_slots=2 charges the extra landing slot to every
    # regime, including the resident-vs-streamed decision itself
    xs_pinned = x_slots if x_slots is not None else 1
    stream = (
        prog.vmem_bytes(xs_pinned) > vmem_budget
        if streamed is None
        else streamed
    )
    if stream and (w_slots is None or c_tiles is None):
        # resolve the open knobs along plan_launch's rung order, accounting
        # for already-pinned x_slots / w_slots / c_tiles so the derived
        # combo is jointly feasible (e.g. a pinned w_slots=2 that busts
        # untiled adopts the smallest feasible channel tiling; w_slots=1 +
        # pipelined input may fit where w_slots=2 + pipelined busts)
        w_slots, c_tiles = prog.resolve_stream_regime(
            vmem_budget, xs_pinned, w_slots, c_tiles
        )
    if not stream:
        w_slots = 1  # unused by the resident kernel; pin for the jit key
    if c_tiles is None:
        c_tiles = 1  # channel tiling is opt-in outside the streamed ladder
    if x_slots is None:
        if prog.alpha == 1:
            x_slots = 1  # no successor cell: nothing to prefetch
        elif stream:
            x_slots = (
                2
                if prog.vmem_stream_bytes(w_slots, 2, c_tiles) <= vmem_budget
                else 1
            )
        else:
            x_slots = 2 if prog.vmem_bytes(2, c_tiles) <= vmem_budget else 1
    vmem = (
        prog.vmem_stream_bytes(w_slots, x_slots, c_tiles)
        if stream
        else prog.vmem_bytes(x_slots, c_tiles)
    )
    if vmem > vmem_budget:
        from repro.robust.errors import BudgetError

        raise BudgetError(
            f"working set {vmem} exceeds VMEM"
            + ("" if stream else "; retry with streamed weights or")
            + " chunk via fused_pyramid_chain",
            vmem_bytes=vmem, vmem_budget=vmem_budget,
        )
    xp = jnp.pad(
        x.astype(cdt),
        ((0, 0), (prog.pad_lo, prog.pad_hi), (prog.pad_lo, prog.pad_hi), (0, 0)),
    )
    return fused_pyramid_pallas(
        xp,
        None if weights is None else [w.astype(cdt) for w in weights],
        [b.astype(cdt) for b in biases],
        program=prog,
        relu=relu,
        end_skip=end_skip,
        interpret=interpret,
        stream_weights=stream,
        w_slots=w_slots,
        x_slots=x_slots,
        c_tiles=c_tiles,
        weights_flat=weights_flat,
    )


def fused_conv2(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
    *,
    spec: FusionSpec,
    out_region: int,
    relu: bool = True,
    end_skip: bool = True,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused 2-conv pyramid forward — compatibility wrapper.

    Returns (output map, skip map) with ``skip``: (B, alpha, alpha) int32 —
    1 where the END cascade skipped the second conv (the historical
    2-level-kernel semantics; new code should call :func:`fused_pyramid`).
    """
    out, skip = fused_pyramid(
        x,
        [w1, w2],
        [b1, b2],
        spec=spec,
        out_region=out_region,
        relu=relu,
        end_skip=end_skip,
        interpret=interpret,
    )
    return out, skip[..., 1]


def conv_groups(spec: FusionSpec) -> list[list]:
    """Split the level chain into [conv + trailing pools] groups — the
    indivisible units of chunking (a pool executes as its conv's epilogue)."""
    if not (spec.levels and spec.levels[0].kind == "conv"):
        from repro.robust.errors import PreflightError

        raise PreflightError(
            "chain must start with a conv level",
            levels=[lvl.kind for lvl in spec.levels],
        )
    groups: list[list] = []
    for lvl in spec.levels:
        if lvl.kind == "conv":
            groups.append([lvl])
        else:
            groups[-1].append(lvl)
    return groups


def plan_chunks(
    spec: FusionSpec,
    *,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    max_convs_per_chunk: int | None = None,
    compute_dtype: str = "float32",
) -> list[FusionSpec]:
    """Greedy chunking: grow each chunk conv-group by conv-group until the
    VMEM budget (or an explicit conv cap) forces a split.

    A chain that fits the budget returns a single chunk — one kernel launch,
    no intermediate HBM round-trip.  Odd conv counts are fine: a remainder
    simply becomes a final Q=1/Q=3 chunk.  Feasibility is dtype-aware: a
    bf16 chain's halved working set can merge chunks an f32 chain must
    split.  Raises ``ValueError`` when even a lone conv group cannot fit the
    budget (chunking cannot help: a group is the indivisible launch unit).
    """
    groups = conv_groups(spec)
    chunks: list[FusionSpec] = []
    size = spec.input_size

    def fits(levels: list) -> bool:
        sub = FusionSpec(levels=tuple(levels), input_size=size)
        return (
            pick_out_region(
                sub, vmem_budget=vmem_budget, compute_dtype=compute_dtype
            )
            is not None
        )

    cur: list = []
    for g in groups:
        if cur:
            convs = sum(l.kind == "conv" for l in cur)
            capped = max_convs_per_chunk is not None and convs >= max_convs_per_chunk
            if capped or not fits(cur + g):
                chunks.append(FusionSpec(levels=tuple(cur), input_size=size))
                size = chunks[-1].feature_sizes()[-1]
                cur = []
        if not cur and not fits(g):
            name = g[0].name or f"conv K={g[0].K} {g[0].n_in}->{g[0].n_out}"
            from repro.robust.errors import BudgetError

            raise BudgetError(
                f"conv group [{name}] does not fit the {vmem_budget}-byte"
                " VMEM budget even alone (streamed); chunking cannot help",
                node=g[0].name, vmem_budget=vmem_budget,
            )
        cur = cur + g
    chunks.append(FusionSpec(levels=tuple(cur), input_size=size))
    return chunks


def fused_pyramid_chain(
    x: jnp.ndarray,
    weights: list,
    biases: list,
    *,
    spec: FusionSpec,
    out_regions: list[int] | None = None,
    relu: bool = True,
    end_skip: bool = True,
    interpret: bool | None = None,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    max_convs_per_chunk: int | None = None,
    compute_dtype: str = "float32",
):
    """Execute a fusion chain in as few kernel launches as VMEM allows.

    With the variadic kernel a chain that fits the budget runs as **one**
    launch (the paper's §4 VGG Q=4 experiment no longer round-trips the
    level-2 feature map through HBM); larger chains split at conv-group
    boundaries, and only those chunk boundaries touch HBM.  Pass
    ``max_convs_per_chunk=2`` to reproduce the historical 2+2 chained path
    (USEFUSE's own FPGA granularity, §4.4).

    Returns ``(y, skips)`` — ``skips[c]`` is chunk ``c``'s (B, alpha, alpha,
    Q_c) END-cascade flag map.
    """
    chunks = plan_chunks(
        spec,
        vmem_budget=vmem_budget,
        max_convs_per_chunk=max_convs_per_chunk,
        compute_dtype=compute_dtype,
    )
    if out_regions is not None and len(out_regions) != len(chunks):
        from repro.robust.errors import PreflightError

        raise PreflightError(
            f"{len(out_regions)} out_regions for {len(chunks)} chunks",
            out_regions=list(out_regions), chunks=len(chunks),
        )
    y = x
    skips = []
    wi = 0
    for ci, sub in enumerate(chunks):
        q = sub.q_convs
        y, skip = fused_pyramid(
            y,
            list(weights[wi : wi + q]),
            list(biases[wi : wi + q]),
            spec=sub,
            out_region=out_regions[ci] if out_regions is not None else None,
            relu=relu,
            end_skip=end_skip,
            interpret=interpret,
            vmem_budget=vmem_budget,
            compute_dtype=compute_dtype,
        )
        skips.append(skip)
        wi += q
    return y, skips
