"""Pure-jnp oracle for the fused 2-conv pyramid kernel: the monolithic
layer-by-layer execution from :mod:`repro.core.executor`."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.executor import PyramidParams, reference_forward
from repro.core.fusion import FusionSpec


def fused_conv2_ref(
    x: jnp.ndarray,
    spec: FusionSpec,
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
    *,
    relu: bool = True,
) -> jnp.ndarray:
    params = PyramidParams(weights=[w1, w2], biases=[b1, b2])
    return reference_forward(x, spec, params, relu=relu)
