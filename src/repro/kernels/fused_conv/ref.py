"""Pure-jnp oracles for the fused pyramid kernel: the monolithic
layer-by-layer execution from :mod:`repro.core.executor`."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.executor import PyramidParams, reference_forward
from repro.core.fusion import FusionSpec


def fused_pyramid_ref(
    x: jnp.ndarray,
    spec: FusionSpec,
    weights: list,
    biases: list,
    *,
    relu: bool = True,
) -> jnp.ndarray:
    """Oracle for :func:`~repro.kernels.fused_conv.ops.fused_pyramid`."""
    params = PyramidParams(weights=list(weights), biases=list(biases))
    return reference_forward(x, spec, params, relu=relu)


def fused_conv2_ref(
    x: jnp.ndarray,
    spec: FusionSpec,
    w1: jnp.ndarray,
    b1: jnp.ndarray,
    w2: jnp.ndarray,
    b2: jnp.ndarray,
    *,
    relu: bool = True,
) -> jnp.ndarray:
    return fused_pyramid_ref(x, spec, [w1, w2], [b1, b2], relu=relu)
