"""Pallas TPU kernel: digit-serial MSDF sum-of-products with END.

The window-processing unit (WPU, paper §3.1.1/§3.2) as a TPU kernel: each
grid cell holds a (BLOCK_P, m) tile of SOP problems in VMEM and runs the
digit-serial recurrence over ``n_digits`` cycles with a ``fori_loop``:

  * SD radix-2 digit generation for every serial operand (the residual
    recurrence of Algorithm 1's serial side, vectorized across the tile);
  * MSDF prefix accumulation of the SOP: ``P_j = P_{j-1} + 2**-j (d_j . y)``;
  * END (Algorithm 2): latch the first cycle where the prefix is provably
    negative, ``P_j <= -2**-j * sum|y|``.

TPU adaptation notes (DESIGN.md §2): lanes cannot retire early on a TPU, so
END here *records* the termination cycle per problem (the quantity the
paper's energy/cycle results are built from) rather than gating the loop; the
block-granular compute skip lives in the fused_conv kernel.  The digit loop
maps to VPU element-ops on (BLOCK_P, m) tiles resident in VMEM; the final
full-precision SOP uses one MXU dot per tile.

BLOCK_P is sized so the working set (x tile, residuals, prefix, y) fits VMEM:
(BLOCK_P=256, m<=1024) * 4 B * ~4 arrays ≈ 4 MiB < 16 MiB/core (v5e).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_P = 256


def _sop_end_kernel(x_ref, y_ref, sop_ref, cyc_ref, det_ref, *, n_digits: int):
    x = x_ref[...]  # (BLOCK_P, m) serial operands, |x| < 1
    y = y_ref[...]  # (1, m) parallel operand (kernel weights)
    tail_scale = jnp.sum(jnp.abs(y))

    def cycle(j, carry):
        w, prefix, det, cyc = carry
        # --- SD radix-2 digit generation (Algorithm 1 serial side) ---
        v = 2.0 * w
        d = jnp.where(v >= 0.5, 1.0, jnp.where(v <= -0.5, -1.0, 0.0))
        w = v - d
        # --- MSDF SOP prefix accumulation ---
        scale = 2.0 ** -(j + 1).astype(jnp.float32)
        prefix = prefix + scale * jnp.sum(d * y, axis=-1)
        # --- END (Algorithm 2): provably-negative latch ---
        hit = (prefix + scale * tail_scale <= 0.0) & (~det)
        cyc = jnp.where(hit, j + 1, cyc)
        det = det | hit
        return w, prefix, det, cyc

    w0 = x.astype(jnp.float32)
    p0 = jnp.zeros((x.shape[0],), jnp.float32)
    d0 = jnp.zeros((x.shape[0],), bool)
    c0 = jnp.full((x.shape[0],), n_digits, jnp.int32)
    _, _, det, cyc = jax.lax.fori_loop(0, n_digits, cycle, (w0, p0, d0, c0))

    # full-precision SOP on the MXU (the value a non-terminated WPU emits)
    sop_ref[...] = jnp.sum(x * y, axis=-1, keepdims=True)
    cyc_ref[...] = cyc[:, None]
    det_ref[...] = det[:, None].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("n_digits", "interpret"))
def online_sop_end_pallas(
    x: jnp.ndarray,
    y: jnp.ndarray,
    n_digits: int = 16,
    *,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(P, m), (m,) -> (sop (P,), term_cycle (P,), detected (P,)).

    P is padded to a BLOCK_P multiple; m rides whole in the lane dimension
    (pad to 128 in the caller for hardware-aligned MXU dots — ops.py does).
    """
    P, m = x.shape
    pad = (-P) % BLOCK_P
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    grid = (x.shape[0] // BLOCK_P,)
    kernel = functools.partial(_sop_end_kernel, n_digits=n_digits)
    sop, cyc, det = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_P, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BLOCK_P, 1), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_P, 1), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_P, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((x.shape[0], 1), jnp.float32),
            jax.ShapeDtypeStruct((x.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((x.shape[0], 1), jnp.int32),
        ],
        interpret=interpret,
    )(x, y[None, :].astype(jnp.float32))
    return sop[:P, 0], cyc[:P, 0], det[:P, 0].astype(bool)
