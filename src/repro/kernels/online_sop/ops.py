"""Jit'd public wrapper for the digit-serial SOP + END kernel.

Pads the reduction dimension to a lane multiple (128) for hardware-aligned
MXU dots, flattens arbitrary batch dims, and dispatches to the Pallas kernel
(interpret=True on CPU — the TPU target is compiled from the same kernel).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import resolve_interpret
from .online_sop import online_sop_end_pallas

LANE = 128


@partial(jax.jit, static_argnames=("n_digits", "interpret"))
def online_sop_end(
    x: jnp.ndarray,
    y: jnp.ndarray,
    n_digits: int = 16,
    *,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Digit-serial SOP + END over arbitrary batch dims.

    ``x``: (..., m) serial operands in (-1, 1); ``y``: (m,) parallel weights.
    ``interpret=None`` resolves to compiled on TPU, interpreted elsewhere.
    Returns (sop (...,), term_cycle (...,), detected (...,)).
    """
    interpret = resolve_interpret(interpret)
    batch_shape = x.shape[:-1]
    m = x.shape[-1]
    pad_m = (-m) % LANE
    xf = x.reshape(-1, m).astype(jnp.float32)
    yf = y.astype(jnp.float32)
    if pad_m:
        xf = jnp.pad(xf, ((0, 0), (0, pad_m)))
        yf = jnp.pad(yf, (0, pad_m))
    sop, cyc, det = online_sop_end_pallas(xf, yf, n_digits, interpret=interpret)
    return (
        sop.reshape(batch_shape),
        cyc.reshape(batch_shape),
        det.reshape(batch_shape),
    )
