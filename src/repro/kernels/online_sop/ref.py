"""Pure-jnp oracle for the digit-serial SOP + END kernel.

Semantics (see kernel docstring): inputs ``x`` (…, m) in (-1, 1) and parallel
weights ``y`` (m,); the WPU consumes one SD radix-2 digit of every ``x_i`` per
cycle (MSDF), accumulates the running SOP prefix, and terminates when the
prefix is provably negative:

    P_j + 2**-j * sum_i |y_i| <= 0

(the remaining digits can contribute at most ``2**-j * sum|y|``).  Outputs:
the full-precision SOP, the 1-based termination cycle (== T when it never
fires) and the detected flag.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.online_arith import to_digits


@partial(jax.jit, static_argnames=("n_digits",))
def online_sop_end_ref(
    x: jnp.ndarray, y: jnp.ndarray, n_digits: int = 16
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle: (sop, term_cycle, detected) for x: (..., m), y: (m,)."""
    digits = to_digits(x, n_digits)  # (..., m, T)
    weights = 2.0 ** -(jnp.arange(1, n_digits + 1, dtype=jnp.float32))
    # prefix_j of the SOP after digit j of every operand
    contrib = jnp.einsum("...mt,m->...t", digits * weights, y)
    prefixes = jnp.cumsum(contrib, axis=-1)  # (..., T)
    tail = weights * jnp.sum(jnp.abs(y))  # 2^-j * sum|y|
    provably_neg = prefixes + tail <= 0.0
    detected = jnp.any(provably_neg, axis=-1)
    term = jnp.argmax(provably_neg, axis=-1) + 1  # first firing cycle
    term = jnp.where(detected, term, n_digits)
    sop = x @ y
    return sop, term.astype(jnp.int32), detected
