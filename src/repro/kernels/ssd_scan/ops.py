"""Public wrapper for the SSD chunk-scan kernel.

Handles trailing-pad to a uniform chunk grid (causal: pad never leaks
backward) and exposes the same signature as the pure-JAX
:func:`repro.models.ssm.ssd_chunked`, so `mamba2_mixer` can swap
implementations (`use_pallas=True` on TPU; the pure-JAX path remains the
CPU/autodiff default).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import resolve_interpret
from .ssd_scan import ssd_scan_pallas


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, D, *, chunk: int = 64,
             interpret: bool | None = None):
    """SSD over (b,S,H,P); pads S to a chunk multiple internally.
    ``interpret=None`` resolves to compiled on TPU, interpreted elsewhere."""
    interpret = resolve_interpret(interpret)
    b, S, H, P = x.shape
    ch = min(chunk, S)
    pad = (-S) % ch
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y, state = ssd_scan_pallas(x, dt, A, B, C, D, chunk=ch, interpret=interpret)
    return y[:, :S], state
