"""Pure-jnp oracle for the SSD chunk-scan kernel: the token-by-token
state-space recurrence (independent of the chunked decomposition)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C, D):
    """Sequential SSM recurrence.

    x: (b,S,H,P); dt: (b,S,H) post-softplus; A: (H,) negative;
    B/C: (b,S,N); D: (H,).  Returns (y (b,S,H,P), final_state (b,H,P,N)).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]

    def step(state, t):
        xt, dtt, Bt, Ct = t
        dA = jnp.exp(dtt * A[None, :])  # (b,H)
        xb = xt * dtt[..., None]
        state = state * dA[..., None, None] + jnp.einsum("bhp,bn->bhpn", xb, Bt)
        y = jnp.einsum("bhpn,bn->bhp", state, Ct) + xt * D[None, :, None]
        return state, y

    s0 = jnp.zeros((b, H, P, N), jnp.float32)
    xs = (
        x.swapaxes(0, 1),
        dt.swapaxes(0, 1),
        B.swapaxes(0, 1),
        C.swapaxes(0, 1),
    )
    final, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1), final
