"""Pallas TPU kernel: Mamba-2 SSD chunk scan with VMEM-carried state.

The framework's USEFUSE-analogue hot loop (DESIGN.md §5): a windowed op
feeding a recurrent op, fused so chunk intermediates never leave VMEM.  The
TPU grid iterates chunks **sequentially** (TPU pallas grids are ordered), so
the inter-chunk SSM state lives in a VMEM scratch buffer that persists
across grid steps — the hardware analogue of the fusion pyramid's
activation buffer between levels.

Per grid step (one chunk of Q tokens):
  * intra-chunk: decay-masked quadratic form  Y_diag = (L ⊙ C Bᵀ) · X̄
    (MXU dots over (Q, N) x (N, Q) and (Q, Q) x (Q, P));
  * state in:   Y_off = C · h_in, scaled by the running decay;
  * state out:  h_out = e^{ΣdA} h_in + (decay-weighted B)ᵀ X̄  — written back
    to the scratch carry.

Shapes: x (b, S, H, P), dt (b, S, H), A (H,), B/C (b, S, N), D (H,);
uniform chunk grid (S % Q == 0, the uniform-stride contract).  Block layout
keeps (Q, N/P) tiles MXU-aligned for N, P in {64, 128}.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_ref,
                *, chunk: int):
    ci = pl.program_id(0)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0]  # (Q, H, P)
    dt = dt_ref[0]  # (Q, H)
    A = a_ref[...]  # (H,)
    B = b_ref[0]  # (Q, N)
    C = c_ref[0]  # (Q, N)
    D = d_ref[...]  # (H,)

    dA = dt * A[None, :]  # (Q, H) negative
    cums = jnp.cumsum(dA, axis=0)  # (Q, H)
    xb = x * dt[..., None]  # dt-scaled input

    # ---- intra-chunk: L[q, k, h] = exp(sum dA_{k+1..q}), lower-tri ----
    seg = cums[:, None, :] - cums[None, :, :]  # (q,k,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), 0)
    L = jnp.where(tri[:, :, None], jnp.exp(seg), 0.0)  # (q,k,H)
    scores = jnp.dot(C, B.T, preferred_element_type=jnp.float32)  # (q,k)
    y_diag = jnp.einsum(
        "qkh,qk,khp->qhp", L, scores, xb.astype(jnp.float32)
    )

    # ---- carried state contribution ----
    h_in = state_ref[...]  # (H, P, N) f32 (batch block of 1 folded in ops)
    decay_in = jnp.exp(cums)  # (Q, H)
    y_off = jnp.einsum("qn,hpn,qh->qhp", C.astype(jnp.float32), h_in, decay_in)

    y_ref[0] = (y_diag + y_off + x.astype(jnp.float32) * D[None, :, None]).astype(
        y_ref.dtype
    )

    # ---- state update ----
    decay_out = jnp.exp(cums[-1:, :] - cums)  # (Q, H)
    h_new = h_in * jnp.exp(cums[-1])[:, None, None] + jnp.einsum(
        "qn,qh,qhp->hpn", B.astype(jnp.float32), decay_out,
        xb.astype(jnp.float32),
    )
    state_ref[...] = h_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, A, B, C, D, *, chunk: int = 64,
                    interpret: bool = True):
    """(b,S,H,P) SSD scan; vmapped over batch (one sequence per program).

    Returns (y (b,S,H,P), final_state (b,H,P,N)).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    assert S % chunk == 0, "uniform chunk grid"
    nc = S // chunk

    def one_seq(xs, dts, Bs, Cs):
        kernel = functools.partial(_ssd_kernel, chunk=chunk)
        y, state = pl.pallas_call(
            kernel,
            grid=(nc,),
            in_specs=[
                pl.BlockSpec((1, chunk, H, P), lambda c: (c, 0, 0, 0)),
                pl.BlockSpec((1, chunk, H), lambda c: (c, 0, 0)),
                pl.BlockSpec((H,), lambda c: (0,)),
                pl.BlockSpec((1, chunk, N), lambda c: (c, 0, 0)),
                pl.BlockSpec((1, chunk, N), lambda c: (c, 0, 0)),
                pl.BlockSpec((H,), lambda c: (0,)),
            ],
            out_specs=[
                pl.BlockSpec((1, chunk, H, P), lambda c: (c, 0, 0, 0)),
                # state: same block every step -> persists as the carry
                pl.BlockSpec((H, P, N), lambda c: (0, 0, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((nc, chunk, H, P), x.dtype),
                jax.ShapeDtypeStruct((H, P, N), jnp.float32),
            ],
            interpret=interpret,
        )(
            xs.reshape(nc, chunk, H, P),
            dts.reshape(nc, chunk, H),
            A,
            Bs.reshape(nc, chunk, N),
            Cs.reshape(nc, chunk, N),
            D,
        )
        return y.reshape(S, H, P), state

    y, state = jax.vmap(one_seq)(x, dt, B, C)
    return y, state
