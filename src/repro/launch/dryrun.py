import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST precede any jax-importing module: jax locks
# the device count on first init, and the dry-run needs 512 placeholder host
# devices to build the production meshes.  Tests/benches import other
# modules and correctly see 1 device.

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec  # noqa: E402

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.configs.shapes import SHAPES, cell_supported  # noqa: E402
from repro.launch.hloanalysis import analyze_hlo, xla_cost_dict  # noqa: E402
from repro.launch.mesh import make_production_mesh, chips  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    input_specs,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.models.model import abstract_params, build_param_specs  # noqa: E402
from repro.models.serving import build_cache_specs  # noqa: E402
from repro.optim.adamw import AdamWState  # noqa: E402
from repro.parallel.constraints import mesh_rules  # noqa: E402
from repro.parallel.sharding import (  # noqa: E402
    ShardingRules,
    partition_spec,
    rules_for,
    spec_shardings,
)

HBM_PER_CHIP = 16 * 1024 ** 3  # v5e

# microbatch policy: rows-per-device-per-microbatch (activation-memory
# control); default 2 rows, HBM-tight archs drop to 1 row (+ bf16 grad
# accumulation for the 480B MoE).
TRAIN_ROWS_PER_DEVICE = 2
TRAIN_OVERRIDES: dict[str, dict] = {
    "arctic_480b": {"rows": 1, "accum_dtype": "bfloat16"},
    "whisper_large_v3": {"rows": 1},
    "minicpm3_4b": {"rows": 1},
    "qwen2_moe_a2_7b": {"rows": 1},
    "llama32_vision_11b": {"rows": 1},
}


def _batch_shardings(specs: dict, mesh, rules: ShardingRules):
    out = {}
    for k, v in specs.items():
        if k == "tokens":
            logical = ("batch",) + (None,) * (len(v.shape) - 1)
        elif k in ("vision", "frames"):
            logical = ("batch", None, None)
        else:
            logical = (None,) * len(v.shape)
        out[k] = NamedSharding(mesh, partition_spec(v.shape, logical, mesh, rules))
    return out


def lower_cell(cfg, shape, mesh, *, microbatches: int | None = None,
               rules_override: dict | None = None,
               cfg_override: dict | None = None,
               grad_dtype=None):
    """Lower + compile one (arch x shape x mesh) cell; return (compiled, meta)."""
    import dataclasses

    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    rules = rules_for(shape.step, long_context=shape.name == "long_500k")
    if rules_override:
        rules = rules.override(**rules_override)
    pspecs = build_param_specs(cfg)
    p_sh = spec_shardings(pspecs, mesh, rules)
    params = abstract_params(cfg)
    scalar = NamedSharding(mesh, PartitionSpec())

    if shape.step == "train":
        import jax.numpy as jnp

        ov = TRAIN_OVERRIDES.get(cfg.name.replace("-", "_").replace(".", "_"), {})
        dp = int(mesh.shape.get("data", 1)) * int(mesh.shape.get("pod", 1))
        rows = ov.get("rows", TRAIN_ROWS_PER_DEVICE)
        mb = microbatches or max(1, shape.global_batch // (dp * rows))
        accum = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[
            ov.get("accum_dtype", "float32")
        ]
        step_fn, opt = make_train_step(cfg, microbatches=mb, accum_dtype=accum,
                                       grad_dtype=grad_dtype)
        opt_abs = opt.init_abstract(params)
        opt_sh = AdamWState(
            step=scalar,
            mu=spec_shardings(pspecs, mesh, rules),
            nu=spec_shardings(pspecs, mesh, rules),
        )
        batch = input_specs(cfg, shape)
        b_sh = _batch_shardings(batch, mesh, rules)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_sh, opt_sh, b_sh),
            out_shardings=(p_sh, opt_sh, scalar),
            donate_argnums=(0, 1),  # params/opt update in place
        )
        args = (params, opt_abs, batch)
    elif shape.step == "prefill":
        step_fn = make_prefill_step(cfg)
        batch = input_specs(cfg, shape)
        b_sh = _batch_shardings(batch, mesh, rules)
        logits_sh = NamedSharding(
            mesh,
            partition_spec(
                (shape.global_batch, cfg.vocab), ("batch", "vocab"), mesh, rules
            ),
        )
        jitted = jax.jit(step_fn, in_shardings=(p_sh, b_sh), out_shardings=logits_sh)
        args = (params, batch)
    else:  # decode
        step_fn = make_decode_step(cfg)
        specs = input_specs(cfg, shape)
        cache_specs = build_cache_specs(cfg, shape.global_batch, shape.seq_len)
        c_sh = spec_shardings(cache_specs, mesh, rules)
        tok_sh = NamedSharding(
            mesh,
            partition_spec(specs["tokens"].shape, ("batch", None), mesh, rules),
        )
        logits_sh = NamedSharding(
            mesh,
            partition_spec(
                (shape.global_batch, cfg.vocab), ("batch", "vocab"), mesh, rules
            ),
        )
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_sh, tok_sh, c_sh, scalar),
            out_shardings=(logits_sh, c_sh),
            donate_argnums=(2,),  # caches update in place
        )
        args = (params, specs["tokens"], specs["caches"], specs["cache_index"])

    with mesh_rules(mesh, rules):
        t0 = time.time()
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return compiled, {"t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2)}


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str, *, analyze=True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": chips(mesh),
        "step": shape.step,
    }
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    try:
        compiled, meta = lower_cell(cfg, shape, mesh)
        rec.update(meta)
        ma = compiled.memory_analysis()
        per_dev = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
        }
        per_dev["total_bytes"] = (
            per_dev["argument_bytes"] + per_dev["temp_bytes"]
        )
        rec["memory"] = per_dev
        rec["fits_hbm"] = bool(per_dev["total_bytes"] < HBM_PER_CHIP)
        ca = xla_cost_dict(compiled)
        rec["xla_cost_analysis_flops_once_per_loop"] = float(ca.get("flops", 0.0))
        if analyze:
            cost = analyze_hlo(compiled.as_text())
            rec["hlo"] = {
                "flops_per_device": cost.flops,
                "collective_bytes_per_device": cost.collective_bytes,
                "traffic_bytes_per_device": cost.traffic_bytes,
                "n_collectives": cost.n_collectives,
                "by_collective": {
                    k: round(v) for k, v in sorted(
                        cost.by_collective.items(), key=lambda kv: -kv[1]
                    )
                },
            }
        rec["status"] = "ok"
    except Exception as e:  # a failed cell is a bug; record and keep going
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--no-analyze", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = []
    if out_path.exists():
        results = json.loads(out_path.read_text())
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") in ("ok", "skipped")}

    for mesh_name, mesh in meshes:
        for arch in archs:
            for shape_name in shapes:
                key = (arch, shape_name, mesh_name)
                if key in done:
                    continue
                t0 = time.time()
                rec = run_cell(arch, shape_name, mesh, mesh_name,
                               analyze=not args.no_analyze)
                rec["t_total_s"] = round(time.time() - t0, 1)
                results = [r for r in results
                           if (r["arch"], r["shape"], r["mesh"]) != key]
                results.append(rec)
                out_path.write_text(json.dumps(results, indent=1))
                mem = rec.get("memory", {}).get("total_bytes", 0) / 2 ** 30
                print(
                    f"[{mesh_name}] {arch:20s} {shape_name:12s} "
                    f"{rec['status']:8s} mem/dev={mem:6.2f}GiB "
                    f"fits={rec.get('fits_hbm', '-')} t={rec['t_total_s']}s",
                    flush=True,
                )

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped (recorded), {n_err} errors")
    if n_err:
        for r in results:
            if r["status"] == "error":
                print(f"  ERROR {r['arch']} {r['shape']} {r['mesh']}: {r['error']}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
