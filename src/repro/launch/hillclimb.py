import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb harness (§Perf): lower a cell under named sharding/config
variants, re-analyze the roofline terms, and print before/after rows.

Variants are explicit, named experiments so EXPERIMENTS.md can cite them:

  baseline       — the rules the dry-run table used
  fsdp           — drop tensor parallelism for weights; both mesh axes do
                   parameter sharding (data-parallel compute, FSDP gathers)
  sp             — sequence parallelism: residual stream seq-sharded over
                   'model' between layers (activation stacks shrink 16x)
  fsdp_sp        — both

Usage: PYTHONPATH=src python -m repro.launch.hillclimb --arch glm4_9b \
           --shape train_4k --mesh single --variants baseline,fsdp
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.configs.shapes import SHAPES  # noqa: E402
from repro.launch.dryrun import lower_cell  # noqa: E402
from repro.launch.hloanalysis import analyze_hlo  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import (  # noqa: E402
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    memory_bytes_per_device,
    model_flops_per_device,
)

# each variant: sharding-rule overrides + optional lowering knobs.
# Iteration log lives in EXPERIMENTS.md §Perf — including refuted variants
# (e.g. FSDP *without* widening the batch axes turns the model axis into
# pure replication: 14x more flops/device; refuted and fixed below).
VARIANTS: dict[str, dict] = {
    "baseline": {"rules": {}},
    # FSDP-dominant: DP over BOTH mesh axes (256-way), weights sharded over
    # both axes, no tensor parallelism.  batch 256 -> 1 row/device, no
    # microbatching needed.
    "fsdp": {
        "rules": {
            "batch": ("data", "model"),
            "heads": (), "kv_heads": (), "mlp": (), "experts": (), "lora": (),
            "embed": ("data", "model"),
            "vocab": (),
        },
        "microbatches": 1,
    },
    # sequence parallelism on the residual stream (keeps TP)
    "sp": {"rules": {"seq": ("model",)}},
    # fsdp + bf16 gradients before the data-parallel reduce
    "fsdp_gbf16": {
        "rules": {
            "batch": ("data", "model"),
            "heads": (), "kv_heads": (), "mlp": (), "experts": (), "lora": (),
            "embed": ("data", "model"),
            "vocab": (),
        },
        "microbatches": 1,
        "grad_dtype": "bfloat16",
    },
    # fsdp + bf16 grads + dots-saveable remat (no recompute re-gathers)
    "fsdp_gbf16_dots": {
        "rules": {
            "batch": ("data", "model"),
            "heads": (), "kv_heads": (), "mlp": (), "experts": (), "lora": (),
            "embed": ("data", "model"),
            "vocab": (),
        },
        "microbatches": 1,
        "grad_dtype": "bfloat16",
        "cfg": {"remat": "dots"},
    },
    # expert parallelism on 'model' + dense/attn weights FSDP + 16-wide DP
    "ep_fsdp": {
        "rules": {
            "heads": (), "kv_heads": (), "mlp": (), "lora": (),
            "embed": ("data",),
        },
    },
    # 2D expert parallelism: 128 experts over (pod x model)=32 shards of 4,
    # expert-internal dims over 'data' — tokens move (all-to-all), weights
    # never gathered whole (the per-layer 58-GB expert AG disappears)
    "ep2d": {
        "rules": {
            "experts": ("pod", "model"),
            "heads": (), "kv_heads": (), "lora": (),
            "mlp": ("data",),
            "embed": ("data",),
        },
    },
}


def run_variant(arch: str, shape_name: str, mesh_name: str, variant: str):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod="multi" in mesh_name)
    v = VARIANTS[variant]
    t0 = time.time()
    import jax.numpy as jnp

    gd = {"bfloat16": jnp.bfloat16}.get(v.get("grad_dtype"))
    compiled, meta = lower_cell(
        cfg, shape, mesh,
        rules_override=v["rules"] or None,
        microbatches=v.get("microbatches"),
        cfg_override=v.get("cfg"),
        grad_dtype=gd,
    )
    cost = analyze_hlo(compiled.as_text())
    ma = compiled.memory_analysis()
    mem_total = ma.argument_size_in_bytes + ma.temp_size_in_bytes
    compute_s = cost.flops / PEAK_FLOPS
    memory_s = memory_bytes_per_device(cfg, shape, mesh_name) / HBM_BW
    coll_s = cost.collective_bytes / ICI_BW
    mflops = model_flops_per_device(cfg, shape, mesh_name)
    bound = max(compute_s, memory_s, coll_s)
    return {
        "variant": variant,
        "compute_s": round(compute_s, 4),
        "memory_s": round(memory_s, 4),
        "collective_s": round(coll_s, 4),
        "dominant": max(
            ("compute", compute_s), ("memory", memory_s), ("collective", coll_s),
            key=lambda kv: kv[1],
        )[0],
        "roofline_frac": round((mflops / PEAK_FLOPS) / max(bound, 1e-12), 4),
        "hbm_gib": round(mem_total / 2 ** 30, 2),
        "flops_per_dev": cost.flops,
        "collective_gb": round(cost.collective_bytes / 1e9, 1),
        "by_collective": {
            k: round(v / 1e9, 1)
            for k, v in sorted(cost.by_collective.items(), key=lambda kv: -kv[1])[:5]
        },
        "t_s": round(time.time() - t0, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variants", default="baseline,fsdp")
    args = ap.parse_args()
    mesh_name = "single_pod_16x16" if args.mesh == "single" else "multi_pod_2x16x16"
    for v in args.variants.split(","):
        r = run_variant(args.arch, args.shape, mesh_name, v)
        print(json.dumps({"arch": args.arch, "shape": args.shape,
                          "mesh": mesh_name, **r}))


if __name__ == "__main__":
    main()
