"""Trip-count-aware analysis of compiled (post-SPMD) HLO text.

XLA's ``cost_analysis()`` visits while-loop bodies ONCE, so for scan-over-
layers models it under-reports FLOPs by ~n_layers x (verified empirically —
see EXPERIMENTS.md §Dry-run).  This analyzer parses ``compiled.as_text()``:

* builds the computation call graph (fusion ``calls=``, while
  ``body=/condition=``, conditional branches);
* recovers scan trip counts from the loop-condition constant
  (``compare(iter, constant(N))`` — exact for lax.scan lowering);
* multiplies per-computation costs by call multiplicity;
* dot FLOPs: ``2 * prod(result) * prod(lhs contracting dims)``;
* collective bytes ON WIRE per device (ring model, group size g):
  all-reduce ``2*S*(g-1)/g``, all-gather ``S*(g-1)/g`` (S = result),
  reduce-scatter ``S*(g-1)`` (S = result), all-to-all ``S*(g-1)/g``,
  collective-permute ``S``;
* HBM-traffic proxy: sum of (result + operand) bytes of top-level ops
  (each materialized buffer = one write + reads), trip-count aware.

Shapes in post-partitioning HLO are PER-DEVICE, so all outputs are
per-device quantities — exactly what the roofline terms need.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field


_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
# header: unindented, "name (args) -> result {"; args may nest parens, so
# match only the leading name and check structure cheaply
_COMP_NAME = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _lhs_shapes_bytes(lhs: str) -> int:
    """Total bytes of all shapes appearing before the op name (tuples too)."""
    return sum(_shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(lhs))


@dataclass
class Computation:
    name: str
    lines: list[str] = field(default_factory=list)
    shapes: dict[str, int] = field(default_factory=dict)  # %name -> bytes
    dims: dict[str, list[int]] = field(default_factory=dict)  # %name -> dims


@dataclass
class HloCost:
    flops: float = 0.0
    collective_bytes: float = 0.0
    traffic_bytes: float = 0.0
    by_collective: dict = field(default_factory=dict)
    n_collectives: int = 0


def xla_cost_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions (older
    releases return ``[dict]`` per device program, newer a plain dict)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def _parse_computations(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in text.splitlines():
        if line.startswith("}"):
            cur = None
            continue
        is_hdr = (
            not line.startswith((" ", "\t"))
            and line.rstrip().endswith("{")
            and "->" in line
            and "=" not in line.split("->")[0].split("(")[0]
        )
        if is_hdr:
            hdr = _COMP_NAME.match(line)
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is not None and line.strip():
            cur.lines.append(line)
            m = _DEF_RE.match(line)
            if m:
                name, rhs = m.groups()
                sm = _SHAPE_RE.match(rhs.lstrip("("))
                if sm:
                    cur.shapes[name] = _shape_bytes(sm.group(1), sm.group(2))
                    cur.dims[name] = [
                        int(d) for d in sm.group(2).split(",") if d
                    ]
    return comps, entry


def _trip_count(cond: Computation) -> int:
    consts = [
        int(m.group(1))
        for l in cond.lines
        for m in re.finditer(r"constant\((\d+)\)", l)
    ]
    return max(consts) if consts else 1


def _multiplicities(comps: dict[str, Computation], entry: str) -> dict[str, float]:
    mult = {name: 0.0 for name in comps}
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # BFS in call order; graphs are DAGs in HLO
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps[cname]
        m = mult[cname]
        body_text = "\n".join(comp.lines)
        # fusions / calls
        for callee in re.findall(r"(?:calls|to_apply)=%?([\w.\-]+)", body_text):
            if callee in comps:
                mult[callee] = mult.get(callee, 0.0) + m
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
        # while loops
        for wm in re.finditer(
            r"condition=%?([\w.\-]+), body=%?([\w.\-]+)", body_text
        ):
            cond, body = wm.groups()
            trips = _trip_count(comps[cond]) if cond in comps else 1
            if body in comps:
                mult[body] = mult.get(body, 0.0) + m * trips
                if body not in seen:
                    seen.add(body)
                    order.append(body)
        # conditionals: charge the more expensive branch once (max later;
        # approximation: count each branch once — branches are rare here)
        for bm in re.finditer(r"(?:true|false)_computation=%?([\w.\-]+)", body_text):
            callee = bm.group(1)
            if callee in comps:
                mult[callee] = mult.get(callee, 0.0) + m
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
    return mult


def analyze_hlo(text: str) -> HloCost:
    comps, entry = _parse_computations(text)
    if entry is None:
        return HloCost()
    mult = _multiplicities(comps, entry)
    # computations reached via calls= are fusion bodies: their internals are
    # registers/VMEM, only the ROOT result materializes
    fused = set()
    for comp in comps.values():
        for callee in re.findall(
            r"(?:calls|to_apply)=%?([\w.\-]+)", "\n".join(comp.lines)
        ):
            fused.add(callee)
    cost = HloCost()
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for line in comp.lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            name, rhs = dm.groups()
            # ---- dot flops ----
            if " dot(" in rhs or rhs.startswith("dot("):
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                res_elems = 1
                sm = _SHAPE_RE.match(rhs)
                if sm:
                    for d in sm.group(2).split(","):
                        if d:
                            res_elems *= int(d)
                # lhs dims: newer HLO prints operands with inline shapes
                # (``dot(f32[64,128]{1,0} %op, ...)``); older dialects print
                # bare operand names resolved via the def table
                shape = None
                ism = re.search(r"dot\(([a-z0-9]+)\[([\d,]*)\]", rhs)
                if ism and ism.group(1) in _DTYPE_BYTES:
                    shape = [int(d) for d in ism.group(2).split(",") if d]
                else:
                    opm = re.search(r"dot\(%?([\w.\-]+)", rhs)
                    if opm and opm.group(1) in comp.dims:
                        shape = comp.dims[opm.group(1)]
                csize = 1
                if shape and cm:
                    for idx in cm.group(1).split(","):
                        if idx:
                            csize *= shape[int(idx)]
                cost.flops += m * 2.0 * res_elems * csize
            # ---- collectives ----
            for kind in _COLLECTIVES:
                if re.search(rf"(?:^|\s){kind}(?:-start)?\(", rhs):
                    size = _lhs_shapes_bytes(rhs.split(kind)[0])
                    g = _group_size(rhs)
                    wire = _wire_bytes(kind, size, g)
                    cost.collective_bytes += m * wire
                    cost.n_collectives += 1
                    key = f"{kind}(g={g})"
                    cost.by_collective[key] = (
                        cost.by_collective.get(key, 0.0) + m * wire
                    )
                    break
            # ---- traffic proxy (materialized results only; debug column —
            # the roofline memory term is analytic, see roofline.py) ----
            if cname in fused and not line.lstrip().startswith("ROOT"):
                continue  # fusion internals never touch HBM
            opm = re.search(r"[\s)]([a-z][a-z0-9\-_]*)\(", " " + rhs)
            op = opm.group(1) if opm else ""
            if op not in ("parameter", "constant", "get-tuple-element", "tuple",
                          "bitcast", "reshape", "iota", "after-all"):
                sm = _SHAPE_RE.match(rhs.lstrip("("))
                if sm:
                    cost.traffic_bytes += m * _shape_bytes(sm.group(1), sm.group(2))
    return cost


def _group_size(rhs: str) -> int:
    # iota format: replica_groups=[G,N]<=[...]
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", rhs)
    if m:
        return int(m.group(2))
    # explicit format: replica_groups={{0,1,2,...},{...}}
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", rhs)
    if m:
        return len(m.group(1).split(","))
    return 1


def _wire_bytes(kind: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)  # collective-permute
