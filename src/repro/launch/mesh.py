"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds meshes.

Topology: TPU v5e pods, 256 chips each.  Single pod = (data=16, model=16);
two pods = (pod=2, data=16, model=16) with the 'pod' axis crossing DCI —
gradient reduction over 'pod' is the slow-link collective the overlap and
compression knobs target.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def chips(mesh) -> int:
    return int(mesh.devices.size)
