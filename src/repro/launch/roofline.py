"""Roofline analysis over dry-run records (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, three per-chip time terms on TPU v5e:

  compute    = HLO_FLOPs_per_device / 197e12        (bf16 MXU peak)
  memory     = analytic_HBM_bytes_per_device / 819e9
  collective = HLO_collective_wire_bytes_per_device / 50e9  (ICI per link)

HLO FLOPs / collective bytes come from the trip-count-aware analyzer
(:mod:`repro.launch.hloanalysis`) over the compiled per-device module.

The memory term is ANALYTIC (the CPU backend's fusion/buffer layout is not
TPU's, so HLO byte-scans mislead — DESIGN.md §7):

  train:   params(2 reads: fwd+bwd) + grad write+read + moments r/w +
           param write + residual-stack write+read+recompute-read
           (3 x L x local x-bytes x microbatches)
  prefill: params read + 2 x L x local activation bytes
  decode:  params read (streamed per token) + KV/state cache read + write

MODEL_FLOPS = 6*N*D for train (N = active params for MoE), 2*N*D prefill,
2*N per token decode (D = tokens); attention excluded by convention — the
ratio MODEL_FLOPS/HLO_FLOPs therefore shows remat + attention + dispatch
overhead explicitly.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

from repro.configs import get_config
from repro.configs.shapes import SHAPES

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

_MOMENT_BYTES = {"float32": 4, "bfloat16": 2}


def _dp_shards(mesh_name: str) -> int:
    return 32 if "multi" in mesh_name else 16


def _chips(mesh_name: str) -> int:
    return 512 if "multi" in mesh_name else 256


def model_flops_per_device(cfg, shape, mesh_name: str) -> float:
    n_active = cfg.active_param_count()
    chips = _chips(mesh_name)
    if shape.step == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n_active * d / chips
    if shape.step == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n_active * d / chips
    return 2.0 * n_active * shape.global_batch / chips  # decode: per step


def _cache_bytes(cfg, shape) -> float:
    """Global KV/state cache bytes for a decode shape."""
    B, S = shape.global_batch, shape.seq_len
    bpe = 2  # bf16
    L = cfg.n_layers
    if cfg.family == "ssm":
        conv = (cfg.ssm_conv - 1) * (cfg.d_inner + 2 * cfg.ssm_state)
        state = cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
        return L * B * (conv + state) * bpe
    if cfg.family == "hybrid":
        attn = L * B * S * 2 * cfg.n_kv_heads * cfg.d_head * bpe
        conv = (cfg.ssm_conv - 1) * (cfg.d_inner + 2 * cfg.ssm_state)
        state = cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
        return attn + L * B * (conv + state) * bpe
    if cfg.attn_kind == "mla":
        return L * B * S * (cfg.kv_lora_rank + cfg.d_rope) * bpe
    kv = L * B * S * 2 * cfg.n_kv_heads * cfg.d_head * bpe
    if cfg.kind == "encdec":
        kv += L * B * cfg.enc_seq * 2 * cfg.n_kv_heads * cfg.d_head * bpe
    if cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_every
        kv += n_cross * B * cfg.vis_seq * 2 * cfg.n_kv_heads * cfg.d_head * bpe
    return kv


def memory_bytes_per_device(cfg, shape, mesh_name: str, *, microbatches=1) -> float:
    chips = _chips(mesh_name)
    p_total = cfg.param_count()
    p_loc = p_total * 2 / chips  # bf16 shard
    mom = _MOMENT_BYTES[cfg.moment_dtype]
    if shape.step == "train":
        tokens_loc = shape.global_batch * shape.seq_len / _dp_shards(mesh_name)
        act = 3.0 * cfg.n_layers * tokens_loc * cfg.d_model * 2  # stacks+recompute
        opt = p_total / chips * (4 + 2 * 2 * mom)  # grads fp32 + moments r/w
        return 2 * p_loc + p_loc + opt + act
    if shape.step == "prefill":
        tokens_loc = shape.global_batch * shape.seq_len / _dp_shards(mesh_name)
        return p_loc + 2.0 * cfg.n_layers * tokens_loc * cfg.d_model * 2
    cache = _cache_bytes(cfg, shape) / chips
    return p_loc + cache  # decode: stream params + read cache


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0
    hlo_flops: float = 0.0
    useful_ratio: float = 0.0
    roofline_frac: float = 0.0  # compute / max(all terms): fraction of peak
    fits: bool | None = None
    note: str = ""

    def bound(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


_MOVES = {
    "compute": "cut remat recompute / attention flops (fused kernels, "
               "policy='dots'), or grow per-chip batch",
    "memory": "shard or shrink the streamed state (SP residuals, smaller "
              "moments, ring-buffer window caches)",
    "collective": "reshard to cheaper collectives (SP reduce-scatter, "
                  "grad-compression over 'pod', overlap with compute)",
}


def analyze_record(rec: dict) -> RooflineRow:
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    row = RooflineRow(rec["arch"], rec["shape"], rec["mesh"], rec["status"])
    if rec["status"] != "ok":
        row.note = rec.get("reason", rec.get("error", ""))
        return row
    h = rec["hlo"]
    row.hlo_flops = h["flops_per_device"]
    row.compute_s = row.hlo_flops / PEAK_FLOPS
    mb = 1
    row.memory_s = memory_bytes_per_device(
        cfg, shape, rec["mesh"], microbatches=mb
    ) / HBM_BW
    row.collective_s = h["collective_bytes_per_device"] / ICI_BW
    row.model_flops = model_flops_per_device(cfg, shape, rec["mesh"])
    row.useful_ratio = row.model_flops / max(row.hlo_flops, 1.0)
    terms = {
        "compute": row.compute_s,
        "memory": row.memory_s,
        "collective": row.collective_s,
    }
    row.dominant = max(terms, key=terms.get)
    # fraction of the compute roofline actually achievable: useful model
    # flops-time over the binding term
    row.roofline_frac = (row.model_flops / PEAK_FLOPS) / max(row.bound(), 1e-12)
    row.fits = rec.get("fits_hbm")
    return row


def load_rows(path: str | Path) -> list[RooflineRow]:
    recs = json.loads(Path(path).read_text())
    return [analyze_record(r) for r in recs]


def markdown_table(rows: list[RooflineRow]) -> str:
    hdr = (
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | MODEL/HLO flops | roofline frac | fits | what moves it |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        if r.status == "skipped":
            lines.append(
                f"| {r.arch} | {r.shape} | {r.mesh} | — | — | — | skipped "
                f"| — | — | — | {r.note[:60]} |"
            )
            continue
        if r.status == "error":
            lines.append(
                f"| {r.arch} | {r.shape} | {r.mesh} | ERR | | | {r.note[:40]} | | | | |"
            )
            continue
        lines.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.4f} | "
            f"{r.memory_s:.4f} | {r.collective_s:.4f} | **{r.dominant}** | "
            f"{r.useful_ratio:.2f} | {r.roofline_frac:.3f} | "
            f"{'y' if r.fits else 'n'} | {_MOVES[r.dominant]} |"
        )
    return hdr + "\n".join(lines) + "\n"


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun.json")
    ap.add_argument("--out", default="results/roofline.md")
    args = ap.parse_args()
    rows = load_rows(args.results)
    md = markdown_table(rows)
    Path(args.out).write_text(md)
    print(md)


if __name__ == "__main__":
    main()
