"""Serving driver: batched decode with KV caches.

``python -m repro.launch.serve --arch <id> --tokens 32`` greedily decodes a
batch of synthetic prompts on the reduced config (CPU path); the full-config
variant is exercised structurally by the dry-run decode cells.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import init_params
from repro.models.serving import decode_step, init_caches, prefill_cross_caches


def serve(arch: str, *, batch: int = 4, prompt_len: int = 8, new_tokens: int = 24,
          reduced: bool = True, seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(seed))
    max_seq = prompt_len + new_tokens
    caches = init_caches(cfg, batch, max_seq)
    vision = frames = None
    if cfg.family == "vlm":
        vision = jax.random.normal(
            jax.random.PRNGKey(1), (batch, cfg.vis_seq, cfg.d_model), jnp.bfloat16
        )
    if cfg.kind == "encdec":
        frames = jax.random.normal(
            jax.random.PRNGKey(1), (batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16
        )
    caches = prefill_cross_caches(cfg, params, caches, vision=vision, frames=frames)

    step = jax.jit(
        lambda p, t, c, i: decode_step(cfg, p, t, c, i, vision=vision)
    )
    prompt = jax.random.randint(
        jax.random.PRNGKey(2), (batch, prompt_len), 0, cfg.vocab
    )
    # prefill via repeated decode (single compiled step serves all positions)
    out_tokens = []
    tok = prompt[:, :1]
    t0 = time.time()
    for i in range(max_seq - 1):
        logits, caches = step(params, tok, caches, jnp.int32(i))
        nxt = jnp.argmax(logits, axis=-1)[:, None]
        tok = prompt[:, i + 1 : i + 2] if i + 1 < prompt_len else nxt
        if i + 1 >= prompt_len:
            out_tokens.append(nxt)
    dt = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    tps = batch * gen.shape[1] / dt
    return gen, tps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()
    gen, tps = serve(args.arch, batch=args.batch, new_tokens=args.tokens)
    print(f"generated {gen.shape} tokens at {tps:.1f} tok/s (reduced config, CPU)")
    print(gen[:, :12])


if __name__ == "__main__":
    main()
