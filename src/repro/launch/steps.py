"""Step builders + abstract input specs for every (arch x shape) cell.

``make_*_step`` return pure functions ready for ``jax.jit``;
``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no allocation) for the dry-run and the launchers.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.models import model as M
from repro.models import serving as S
from repro.optim.adamw import AdamW
from repro.optim.schedule import warmup_cosine

MOMENT_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


def make_optimizer(cfg: ArchConfig) -> AdamW:
    return AdamW(moment_dtype=MOMENT_DTYPES[cfg.moment_dtype])


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, *, microbatches: int = 1,
                    accum_dtype=jnp.float32, grad_dtype=None):
    """``grad_dtype=bf16`` casts gradients before the data-parallel
    reduction (halves reduce bytes; AdamW upcasts to f32 internally)."""
    opt = make_optimizer(cfg)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(
                lambda p: M.lm_loss(cfg, p, batch)
            )(params)
            if grad_dtype is not None:
                grads = jax.tree.map(lambda g: g.astype(grad_dtype), grads)
        else:
            # microbatch gradient accumulation: compute of microbatch i+1
            # overlaps the (async) reduction tail of microbatch i under XLA's
            # latency-hiding scheduler.  accum_dtype=bf16 halves the carried
            # accumulator for HBM-tight giants (arctic); fp32 is the default.
            def mb(batch_i):
                return jax.value_and_grad(
                    lambda p: M.lm_loss(cfg, p, batch_i)
                )(params)

            split = jax.tree.map(
                lambda t: t.reshape((microbatches, t.shape[0] // microbatches)
                                    + t.shape[1:]),
                batch,
            )

            def acc(carry, batch_i):
                loss_i, g_i = mb(batch_i)
                loss_a, g_a = carry
                return (
                    loss_a + loss_i / microbatches,
                    jax.tree.map(
                        lambda a, b: a + b.astype(a.dtype) / microbatches, g_a, g_i
                    ),
                ), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params
            )
            (loss, grads), _ = jax.lax.scan(acc, (0.0, zero_g), split)
        lr_scale = warmup_cosine(opt_state.step)
        new_params, new_state = opt.update(grads, opt_state, params, lr_scale)
        return new_params, new_state, loss

    return train_step, opt


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        hidden, _, _ = M.hidden_forward(
            cfg,
            params,
            batch["tokens"],
            mode="prefill",
            chunked=True,
            vision=batch.get("vision"),
            frames=batch.get("frames"),
        )
        # project ONLY the last position: (B, S, V) logits never materialize
        return M.logits_fn(cfg, params, hidden[:, -1:, :])[:, 0, :]

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def step(params, tokens, caches, cache_index):
        return S.decode_step(cfg, params, tokens, caches, cache_index)

    return step


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------


def _tok(shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    Modality frontends are STUBS per the assignment: vlm gets precomputed
    patch embeddings, whisper precomputed frame embeddings.
    """
    B, Sq = shape.global_batch, shape.seq_len
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if shape.step == "train":
        specs = {"tokens": _tok((B, Sq + 1))}
        if cfg.family == "vlm":
            specs["vision"] = jax.ShapeDtypeStruct((B, cfg.vis_seq, cfg.d_model), dt)
        if cfg.kind == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), dt)
        return specs
    if shape.step == "prefill":
        specs = {"tokens": _tok((B, Sq))}
        if cfg.family == "vlm":
            specs["vision"] = jax.ShapeDtypeStruct((B, cfg.vis_seq, cfg.d_model), dt)
        if cfg.kind == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), dt)
        return specs
    # decode: one new token against a seq_len cache
    return {
        "tokens": _tok((B, 1)),
        "caches": S.abstract_caches(cfg, B, Sq),
        "cache_index": jax.ShapeDtypeStruct((), jnp.int32),
    }
