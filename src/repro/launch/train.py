"""End-to-end training driver: ``python -m repro.launch.train --arch <id>``.

Production loop on whatever devices exist (CPU/TPU): deterministic data
pipeline, sharded AdamW, checkpoint/restart, straggler detection hooks.
``--reduced`` runs the family-preserving small config (the CPU path used by
examples/ and CI); full configs want the real mesh.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig, batch_at
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models.model import build_param_specs, init_params
from repro.parallel.constraints import mesh_rules
from repro.parallel.sharding import (
    ShardingRules,
    partition_spec,
    spec_shardings,
)
from repro.runtime.straggler import StragglerDetector


def train(
    arch: str,
    *,
    steps: int = 100,
    reduced: bool = True,
    seq_len: int = 256,
    global_batch: int = 8,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    resume: bool = True,
    log_every: int = 10,
    microbatches: int = 1,
):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    rules = ShardingRules()
    pspecs = build_param_specs(cfg)
    p_sh = spec_shardings(pspecs, mesh, rules)
    scalar = NamedSharding(mesh, PartitionSpec())

    params = init_params(cfg, jax.random.PRNGKey(0))
    params = jax.tree.map(jax.device_put, params, p_sh)
    step_fn, opt = make_train_step(cfg, microbatches=microbatches)
    opt_state = opt.init(params)

    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch)
    start_step = 0
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    if ckpt and resume:
        latest = ckpt.latest_complete()
        if latest is not None:
            state = ckpt.restore(latest, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = latest + 1
            print(f"resumed from checkpoint step {latest}")

    tok_sh = NamedSharding(
        mesh, partition_spec((global_batch, seq_len + 1), ("batch", None), mesh, rules)
    )
    jitted = jax.jit(step_fn, in_shardings=(p_sh, None, {"tokens": tok_sh}),
                     out_shardings=(p_sh, None, scalar))
    detector = StragglerDetector(n_hosts=1)
    losses = []
    t_last = time.time()
    with mesh_rules(mesh, rules):
        for step in range(start_step, steps):
            batch = batch_at(data_cfg, step)
            batch = {"tokens": jnp.asarray(batch["tokens"])}
            if cfg.family == "vlm":
                batch["vision"] = jnp.zeros(
                    (global_batch, cfg.vis_seq, cfg.d_model), jnp.bfloat16
                )
            if cfg.kind == "encdec":
                batch["frames"] = jnp.zeros(
                    (global_batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16
                )
            if step == start_step:  # re-jit with the actual batch structure
                jitted = jax.jit(step_fn)
            params, opt_state, loss = jitted(params, opt_state, batch)
            losses.append(float(loss))
            detector.observe([time.time() - t_last])
            t_last = time.time()
            if step % log_every == 0 or step == steps - 1:
                print(f"step {step:5d} loss {float(loss):.4f}")
            if ckpt and step and step % ckpt_every == 0:
                ckpt.save(step, {"params": params, "opt": opt_state})
    if ckpt:
        ckpt.wait()
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--full", action="store_true", help="full (non-reduced) config")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    losses = train(
        args.arch,
        steps=args.steps,
        reduced=not args.full,
        seq_len=args.seq_len,
        global_batch=args.global_batch,
        ckpt_dir=args.ckpt_dir,
    )
    print(f"first loss {losses[0]:.4f} -> last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
