"""Per-family transformer layer bodies.

Every body has the signature ``(cfg, p, x, ctx) -> (x, new_cache, aux)`` where
``ctx`` is a :class:`LayerCtx` carrying mode (train / prefill / decode),
caches and auxiliary inputs (vision/encoder states).  Bodies are scanned over
stacked params by :mod:`repro.models.model`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from .layers import (
    gelu_mlp,
    gqa_attention,
    layer_norm,
    mla_attention,
    rms_norm,
    swiglu,
)
from .moe import moe_ffn
from .ssm import mamba2_mixer


@dataclass
class LayerCtx:
    mode: str = "train"  # train | prefill | decode
    cache_index: Any = None  # scalar position for decode
    chunked: bool = False  # use flash-chunked attention
    causal: bool = True
    window: int = 0  # sliding window for this layer (0 = full)
    vision: Any = None  # (B, vis_seq, d) stub embeddings (vlm)
    encoder_out: Any = None  # (B, enc_seq, d) encoder states (encdec)


def _norm(cfg, x, p_scale, p_bias=None):
    if cfg.norm == "layernorm":
        return layer_norm(x, p_scale, p_bias)
    return rms_norm(x, p_scale)


def _ffn(cfg, p, x):
    if cfg.act == "gelu":
        return gelu_mlp(x, p["w_in"], p["w_out"])
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])


def _self_attention(cfg, p, x, ctx: LayerCtx, cache):
    if cfg.attn_kind == "mla":
        return mla_attention(
            p,
            x,
            n_heads=cfg.n_heads,
            q_lora=cfg.q_lora_rank,
            kv_lora=cfg.kv_lora_rank,
            d_nope=cfg.d_nope,
            d_rope=cfg.d_rope,
            d_v=cfg.d_v,
            rope_theta=cfg.rope_theta,
            kv_cache=cache,
            cache_index=ctx.cache_index,
            chunked=ctx.chunked,
            q_chunk=cfg.attn_chunk,
            kv_chunk=cfg.attn_chunk,
        )
    return gqa_attention(
        p,
        x,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.d_head,
        rope_theta=cfg.rope_theta,
        causal=ctx.causal,
        window=ctx.window,
        kv_cache=cache,
        cache_index=ctx.cache_index,
        chunked=ctx.chunked,
        q_chunk=cfg.attn_chunk,
        kv_chunk=cfg.attn_chunk,
    )


# ---------------------------------------------------------------------------
# family bodies
# ---------------------------------------------------------------------------


def dense_layer(cfg, p, x, ctx: LayerCtx, cache=None):
    """Pre-norm dense block (deepseek / glm4 / phi4 / minicpm3 / llama)."""
    h, new_cache = _self_attention(
        cfg, p["attn"], _norm(cfg, x, p["attn_norm"], p.get("attn_norm_b")), ctx,
        cache,
    )
    x = x + h
    x = x + _ffn(cfg, p["ffn"], _norm(cfg, x, p["ffn_norm"], p.get("ffn_norm_b")))
    return x, new_cache, 0.0


def moe_layer(cfg, p, x, ctx: LayerCtx, cache=None):
    """MoE block: attention + routed experts (+ shared / dense residual)."""
    h, new_cache = _self_attention(
        cfg, p["attn"], _norm(cfg, x, p["attn_norm"]), ctx, cache
    )
    x = x + h
    xn = _norm(cfg, x, p["ffn_norm"])
    tokens = xn.shape[0] * xn.shape[1]
    groups = max(1, tokens // cfg.moe_group_tokens)
    moe_out, aux = moe_ffn(
        p["moe"],
        xn,
        n_experts=cfg.n_experts,
        top_k=cfg.top_k,
        capacity_factor=cfg.capacity_factor,
        groups=groups,
    )
    y = moe_out
    if cfg.n_shared_experts:
        y = y + _ffn(cfg, p["shared"], xn)
    if cfg.dense_residual:
        y = y + _ffn(cfg, p["dense"], xn)
    return x + y, new_cache, aux


def ssm_layer(cfg, p, x, ctx: LayerCtx, cache=None):
    """Mamba-2 block: norm -> mixer -> residual (no separate FFN)."""
    h, new_cache = mamba2_mixer(
        p["mixer"],
        _norm(cfg, x, p["norm"]),
        n_heads=cfg.ssm_heads,
        head_dim=cfg.ssm_head_dim,
        state_dim=cfg.ssm_state,
        conv_dim=cfg.ssm_conv,
        chunk=cfg.ssd_chunk,
        ssm_cache=cache,
    )
    return x + h, new_cache, 0.0


def hybrid_layer(cfg, p, x, ctx: LayerCtx, cache=None):
    """Hymba block: attention and mamba heads in parallel, then FFN.

    ``cache`` is a dict with 'attn' and 'ssm' sub-caches (either may be None
    outside decode).
    """
    attn_cache = cache.get("attn") if cache else None
    ssm_cache = cache.get("ssm") if cache else None
    xn = _norm(cfg, x, p["attn_norm"])
    h_attn, new_attn = _self_attention(cfg, p["attn"], xn, ctx, attn_cache)
    h_ssm, new_ssm = mamba2_mixer(
        p["mixer"],
        xn,
        n_heads=cfg.ssm_heads,
        head_dim=cfg.ssm_head_dim,
        state_dim=cfg.ssm_state,
        conv_dim=cfg.ssm_conv,
        chunk=cfg.ssd_chunk,
        ssm_cache=ssm_cache,
    )
    x = x + 0.5 * (h_attn + h_ssm)  # parallel-head fusion (mean combine)
    x = x + _ffn(cfg, p["ffn"], _norm(cfg, x, p["ffn_norm"]))
    new_cache = None
    if cache is not None:
        new_cache = {"attn": new_attn, "ssm": new_ssm}
    return x, new_cache, 0.0


def cross_attn_block(cfg, p, x, kv_src, ctx: LayerCtx, kv_cache=None):
    """Gated cross-attention (llama-vision) / plain cross-attn (whisper).

    ``kv_src``: (B, S_src, d) keys/values source (vision or encoder states).
    ``kv_cache``: optional precomputed dict(k=, v=) to skip the projections
    (decode: projected once per request, reused every step).
    """
    xn = _norm(cfg, x, p["norm"], p.get("norm_b"))
    q = jnp.einsum("bsd,dhk->bshk", xn, p["wq"])
    if kv_cache is not None:
        k, v = kv_cache["k"].astype(q.dtype), kv_cache["v"].astype(q.dtype)
    else:
        k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"])
    from .layers import chunked_attention, dense_attention

    sq, skv = q.shape[1], k.shape[1]
    if sq > 2048:
        # long decoder sequences: chunk the cross-attention so the
        # (B, H, Sq, S_src) score block never materializes whole.  Small
        # (or prime — llama-vision's 1601) KV sources stay a single block:
        # a kv_chunk of 1 would stack scan carries catastrophically.
        qc = 1024 if sq % 1024 == 0 else sq
        if skv <= 2048:
            kc = skv
        else:
            divisors = [d for d in range(512, 2049) if skv % d == 0]
            kc = max(divisors) if divisors else skv
        out = chunked_attention(q, k, v, causal=False, q_chunk=qc, kv_chunk=kc)
    else:
        out = dense_attention(q, k, v, causal=False)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if "gate" in p:
        y = jnp.tanh(p["gate"]) * y
    return x + y, 0.0
