"""Transformer building blocks: norms, RoPE, MLPs, GQA / MLA attention.

Pure-functional JAX: every block is ``apply(params, x, ...)`` with params as
plain dicts.  Parameter *creation* lives in :mod:`repro.models.params` so the
same specs drive real init (smoke tests) and abstract init (dry-run).

Attention comes in two dataflows:

* :func:`dense_attention` — materialized scores, for short sequences.
* :func:`chunked_attention` — flash-style online-softmax double scan over
  query/key chunks; O(chunk^2) live memory at any sequence length.  This is
  the uniform-stride tiling discipline of the paper applied to attention:
  a fixed chunk grid with identical chunk counts per scan level (DESIGN.md
  §5), no ragged tail (sequence lengths are multiples of the chunk).

Sliding-window masking is chunk-aware: chunks entirely outside the window are
still visited (lax.scan is shape-static) but fully masked; the window cache
path in serve.py keeps decode sub-quadratic.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale + bias


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP: down( silu(x@gate) * (x@up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def gelu_mlp(x, w_in, w_out):
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_in))
    return jnp.einsum("...f,fd->...d", h, w_out)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., S, H, D) with positions (..., S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------


def _expand_kv(k, n_rep: int):
    """(B, S, Hkv, D) -> (B, S, Hkv*n_rep, D) for GQA."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d
    )


def dense_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int | jnp.ndarray = 0):
    """Materialized attention.  q: (B,Sq,H,D), k/v: (B,Skv,Hkv,D)."""
    n_rep = q.shape[2] // k.shape[2]
    k = _expand_kv(k, n_rep)
    v = _expand_kv(v, n_rep)
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    sq, skv = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    skip_masked_blocks: bool = True,
):
    """Flash-style attention: online softmax over a (Q-chunk x KV-chunk) grid.

    Both sequence lengths must be chunk multiples (the uniform-stride
    contract: every scan level runs the same static trip count).

    ``skip_masked_blocks`` (§Perf hillclimb, confirmed): the q-chunk loop is
    unrolled so each q-chunk's inner scan visits ONLY its live KV block range
    — causal skips future blocks (~2x fewer block dots at long S), sliding
    windows skip both tails (O(window) per q-chunk).  The block range is
    static per q-chunk, so the saving is visible to the compiled-FLOP
    roofline, not just a runtime branch.
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    dv = v.shape[-1]  # may differ from d (MLA: qk 96, v 64)
    assert sq % q_chunk == 0 and skv % kv_chunk == 0, "uniform chunk grid"
    n_rep = h // k.shape[2]
    k = _expand_kv(k, n_rep)
    v = _expand_kv(v, n_rep)
    scale = d ** -0.5
    nq, nk = sq // q_chunk, skv // kv_chunk

    qs = q.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 3, 2, 4)  # (nq,B,H,Cq,D)
    ks = k.reshape(b, nk, kv_chunk, h, d).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, nk, kv_chunk, h, dv).transpose(1, 0, 3, 2, 4)
    kv_offset = skv - sq  # causal alignment when skv > sq (cache prefixes)

    def run_q_chunk(qc, iq, lo: int, hi: int):
        """Online softmax for one q-chunk over KV blocks [lo, hi)."""

        def kv_step(carry, ik):
            # index (not slice) the chunk stacks: no triangular prefix copies
            acc, m, l = carry
            kc = jax.lax.dynamic_index_in_dim(ks, ik, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vs, ik, 0, keepdims=False)
            logits = (
                jnp.einsum("bhqd,bhkd->bhqk", qc, kc).astype(jnp.float32) * scale
            )
            qpos = iq * q_chunk + jnp.arange(q_chunk) + kv_offset
            kpos = ik * kv_chunk + jnp.arange(kv_chunk)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window:
                mask &= qpos[:, None] - kpos[None, :] < window
            logits = jnp.where(mask[None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vc.dtype), vc
            ).astype(jnp.float32)
            return (acc, m_new, l), None

        acc0 = jnp.zeros((b, h, q_chunk, dv), jnp.float32)
        m0 = jnp.full((b, h, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        # checkpoint per KV block: backward recomputes each block's logits
        # instead of saving nq*nk score blocks (the flash-attention backward)
        (acc, m, l), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (acc0, m0, l0), jnp.arange(lo, hi)
        )
        return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    if skip_masked_blocks:
        outs = []
        for iq in range(nq):
            hi = nk
            lo = 0
            if causal:  # last causally-visible kv block for this q chunk
                hi = min(nk, (iq * q_chunk + q_chunk - 1 + kv_offset) // kv_chunk + 1)
            if window:  # first block within the window of the oldest query
                lo = max(0, (iq * q_chunk + kv_offset - window + 1) // kv_chunk)
            outs.append(run_q_chunk(qs[iq], iq, lo, hi))
        out = jnp.stack(outs, axis=0)
    else:

        def q_step(_, qc_i):
            qc, iq = qc_i
            return None, run_q_chunk(qc, iq, 0, nk)

        _, out = jax.lax.scan(jax.checkpoint(q_step), None, (qs, jnp.arange(nq)))
    # (nq, B, H, Cq, Dv) -> (B, Sq, H, Dv)
    return out.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, dv)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def gqa_attention(
    p,
    x,
    *,
    n_heads: int,
    n_kv_heads: int,
    d_head: int,
    rope_theta: float,
    causal: bool = True,
    window: int = 0,
    positions=None,
    kv_cache=None,
    cache_index=None,
    chunked: bool = False,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
):
    """Full GQA block: qkv proj + RoPE + attention + out proj.

    ``kv_cache``: optional dict(k=(B,Smax,Hkv,D), v=...) for decode; the new
    token's k/v are written at ``cache_index`` and attention runs over the
    whole cache with position masking.
    Returns (out, new_cache).
    """
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])  # (B,S,H,Dh)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if positions is None:
        positions = jnp.arange(s)
        if cache_index is not None:
            positions = positions + cache_index
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)

    if kv_cache is not None:
        kc = jax.lax.dynamic_update_slice(
            kv_cache["k"], k.astype(kv_cache["k"].dtype), (0, cache_index, 0, 0)
        )
        vc = jax.lax.dynamic_update_slice(
            kv_cache["v"], v.astype(kv_cache["v"].dtype), (0, cache_index, 0, 0)
        )
        new_cache = {"k": kc, "v": vc}
        # decode: attend over the cache up to cache_index+s
        skv = kc.shape[1]
        n_rep = n_heads // n_kv_heads
        ke = _expand_kv(kc.astype(q.dtype), n_rep)
        ve = _expand_kv(vc.astype(q.dtype), n_rep)
        scale = d_head ** -0.5
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, ke).astype(jnp.float32) * scale
        kpos = jnp.arange(skv)
        qpos = positions
        mask = kpos[None, :] <= qpos[:, None]
        if window:
            mask &= qpos[:, None] - kpos[None, :] < window
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, ve)
    else:
        new_cache = None
        if chunked:
            out = chunked_attention(
                q, k, v, causal=causal, window=window,
                q_chunk=q_chunk, kv_chunk=kv_chunk,
            )
        else:
            out = dense_attention(q, k, v, causal=causal, window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------


def mla_attention(
    p,
    x,
    *,
    n_heads: int,
    q_lora: int,
    kv_lora: int,
    d_nope: int,
    d_rope: int,
    d_v: int,
    rope_theta: float,
    kv_cache=None,
    cache_index=None,
    chunked: bool = False,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
):
    """Multi-head latent attention with compressed KV cache.

    The cache stores only the latent ``c_kv`` (kv_lora) and the shared RoPE
    key (d_rope) per position — the memory win that makes MLA's long-context
    decode cheap; K/V are re-expanded per chunk at compute time.
    Returns (out, new_cache) with cache dict(ckv=(B,S,kv_lora), krope=...).
    """
    b, s, d_model = x.shape
    # --- queries through the low-rank bottleneck ---
    cq = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    cq = rms_norm(cq, p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])  # (B,S,H,d_nope+d_rope)
    q_nope, q_rope = q[..., :d_nope], q[..., d_nope:]
    # --- compressed kv + shared rope key ---
    ckv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])  # (B,S,kv_lora)
    ckv = rms_norm(ckv, p["kv_norm"])
    krope = jnp.einsum("bsd,dk->bsk", x, p["wk_rope"])  # (B,S,d_rope)

    positions = jnp.arange(s) + (cache_index if cache_index is not None else 0)
    q_rope = apply_rope(q_rope, positions, rope_theta)
    krope = apply_rope(krope[:, :, None, :], positions, rope_theta)[:, :, 0]

    if kv_cache is not None:
        ckv_c = jax.lax.dynamic_update_slice(
            kv_cache["ckv"], ckv.astype(kv_cache["ckv"].dtype), (0, cache_index, 0)
        )
        kr_c = jax.lax.dynamic_update_slice(
            kv_cache["krope"], krope.astype(kv_cache["krope"].dtype),
            (0, cache_index, 0),
        )
        new_cache = {"ckv": ckv_c, "krope": kr_c}
        ckv_full, krope_full = ckv_c.astype(x.dtype), kr_c.astype(x.dtype)
    else:
        new_cache = None
        ckv_full, krope_full = ckv, krope

    # expand latent to per-head K/V
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv_full, p["wk_b"])  # (B,Skv,H,d_nope)
    vfull = jnp.einsum("bsr,rhk->bshk", ckv_full, p["wv_b"])  # (B,Skv,H,d_v)
    kr = jnp.broadcast_to(
        krope_full[:, :, None, :],
        (b, krope_full.shape[1], n_heads, d_rope),
    )
    k = jnp.concatenate([k_nope, kr], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)

    if kv_cache is not None:
        skv = k.shape[1]
        scale = (d_nope + d_rope) ** -0.5
        logits = jnp.einsum("bqhd,bkhd->bhqk", qf, k).astype(jnp.float32) * scale
        mask = jnp.arange(skv)[None, :] <= positions[:, None]
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, -1).astype(x.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, vfull)
    elif chunked:
        out = chunked_attention(
            qf, k, vfull, causal=True, q_chunk=q_chunk, kv_chunk=kv_chunk
        )
    else:
        out = dense_attention(qf, k, vfull, causal=True)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache
