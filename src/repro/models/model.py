"""Model assembly: param specs, scan-over-layers forward, loss.

HLO size is O(1) in depth: homogeneous layer stacks are ``lax.scan``-ed over
stacked parameters (``(L, ...)`` leaves).  Heterogeneous structures keep the
discipline:

* llama-vision: 8 groups of (4 self layers -> scan) + 1 unrolled gated
  cross-attn block (pattern: cross every 5th layer);
* hymba: order-faithful segments — global full-attention layers at
  (first, middle, last) unrolled, sliding-window segments scanned;
* whisper: encoder scan + decoder scan (self + cross per layer).

Remat: ``cfg.remat`` wraps the scanned bodies with jax.checkpoint
(``full`` = nothing saveable, ``dots`` = dot outputs saveable).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import params as prm
from .blocks import (
    LayerCtx,
    cross_attn_block,
    dense_layer,
    hybrid_layer,
    moe_layer,
    ssm_layer,
)
from .layers import layer_norm, rms_norm
from .params import P, stack_specs


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def _norm_specs(cfg, name):
    s = {name: P((cfg.d_model,), (None,), "one")}
    if cfg.norm == "layernorm":
        s[name + "_b"] = P((cfg.d_model,), (None,), "zero")
    return s


def _layer_specs(cfg: ArchConfig, *, cross: bool = False) -> dict:
    """Spec of ONE layer of the main stack (unstacked)."""
    d = cfg.d_model
    s: dict = {}
    if cfg.family == "ssm":
        s["norm"] = P((d,), (None,), "one")
        s["mixer"] = prm.mamba_specs(cfg)
        return s
    s.update(_norm_specs(cfg, "attn_norm"))
    s["attn"] = prm.mla_specs(cfg) if cfg.attn_kind == "mla" else prm.gqa_specs(cfg)
    if cfg.family == "hybrid":
        s["mixer"] = prm.mamba_specs(cfg)
    s.update(_norm_specs(cfg, "ffn_norm"))
    if cfg.family == "moe":
        s["moe"] = prm.moe_specs(cfg)
        if cfg.n_shared_experts:
            s["shared"] = prm.swiglu_specs(d, cfg.d_ff)
        if cfg.dense_residual:
            s["dense"] = prm.swiglu_specs(d, cfg.d_ff)
    else:
        s["ffn"] = (
            prm.gelu_mlp_specs(d, cfg.d_ff)
            if cfg.act == "gelu"
            else prm.swiglu_specs(d, cfg.d_ff)
        )
    return s


def _hymba_segments(cfg: ArchConfig):
    """Order-faithful (kind, count) segments: g = global, s = sliding."""
    globals_ = sorted(cfg.global_layers)
    segs, prev = [], 0
    for g in globals_:
        if g > prev:
            segs.append(("s", g - prev))
        segs.append(("g", 1))
        prev = g + 1
    if prev < cfg.n_layers:
        segs.append(("s", cfg.n_layers - prev))
    return segs


def build_param_specs(cfg: ArchConfig) -> dict:
    d, V = cfg.d_model, cfg.vocab
    specs: dict = {
        "embed": P((V, d), ("vocab", "embed"), 0.02),
    }
    specs.update(_norm_specs(cfg, "final_norm"))
    if not cfg.tie_embeddings:
        specs["lm_head"] = P((d, V), ("embed", "vocab"))

    layer = _layer_specs(cfg)
    if cfg.family == "vlm":
        # n_layers total = (cross_every-1) self + 1 gated cross per group
        # (llama-3.2-vision: 40 = 8 x (4 self + 1 cross))
        assert cfg.n_layers % cfg.cross_every == 0
        n_cross = cfg.n_layers // cfg.cross_every
        self_per_group = cfg.cross_every - 1
        specs["layers"] = stack_specs(
            stack_specs(layer, self_per_group, "layers"), n_cross, "layers"
        )
        specs["cross"] = stack_specs(prm.cross_attn_specs(cfg), n_cross, "layers")
    elif cfg.family == "hybrid":
        n_g = len(cfg.global_layers)
        specs["global"] = stack_specs(layer, n_g, "layers")
        specs["sliding"] = stack_specs(layer, cfg.n_layers - n_g, "layers")
    else:
        specs["layers"] = stack_specs(layer, cfg.n_layers, "layers")

    if cfg.kind == "encdec":
        enc_layer = {
            **_norm_specs(cfg, "attn_norm"),
            "attn": prm.gqa_specs(cfg),
            **_norm_specs(cfg, "ffn_norm"),
            "ffn": prm.gelu_mlp_specs(d, cfg.d_ff),
        }
        specs["encoder"] = stack_specs(enc_layer, cfg.enc_layers, "layers")
        cross = prm.cross_attn_specs(cfg)
        cross.pop("gate")  # whisper cross-attn is ungated
        specs["cross"] = stack_specs(cross, cfg.n_layers, "layers")
        specs.update(_norm_specs(cfg, "enc_final_norm"))
    return specs


def init_params(cfg: ArchConfig, key: jax.Array):
    return prm.init_tree(build_param_specs(cfg), key, _dtype(cfg))


def abstract_params(cfg: ArchConfig):
    return prm.abstract_tree(build_param_specs(cfg), _dtype(cfg))


def param_axes(cfg: ArchConfig):
    return prm.axes_tree(build_param_specs(cfg))


def _dtype(cfg: ArchConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

_BODY = {
    "dense": dense_layer,
    "moe": moe_layer,
    "ssm": ssm_layer,
    "hybrid": hybrid_layer,
    "vlm": dense_layer,
    "audio": dense_layer,
}


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _scan_stack(cfg, body, x, stacked_params, ctx: LayerCtx, caches=None):
    """Scan a homogeneous layer stack; caches ride as scanned xs/ys."""

    from repro.parallel.constraints import constrain

    def step(carry, xs):
        x, aux = carry
        # pin residual-stream sharding per layer; "seq" maps to () by default
        # and to ("model",) under sequence parallelism (see §Perf hillclimb)
        x = constrain(x, "batch", "seq", None)
        if caches is None:
            p = xs
            x, _, a = body(cfg, p, x, ctx, None)
            return (x, aux + a), None
        p, cache = xs
        x, new_cache, a = body(cfg, p, x, ctx, cache)
        return (x, aux + a), new_cache

    xs = stacked_params if caches is None else (stacked_params, caches)
    (x, aux), new_caches = jax.lax.scan(_remat(cfg, step), (x, 0.0), xs)
    return x, aux, new_caches


def _decoder_forward(cfg, params, x, ctx: LayerCtx, caches=None):
    """Run the decoder stack; returns (hidden, aux, new_caches)."""
    body = _BODY[cfg.family]
    if cfg.family == "vlm":
        return _vlm_forward(cfg, params, x, ctx, caches)
    if cfg.family == "hybrid":
        return _hymba_forward(cfg, params, x, ctx, caches)
    if cfg.kind == "encdec":
        return _whisper_decoder(cfg, params, x, ctx, caches)
    return _scan_stack(cfg, body, x, params["layers"], ctx, caches)


def _vlm_forward(cfg, params, x, ctx: LayerCtx, caches=None):
    g = cfg.cross_every
    n_groups = cfg.n_layers // g
    aux = 0.0
    new_self, new_cross = [], []
    for gi in range(n_groups):
        grp = jax.tree.map(lambda t: t[gi], params["layers"])
        cache_g = None
        if caches is not None:
            cache_g = jax.tree.map(lambda t: t[gi], caches["self"])
        x, a, nc = _scan_stack(cfg, dense_layer, x, grp, ctx, cache_g)
        aux += a
        new_self.append(nc)
        cp = jax.tree.map(lambda t: t[gi], params["cross"])
        cross_cache = (
            jax.tree.map(lambda t: t[gi], caches["cross"]) if caches else None
        )
        x, _ = cross_attn_block(cfg, cp, x, ctx.vision, ctx, cross_cache)
    new_caches = None
    if caches is not None:
        new_caches = {
            "self": jax.tree.map(lambda *ts: jnp.stack(ts), *new_self),
            "cross": caches["cross"],  # static per request
        }
    return x, aux, new_caches


def _hymba_forward(cfg, params, x, ctx: LayerCtx, caches=None):
    segs = _hymba_segments(cfg)
    gi = si = 0
    aux = 0.0
    new_g, new_s = [], []
    for kind, count in segs:
        if kind == "g":
            p = jax.tree.map(lambda t: t[gi], params["global"])
            cache = (
                jax.tree.map(lambda t: t[gi], caches["global"]) if caches else None
            )
            gctx = LayerCtx(**{**ctx.__dict__, "window": 0})
            x, nc, a = hybrid_layer(cfg, p, x, gctx, cache)
            new_g.append(nc)
            gi += 1
        else:
            sl = jax.tree.map(lambda t: t[si : si + count], params["sliding"])
            cache = (
                jax.tree.map(lambda t: t[si : si + count], caches["sliding"])
                if caches
                else None
            )
            sctx = LayerCtx(**{**ctx.__dict__, "window": cfg.window})
            x, a, nc = _scan_stack(cfg, hybrid_layer, x, sl, sctx, cache)
            new_s.append(nc)
            si += count
        aux += a if isinstance(a, float) or a is not None else 0.0
    new_caches = None
    if caches is not None:
        new_caches = {
            "global": jax.tree.map(lambda *ts: jnp.stack(ts), *new_g),
            "sliding": jax.tree.map(
                lambda *ts: jnp.concatenate(ts, axis=0), *new_s
            ),
        }
    return x, aux, new_caches


def _whisper_encoder(cfg, params, frames):
    """Encoder over stub frame embeddings (B, enc_seq, d)."""
    ctx = LayerCtx(mode="train", causal=False)
    x, _, _ = _scan_stack(cfg, dense_layer, frames, params["encoder"], ctx)
    return layer_norm(x, params["enc_final_norm"], params["enc_final_norm_b"])


def _whisper_decoder(cfg, params, x, ctx: LayerCtx, caches=None):
    """Decoder: per layer self-attn then cross-attn to encoder states."""
    from repro.parallel.constraints import constrain

    def step(carry, xs):
        x, aux = carry
        x = constrain(x, "batch", "seq", None)  # pin residual sharding
        if caches is None:
            p, cp = xs
            x, _, _ = dense_layer(cfg, p, x, ctx, None)
            x, _ = cross_attn_block(cfg, cp, x, ctx.encoder_out, ctx, None)
            return (x, aux), None
        (p, cp), (cache, ccache) = xs
        x, nc, _ = dense_layer(cfg, p, x, ctx, cache)
        x, _ = cross_attn_block(cfg, cp, x, ctx.encoder_out, ctx, ccache)
        return (x, aux), nc

    xs = (params["layers"], params["cross"])
    if caches is not None:
        xs = (xs, (caches["self"], caches["cross"]))
    (x, aux), new_self = jax.lax.scan(_remat(cfg, step), (x, 0.0), xs)
    new_caches = None
    if caches is not None:
        new_caches = {"self": new_self, "cross": caches["cross"]}
    return x, aux, new_caches


def _final_norm(cfg, params, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, params["final_norm"], params["final_norm_b"])
    return rms_norm(x, params["final_norm"])


def logits_fn(cfg, params, x):
    x = _final_norm(cfg, params, x)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def forward(
    cfg: ArchConfig,
    params,
    tokens: jnp.ndarray,  # (B, S) int32
    *,
    mode: str = "train",
    chunked: bool | None = None,
    vision=None,
    frames=None,
    caches=None,
    cache_index=None,
):
    """Full forward. Returns (logits, aux, new_caches)."""
    x = params["embed"][tokens].astype(_dtype(cfg))
    if chunked is None:
        chunked = tokens.shape[1] > 2048
    encoder_out = None
    if cfg.kind == "encdec" and frames is not None:
        # decode passes frames=None: cross-attn reads precomputed caches
        encoder_out = _whisper_encoder(cfg, params, frames)
    ctx = LayerCtx(
        mode=mode,
        cache_index=cache_index,
        chunked=chunked and caches is None,
        causal=True,
        window=0,
        vision=vision,
        encoder_out=encoder_out,
    )
    x, aux, new_caches = _decoder_forward(cfg, params, x, ctx, caches)
    return logits_fn(cfg, params, x), aux, new_caches


def hidden_forward(cfg, params, tokens, **kw):
    """Forward returning the pre-head hidden states (B, S, d)."""
    x = params["embed"][tokens].astype(_dtype(cfg))
    chunked = kw.pop("chunked", None)
    if chunked is None:
        chunked = tokens.shape[1] > 2048
    encoder_out = None
    if cfg.kind == "encdec" and kw.get("frames") is not None:
        encoder_out = _whisper_encoder(cfg, params, kw["frames"])
    ctx = LayerCtx(
        mode=kw.get("mode", "train"),
        cache_index=kw.get("cache_index"),
        chunked=chunked and kw.get("caches") is None,
        causal=True,
        window=0,
        vision=kw.get("vision"),
        encoder_out=encoder_out,
    )
    x, aux, new_caches = _decoder_forward(cfg, params, x, ctx, kw.get("caches"))
    return x, aux, new_caches


def chunked_ce(cfg, params, hidden, targets, *, chunk: int = 2048):
    """Memory-safe cross-entropy: logits are never materialized whole.

    Scans over sequence chunks; each chunk projects to (B, C, V), reduces to
    logsumexp + label logit (one-hot contraction — stays vocab-sharded under
    SPMD, no gather all-gather), and is immediately freed.  This bounds the
    logits working set to B*C*V/devices regardless of sequence length.
    """
    b, s, d = hidden.shape
    c = min(chunk, s)
    while s % c:
        c -= 1  # largest chunk that tiles s (uniform grid, no ragged tail)
    nc = s // c
    hs = hidden.reshape(b, nc, c, d).swapaxes(0, 1)  # (nc, b, c, d)
    ts = targets.reshape(b, nc, c).swapaxes(0, 1)

    from repro.parallel.constraints import constrain

    def step(acc, xs):
        h, t = xs
        h = constrain(h, "batch", None, None)
        logits = logits_fn(cfg, params, h).astype(jnp.float32)  # (b,c,V)
        # keep logits batch-sharded x vocab-sharded: without this pin XLA has
        # been observed to all-reduce batch-replicated logits over fsdp
        logits = constrain(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        label = jnp.sum(
            logits * jax.nn.one_hot(t, logits.shape[-1], dtype=jnp.float32),
            axis=-1,
        )
        return acc + jnp.sum(lse - label), None

    total, _ = jax.lax.scan(jax.checkpoint(step), jnp.float32(0.0), (hs, ts))
    return total / (b * s)


def lm_loss(cfg, params, batch, *, aux_weight: float = 0.01):
    """Next-token CE (+ MoE aux).  batch: dict(tokens, plus stub inputs)."""
    tokens = batch["tokens"]
    hidden, aux, _ = hidden_forward(
        cfg,
        params,
        tokens[:, :-1],
        vision=batch.get("vision"),
        frames=batch.get("frames"),
    )
    loss = chunked_ce(cfg, params, hidden, tokens[:, 1:])
    if cfg.n_experts:
        loss = loss + aux_weight * aux / max(cfg.n_layers, 1)
    return loss
