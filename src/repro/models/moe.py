"""Mixture-of-Experts: top-k token-choice routing with capacity dispatch.

GShard/MaxText-style dense dispatch: tokens are organized into groups
(``(G, T_g, d)``; G shards over the data axis), each group dispatches into
``(E, C)`` expert buffers via one-hot einsums, experts run as a single
grouped matmul ``(G, E, C, d) x (E, d, f)`` (E shards over the expert axis =
mesh 'model'), and results combine back with the routing weights.  Dropped
tokens (over capacity) fall through the residual connection.

Active-FLOPs accounting is exact: G*E*C == tokens * top_k (capacity factor
1.0), so ``cost_analysis`` FLOPs match 6*N_active*D for the roofline's
MODEL_FLOPS ratio.

Shared experts (Qwen2-MoE) and a parallel dense residual MLP (Arctic) are
composed in :mod:`repro.models.blocks`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp



def route_topk(logits: jnp.ndarray, top_k: int):
    """Top-k routing: returns (expert_idx (..., k), weights (..., k)).

    Weights are the softmax over the selected experts' logits (Mixtral /
    Qwen2-MoE convention).
    """
    vals, idx = jax.lax.top_k(logits, top_k)
    w = jax.nn.softmax(vals.astype(jnp.float32), axis=-1)
    return idx, w


def dispatch_combine(
    x: jnp.ndarray,  # (G, T, d) grouped tokens
    expert_idx: jnp.ndarray,  # (G, T, k)
    weights: jnp.ndarray,  # (G, T, k)
    n_experts: int,
    capacity: int,
):
    """Build dispatch/combine tensors with per-expert capacity.

    Position of a token inside its expert buffer = running count of earlier
    claims on that expert within the group (cumsum trick); claims beyond
    ``capacity`` are dropped.
    Returns (dispatched (G, E, C, d), combine (G, T, E, C)).
    """
    g, t, k = expert_idx.shape
    onehot = jax.nn.one_hot(expert_idx, n_experts, dtype=jnp.int32)  # (G,T,k,E)
    # claims ordered (token0 choice0, token0 choice1, token1 choice0, ...):
    # capacity is first-come-first-served in token order
    claims = onehot.reshape(g, t * k, n_experts)
    pos = (jnp.cumsum(claims, axis=1) - claims).reshape(g, t, k, n_experts)
    # position of each claim inside ITS chosen expert only — keeps every
    # materialized tensor at (G,T,k,·); the (G,T,k,E,C) outer product below
    # is contracted over k by dot_general without materializing.
    pos_sel = jnp.take_along_axis(pos, expert_idx[..., None], axis=-1)[..., 0]
    in_cap = (pos_sel < capacity).astype(x.dtype)  # (G,T,k)
    oh_e = onehot.astype(x.dtype) * in_cap[..., None]  # (G,T,k,E)
    oh_c = jax.nn.one_hot(pos_sel, capacity, dtype=x.dtype)  # (G,T,k,C)
    dispatch = jnp.einsum("gtke,gtkc->gtec", oh_e, oh_c)  # (G,T,E,C) 0/1
    combine = jnp.einsum(
        "gtke,gtkc->gtec", oh_e, oh_c * weights[..., None].astype(x.dtype)
    )
    dispatched = jnp.einsum("gtec,gtd->gecd", dispatch, x)
    return dispatched, combine


def moe_ffn(
    p: dict,
    x: jnp.ndarray,  # (B, S, d)
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.0,
    groups: int = 1,
    router_dtype=jnp.float32,
):
    """Full MoE FFN block.  Returns (y, aux) with load-balance aux loss."""
    b, s, d = x.shape
    tokens = b * s
    assert tokens % groups == 0
    tg = tokens // groups
    xg = x.reshape(groups, tg, d)

    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(router_dtype), p["router"].astype(router_dtype)
    )
    idx, w = route_topk(logits, top_k)
    capacity = max(1, int(tg * top_k * capacity_factor) // n_experts)
    dispatched, combine = dispatch_combine(xg, idx, w, n_experts, capacity)

    # experts: grouped SwiGLU over (G, E, C, d)
    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", dispatched, p["w_gate"]))
    up = jnp.einsum("gecd,edf->gecf", dispatched, p["w_up"])
    h = jnp.einsum("gecf,efd->gecd", gate * up, p["w_down"])
    y = jnp.einsum("gtec,gecd->gtd", combine, h)

    # load-balance aux (Switch): E * mean(frac_tokens_e * mean_prob_e)
    probs = jax.nn.softmax(logits, axis=-1)
    frac = jnp.mean(
        jax.nn.one_hot(idx[..., 0], n_experts, dtype=jnp.float32), axis=(0, 1)
    )
    aux = n_experts * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))
    return y.reshape(b, s, d).astype(x.dtype), aux
