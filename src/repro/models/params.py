"""Parameter specs: one source of truth for shapes, init scales and logical
sharding axes.

Every leaf is declared as ``P(shape, axes, scale)``; the same tree drives

* real initialization (smoke tests / training) — truncated-normal with
  fan-in scaling;
* abstract initialization (dry-run) — ``jax.ShapeDtypeStruct`` only;
* sharding — the ``axes`` tuple of logical names is resolved against the
  mesh by :mod:`repro.parallel.sharding`.

Logical axis vocabulary: ``embed, mlp, heads, kv_heads, head, vocab,
experts, expert_mlp, lora, state, conv, layers`` (None = replicated).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class P:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    scale: float | str = "fan_in"  # "fan_in" | "zero" | "one" | float

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(key, spec: P, dtype) -> jnp.ndarray:
    if spec.scale == "zero":
        return jnp.zeros(spec.shape, dtype)
    if spec.scale == "one":
        return jnp.ones(spec.shape, dtype)
    if spec.scale == "fan_in":
        fan_in = spec.shape[0] if len(spec.shape) == 1 else int(
            np.prod(spec.shape[:-1])
        )
        std = min(1.0, (1.0 / max(fan_in, 1)) ** 0.5)
    else:
        std = float(spec.scale)
    return (jax.random.truncated_normal(key, -2.0, 2.0, spec.shape) * std).astype(
        dtype
    )


def init_tree(specs: Any, key: jax.Array, dtype=jnp.bfloat16):
    """Materialize a spec tree into real parameters."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_tree(specs: Any, dtype=jnp.bfloat16):
    """Spec tree -> ShapeDtypeStruct tree (no allocation; dry-run)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def axes_tree(specs: Any):
    """Spec tree -> logical-axes tree (same structure)."""
    return jax.tree.map(
        lambda s: s.axes, specs, is_leaf=lambda x: isinstance(x, P)
    )


def stack_specs(specs: Any, n: int, axis_name: str = "layers"):
    """Prepend a stacked (scan) dimension to every leaf of a layer spec."""
    return jax.tree.map(
        lambda s: P((n,) + s.shape, (axis_name,) + s.axes, s.scale),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Layer spec builders (cfg is an ArchConfig; import-free to avoid cycles)
# ---------------------------------------------------------------------------


def gqa_specs(cfg) -> dict:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    return {
        "wq": P((d, H, Dh), ("embed", "heads", None)),
        "wk": P((d, Hkv, Dh), ("embed", "kv_heads", None)),
        "wv": P((d, Hkv, Dh), ("embed", "kv_heads", None)),
        "wo": P((H, Dh, d), ("heads", None, "embed")),
    }


def mla_specs(cfg) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.d_nope, cfg.d_rope, cfg.d_v
    return {
        "wq_a": P((d, r_q), ("embed", "lora")),
        "q_norm": P((r_q,), (None,), "one"),
        "wq_b": P((r_q, H, dn + dr), ("lora", "heads", None)),
        "wkv_a": P((d, r_kv), ("embed", "lora")),
        "kv_norm": P((r_kv,), (None,), "one"),
        "wk_rope": P((d, dr), ("embed", None)),
        "wk_b": P((r_kv, H, dn), ("lora", "heads", None)),
        "wv_b": P((r_kv, H, dv), ("lora", "heads", None)),
        "wo": P((H, dv, d), ("heads", None, "embed")),
    }


def swiglu_specs(d: int, f: int) -> dict:
    return {
        "w_gate": P((d, f), ("embed", "mlp")),
        "w_up": P((d, f), ("embed", "mlp")),
        "w_down": P((f, d), ("mlp", "embed")),
    }


def gelu_mlp_specs(d: int, f: int) -> dict:
    return {
        "w_in": P((d, f), ("embed", "mlp")),
        "w_out": P((f, d), ("mlp", "embed")),
    }


def moe_specs(cfg) -> dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    specs = {
        "router": P((d, E), ("embed", None)),
        "w_gate": P((E, d, f), ("experts", "embed", None)),
        "w_up": P((E, d, f), ("experts", "embed", None)),
        "w_down": P((E, f, d), ("experts", None, "embed")),
    }
    return specs


def mamba_specs(cfg) -> dict:
    d = cfg.d_model
    H, Pd, N, K = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_conv
    di = H * Pd
    conv_ch = di + 2 * N
    return {
        "w_in": P((d, 2 * di + 2 * N + H), ("embed", "mlp")),
        "conv_w": P((K, conv_ch), (None, "mlp")),
        "dt_bias": P((H,), (None,), "zero"),
        "A_log": P((H,), (None,), 0.5),
        "D": P((H,), (None,), "one"),
        "w_out": P((di, d), ("mlp", "embed")),
    }


def cross_attn_specs(cfg) -> dict:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    s = {
        "wq": P((d, H, Dh), ("embed", "heads", None)),
        "wk": P((d, Hkv, Dh), ("embed", "kv_heads", None)),
        "wv": P((d, Hkv, Dh), ("embed", "kv_heads", None)),
        "wo": P((H, Dh, d), ("heads", None, "embed")),
        "gate": P((1,), (None,), "zero"),  # gated cross-attn (llama-vision)
        "norm": P((d,), (None,), "one"),
    }
    if cfg.norm == "layernorm":
        s["norm_b"] = P((d,), (None,), "zero")
    return s
