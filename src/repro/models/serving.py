"""Serving substrate: cache specs, init, and the decode step.

Caches are declared with the same :class:`~repro.models.params.P` spec
machinery as parameters, so abstract init (dry-run) and sharding resolution
are shared.  Cache layouts per family:

* GQA:    k/v  (L, B, S_max, H_kv, D_h)
* MLA:    ckv  (L, B, S_max, kv_lora) + krope (L, B, S_max, d_rope) — the
          compressed-latent cache that makes MLA decode memory ~20x smaller
* SSM:    conv (L, B, K-1, conv_ch) + state (L, B, H, P, N) — O(1) in S
* hybrid: 'global' (full attn caches, len 3) + 'sliding' stacks + ssm states
* vlm:    'self' (grouped) + 'cross' (precomputed vision K/V per request)
* encdec: 'self' + 'cross' (precomputed audio K/V per request)

The decode step consumes one token per sequence and updates caches at
``cache_index`` (a traced scalar), so one compiled step serves every
position.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import params as prm
from .model import _dtype, forward
from .params import P


def _gqa_cache(cfg, L, B, S) -> dict:
    Hkv, Dh = cfg.n_kv_heads, cfg.d_head
    ax = ("layers", "batch", "cache_seq", "kv_heads", None)
    return {
        "k": P((L, B, S, Hkv, Dh), ax, "zero"),
        "v": P((L, B, S, Hkv, Dh), ax, "zero"),
    }


def _mla_cache(cfg, L, B, S) -> dict:
    return {
        "ckv": P((L, B, S, cfg.kv_lora_rank), ("layers", "batch", "cache_seq", None), "zero"),
        "krope": P((L, B, S, cfg.d_rope), ("layers", "batch", "cache_seq", None), "zero"),
    }


def _ssm_cache(cfg, L, B) -> dict:
    di = cfg.ssm_heads * cfg.ssm_head_dim
    conv_ch = di + 2 * cfg.ssm_state
    return {
        "conv": P((L, B, cfg.ssm_conv - 1, conv_ch), ("layers", "batch", None, "mlp"), "zero"),
        "state": P(
            (L, B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            ("layers", "batch", "heads", None, None),
            "zero",
        ),
    }


def _cross_cache(cfg, L, B, S_src) -> dict:
    ax = ("layers", "batch", None, "kv_heads", None)
    return {
        "k": P((L, B, S_src, cfg.n_kv_heads, cfg.d_head), ax, "zero"),
        "v": P((L, B, S_src, cfg.n_kv_heads, cfg.d_head), ax, "zero"),
    }


def build_cache_specs(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    L, B, S = cfg.n_layers, batch, max_seq
    if cfg.family == "ssm":
        return _ssm_cache(cfg, L, B)
    if cfg.family == "hybrid":
        n_g = len(cfg.global_layers)
        n_s = L - n_g
        return {
            "global": {"attn": _gqa_cache(cfg, n_g, B, S), "ssm": _ssm_cache(cfg, n_g, B)},
            "sliding": {"attn": _gqa_cache(cfg, n_s, B, S), "ssm": _ssm_cache(cfg, n_s, B)},
        }
    if cfg.family == "vlm":
        n_cross = L // cfg.cross_every
        spg = cfg.cross_every - 1
        self_c = _gqa_cache(cfg, n_cross, B, S)
        self_c = jax.tree.map(
            lambda p: P((p.shape[0], spg) + p.shape[1:], (p.axes[0], "layers") + p.axes[1:], "zero"),
            self_c,
            is_leaf=lambda x: isinstance(x, P),
        )
        return {"self": self_c, "cross": _cross_cache(cfg, n_cross, B, cfg.vis_seq)}
    if cfg.kind == "encdec":
        return {
            "self": _gqa_cache(cfg, L, B, S),
            "cross": _cross_cache(cfg, L, B, cfg.enc_seq),
        }
    if cfg.attn_kind == "mla":
        return _mla_cache(cfg, L, B, S)
    return _gqa_cache(cfg, L, B, S)


def init_caches(cfg: ArchConfig, batch: int, max_seq: int):
    specs = build_cache_specs(cfg, batch, max_seq)
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, _dtype(cfg)),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def abstract_caches(cfg: ArchConfig, batch: int, max_seq: int):
    return prm.abstract_tree(build_cache_specs(cfg, batch, max_seq), _dtype(cfg))


def cache_axes(cfg: ArchConfig, batch: int, max_seq: int):
    return prm.axes_tree(build_cache_specs(cfg, batch, max_seq))


def hybrid_split_caches(cfg, caches):
    """Reorder hybrid caches into the forward pass's (global, sliding) view.

    The specs already separate global/sliding stacks; the forward pass
    additionally needs hybrid sub-caches zipped as {'attn':..., 'ssm':...}
    per layer — the spec layout matches, so this is the identity today; kept
    as the single point of change if cache layouts diverge.
    """
    return caches


def prefill_cross_caches(cfg: ArchConfig, params, caches, *, vision=None, frames=None):
    """Fill the per-request cross-attention K/V caches (vlm / encdec).

    Projections run once per request; every decode step then reads the
    cached K/V (production-serving dataflow).
    """
    if cfg.family == "vlm":
        src = vision  # (B, vis_seq, d)
        wk, wv = params["cross"]["wk"], params["cross"]["wv"]
    elif cfg.kind == "encdec":
        from .model import _whisper_encoder

        src = _whisper_encoder(cfg, params, frames)
        wk, wv = params["cross"]["wk"], params["cross"]["wv"]
    else:
        return caches
    k = jnp.einsum("bsd,ldhk->lbshk", src, wk)
    v = jnp.einsum("bsd,ldhk->lbshk", src, wv)
    dt = caches["cross"]["k"].dtype
    new = dict(caches)
    new["cross"] = {"k": k.astype(dt), "v": v.astype(dt)}
    return new


def decode_step(
    cfg: ArchConfig,
    params,
    tokens: jnp.ndarray,  # (B, 1) int32
    caches,
    cache_index,  # scalar int32 position
    *,
    vision=None,
    frames=None,
    encoder_out=None,
):
    """One serving step: next-token logits + updated caches.

    For encdec, ``frames`` drives the (stub-frontend) encoder each call only
    if ``encoder_out`` is not provided; production serving passes the cross
    caches precomputed and ``encoder_out=None`` is fine because cross-attn
    reads ``caches['cross']`` directly.
    """
    c = _to_forward_caches(cfg, caches)
    logits, _, new_c = forward(
        cfg,
        params,
        tokens,
        mode="decode",
        chunked=False,
        vision=vision,
        frames=frames,
        caches=c,
        cache_index=cache_index,
    )
    return logits[:, -1, :], _from_forward_caches(cfg, new_c)


def _to_forward_caches(cfg, caches):
    if cfg.family == "hybrid":
        # forward scans want per-layer dicts {'attn': {k,v}, 'ssm': {...}}
        def regroup(part):
            return {"attn": part["attn"], "ssm": part["ssm"]}

        return {"global": regroup(caches["global"]), "sliding": regroup(caches["sliding"])}
    return caches


def _from_forward_caches(cfg, caches):
    return caches
