"""Mamba-2 (SSD, state-space duality) blocks — arXiv:2405.21060.

Chunked SSD for train/prefill (intra-chunk quadratic + inter-chunk state
recurrence, the paper's Listing-1 decomposition) and an O(1)-per-token
recurrent step for decode.  The depthwise causal conv1d + gating + SSD chunk
scan is the framework's direct analogue of a USEFUSE fusion pyramid — a
windowed op feeding a recurrent op with a uniform chunk stride (DESIGN.md
§5) — and is fused accordingly: all chunk intermediates stay in the scan
body, never materialized across the sequence.

Shapes: heads H with head dim P (= d_inner / H), state N, groups G=1 (B/C
shared across heads).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _segsum(a):
    """Stable segment-sum: out[..., i, j] = sum a[..., j+1..i] (lower-tri)."""
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, D, *, chunk: int, h0=None):
    """Chunked SSD scan.

    x: (b, S, H, P), dt: (b, S, H) (post-softplus), A: (H,) negative,
    B/C: (b, S, N) shared across heads (G=1), D: (H,).
    Returns (y (b,S,H,P), final_state (b,H,P,N)).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    assert S % chunk == 0, "uniform chunk grid"
    nc = S // chunk

    # discretize: per-step log decay and input scaling
    dA = dt * A[None, None, :]  # (b,S,H) negative
    xb = x * dt[..., None]  # dt-scaled input (ZOH simplification, mamba2)

    # chunk views: (nc, b, chunk, ...)
    def chunked(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xc, dAc, Bc, Cc = chunked(xb), chunked(dA), chunked(B), chunked(C)

    def chunk_step(state, inp):
        xk, dAk, Bk, Ck = inp  # (b,chunk,H,P), (b,chunk,H), (b,chunk,N)
        cums = jnp.cumsum(dAk, axis=1)  # (b,chunk,H)
        # ---- intra-chunk (quadratic, attention-like with decay) ----
        L = jnp.exp(_segsum(dAk.transpose(0, 2, 1)))  # (b,H,chunk,chunk)
        scores = jnp.einsum("bqn,bkn->bqk", Ck, Bk)  # (b,chunk,chunk)
        y_diag = jnp.einsum(
            "bhqk,bqk,bkhp->bqhp", L.astype(x.dtype), scores.astype(x.dtype), xk
        )
        # ---- contribution of the carried state ----
        decay_in = jnp.exp(cums)  # (b,chunk,H)
        y_off = jnp.einsum(
            "bqn,bhpn,bqh->bqhp", Ck, state.astype(jnp.float32), decay_in
        ).astype(x.dtype)
        # ---- new carried state ----
        decay_out = jnp.exp(cums[:, -1:, :] - cums)  # (b,chunk,H)
        new_state = state * jnp.exp(cums[:, -1, :])[..., None, None] + jnp.einsum(
            "bkn,bkh,bkhp->bhpn", Bk, decay_out, xk
        ).astype(jnp.float32)
        return new_state, y_diag + y_off

    state0 = (
        jnp.zeros((b, H, P, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    )
    final_state, ys = jax.lax.scan(chunk_step, state0, (xc, dAc, Bc, Cc))
    y = ys.swapaxes(0, 1).reshape(b, S, H, P)
    return y + x * D[None, None, :, None], final_state


def ssd_decode_step(state, x, dt, A, B, C, D):
    """Single-token SSD recurrence.  state: (b,H,P,N); x: (b,H,P);
    dt: (b,H); B/C: (b,N).  Returns (y (b,H,P), new_state)."""
    dA = jnp.exp(dt * A[None, :])  # (b,H)
    xb = x * dt[..., None]
    new_state = state * dA[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", xb, B
    ).astype(state.dtype)
    y = jnp.einsum("bhpn,bn->bhp", new_state.astype(jnp.float32), C).astype(x.dtype)
    return y + x * D[None, :, None], new_state


def causal_conv1d(x, w, *, state=None):
    """Depthwise causal conv over (b, S, C) with kernel (K, C).

    ``state``: (b, K-1, C) rolling buffer for decode.  Returns (y, new_state).
    """
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1) :, :] if K > 1 else None
    return y, new_state


def mamba2_mixer(
    p: dict,
    x: jnp.ndarray,  # (b, S, d_model)
    *,
    n_heads: int,
    head_dim: int,
    state_dim: int,
    conv_dim: int = 4,
    chunk: int = 256,
    ssm_cache=None,  # dict(conv=(b,K-1,conv_ch), state=(b,H,P,N)) for decode
):
    """Full Mamba-2 mixer: in_proj -> conv1d -> SSD -> gate -> out_proj.

    Returns (y, new_cache).
    """
    b, S, _ = x.shape
    d_inner = n_heads * head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xbc, dt = jnp.split(
        zxbcdt, [d_inner, d_inner + d_inner + 2 * state_dim], axis=-1
    )
    conv_state = None if ssm_cache is None else ssm_cache["conv"]
    xbc, new_conv = causal_conv1d(xbc, p["conv_w"], state=conv_state)
    xbc = jax.nn.silu(xbc)  # mamba2: silu AFTER the causal conv
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + state_dim], axis=-1)
    xs = xs.reshape(b, S, n_heads, head_dim)
    dt = jax.nn.softplus(dt + p["dt_bias"])  # (b,S,H)
    A = -jnp.exp(p["A_log"])  # (H,) negative

    if ssm_cache is None:
        ch = min(chunk, S)
        pad = (-S) % ch  # trailing pad never leaks backward (causal)
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
            C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        y, final_state = ssd_chunked(xs, dt, A, B, C, p["D"], chunk=ch)
        if pad:
            y = y[:, :S]
        new_cache = None
    else:
        assert S == 1
        y, final_state = ssd_decode_step(
            ssm_cache["state"], xs[:, 0], dt[:, 0], A, B[:, 0], C[:, 0], p["D"]
        )
        y = y[:, None]
        new_cache = {"conv": new_conv, "state": final_state}

    y = y.reshape(b, S, d_inner)
    y = y * jax.nn.silu(z)  # gating
    y = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return y, new_cache
