"""Whole-network fusion: graph IR, memory-aware auto-partitioner, runner.

The subsystem that turns per-pyramid fusion (``kernels/fused_conv``) into
end-to-end CNN inference with machine-chosen fusion boundaries:

* :mod:`repro.net.graph` — small CNN graph IR + the model zoo (LeNet-5,
  AlexNet, VGG-16, ResNet-18) and fusable-segment extraction.
* :mod:`repro.net.partition` — memory-aware auto-partitioner: a dynamic
  program over legal pyramid cuts minimizing modeled HBM traffic, then
  modeled latency, under the VMEM budget.
* :mod:`repro.net.runner` — jit-compiled batched ``run_network`` executing a
  :class:`~repro.net.partition.PartitionPlan` as fused-pyramid launches plus
  residual adds and the classifier head, with per-level END skip statistics.
"""

from .graph import MODELS, Graph, Node, fusable_segments, infer_shapes
from .partition import (
    PartitionPlan,
    PyramidPlan,
    auto_partition,
    layerwise_partition,
    paper_partition,
)
from .runner import (
    bf16_logit_tol,
    init_network_params,
    prepare_network_params,
    reference_network,
    run_network,
)

__all__ = [
    "MODELS",
    "Graph",
    "Node",
    "PartitionPlan",
    "PyramidPlan",
    "auto_partition",
    "bf16_logit_tol",
    "fusable_segments",
    "infer_shapes",
    "init_network_params",
    "layerwise_partition",
    "paper_partition",
    "prepare_network_params",
    "reference_network",
    "run_network",
]
