"""Whole-network fusion: graph IR, memory-aware auto-partitioner, runner.

The subsystem that turns per-pyramid fusion (``kernels/fused_conv``) into
end-to-end CNN inference with machine-chosen fusion boundaries:

* :mod:`repro.net.graph` — small CNN graph IR + the model zoo (LeNet-5,
  AlexNet, VGG-16, ResNet-18) and fusable-segment extraction.
* :mod:`repro.net.partition` — memory-aware auto-partitioner: a dynamic
  program over legal pyramid cuts minimizing modeled HBM traffic, then
  modeled latency, under the VMEM budget.
* :mod:`repro.net.runner` — jit-compiled batched ``run_network`` executing a
  :class:`~repro.net.partition.PartitionPlan` as fused-pyramid launches plus
  residual adds and the classifier head, with per-level END skip statistics.
* :mod:`repro.net.serve` — continuous bucketed batching over the runner:
  FIFO admission through ``robust.validate.check_request``, pad-to-bucket
  execution through a plan+jit LRU cache keyed (graph, bucket, dtype),
  double-buffered host→device input staging, and per-bucket modeled-SLO vs
  measured-latency reporting (DESIGN.md §14), plus the §15 resilience
  layer: deadline/priority EDF admission with load shedding, a per-bucket
  circuit breaker, a watchdog, and an output sentinel.
* :mod:`repro.net.frontend` — the concurrent front end: thread-safe
  ``submit`` returning Future-style handles, one background drain thread.
"""

from .graph import MODELS, Graph, Node, fusable_segments, infer_shapes
from .partition import (
    PartitionPlan,
    PyramidPlan,
    auto_partition,
    layerwise_partition,
    paper_partition,
)
from .runner import (
    bf16_logit_tol,
    init_network_params,
    jit_trace_count,
    prepare_network_params,
    reference_network,
    reset_jit_trace_count,
    run_network,
)
# serve.py loads lazily so `python -m repro.net.serve` doesn't import the
# module twice (once as repro.net.serve, once as __main__ via runpy)
_LAZY_SERVE = (
    "Request", "RequestResult", "ServeConfig", "ServingEngine",
    "bucket_for", "pad_to_bucket",
)
_LAZY_FRONTEND = ("RequestHandle", "ServingFrontend")


def __getattr__(name: str):
    if name in _LAZY_SERVE:
        from . import serve

        return getattr(serve, name)
    if name in _LAZY_FRONTEND:
        from . import frontend

        return getattr(frontend, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "MODELS",
    "Graph",
    "Node",
    "PartitionPlan",
    "PyramidPlan",
    "Request",
    "RequestHandle",
    "RequestResult",
    "ServeConfig",
    "ServingEngine",
    "ServingFrontend",
    "auto_partition",
    "bf16_logit_tol",
    "bucket_for",
    "fusable_segments",
    "infer_shapes",
    "init_network_params",
    "jit_trace_count",
    "layerwise_partition",
    "pad_to_bucket",
    "paper_partition",
    "prepare_network_params",
    "reference_network",
    "reset_jit_trace_count",
    "run_network",
]
