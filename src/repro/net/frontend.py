"""Concurrent serving front end: Future-style handles over one drain loop.

:class:`~repro.net.serve.ServingEngine` is deliberately synchronous — its
``submit``/``drain`` split keeps the execution path testable and the jax
work single-threaded.  Production traffic is neither: requests arrive from
many threads and callers want to *wait on their own result*, not poll a
results dict.  This module is the bridge (DESIGN.md §15):

* :class:`ServingFrontend` wraps an engine with a **background drain
  thread**: producer threads call :meth:`ServingFrontend.submit` (the
  engine's locked admission path — shape checks, shedding, typed
  rejection all still apply) and get back a :class:`RequestHandle`; a
  daemon thread wakes on every submit and runs ``engine.drain()``, so
  batches keep the engine's double-buffered staging and all jax calls
  stay on one thread.
* :class:`RequestHandle` is a minimal Future: :meth:`RequestHandle.result`
  blocks (with timeout) until the request is terminal and returns the
  :class:`~repro.net.serve.RequestResult` — completed, rejected, shed,
  expired, or failed, always typed, never an exception from the engine's
  internals.

Delivery rides the engine's completion listeners: every terminal result
fires the frontend's listener, which resolves the matching handle.  A
request can complete *before* its handle is registered (the drain thread
races the submit return path), so results with no handle yet are parked
and claimed at registration — no result is ever lost to the race, which
is exactly what the multi-threaded hammer test asserts.

Use::

    frontend = ServingFrontend(engine)
    with frontend:
        handles = [frontend.submit(x, deadline_us=5e5) for x in stream]
        results = [h.result(timeout=30.0) for h in handles]
"""

from __future__ import annotations

import threading

from .serve import RequestResult, ServingEngine


class RequestHandle:
    """A Future-style handle for one submitted request."""

    def __init__(self, rid: int) -> None:
        self.id = rid
        self._event = threading.Event()
        self._result: RequestResult | None = None

    def _resolve(self, result: RequestResult) -> None:
        self._result = result
        self._event.set()

    def done(self) -> bool:
        """True once the request is terminal (result available)."""
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> RequestResult:
        """Block until the request is terminal; returns its
        :class:`RequestResult`.  Raises ``TimeoutError`` if ``timeout``
        seconds pass first — the request may still complete later."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.id} not terminal after {timeout}s"
            )
        return self._result


class ServingFrontend:
    """Thread-safe async layer over one :class:`ServingEngine`.

    ``start()`` launches the daemon drain thread (the context manager does
    it for you); ``submit`` admits from any thread and returns a
    :class:`RequestHandle`; ``stop()`` drains outstanding work and joins
    the thread.  The engine must not be drained by anyone else while the
    frontend owns it — the engine's drain lock enforces serialization, but
    a foreign drain would steal completions the frontend expects to
    observe (it still would via the listener; it just wastes a wake-up).
    """

    def __init__(self, engine: ServingEngine) -> None:
        self.engine = engine
        self._handles: dict[int, RequestHandle] = {}
        self._early: dict[int, RequestResult] = {}
        self._lock = threading.Lock()
        self._work = threading.Event()
        self._stopping = threading.Event()
        self._thread: threading.Thread | None = None
        engine.add_listener(self._on_result)

    # -- result delivery ----------------------------------------------------

    def _on_result(self, result: RequestResult) -> None:
        # called by the engine (under its lock) for every terminal result;
        # park results whose handle is not registered yet — submit() may
        # still be between engine.submit() and _register()
        with self._lock:
            handle = self._handles.pop(result.id, None)
            if handle is None:
                self._early[result.id] = result
                return
        handle._resolve(result)

    def _register(self, rid: int) -> RequestHandle:
        handle = RequestHandle(rid)
        with self._lock:
            early = self._early.pop(rid, None)
            if early is None:
                self._handles[rid] = handle
        if early is not None:
            handle._resolve(early)
        return handle

    # -- producer API -------------------------------------------------------

    def submit(self, x, *, deadline_us: float | None = None,
               priority: int = 0) -> RequestHandle:
        """Admit one request from any thread; returns its handle.

        Rejections (bad shape, full queue, admission shed) resolve the
        handle immediately with the typed error result — ``submit`` itself
        never raises for a bad request."""
        rid = self.engine.submit(x, deadline_us=deadline_us,
                                 priority=priority)
        handle = self._register(rid)
        self._work.set()
        return handle

    # -- drain loop ---------------------------------------------------------

    def _loop(self) -> None:
        while not self._stopping.is_set():
            self._work.wait(timeout=0.05)
            self._work.clear()
            self.engine.drain()
        self.engine.drain()  # final sweep: nothing submitted is abandoned

    def start(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            return
        self._stopping.clear()
        self._thread = threading.Thread(
            target=self._loop, name="serve-drain", daemon=True
        )
        self._thread.start()

    def stop(self, timeout: float | None = 30.0) -> None:
        """Signal the drain thread, let it finish outstanding work, join."""
        if self._thread is None:
            return
        self._stopping.set()
        self._work.set()
        self._thread.join(timeout)
        self._thread = None

    def __enter__(self) -> ServingFrontend:
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
