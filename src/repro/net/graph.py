"""CNN graph IR: whole networks as explicit dataflow graphs.

A :class:`Graph` is a topologically-ordered tuple of :class:`Node` — conv /
pool / relu / residual-add / global-pool / flatten / dense — each naming its
producer nodes.  Construction validates the whole graph (unique names,
forward references only, shape/channel chaining) so malformed networks fail
here with a named node, not deep inside a kernel.

The IR is the single source of the model zoo: :func:`lenet5`,
:func:`alexnet`, :func:`vgg16` and :func:`resnet18` replace the raw tuple
tables that used to live in ``core/cnn_models.py`` (which now *derives* its
paper fusion specs from these graphs).  All builders take ``input_size`` so
tests and interpret-mode demos can run reduced-scale variants of the same
topology.

:func:`fusable_segments` extracts the maximal linear conv/pool chains the
auto-partitioner (:mod:`repro.net.partition`) is allowed to cut into fusion
pyramids.  Chain boundaries — residual joins, multi-consumer forks (the
block input feeding both body and shortcut), standalone activations, the
classifier head — are exactly the IR nodes that force a feature map to
materialize, i.e. the partitioner's legal cut points.

Activation convention: conv and dense nodes carry a fused ``relu`` flag (the
paper's pyramids are conv+ReLU stacks; the Pallas kernel applies ReLU per
conv level), while standalone ``relu`` nodes express post-residual-add
activations.  A fusable chain must be relu-uniform across its convs because
one pyramid launch applies a single activation mode.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

from repro.core.dtypes import canonical_dtype
from repro.core.fusion import FusedLevel, FusionSpec

_OPS = ("input", "conv", "pool", "relu", "add", "global_pool", "flatten", "dense")


@dataclass(frozen=True)
class Node:
    """One IR node.  ``K``/``S``/``pad`` apply to conv and pool nodes,
    ``n_out`` to conv and dense nodes, ``relu`` to conv and dense nodes
    (fused activation)."""

    op: str
    name: str
    inputs: tuple[str, ...] = ()
    K: int = 0
    S: int = 1
    pad: int = 0
    n_out: int = 0
    relu: bool = True


@dataclass(frozen=True)
class Shape:
    """Feature shape leaving a node: a square ``size x size x channels`` map,
    or a flat vector (``size == 0``, ``channels`` = feature count)."""

    size: int
    channels: int

    @property
    def is_map(self) -> bool:
        return self.size > 0


@dataclass(frozen=True)
class Graph:
    """A whole CNN as a topologically-ordered node tuple.

    ``nodes[0]`` must be the single ``input`` node; ``nodes[-1]`` is the
    network output (the logits for the zoo models).  Hashable — usable as a
    jit static argument.

    ``compute_dtype`` (canonical name string, DESIGN.md §11) is the value
    width the network's tiles and weights move at — the default the
    partitioner and runner inherit when no explicit dtype override is given.
    Accumulation is always f32 regardless.
    """

    name: str
    input_size: int
    in_channels: int
    nodes: tuple[Node, ...]
    compute_dtype: str = "float32"

    def __post_init__(self) -> None:
        if not self.nodes or self.nodes[0].op != "input":
            raise ValueError(f"graph {self.name}: nodes[0] must be the input node")
        object.__setattr__(
            self, "compute_dtype", canonical_dtype(self.compute_dtype)
        )
        infer_shapes(self)  # raises on any structural error

    def node(self, name: str) -> Node:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(f"graph {self.name} has no node {name!r}")

    @property
    def output(self) -> Node:
        return self.nodes[-1]

    def consumers(self) -> dict[str, tuple[str, ...]]:
        out: dict[str, tuple[str, ...]] = {n.name: () for n in self.nodes}
        for n in self.nodes:
            for src in n.inputs:
                out[src] = out[src] + (n.name,)
        return out


def infer_shapes(graph: Graph) -> dict[str, Shape]:
    """Shape/channel inference over the whole graph; the single validation
    pass every other net/ component builds on.  Raises ``ValueError`` naming
    the offending node."""
    shapes: dict[str, Shape] = {}
    for n in graph.nodes:
        if n.op not in _OPS:
            raise ValueError(f"node {n.name}: unknown op {n.op!r}")
        if n.name in shapes:
            raise ValueError(f"node {n.name}: duplicate name")
        ins = []
        for src in n.inputs:
            if src not in shapes:
                raise ValueError(
                    f"node {n.name}: input {src!r} is not an earlier node"
                )
            ins.append(shapes[src])
        n_in = {"input": 0, "add": 2}.get(n.op, 1)
        if len(ins) != n_in:
            raise ValueError(
                f"node {n.name}: op {n.op} takes {n_in} inputs, got {len(ins)}"
            )
        if n.op == "input":
            shapes[n.name] = Shape(graph.input_size, graph.in_channels)
            continue
        if n.op in ("conv", "pool"):
            s = ins[0]
            if not s.is_map:
                raise ValueError(f"node {n.name}: {n.op} needs a feature map")
            out = (s.size + 2 * n.pad - n.K) // n.S + 1
            if out < 1:
                raise ValueError(
                    f"node {n.name}: K={n.K} S={n.S} pad={n.pad} leaves no "
                    f"output from a {s.size}x{s.size} input"
                )
            ch = n.n_out if n.op == "conv" else s.channels
            shapes[n.name] = Shape(out, ch)
        elif n.op == "relu":
            shapes[n.name] = ins[0]
        elif n.op == "add":
            if ins[0] != ins[1]:
                raise ValueError(
                    f"node {n.name}: add operands disagree: {ins[0]} vs {ins[1]}"
                )
            shapes[n.name] = ins[0]
        elif n.op == "global_pool":
            if not ins[0].is_map:
                raise ValueError(f"node {n.name}: global_pool needs a feature map")
            shapes[n.name] = Shape(0, ins[0].channels)
        elif n.op == "flatten":
            s = ins[0]
            feats = s.size * s.size * s.channels if s.is_map else s.channels
            shapes[n.name] = Shape(0, feats)
        elif n.op == "dense":
            if ins[0].is_map:
                raise ValueError(
                    f"node {n.name}: dense needs a flat vector (flatten or "
                    "global_pool first)"
                )
            shapes[n.name] = Shape(0, n.n_out)
    return shapes


# ---------------------------------------------------------------------------
# Fusable segments — the partitioner's search domains
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Segment:
    """A maximal linear conv/pool chain: the domain one dynamic program cuts.

    Every interior node has exactly one consumer (its successor), so no map
    inside the segment is needed elsewhere — fusing across any interior edge
    is legal.  Segment ends are the graph's materialization points.
    """

    nodes: tuple[Node, ...]
    input_size: int
    in_channels: int
    relu: bool  # uniform fused activation of the chain's convs

    @property
    def node_names(self) -> tuple[str, ...]:
        return tuple(n.name for n in self.nodes)

    def spec(self) -> FusionSpec:
        """Lower the chain to the fusion planner's :class:`FusionSpec`."""
        return FusionSpec(
            levels=_levels(self.nodes, self.in_channels),
            input_size=self.input_size,
        )


def _levels(nodes: tuple[Node, ...], in_channels: int) -> tuple[FusedLevel, ...]:
    levels, c = [], in_channels
    for n in nodes:
        if n.op == "conv":
            levels.append(
                FusedLevel("conv", K=n.K, S=n.S, pad=n.pad, n_in=c,
                           n_out=n.n_out, name=n.name)
            )
            c = n.n_out
        else:
            levels.append(
                FusedLevel("pool", K=n.K, S=n.S, pad=n.pad, n_in=c, n_out=c,
                           name=n.name)
            )
    return tuple(levels)


def fusable_segments(graph: Graph) -> tuple[Segment, ...]:
    """Maximal fusable chains, in topological order.

    A conv starts or extends a chain; a pool extends one.  A node extends the
    current chain only when it consumes the chain tail, the tail has no other
    consumer, and (for convs) its fused-relu mode matches the chain's — a
    pyramid launch applies one activation mode.  Everything else (residual
    add, fork, head op) terminates the chain: these are the cut points.
    """
    shapes = infer_shapes(graph)
    n_consumers = {k: len(v) for k, v in graph.consumers().items()}
    segments: list[Segment] = []
    cur: list[Node] = []

    def flush() -> None:
        if cur:
            src = graph.node(cur[0].inputs[0])
            s_in = shapes[src.name]
            segments.append(
                Segment(
                    nodes=tuple(cur),
                    input_size=s_in.size,
                    in_channels=s_in.channels,
                    relu=cur[0].relu,
                )
            )
            cur.clear()

    for n in graph.nodes:
        if n.op in ("conv", "pool"):
            extends = (
                cur
                and n.inputs[0] == cur[-1].name
                and n_consumers[cur[-1].name] == 1
                and (n.op == "pool" or n.relu == cur[0].relu)
            )
            if extends:
                cur.append(n)
                continue
            flush()
            if n.op == "conv":
                cur.append(n)
            # an orphan pool (no conv head) cannot start a pyramid; the
            # runner executes it as a plain op
        else:
            flush()
    flush()
    return tuple(segments)


# ---------------------------------------------------------------------------
# Model zoo
# ---------------------------------------------------------------------------


class _Builder:
    """Tiny fluent helper: tracks the running tail so linear stretches read
    like layer lists; returns node names for explicit wiring."""

    def __init__(self, in_name: str = "image"):
        self.nodes: list[Node] = [Node("input", in_name)]
        self.tail = in_name

    def _add(self, node: Node) -> str:
        self.nodes.append(node)
        self.tail = node.name
        return node.name

    def conv(self, name, K, S, pad, n_out, *, src=None, relu=True) -> str:
        return self._add(
            Node("conv", name, (src or self.tail,), K=K, S=S, pad=pad,
                 n_out=n_out, relu=relu)
        )

    def pool(self, name, K, S, pad=0, *, src=None) -> str:
        return self._add(Node("pool", name, (src or self.tail,), K=K, S=S, pad=pad))

    def op(self, op, name, *srcs, n_out=0, relu=True) -> str:
        return self._add(
            Node(op, name, srcs or (self.tail,), n_out=n_out, relu=relu)
        )

    def graph(self, name, input_size, in_channels,
              compute_dtype="float32") -> Graph:
        return Graph(name, input_size, in_channels, tuple(self.nodes),
                     compute_dtype)


def lenet5(input_size: int = 32, num_classes: int = 10, *,
           compute_dtype: str = "float32") -> Graph:
    """LeNet-5 (paper §3.3.1): two conv+pool stages, three dense layers."""
    b = _Builder()
    b.conv("CL1", 5, 1, 0, 6)
    b.pool("MPL1", 2, 2)
    b.conv("CL2", 5, 1, 0, 16)
    b.pool("MPL2", 2, 2)
    b.op("flatten", "flatten")
    b.op("dense", "FC1", n_out=120)
    b.op("dense", "FC2", n_out=84)
    b.op("dense", "FC3", n_out=num_classes, relu=False)
    return b.graph("lenet", input_size, 1, compute_dtype)


def alexnet(input_size: int = 227, num_classes: int = 1000, *,
            compute_dtype: str = "float32") -> Graph:
    """AlexNet conv stack (no LRN) + the three dense layers."""
    b = _Builder()
    b.conv("CONV1", 11, 4, 0, 96)
    b.pool("POOL1", 3, 2)
    b.conv("CONV2", 5, 1, 2, 256)
    b.pool("POOL2", 3, 2)
    b.conv("CONV3", 3, 1, 1, 384)
    b.conv("CONV4", 3, 1, 1, 384)
    b.conv("CONV5", 3, 1, 1, 256)
    b.pool("POOL5", 3, 2)
    b.op("flatten", "flatten")
    b.op("dense", "FC6", n_out=4096)
    b.op("dense", "FC7", n_out=4096)
    b.op("dense", "FC8", n_out=num_classes, relu=False)
    return b.graph("alexnet", input_size, 3, compute_dtype)


_VGG16_PLAN = ((2, 64), (2, 128), (3, 256), (3, 512), (3, 512))


def vgg16(input_size: int = 224, num_classes: int = 1000, *,
          compute_dtype: str = "float32") -> Graph:
    """VGG-16: five conv blocks with trailing 2x2 pools, three dense layers."""
    b = _Builder()
    ci = 0
    for bi, (n_convs, ch) in enumerate(_VGG16_PLAN):
        for _ in range(n_convs):
            ci += 1
            b.conv(f"CONV{ci}", 3, 1, 1, ch)
        b.pool(f"POOL{bi + 1}", 2, 2)
    b.op("flatten", "flatten")
    b.op("dense", "FC1", n_out=4096)
    b.op("dense", "FC2", n_out=4096)
    b.op("dense", "FC3", n_out=num_classes, relu=False)
    return b.graph("vgg16", input_size, 3, compute_dtype)


# (n_out, stride of convA) per residual block
_RESNET18_PLAN = ((64, 1), (64, 1), (128, 2), (128, 1), (256, 2), (256, 1),
                  (512, 2), (512, 1))


def resnet18(input_size: int = 224, num_classes: int = 1000, *,
             compute_dtype: str = "float32") -> Graph:
    """ResNet-18: 7x7/2 stem + 3x3/2 maxpool, eight 2-conv residual blocks
    (1x1 projection shortcuts at the stride-2 / channel-change blocks),
    global average pool and the classifier.

    Per the repro's block variant (and the repo's historical per-block
    fusion), every conv applies fused ReLU — including convB before the add —
    since a fusion pyramid applies one activation mode; the residual join is
    a standalone ``add`` + ``relu`` pair.  Projection shortcuts are
    relu-free 1x1 convs, which makes them their own Q=1 pyramids.
    """
    b = _Builder()
    b.conv("conv1", 7, 2, 3, 64)
    b.pool("maxpool", 3, 2, pad=1)
    c_in = 64
    for i, (ch, s1) in enumerate(_RESNET18_PLAN):
        blk, block_in = f"b{i}", b.tail
        b.conv(f"{blk}_convA", 3, s1, 1, ch, src=block_in)
        body = b.conv(f"{blk}_convB", 3, 1, 1, ch)
        if s1 != 1 or c_in != ch:
            shortcut = b.conv(f"{blk}_proj", 1, s1, 0, ch, src=block_in,
                              relu=False)
        else:
            shortcut = block_in
        b.op("add", f"{blk}_add", body, shortcut)
        b.op("relu", f"{blk}_relu")
        c_in = ch
    b.op("global_pool", "gap")
    b.op("dense", "FC", n_out=num_classes, relu=False)
    return b.graph("resnet18", input_size, 3, compute_dtype)


MODELS = {
    "lenet": lenet5,
    "alexnet": alexnet,
    "vgg16": vgg16,
    "resnet18": resnet18,
}


def backbone_prefix(graph: Graph, n_convs: int) -> FusionSpec:
    """FusionSpec of the first ``n_convs`` convs (+ interleaved/trailing
    pools) of the graph's leading fusable segment — how ``core/cnn_models``
    derives the paper's hand-picked fusion groups from the zoo graphs."""
    seg = fusable_segments(graph)[0]
    taken, convs = [], 0
    for n in seg.nodes:
        if n.op == "conv":
            if convs == n_convs:
                break
            convs += 1
        taken.append(n)
    if convs < n_convs:
        raise ValueError(
            f"graph {graph.name}: leading segment has only {convs} convs"
        )
    sub = replace(seg, nodes=tuple(taken))
    return sub.spec()
