"""Memory-aware auto-partitioner: where to cut the fusion pyramids.

USEFUSE fuses hand-picked layer groups; the whole-network claim — reduced
off-chip communication for CNN deployment — needs the *cut points* chosen by
a memory-aware search (MAFAT's fusing/tiling formulation).  This module runs
that search over the graph IR:

* Legality: pyramids live inside :func:`~repro.net.graph.fusable_segments`
  (linear conv/pool chains).  Residual joins, forks (a block input feeding
  body + shortcut), and head ops terminate segments, so they are cut points
  by construction.  Within a segment the indivisible unit is the *conv
  group* — one conv plus its trailing pools — because a pool executes as its
  conv's epilogue (Fig. 4; ``kernels/fused_conv/ops.conv_groups``).
* Cost: each candidate pyramid is costed by the tile-program compiler's
  :func:`~repro.core.program.plan_launch` hook — exact modeled HBM bytes for
  the launch (reads + writes + weights, re-read per grid cell when the
  VMEM budget forces the streamed-weight regime) and the DS-1 cycle model as
  the latency tiebreaker.  A pyramid no launch regime can fit is illegal.
* Search: per segment, a dynamic program over conv-group cut positions
  minimizing summed (HBM bytes, modeled cycles) lexicographically — optimal
  over the exponential cut space in O(G^2) cost evaluations
  (:func:`partition_segment`; brute-force oracle in the tests).

Baselines built from the same machinery: :func:`layerwise_partition` (every
conv group its own launch — the unfused dataflow) and
:func:`paper_partition` (USEFUSE's hand-picked groups: first two convs for
LeNet/AlexNet, VGG blocks 1-2, ResNet-18 per-block conv pairs).
"""

from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass
from typing import NamedTuple

from repro.core.dtypes import canonical_dtype
from repro.core.fusion import FusionSpec
from repro.core.program import VMEM_BUDGET_BYTES, LaunchPlan, plan_launch
from repro.kernels.fused_conv.ops import conv_groups
from repro.obs.trace import get_tracer
from repro.robust.errors import BudgetError

from .graph import Graph, Segment, fusable_segments, infer_shapes

INFEASIBLE = (float("inf"), float("inf"))


@dataclass(frozen=True)
class PyramidPlan:
    """One chosen pyramid: the launch configuration plus the graph nodes it
    covers.  ``relu`` is the chain's uniform fused activation."""

    launch: LaunchPlan
    node_names: tuple[str, ...]
    relu: bool

    @property
    def spec(self) -> FusionSpec:
        return self.launch.spec

    @property
    def name(self) -> str:
        return self.node_names[0] if len(self.node_names) == 1 else (
            f"{self.node_names[0]}..{self.node_names[-1]}"
        )

    @property
    def q_convs(self) -> int:
        return self.spec.q_convs


@dataclass(frozen=True)
class PartitionPlan:
    """A full execution plan: pyramids keyed by their first covered node,
    everything else executed as plain ops by the runner.  Hashable — a jit
    static argument of :func:`repro.net.runner.run_network`."""

    graph: Graph
    pyramids: tuple[PyramidPlan, ...]
    vmem_budget: int
    batch: int
    # the compute dtype every pyramid was planned (and will launch) at; the
    # runner casts params/activations to match (DESIGN.md §11)
    compute_dtype: str = "float32"

    def pyramid_at(self, node_name: str) -> PyramidPlan | None:
        for p in self.pyramids:
            if p.node_names[0] == node_name:
                return p
        return None

    def covered(self) -> frozenset[str]:
        return frozenset(n for p in self.pyramids for n in p.node_names)

    def hbm_bytes(self) -> int:
        """Modeled off-chip traffic of all pyramid launches.  Head ops and
        residual adds are identical across partitions, so they are excluded —
        this is the quantity the DP minimizes and the benchmarks compare."""
        return sum(p.launch.hbm_bytes(self.batch) for p in self.pyramids)

    def modeled_cycles(self) -> int:
        return sum(p.launch.modeled_cycles(self.batch) for p in self.pyramids)

    def modeled_us(self) -> float:
        """Whole-plan modeled latency at the cycle model's reference
        frequency — the serving engine's per-bucket SLO seed (DESIGN.md
        §14): launches run back to back, so the plan's modeled time is the
        sum of its launches'."""
        return sum(p.launch.modeled_us(self.batch) for p in self.pyramids)

    def n_launches(self) -> int:
        return len(self.pyramids)

    def summary(self) -> str:
        rows = [
            f"  {p.name:<24} Q={p.q_convs} region={p.launch.out_region}"
            f" {p.launch.regime}"
            f" hbm={p.launch.hbm_bytes(self.batch):,}B"
            for p in self.pyramids
        ]
        return (
            f"PartitionPlan[{self.graph.name}] batch={self.batch} "
            f"dtype={self.compute_dtype} "
            f"launches={self.n_launches()} hbm={self.hbm_bytes():,}B\n"
            + "\n".join(rows)
        )


# ---------------------------------------------------------------------------
# Segment-level dynamic program
# ---------------------------------------------------------------------------


def _group_specs(segment: Segment) -> tuple[list[list], list[int], list[int]]:
    """Conv groups of a segment plus the spatial size / channel count
    entering each group boundary (index g = before group g)."""
    spec = segment.spec()
    groups = conv_groups(spec)
    sizes = spec.feature_sizes()
    bound_sizes, bound_ch = [segment.input_size], [segment.in_channels]
    li = 0
    for g in groups:
        li += len(g)
        bound_sizes.append(sizes[li])
        bound_ch.append(g[0].n_out)
    return groups, bound_sizes, bound_ch


def _span_launch(
    groups: list[list], bound_sizes: list[int], i: int, j: int,
    vmem_budget: int, prefer_region: str = "largest",
    compute_dtype: str = "float32", batch: int = 1,
) -> LaunchPlan | None:
    """Launch plan (or None) for one pyramid covering groups [i, j),
    knob-costed at ``batch`` (the serving bucket's batch reaches all the way
    into the per-launch ladder, not just the DP's span comparison)."""
    levels = tuple(itertools.chain.from_iterable(groups[i:j]))
    spec = FusionSpec(levels=levels, input_size=bound_sizes[i])
    return plan_launch(
        spec, vmem_budget=vmem_budget, batch=batch,
        prefer_region=prefer_region, compute_dtype=compute_dtype,
    )


def partition_segment(
    segment: Segment,
    *,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    batch: int = 1,
    max_convs: int | None = None,
    prefer_region: str = "largest",
    compute_dtype: str = "float32",
) -> list[LaunchPlan]:
    """Optimal cuts of one segment: DP over conv-group boundaries minimizing
    (sum HBM bytes, sum modeled cycles) lexicographically.

    The DP is dtype-aware end to end: each candidate span is costed (and its
    regime laddered) at ``compute_dtype``, so bf16's halved bytes can both
    move cut points and flip regimes relative to the f32 plan.

    ``max_convs`` caps pyramid depth (1 = the layer-by-layer baseline).
    Raises :class:`repro.robust.errors.BudgetError` (a ``ValueError``) when
    some single conv group fits no launch regime even alone — no partition
    can execute that segment.
    """
    groups, bound_sizes, _ = _group_specs(segment)
    n = len(groups)
    launches: dict[tuple[int, int], LaunchPlan] = {}
    cost: dict[tuple[int, int], tuple[float, float]] = {}
    for i in range(n):
        for j in range(i + 1, n + 1):
            convs = sum(1 for g in groups[i:j] for l in g if l.kind == "conv")
            if max_convs is not None and convs > max_convs:
                cost[(i, j)] = INFEASIBLE
                continue
            lp = _span_launch(groups, bound_sizes, i, j, vmem_budget,
                              prefer_region, compute_dtype, batch)
            if lp is None:
                cost[(i, j)] = INFEASIBLE
                continue
            launches[(i, j)] = lp
            cost[(i, j)] = (
                float(lp.hbm_bytes(batch)), float(lp.modeled_cycles(batch))
            )

    best: list[tuple[float, float]] = [(0.0, 0.0)] + [INFEASIBLE] * n
    back: list[int] = [0] * (n + 1)
    for j in range(1, n + 1):
        for i in range(j):
            if best[i] == INFEASIBLE or cost[(i, j)] == INFEASIBLE:
                continue
            cand = (best[i][0] + cost[(i, j)][0], best[i][1] + cost[(i, j)][1])
            if cand < best[j]:
                best[j] = cand
                back[j] = i
    if best[n] == INFEASIBLE:
        bad = next(
            g for k, g in enumerate(groups) if cost[(k, k + 1)] == INFEASIBLE
        )
        raise BudgetError(
            f"conv group [{bad[0].name or bad[0]}] fits no launch regime under"
            f" the {vmem_budget}-byte VMEM budget; no partition can run it",
            node=bad[0].name, vmem_budget=vmem_budget,
        )
    cuts, j = [], n
    while j > 0:
        i = back[j]
        cuts.append(launches[(i, j)])
        j = i
    return list(reversed(cuts))


def brute_force_segment(
    segment: Segment,
    *,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    batch: int = 1,
    compute_dtype: str = "float32",
) -> tuple[float, float]:
    """Exhaustive minimum over all 2^(G-1) cut sets — the DP's test oracle."""
    groups, bound_sizes, _ = _group_specs(segment)
    n = len(groups)
    best = INFEASIBLE
    for mask in range(1 << (n - 1)):
        bounds = [0] + [k + 1 for k in range(n - 1) if mask >> k & 1] + [n]
        hbm = cyc = 0.0
        for i, j in zip(bounds, bounds[1:]):
            lp = _span_launch(groups, bound_sizes, i, j, vmem_budget,
                              compute_dtype=compute_dtype, batch=batch)
            if lp is None:
                break
            hbm += lp.hbm_bytes(batch)
            cyc += lp.modeled_cycles(batch)
        else:
            best = min(best, (hbm, cyc))
    return best


# ---------------------------------------------------------------------------
# Whole-graph partitions
# ---------------------------------------------------------------------------


def _segment_pyramids(
    segment: Segment, launches: list[LaunchPlan]
) -> list[PyramidPlan]:
    """Attach covered node names to each launch, walking the chain."""
    out, li = [], 0
    for lp in launches:
        n_levels = len(lp.spec.levels)
        names = tuple(n.name for n in segment.nodes[li : li + n_levels])
        out.append(PyramidPlan(launch=lp, node_names=names, relu=segment.relu))
        li += n_levels
    assert li == len(segment.nodes), "launches must tile the segment"
    return out


def replan_pyramid(
    graph: Graph,
    pyr: PyramidPlan,
    *,
    vmem_budget: int,
    batch: int = 1,
    compute_dtype: str = "float32",
) -> list[PyramidPlan]:
    """Re-cut one planned pyramid under a (smaller) VMEM budget.

    The degradation ladder's replan rung (DESIGN.md §13): when a launch's
    working set no longer fits at run time, its covered chain is rebuilt as
    a :class:`~repro.net.graph.Segment` and re-run through the same DP —
    tighter cuts, a chain of smaller launches, each individually under the
    new budget.  Raises :class:`repro.robust.errors.BudgetError` when even
    single conv groups cannot fit, i.e. this rung is exhausted.
    """
    shapes = infer_shapes(graph)
    src = graph.node(pyr.node_names[0]).inputs[0]
    seg = Segment(
        nodes=tuple(graph.node(m) for m in pyr.node_names),
        input_size=shapes[src].size,
        in_channels=shapes[src].channels,
        relu=pyr.relu,
    )
    launches = partition_segment(
        seg, vmem_budget=vmem_budget, batch=batch,
        compute_dtype=compute_dtype,
    )
    return _segment_pyramids(seg, launches)


@functools.lru_cache(maxsize=128)
def _auto_partition_cached(
    graph: Graph,
    vmem_budget: int,
    batch: int,
    max_convs: int | None,
    prefer_region: str,
    compute_dtype: str,
) -> PartitionPlan:
    pyramids: list[PyramidPlan] = []
    for seg in fusable_segments(graph):
        launches = partition_segment(
            seg, vmem_budget=vmem_budget, batch=batch, max_convs=max_convs,
            prefer_region=prefer_region, compute_dtype=compute_dtype,
        )
        pyramids.extend(_segment_pyramids(seg, launches))
    return PartitionPlan(
        graph=graph, pyramids=tuple(pyramids), vmem_budget=vmem_budget,
        batch=batch, compute_dtype=compute_dtype,
    )


def auto_partition(
    graph: Graph,
    *,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    batch: int = 1,
    max_convs: int | None = None,
    prefer_region: str = "largest",
    compute_dtype: str | None = None,
) -> PartitionPlan:
    """Machine-chosen fusion boundaries for the whole network.
    ``prefer_region="smallest"`` trades grid overhead for maximal tile grids
    (finest END-skip granularity) — the paper's smallest-tile preference.
    ``compute_dtype`` overrides the graph's default value width
    (``None`` = ``graph.compute_dtype``); the f32 and bf16 plans for the
    same graph are distinct cache entries.

    Memoized on (graph structure, VMEM budget, batch, depth cap, region
    preference, compute dtype): the DP is pure over static shapes, and
    ``run_model`` / the benchmark loop re-request identical plans every call
    — they now hit the cache and reuse the same :class:`PartitionPlan`
    object (which also keeps its jit static-argument identity stable).
    The serving engine keys its plan+jit cache on exactly this memo's key
    tuple, so every executed bucket calls through here and its hit shows up
    in the counters.  Inspect or reset via :func:`partition_cache_info` /
    :func:`clear_partition_cache`."""
    cdt = canonical_dtype(
        graph.compute_dtype if compute_dtype is None else compute_dtype
    )
    before = _auto_partition_cached.cache_info()
    plan = _auto_partition_cached(
        graph, vmem_budget, batch, max_convs, prefer_region, cdt
    )
    after = _auto_partition_cached.cache_info()
    hit = after.misses == before.misses
    # an lru miss always inserts; when the insert did not grow the cache,
    # an older plan was evicted (thrash under many serve-bucket keys)
    evicted = (not hit) and after.currsize == before.currsize
    _CACHE_COUNTERS["hits" if hit else "misses"] += 1
    if evicted:
        _CACHE_COUNTERS["evictions"] += 1
    tracer = get_tracer()
    if tracer.enabled:
        tracer.bump("partition_cache_hit" if hit else "partition_cache_miss")
        if evicted:
            tracer.bump("partition_cache_eviction")
        tracer.record_event(
            "auto_partition",
            model=graph.name,
            cache="hit" if hit else "miss",
            batch=batch,
            compute_dtype=cdt,
            vmem_budget=vmem_budget,
            launches=plan.n_launches(),
            hbm_bytes=plan.hbm_bytes(),
            modeled_cycles=plan.modeled_cycles(),
        )
    return plan


class PartitionCacheInfo(NamedTuple):
    """Hit/miss statistics of the memoized :func:`auto_partition`.

    ``hits``/``misses`` count :func:`auto_partition` *calls* (not raw
    ``lru_cache`` probes) and — unlike the ``functools`` counters this
    module previously exposed directly — are reset by
    :func:`clear_partition_cache`, so repeated benchmark runs that clear
    between configs report per-run statistics instead of a process-lifetime
    accumulation.  ``evictions`` counts plans the bounded lru dropped to
    admit a new key: the serving engine multiplies keys per (model, bucket,
    dtype), so a rising eviction count is the cache-thrash signal traces
    surface via the ``partition_cache_eviction`` counter."""

    hits: int
    misses: int
    evictions: int
    currsize: int
    maxsize: int | None


# auto_partition call counters; cleared alongside the plan cache so a
# cleared cache never reports stale hit/miss history (the trace events and
# partition_cache_info read the same numbers)
_CACHE_COUNTERS = {"hits": 0, "misses": 0, "evictions": 0}


def partition_cache_info() -> PartitionCacheInfo:
    """Cache statistics of the memoized :func:`auto_partition` — counters
    that reset with :func:`clear_partition_cache` (see
    :class:`PartitionCacheInfo`)."""
    lru = _auto_partition_cached.cache_info()
    return PartitionCacheInfo(
        hits=_CACHE_COUNTERS["hits"],
        misses=_CACHE_COUNTERS["misses"],
        evictions=_CACHE_COUNTERS["evictions"],
        currsize=lru.currsize,
        maxsize=lru.maxsize,
    )


def clear_partition_cache() -> None:
    """Drop all memoized partition plans (e.g. between benchmark configs)
    and reset the hit/miss/eviction counters with them."""
    _auto_partition_cached.cache_clear()
    for k in _CACHE_COUNTERS:
        _CACHE_COUNTERS[k] = 0
    tracer = get_tracer()
    if tracer.enabled:
        tracer.record_event("partition_cache_clear")


def min_vmem_budget(graph: Graph, *, compute_dtype: str | None = None) -> int:
    """Smallest VMEM budget under which every conv group of the graph still
    has some launch regime — the floor below which no partition exists
    (dtype-aware: a bf16 graph's floor is roughly half the f32 one).
    Partitioning under this budget forces minimal output regions (maximal
    tile grids), which is also how the example script provokes the END
    cascade at reduced scale."""
    from repro.core.program import compile_program

    cdt = canonical_dtype(
        graph.compute_dtype if compute_dtype is None else compute_dtype
    )
    worst = 0
    for seg in fusable_segments(graph):
        groups, bound_sizes, _ = _group_specs(seg)
        for i in range(len(groups)):
            spec = FusionSpec(levels=tuple(groups[i]), input_size=bound_sizes[i])
            out_size = spec.feature_sizes()[-1]

            def _cheapest_regime(prog) -> int:
                # the floor now includes the channel-tiled streamed rung:
                # a finely sliced last level can undercut even the blocking
                # single-slot regime when one level's weights dominate
                tiled = min(
                    (
                        prog.vmem_stream_bytes(2, 1, ct)
                        for ct in prog.c_tile_options()
                    ),
                    default=prog.vmem_stream_bytes(),
                )
                return min(prog.vmem_bytes(), prog.vmem_stream_bytes(), tiled)

            cheapest = min(
                _cheapest_regime(compile_program(spec, r, compute_dtype=cdt))
                for r in range(1, out_size + 1)
                if out_size % r == 0
            )
            worst = max(worst, cheapest)
    return worst


def layerwise_partition(
    graph: Graph, *, vmem_budget: int = VMEM_BUDGET_BYTES, batch: int = 1,
    compute_dtype: str | None = None,
) -> PartitionPlan:
    """The unfused baseline: every conv group is its own launch, every
    intermediate map round-trips HBM."""
    return auto_partition(
        graph, vmem_budget=vmem_budget, batch=batch, max_convs=1,
        compute_dtype=compute_dtype,
    )


# USEFUSE's hand-picked fusion depth per leading segment: LeNet-5 / AlexNet
# fuse the first two convs (+pools); VGG-16 fuses blocks 1-2 (four convs).
_PAPER_HEAD_CONVS = {"lenet": 2, "alexnet": 2, "vgg16": 4}


def paper_partition(
    graph: Graph, *, vmem_budget: int = VMEM_BUDGET_BYTES, batch: int = 1,
    compute_dtype: str | None = None,
) -> PartitionPlan:
    """The paper's hand-picked fusion choices, expressed as a partition:
    the leading segment fuses the quoted conv count and leaves the rest
    layer-by-layer; ResNet-18 fuses each residual block's conv pair (§4.3),
    which is exactly per-segment maximal fusion — shortcuts and the stem stay
    single launches."""
    cdt = canonical_dtype(
        graph.compute_dtype if compute_dtype is None else compute_dtype
    )
    pyramids: list[PyramidPlan] = []
    head_convs = _PAPER_HEAD_CONVS.get(graph.name)
    for si, seg in enumerate(fusable_segments(graph)):
        groups, bound_sizes, _ = _group_specs(seg)
        if graph.name == "resnet18":
            spans = [(0, len(groups))]  # whole segment: block pair / stem
        elif si == 0 and head_convs is not None:
            convs = head = 0
            for gi, g in enumerate(groups):
                convs += sum(1 for l in g if l.kind == "conv")
                if convs == head_convs:
                    head = gi + 1
                    break
            spans = [(0, head)] + [(k, k + 1) for k in range(head, len(groups))]
        else:
            spans = [(k, k + 1) for k in range(len(groups))]
        launches = []
        for i, j in spans:
            lp = _span_launch(groups, bound_sizes, i, j, vmem_budget,
                              compute_dtype=cdt)
            if lp is None:
                raise BudgetError(
                    f"paper fusion group {i}:{j} of segment {si} does not fit"
                    f" the {vmem_budget}-byte VMEM budget",
                    vmem_budget=vmem_budget,
                )
            launches.append(lp)
        pyramids.extend(_segment_pyramids(seg, launches))
    return PartitionPlan(
        graph=graph, pyramids=tuple(pyramids), vmem_budget=vmem_budget,
        batch=batch, compute_dtype=cdt,
    )
