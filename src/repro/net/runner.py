"""End-to-end batched network execution: ``run_network`` and its oracle.

``run_network`` executes a :class:`~repro.net.partition.PartitionPlan` as a
sequence of fused-pyramid Pallas launches (one per chosen pyramid, weights
resident or streamed per the plan) stitched together with the plain-JAX ops
the plan left outside pyramids: residual adds, standalone activations,
global pooling, flatten, and the dense classifier head.  The whole forward
is jit-compiled with the plan as a static argument; the per-launch END skip
flag maps are returned alongside the logits.

``reference_network`` is the monolithic oracle: the same graph executed
node-by-node with full intermediate feature maps via
``jax.lax.conv_general_dilated`` / ``reduce_window``.  ``run_network`` must
match it bit-close (float32 ``atol 1e-4`` end-to-end; enforced in
``tests/test_network_runner.py``) — that contract is what makes the
auto-partitioner free to move fusion boundaries without changing results.

Low precision (DESIGN.md §11): ``run_network(..., dtype="bfloat16")`` (or a
bf16-planned partition) moves every activation tile, weight, and dense
operand at bf16 while *all* accumulation — conv MXU passes, dense matmuls,
the global-average-pool mean — runs in f32 via ``preferred_element_type``.
End-to-end logits then differ from the f32 reference only by operand
rounding, bounded by :func:`bf16_logit_tol` across the zoo (enforced in
``tests/test_precision.py`` and the CI smoke job).
"""

from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cycle_model import DEFAULT_PARAMS
from repro.core.dtypes import canonical_dtype, jnp_dtype
from repro.kernels.fused_conv.ops import flatten_weights, fused_pyramid
from repro.obs.trace import LaunchSpan, get_tracer
from repro.robust.guard import get_guard

from .graph import Graph, Node, infer_shapes
from .partition import PartitionPlan, auto_partition

Params = dict[str, tuple[jnp.ndarray, jnp.ndarray]]

# key prefix of pre-flattened streamed-weight arrays in a params dict
_FLAT = "_flat/"

# Documented end-to-end bf16 logit tolerance vs the f32 reference.  bf16
# keeps f32's exponent range but only 8 mantissa bits: each layer's
# operands round to ~2^-9 relative error while accumulation stays exact in
# f32, so the end-to-end error is *relative* to logit magnitude — measured
# ~0.5-0.7% across the He-initialized zoo (ResNet-18's logits reach O(100),
# LeNet's O(1); their absolute errors differ 10x, their relative errors
# don't).  The contract is ``max-abs-err <= ATOL + RTOL * max|logit|``:
# RTOL at ~3x the measured worst case, ATOL as a floor for near-zero
# logits.  A precision bug (double rounding, a bf16 accumulator) breaks
# this by an order of magnitude.  Use :func:`bf16_logit_tol`.
BF16_LOGIT_ATOL = 0.05
BF16_LOGIT_RTOL = 0.02


def bf16_logit_tol(reference) -> float:
    """The documented bf16-vs-f32 max-abs-err bound for a given f32
    reference logit tensor (see :data:`BF16_LOGIT_RTOL`)."""
    return BF16_LOGIT_ATOL + BF16_LOGIT_RTOL * float(
        jnp.max(jnp.abs(reference))
    )


def init_network_params(graph: Graph, key: jax.Array, scale: float = 1.0) -> Params:
    """He-initialized weights for every conv and dense node, keyed by node
    name: conv ``(K, K, Cin, Cout)`` + bias, dense ``(fan_in, n_out)`` + bias."""
    shapes = infer_shapes(graph)
    params: Params = {}
    for n in graph.nodes:
        if n.op not in ("conv", "dense"):
            continue
        key, k1, k2 = jax.random.split(key, 3)
        c_in = shapes[n.inputs[0]].channels
        fan_in = (n.K * n.K * c_in) if n.op == "conv" else c_in
        shape = (n.K, n.K, c_in, n.n_out) if n.op == "conv" else (c_in, n.n_out)
        w = jax.random.normal(k1, shape) * (scale * (2.0 / fan_in) ** 0.5)
        b = jax.random.normal(k2, (n.n_out,)) * 0.01
        params[n.name] = (w.astype(jnp.float32), b.astype(jnp.float32))
    return params


def _conv_node(x, n: Node, w, b):
    # f32 accumulation at any operand dtype, cast back to the network's
    # compute dtype — the plain-op mirror of the kernel's §11 contract
    # (identity for f32 inputs, so the reference oracle is unchanged)
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(n.S, n.S),
        padding=[(n.pad, n.pad), (n.pad, n.pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32,
    ) + b
    out = jax.nn.relu(out) if n.relu else out
    return out.astype(x.dtype)


def _pool_node(x, n: Node):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max,
        window_dimensions=(1, n.K, n.K, 1),
        window_strides=(1, n.S, n.S, 1),
        padding=((0, 0), (n.pad, n.pad), (n.pad, n.pad), (0, 0)),
    )


def _head_op(values, n: Node, params: Params, graph: Graph | None = None):
    if n.op == "relu":
        return jax.nn.relu(values[n.inputs[0]])
    if n.op == "add":
        return values[n.inputs[0]] + values[n.inputs[1]]
    if n.op == "global_pool":
        # mean in f32: a bf16 running sum over H*W terms would lose low
        # bits of every partial; cast back to the network dtype once
        x = values[n.inputs[0]]
        return jnp.mean(x.astype(jnp.float32), axis=(1, 2)).astype(x.dtype)
    if n.op == "flatten":
        x = values[n.inputs[0]]
        return x.reshape(x.shape[0], -1)
    if n.op == "dense":
        x = values[n.inputs[0]]
        w, b = params[n.name]
        # operands at the network dtype, accumulation in f32 (§11)
        out = jnp.dot(
            x, w.astype(x.dtype), preferred_element_type=jnp.float32
        ) + b
        out = jax.nn.relu(out) if n.relu else out
        return out.astype(x.dtype)
    from repro.robust.errors import PreflightError

    raise PreflightError(
        f"node {n.name!r} has op {n.op!r}, which the runner cannot execute"
        " (expected one of relu/add/global_pool/flatten/dense outside"
        " pyramids)",
        node=n.name, op=n.op,
        graph=graph.name if graph is not None else None,
    )


def reference_network(x: jnp.ndarray, graph: Graph, params: Params) -> jnp.ndarray:
    """Monolithic node-by-node forward: full intermediate maps, no fusion.
    Ground truth for ``run_network`` and the baseline dataflow whose off-chip
    traffic the partitioner minimizes."""
    values = {graph.nodes[0].name: x.astype(jnp.float32)}
    for n in graph.nodes[1:]:
        if n.op == "conv":
            w, b = params[n.name]
            values[n.name] = _conv_node(values[n.inputs[0]], n, w, b)
        elif n.op == "pool":
            values[n.name] = _pool_node(values[n.inputs[0]], n)
        else:
            values[n.name] = _head_op(values, n, params, graph)
    return values[graph.output.name]


def prepare_network_params(
    plan: PartitionPlan, params: Params, dtype: str | None = None
) -> Params:
    """Cast params to the plan's compute dtype and pre-flatten streamed
    weights, once per model.

    ``dtype`` (``None`` = ``plan.compute_dtype``) is the value width the
    launches move: every conv/dense weight and bias is cast once here
    instead of per ``run_network`` call inside the jit graph, and each
    streamed pyramid gets one ``"_flat/<pyramid>"`` concatenated weight
    array at that width (consumed by :func:`run_network`).  Master params
    stay f32 in the caller's dict — this returns a new dict.  Stale
    ``"_flat/"`` entries from a previous preparation are dropped and
    rebuilt, so re-preparing at another dtype is safe.
    """
    cdt = canonical_dtype(plan.compute_dtype if dtype is None else dtype)
    jdt = jnp_dtype(cdt)
    out: Params = {
        k: (w.astype(jdt), b.astype(jdt))
        for k, (w, b) in params.items()
        if not k.startswith(_FLAT)
    }
    graph = plan.graph
    for pyr in plan.pyramids:
        if not pyr.launch.streamed:
            continue
        conv_names = [m for m in pyr.node_names if graph.node(m).op == "conv"]
        out[_FLAT + pyr.name] = flatten_weights(
            [out[m][0] for m in conv_names], cdt
        )
    return out


def _forward(
    x: jnp.ndarray,
    params: Params,
    *,
    plan: PartitionPlan,
    end_skip: bool,
    interpret: bool | None,
    cdt: str,
    launch_wrapper=None,
) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
    """The plan-driven forward loop, shared by the jit fast path, the
    traced eager path, and the guarded eager path.
    ``launch_wrapper(pyr, call, x_in)``, when given, wraps each
    fused-pyramid launch — the traced path times it there, the guarded path
    (``repro.robust.degrade``) runs its degradation ladder there, using
    ``x_in`` (the launch input) for replans and reference quarantines and
    ``call(interpret=True)``-style keyword overrides for retries.  The jit
    path passes ``None`` so neither adds anything to the compiled graph."""
    jdt = jnp_dtype(cdt)
    graph = plan.graph
    covered = plan.covered()
    values = {graph.nodes[0].name: x.astype(jdt)}
    skips: dict[str, jnp.ndarray] = {}
    for n in graph.nodes[1:]:
        if n.name in covered:
            pyr = plan.pyramid_at(n.name)
            if pyr is None:
                continue  # interior pyramid node: computed with its launch
            conv_names = [m for m in pyr.node_names
                          if graph.node(m).op == "conv"]
            flat = params.get(_FLAT + pyr.name)
            x_in = values[n.inputs[0]]

            def call(pyr=pyr, x_in=x_in, conv_names=conv_names, flat=flat,
                     **overrides):
                kwargs = dict(
                    spec=pyr.spec,
                    out_region=pyr.launch.out_region,
                    streamed=pyr.launch.streamed,
                    w_slots=(
                        pyr.launch.w_slots if pyr.launch.streamed else None
                    ),
                    x_slots=pyr.launch.x_slots,
                    c_tiles=pyr.launch.c_tiles,
                    relu=pyr.relu,
                    end_skip=end_skip,
                    interpret=interpret,
                    vmem_budget=plan.vmem_budget,
                    weights_flat=flat,
                    compute_dtype=cdt,
                )
                # wrapper retries may override launch knobs, e.g.
                # call(interpret=True) on the degradation ladder
                kwargs.update(overrides)
                return fused_pyramid(
                    x_in,
                    # streamed launches with pre-flattened weights don't
                    # need the per-level tensors threaded through the jit
                    # graph
                    None if kwargs["weights_flat"] is not None
                    else [params[m][0] for m in conv_names],
                    [params[m][1] for m in conv_names],
                    **kwargs,
                )

            y, skip = call() if launch_wrapper is None else launch_wrapper(
                pyr, call, x_in
            )
            values[pyr.node_names[-1]] = y
            skips[pyr.name] = skip
        elif n.op == "conv":
            w, b = params[n.name]
            values[n.name] = _conv_node(
                values[n.inputs[0]], n, w.astype(jdt), b.astype(jdt)
            )
        elif n.op == "pool":
            values[n.name] = _pool_node(values[n.inputs[0]], n)
        else:
            values[n.name] = _head_op(values, n, params, graph)
    return values[graph.output.name], skips


# Python-side retrace accounting of the jit fast path.  ``jax.jit`` keys its
# executable cache on (static args, operand shapes/dtypes), so every distinct
# batch size is a fresh trace + compile even when the plan is identical —
# the cost the serving engine's pad-to-bucket admission amortizes: all
# requests in a bucket share one input shape, so wave 2 of a bucket replays
# the wave-1 executable.  The counter increments inside the traced body
# (which Python only executes at trace time), making "how many compiles did
# this workload pay" a testable quantity (``tests/test_serve.py``).
_JIT_STATS = {"traces": 0}


def jit_trace_count() -> int:
    """Process-lifetime count of ``run_network`` jit fast-path traces."""
    return _JIT_STATS["traces"]


def reset_jit_trace_count() -> None:
    """Zero the retrace counter (the executable cache itself is untouched —
    re-running a known shape after a reset still counts 0 new traces)."""
    _JIT_STATS["traces"] = 0


@partial(jax.jit, static_argnames=("plan", "end_skip", "interpret", "dtype"))
def _run_network_jit(
    x: jnp.ndarray,
    params: Params,
    *,
    plan: PartitionPlan,
    end_skip: bool = True,
    interpret: bool | None = None,
    dtype: str | None = None,
) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
    # executes at trace time only: one bump per new (plan, shape, dtype) key
    _JIT_STATS["traces"] += 1
    tracer = get_tracer()
    if tracer.enabled:
        tracer.bump("run_network_jit_trace")
    cdt = canonical_dtype(plan.compute_dtype if dtype is None else dtype)
    return _forward(
        x, params, plan=plan, end_skip=end_skip, interpret=interpret, cdt=cdt
    )


def _run_network_traced(
    x, params, tracer, *, plan, end_skip, interpret, dtype
):
    """The observed forward: the same plan executed launch-by-launch outside
    the whole-graph jit (each ``fused_pyramid`` call is still jit itself),
    every launch blocked-until-ready and recorded as a :class:`LaunchSpan`
    whose modeled fields come straight from the plan — plus per-launch
    END-skip count events and one ``run_network`` summary event.  Slower
    than the fused jit path by construction (that is what it measures); the
    fast path is byte-for-byte unaffected when tracing is off."""
    cdt = canonical_dtype(plan.compute_dtype if dtype is None else dtype)
    model = plan.graph.name
    batch = int(x.shape[0])

    def wrapper(pyr, call, x_in):
        t0 = time.perf_counter()
        y, skip = call()
        jax.block_until_ready((y, skip))
        dur_ms = (time.perf_counter() - t0) * 1e3
        d = pyr.launch.describe(batch, plan.vmem_budget)
        tracer.record_span(LaunchSpan(
            name=pyr.name,
            model=model,
            regime=d["regime"],
            out_region=d["out_region"],
            alpha=d["alpha"],
            q_convs=d["q_convs"],
            x_slots=d["x_slots"],
            w_slots=d["w_slots"],
            c_tiles=d["c_tiles"],
            batch=batch,
            compute_dtype=cdt,
            streamed=d["streamed"],
            hbm_bytes=d["hbm_bytes"],
            vmem_bytes=d["vmem_bytes"],
            modeled_cycles=d["modeled_cycles"],
            modeled_us=d["modeled_cycles"] / DEFAULT_PARAMS.freq_mhz,
            start_s=t0,
            duration_ms=dur_ms,
        ))
        return y, skip

    t0 = time.perf_counter()
    logits, skips = _forward(
        x, params, plan=plan, end_skip=end_skip, interpret=interpret,
        cdt=cdt, launch_wrapper=wrapper,
    )
    jax.block_until_ready(logits)
    total_ms = (time.perf_counter() - t0) * 1e3
    for name, skip in skips.items():
        arr = np.asarray(skip)
        # per-level count of grid cells the END cascade skipped, plus the
        # cell total — the runtime twin of the paper's skipped-convolution
        # accounting (level 0 never skips by construction)
        tracer.record_event(
            "end_skip_counts",
            model=model,
            launch=name,
            per_level=[int(c) for c in arr.sum(axis=(0, 1, 2))],
            cells=int(arr[..., 0].size),
        )
    tracer.record_event(
        "run_network",
        model=model,
        batch=batch,
        compute_dtype=cdt,
        launches=len(skips),
        wallclock_ms=total_ms,
        modeled_cycles=plan.modeled_cycles(),
    )
    return logits, skips


def run_network(
    x: jnp.ndarray,
    params: Params,
    *,
    plan: PartitionPlan,
    end_skip: bool = True,
    interpret: bool | None = None,
    dtype: str | None = None,
) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
    """Execute the partition plan end to end for a batch ``x`` (B, H, W, C).

    ``dtype`` (static; name string or jnp dtype, ``None`` =
    ``plan.compute_dtype``) is the compute dtype of the whole forward:
    every pyramid launch, plain conv/pool, and head op moves operands at
    that width with f32 accumulation, and the logits come back at it.
    Overriding a plan to a *wider* dtype can bust the planned VMEM regimes;
    the supported direction is planning at the dtype you run
    (``auto_partition(..., compute_dtype=...)``) or narrowing.

    ``interpret=None`` resolves per backend (compiled on TPU).  Params may
    come through :func:`prepare_network_params` so streamed launches reuse
    the pre-flattened weight arrays (which must match the run dtype).
    Returns ``(logits, skips)``: ``skips[pyramid.name]`` is that launch's
    ``(B, alpha, alpha, Q)`` int32 END-cascade flag map (level 0 of each
    pyramid never skips).  Aggregate with :func:`skip_fractions`.

    Observability (DESIGN.md §12): with a tracer installed
    (``repro.obs.tracing()``) the forward runs launch-by-launch and records
    one measured+modeled span per fused launch plus END-skip count events.
    With the default no-op tracer the whole forward goes through the
    unchanged jit fast path — the only extra work is this one ``enabled``
    check, *outside* jit, so tracing-off costs nothing per call.

    Guarded execution (DESIGN.md §13): with a guard installed
    (``repro.robust.guarding()``) the forward instead runs the preflighted,
    sentinel-checked degradation-ladder path of
    :func:`repro.robust.degrade.run_network_guarded`.  Like tracing, the
    guard is one static ``enabled`` check outside jit — guards off leaves
    the jit fast path byte-identical.
    """
    guard = get_guard()
    if guard.enabled:
        from repro.robust.degrade import run_network_guarded

        return run_network_guarded(
            x, params, plan=plan, end_skip=end_skip, interpret=interpret,
            dtype=dtype, guard=guard,
        )
    tracer = get_tracer()
    if not tracer.enabled:
        return _run_network_jit(
            x, params, plan=plan, end_skip=end_skip, interpret=interpret,
            dtype=dtype,
        )
    return _run_network_traced(
        x, params, tracer, plan=plan, end_skip=end_skip,
        interpret=interpret, dtype=dtype,
    )


def skip_fractions(skips: dict[str, jnp.ndarray]) -> dict[str, list[float]]:
    """Per-pyramid, per-level fraction of tiles the END cascade skipped."""
    return {
        name: [float(f) for f in np.asarray(s, dtype=np.float64).mean(axis=(0, 1, 2))]
        for name, s in skips.items()
    }


def run_model(
    name: str,
    x: jnp.ndarray,
    params: Params | None = None,
    *,
    input_size: int | None = None,
    num_classes: int | None = None,
    plan: PartitionPlan | None = None,
    seed: int = 0,
    interpret: bool | None = None,
    dtype: str | None = None,
):
    """Convenience one-shot: build the zoo graph, auto-partition, run.

    ``dtype`` selects the compute dtype end to end: the partition is
    *planned* at it (regimes re-tiered under the narrower bytes) and the
    params are cast once before the run; master ``params`` (returned) stay
    f32 so the same dict can be re-run at any dtype.

    Returns ``(logits, skips, plan, params)``.  Used by the example script
    and benchmarks; library code should call :func:`run_network` directly.
    """
    from .graph import MODELS

    kwargs = {}
    if input_size is not None:
        kwargs["input_size"] = input_size
    if num_classes is not None:
        kwargs["num_classes"] = num_classes
    graph = MODELS[name](**kwargs)
    if plan is None:
        plan = auto_partition(graph, batch=x.shape[0], compute_dtype=dtype)
    if params is None:
        params = init_network_params(graph, jax.random.PRNGKey(seed))
    prepped = prepare_network_params(plan, params)
    logits, skips = run_network(x, prepped, plan=plan, interpret=interpret)
    return logits, skips, plan, params
