"""End-to-end batched network execution: ``run_network`` and its oracle.

``run_network`` executes a :class:`~repro.net.partition.PartitionPlan` as a
sequence of fused-pyramid Pallas launches (one per chosen pyramid, weights
resident or streamed per the plan) stitched together with the plain-JAX ops
the plan left outside pyramids: residual adds, standalone activations,
global pooling, flatten, and the dense classifier head.  The whole forward
is jit-compiled with the plan as a static argument; the per-launch END skip
flag maps are returned alongside the logits.

``reference_network`` is the monolithic oracle: the same graph executed
node-by-node with full intermediate feature maps via
``jax.lax.conv_general_dilated`` / ``reduce_window``.  ``run_network`` must
match it bit-close (float32 ``atol 1e-4`` end-to-end; enforced in
``tests/test_network_runner.py``) — that contract is what makes the
auto-partitioner free to move fusion boundaries without changing results.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fused_conv.ops import flatten_weights, fused_pyramid

from .graph import Graph, Node, infer_shapes
from .partition import PartitionPlan, auto_partition

Params = dict[str, tuple[jnp.ndarray, jnp.ndarray]]

# key prefix of pre-flattened streamed-weight arrays in a params dict
_FLAT = "_flat/"


def init_network_params(graph: Graph, key: jax.Array, scale: float = 1.0) -> Params:
    """He-initialized weights for every conv and dense node, keyed by node
    name: conv ``(K, K, Cin, Cout)`` + bias, dense ``(fan_in, n_out)`` + bias."""
    shapes = infer_shapes(graph)
    params: Params = {}
    for n in graph.nodes:
        if n.op not in ("conv", "dense"):
            continue
        key, k1, k2 = jax.random.split(key, 3)
        c_in = shapes[n.inputs[0]].channels
        fan_in = (n.K * n.K * c_in) if n.op == "conv" else c_in
        shape = (n.K, n.K, c_in, n.n_out) if n.op == "conv" else (c_in, n.n_out)
        w = jax.random.normal(k1, shape) * (scale * (2.0 / fan_in) ** 0.5)
        b = jax.random.normal(k2, (n.n_out,)) * 0.01
        params[n.name] = (w.astype(jnp.float32), b.astype(jnp.float32))
    return params


def _conv_node(x, n: Node, w, b):
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(n.S, n.S),
        padding=[(n.pad, n.pad), (n.pad, n.pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + b
    return jax.nn.relu(out) if n.relu else out


def _pool_node(x, n: Node):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max,
        window_dimensions=(1, n.K, n.K, 1),
        window_strides=(1, n.S, n.S, 1),
        padding=((0, 0), (n.pad, n.pad), (n.pad, n.pad), (0, 0)),
    )


def _head_op(values, n: Node, params: Params):
    if n.op == "relu":
        return jax.nn.relu(values[n.inputs[0]])
    if n.op == "add":
        return values[n.inputs[0]] + values[n.inputs[1]]
    if n.op == "global_pool":
        return jnp.mean(values[n.inputs[0]], axis=(1, 2))
    if n.op == "flatten":
        x = values[n.inputs[0]]
        return x.reshape(x.shape[0], -1)
    if n.op == "dense":
        w, b = params[n.name]
        out = values[n.inputs[0]] @ w + b
        return jax.nn.relu(out) if n.relu else out
    raise AssertionError(f"unhandled op {n.op}")


def reference_network(x: jnp.ndarray, graph: Graph, params: Params) -> jnp.ndarray:
    """Monolithic node-by-node forward: full intermediate maps, no fusion.
    Ground truth for ``run_network`` and the baseline dataflow whose off-chip
    traffic the partitioner minimizes."""
    values = {graph.nodes[0].name: x.astype(jnp.float32)}
    for n in graph.nodes[1:]:
        if n.op == "conv":
            w, b = params[n.name]
            values[n.name] = _conv_node(values[n.inputs[0]], n, w, b)
        elif n.op == "pool":
            values[n.name] = _pool_node(values[n.inputs[0]], n)
        else:
            values[n.name] = _head_op(values, n, params)
    return values[graph.output.name]


def prepare_network_params(plan: PartitionPlan, params: Params) -> Params:
    """Pre-flatten the streamed pyramids' weights once per model.

    Streamed launches DMA from one flat concatenated weight array; without
    this step every ``run_network`` call re-concatenates it inside the jit
    graph.  Returns a new params dict with one ``"_flat/<pyramid>"`` entry
    per streamed pyramid (consumed by :func:`run_network`; plain entries are
    untouched, so the dict remains a valid pytree for the reference path).
    """
    out: Params = dict(params)
    graph = plan.graph
    for pyr in plan.pyramids:
        if not pyr.launch.streamed:
            continue
        conv_names = [m for m in pyr.node_names if graph.node(m).op == "conv"]
        out[_FLAT + pyr.name] = flatten_weights(
            [params[m][0] for m in conv_names]
        )
    return out


@partial(jax.jit, static_argnames=("plan", "end_skip", "interpret"))
def run_network(
    x: jnp.ndarray,
    params: Params,
    *,
    plan: PartitionPlan,
    end_skip: bool = True,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, dict[str, jnp.ndarray]]:
    """Execute the partition plan end to end for a batch ``x`` (B, H, W, C).

    ``interpret=None`` resolves per backend (compiled on TPU).  Params may
    come through :func:`prepare_network_params` so streamed launches reuse
    the pre-flattened weight arrays.  Returns ``(logits, skips)``:
    ``skips[pyramid.name]`` is that launch's ``(B, alpha, alpha, Q)`` int32
    END-cascade flag map (level 0 of each pyramid never skips).  Aggregate
    with :func:`skip_fractions`.
    """
    graph = plan.graph
    covered = plan.covered()
    values = {graph.nodes[0].name: x.astype(jnp.float32)}
    skips: dict[str, jnp.ndarray] = {}
    for n in graph.nodes[1:]:
        if n.name in covered:
            pyr = plan.pyramid_at(n.name)
            if pyr is None:
                continue  # interior pyramid node: computed with its launch
            conv_names = [m for m in pyr.node_names
                          if graph.node(m).op == "conv"]
            flat = params.get(_FLAT + pyr.name)
            y, skip = fused_pyramid(
                values[n.inputs[0]],
                # streamed launches with pre-flattened weights don't need
                # the per-level tensors threaded through the jit graph
                None if flat is not None
                else [params[m][0] for m in conv_names],
                [params[m][1] for m in conv_names],
                spec=pyr.spec,
                out_region=pyr.launch.out_region,
                streamed=pyr.launch.streamed,
                w_slots=pyr.launch.w_slots if pyr.launch.streamed else None,
                x_slots=pyr.launch.x_slots,
                c_tiles=pyr.launch.c_tiles,
                relu=pyr.relu,
                end_skip=end_skip,
                interpret=interpret,
                vmem_budget=plan.vmem_budget,
                weights_flat=flat,
            )
            values[pyr.node_names[-1]] = y
            skips[pyr.name] = skip
        elif n.op == "conv":
            w, b = params[n.name]
            values[n.name] = _conv_node(values[n.inputs[0]], n, w, b)
        elif n.op == "pool":
            values[n.name] = _pool_node(values[n.inputs[0]], n)
        else:
            values[n.name] = _head_op(values, n, params)
    return values[graph.output.name], skips


def skip_fractions(skips: dict[str, jnp.ndarray]) -> dict[str, list[float]]:
    """Per-pyramid, per-level fraction of tiles the END cascade skipped."""
    return {
        name: [float(f) for f in np.asarray(s, dtype=np.float64).mean(axis=(0, 1, 2))]
        for name, s in skips.items()
    }


def run_model(
    name: str,
    x: jnp.ndarray,
    params: Params | None = None,
    *,
    input_size: int | None = None,
    num_classes: int | None = None,
    plan: PartitionPlan | None = None,
    seed: int = 0,
    interpret: bool | None = None,
):
    """Convenience one-shot: build the zoo graph, auto-partition, run.

    Returns ``(logits, skips, plan, params)``.  Used by the example script
    and benchmarks; library code should call :func:`run_network` directly.
    """
    from .graph import MODELS

    kwargs = {}
    if input_size is not None:
        kwargs["input_size"] = input_size
    if num_classes is not None:
        kwargs["num_classes"] = num_classes
    graph = MODELS[name](**kwargs)
    if plan is None:
        plan = auto_partition(graph, batch=x.shape[0])
    if params is None:
        params = init_network_params(graph, jax.random.PRNGKey(seed))
    prepped = prepare_network_params(plan, params)
    logits, skips = run_network(x, prepped, plan=plan, interpret=interpret)
    return logits, skips, plan, params
