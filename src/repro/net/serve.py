"""Serving engine: continuous bucketed batching over the fused-pyramid runner.

``run_network`` is a batch call: fast once planned and compiled, but both
costs key on the exact batch size — every distinct request shape pays a
fresh ``auto_partition`` DP and a fresh jit trace.  Sustained traffic is the
opposite shape: many small requests, few distinct sizes.  This module turns
the runner into a service (ROADMAP's continuous-batching item):

* **Admission** — requests (single images or micro-batches) enter the
  queue through :func:`repro.robust.validate.check_request`: shape and
  finiteness are the per-request half of the preflight contract, so a
  poisoned request surfaces as a typed error *at submit* and never stalls
  or contaminates the queue (the plan/params half is validated once per
  cache entry).  The submit path is **thread-safe** (one engine lock), so
  N producer threads can feed one drain loop — the contract the
  :mod:`repro.net.frontend` async layer builds on.
* **Deadlines and priorities** — ``submit(x, deadline_us=, priority=)``
  with ``ServeConfig(deadline_aware=True)`` turns the FIFO queue into an
  earliest-deadline-first scheduler: higher priority first, then nearest
  deadline.  A request whose modeled ETA (queue delay from
  :func:`repro.core.cycle_model.queue_delay_cycles` plus its bucket's SLO,
  scaled by the measured-vs-modeled calibration ratio) already blows its
  deadline is **shed at admission** with a typed
  :class:`~repro.robust.errors.DeadlineExceeded` — load shedding instead
  of wasting a launch on a result nobody can use.  Requests that expire
  while queued complete immediately with the same typed error and never
  occupy a launch.
* **Bucketing** — admitted rows are packed (FIFO, or EDF order when
  deadline-aware) into power-of-two batch **buckets** (:func:`bucket_for`)
  and padded to the bucket size (:func:`pad_to_bucket`).  Batch elements
  are independent through every conv/pool/dense/global-pool op, so the
  real rows of a padded batch are **bit-identical** to running them
  unpadded under the same plan (``tests/test_serve.py`` enforces this at
  f32 and bf16) — padding buys shape reuse for free.
* **Plan + jit cache** — each bucket executes through one cache entry keyed
  ``(graph identity, vmem budget, bucket, dtype)``: the bucket-batch
  ``auto_partition`` plan (the DP costs launches at the *bucket's* batch,
  so cut points shift with bucket — see DESIGN.md §14), its prepared
  params, and the modeled latency estimate.  Plans come through the
  memoized ``auto_partition`` (its lru is the seed cache), and because all
  requests in a bucket share one padded shape, the jit executable is reused
  too — wave 2 of a bucket performs zero replans and zero retraces
  (``repro.net.runner.jit_trace_count`` is the regression hook).  The
  engine's own :class:`collections.OrderedDict` LRU bounds live entries and
  counts hits/misses/evictions next to ``partition_cache_info()``.
* **Double-buffered input staging** — while bucket *n* computes on device,
  bucket *n+1*'s padded host batch is already moving through
  ``jax.device_put`` (jax dispatch is asynchronous, so the host copy
  overlaps device compute).  The cost model twin is
  :func:`repro.core.cycle_model.serve_stream_cycles`.
* **Failure containment** (DESIGN.md §15) — a launch that dies with a
  typed :class:`~repro.robust.errors.RobustError` (including injected
  staging failures) fails *its batch* typed and the queue keeps draining.
  A **watchdog** (``watchdog_factor=N``) flags launches exceeding N× their
  expected wall (the max of the modeled SLO and the bucket's measured
  batch p50, so interpret-mode wall clocks calibrate it).  A per-key
  **circuit breaker** (``breaker_threshold=K``,
  :mod:`repro.robust.breaker`) opens after K consecutive failing launches
  — fallback-laden guarded runs, watchdog trips, sentinel trips, or typed
  errors — and pins the key to its last-good degraded rung (interpret or
  reference) for a cooldown window; a half-open probe re-tries the fused
  path.  An **output sentinel** (``output_sentinel=True``) catches
  non-finite logits post-launch and re-serves the batch from the reference
  walk — degraded-but-correct, never silent garbage.  All of it is off by
  default: a default-config engine behaves exactly like the PR 9 engine.
* **SLO + measurement** — each bucket publishes ``slo_us`` (modeled
  cold latency: host staging + the plan's ``modeled_us()``), ``steady_us``
  (the double-buffered steady state, ``max(compute, staging)``), and
  measured p50/p95 request latency + imgs/s; with a tracer installed
  (``repro.obs.tracing``) every batch records a ``serve_batch`` event, the
  cache bumps ``serve_cache_{hit,miss,eviction}`` counters, and every
  shed/expiry/watchdog/breaker/sentinel action records its own event.
* **Degradation, not drops** — ``ServeConfig(guarded=True)`` runs each
  bucket under the PR 8 ladder (``repro.robust.guarding``): a VMEM miss
  replans, a numeric fault quarantines the launch to the reference path,
  and the requests still complete.

``python -m repro.net.serve --model lenet --requests 32 --dry-stream``
drives a deterministic two-wave synthetic stream and prints the
bucket/SLO/throughput table (the CI smoke contract); ``--inject
slow_launch --breaker 1 --watchdog 3`` arms a wave-2 fault and shows the
breaker cycle in the summary (the CI chaos contract).
"""

from __future__ import annotations

import argparse
import json
import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cycle_model import (
    DEFAULT_PARAMS,
    host_staging_cycles,
    queue_delay_cycles,
    serve_stream_cycles,
)
from repro.core.dtypes import DTYPE_BYTES, canonical_dtype
from repro.core.program import VMEM_BUDGET_BYTES
from repro.obs.stats import percentile
from repro.obs.trace import get_tracer
from repro.robust.breaker import CircuitBreaker
from repro.robust.errors import (
    DeadlineExceeded,
    PreflightError,
    RobustError,
)
from repro.robust.faults import get_injector
from repro.robust.guard import GuardConfig, guarding
from repro.robust.validate import check_request

from .graph import Graph
from .partition import PartitionPlan, auto_partition
from .runner import (
    Params,
    prepare_network_params,
    reference_network,
    run_network,
)


def bucket_for(rows: int, buckets: tuple[int, ...]) -> int:
    """Smallest configured bucket that fits ``rows`` real rows."""
    for b in sorted(buckets):
        if rows <= b:
            return b
    raise PreflightError(
        f"request spans {rows} rows but the largest bucket is"
        f" {max(buckets)}; split micro-batches before submit",
        rows=rows, buckets=sorted(buckets),
    )


def pad_to_bucket(x, bucket: int) -> np.ndarray:
    """Zero-pad a ``(rows, H, W, C)`` batch up to ``bucket`` rows.

    Zero rows ride along through the padded launch and are sliced off
    before results are returned; the real rows' logits are bit-identical to
    the unpadded run under the same plan (batch elements never interact)."""
    x = np.asarray(x)
    rows = x.shape[0]
    if rows == bucket:
        return x
    if rows > bucket:
        raise PreflightError(
            f"cannot pad {rows} rows down to bucket {bucket}",
            rows=rows, bucket=bucket,
        )
    pad = np.zeros((bucket - rows,) + x.shape[1:], dtype=x.dtype)
    return np.concatenate([x, pad], axis=0)


@dataclass(frozen=True)
class ServeConfig:
    """Static knobs of one serving engine.

    ``buckets`` are the admissible padded batch sizes (ascending powers of
    two by convention; any ascending ints work).  ``plan_cache_size`` bounds
    the engine's plan+params LRU — evictions are counted, and because plans
    come through the memoized ``auto_partition``, a re-admitted key usually
    rebuilds from the lru without re-running the DP.  ``compute_dtype``
    ``None`` means the graph's own default.  ``guarded`` runs every bucket
    under the degradation ladder; ``require_finite`` controls the admission
    NaN/Inf scan (shape checks always run).  ``max_queue`` bounds queued
    requests — an overfull queue rejects at submit (backpressure) instead
    of growing without bound.

    The resilience knobs all default **off** (a default engine is the PR 9
    engine):

    * ``deadline_aware`` — EDF batch formation, queue-expiry sweeps, and
      admission-time load shedding against modeled ETA.  ``shed_margin``
      scales the modeled ETA before it is compared to the deadline (>1 is
      more aggressive shedding).
    * ``breaker_threshold`` / ``breaker_cooldown_s`` — per-(graph, bucket,
      dtype) circuit breaker: K consecutive failing launches pin the key
      to its last-good degraded rung for the cooldown window.
    * ``watchdog_factor`` — flag launches whose wall clock exceeds N× the
      expected batch wall (max of modeled SLO and the bucket's measured
      p50 — the measured term calibrates interpret-mode wall clocks that
      dwarf the 100 MHz model).
    * ``output_sentinel`` — host-side finite check on every launch's
      logits; a trip re-serves the batch from the reference walk."""

    buckets: tuple[int, ...] = (1, 2, 4, 8)
    plan_cache_size: int = 16
    compute_dtype: str | None = None
    vmem_budget: int = VMEM_BUDGET_BYTES
    prefer_region: str = "largest"
    interpret: bool | None = None
    end_skip: bool = True
    guarded: bool = False
    require_finite: bool = True
    max_queue: int = 1024
    deadline_aware: bool = False
    shed_margin: float = 1.0
    breaker_threshold: int | None = None
    breaker_cooldown_s: float = 5.0
    watchdog_factor: float | None = None
    output_sentinel: bool = False

    def __post_init__(self):
        if not self.buckets or list(self.buckets) != sorted(set(self.buckets)):
            raise PreflightError(
                f"buckets must be ascending and unique, got {self.buckets}",
                buckets=list(self.buckets),
            )
        if self.shed_margin <= 0:
            raise PreflightError(
                f"shed_margin must be positive, got {self.shed_margin}",
                shed_margin=self.shed_margin,
            )
        if self.breaker_threshold is not None and self.breaker_threshold < 1:
            raise PreflightError(
                f"breaker_threshold must be >= 1, got"
                f" {self.breaker_threshold}",
                breaker_threshold=self.breaker_threshold,
            )
        if self.watchdog_factor is not None and self.watchdog_factor <= 1:
            raise PreflightError(
                f"watchdog_factor must exceed 1, got {self.watchdog_factor}",
                watchdog_factor=self.watchdog_factor,
            )


@dataclass(frozen=True)
class Request:
    """One admitted unit of work: ``rows`` real images awaiting a bucket.

    ``deadline_s`` is the absolute ``time.perf_counter`` deadline computed
    at admission from the caller's relative ``deadline_us`` (``None`` means
    no deadline); ``priority`` orders EDF batches — higher runs first."""

    id: int
    x: np.ndarray  # (rows, H, W, C), host-side
    rows: int
    enqueue_s: float
    deadline_us: float | None = None
    deadline_s: float | None = None
    priority: int = 0


@dataclass(frozen=True)
class RequestResult:
    """Terminal state of one submitted request.

    Exactly one of ``logits``/``error`` is set: rejected, shed, expired,
    and failed requests carry the typed
    :class:`~repro.robust.errors.RobustError` (``bucket``/``latency_ms``
    stay ``None`` unless the request reached a launch); completed requests
    carry their real rows' logits and the enqueue→complete wall clock."""

    id: int
    rows: int
    bucket: int | None = None
    logits: np.ndarray | None = None
    error: RobustError | None = None
    latency_ms: float | None = None

    @property
    def ok(self) -> bool:
        return self.error is None


@dataclass(frozen=True)
class _PlanEntry:
    """One plan+jit cache entry: everything a bucket needs to execute."""

    bucket: int
    plan: PartitionPlan
    prepared: Params
    compute_cycles: int
    staging_cycles: int

    @property
    def slo_us(self) -> float:
        """Modeled cold latency of one bucket execution: the host→device
        input copy plus the plan's launches, nothing overlapped — the
        latency bound the engine publishes per bucket."""
        return serve_stream_cycles(
            1, self.compute_cycles, self.staging_cycles, double_buffered=False
        ) / DEFAULT_PARAMS.freq_mhz

    @property
    def steady_us(self) -> float:
        """Modeled steady-state per-bucket latency under double buffering:
        ``max(compute, staging)`` — the throughput bound."""
        two = serve_stream_cycles(
            2, self.compute_cycles, self.staging_cycles, double_buffered=True
        )
        return (two - (self.compute_cycles + self.staging_cycles)) / (
            DEFAULT_PARAMS.freq_mhz
        )


@dataclass
class _BucketStats:
    requests: int = 0
    images: int = 0
    batches: int = 0
    wall_ms: float = 0.0
    latencies_ms: list = field(default_factory=list)
    # clean per-batch walls only (watchdog-tripped walls are excluded so an
    # injected stall cannot poison its own detection threshold)
    batch_walls_ms: list = field(default_factory=list)


def _percentile(values: list, q: float) -> float:
    # the shared obs.stats helper, kept under the historical name
    return percentile(values, q)


# absolute floor of the watchdog's expected batch wall: steady-state
# interpret-mode walls are sub-millisecond once jax's jit cache is warm, and
# N x a sub-millisecond p50 is scheduler noise, not a stuck launch — the
# watchdog exists for launches stuck for 100s of ms, not 2 ms of jitter
WATCHDOG_FLOOR_MS = 10.0


class ServingEngine:
    """Continuous bucketed batching over one graph's fused-pyramid runner.

    ``submit`` admits (or rejects) requests under the engine lock — safe
    from any thread; ``drain`` forms buckets and executes them with the
    double-buffered input stage (one drain loop at a time — concurrent
    drains serialize); ``summary`` renders the bucket/SLO table.  The
    engine owns no device state beyond the staged batch — all heavy reuse
    lives in the plan+jit cache, so two engines over the same graph share
    compiled executables through jax's own cache.  Completion listeners
    (:meth:`add_listener`) observe every terminal :class:`RequestResult` —
    the hook :mod:`repro.net.frontend` turns into Future-style handles.
    """

    def __init__(
        self, graph: Graph, params: Params, config: ServeConfig | None = None
    ) -> None:
        self.graph = graph
        self.config = config or ServeConfig()
        self.master_params = params
        self.compute_dtype = canonical_dtype(
            self.config.compute_dtype or graph.compute_dtype
        )
        self.queue: deque[Request] = deque()
        self.results: dict[int, RequestResult] = {}
        self._cache: OrderedDict[tuple, _PlanEntry] = OrderedDict()
        self.cache_counters = {"hits": 0, "misses": 0, "evictions": 0}
        self._stats: dict[int, _BucketStats] = {}
        self._next_id = 0
        self.rejected = 0
        self.resilience = {
            "shed": 0, "expired": 0, "failed": 0,
            "watchdog_trips": 0, "sentinel_trips": 0, "stalls": 0,
        }
        self._breakers: dict[tuple, CircuitBreaker] = {}
        self._breaker_emitted: dict[tuple, int] = {}
        self._listeners: list = []
        self._lock = threading.RLock()
        self._drain_lock = threading.Lock()

    # -- listeners ----------------------------------------------------------

    def add_listener(self, fn) -> None:
        """Register ``fn(result)`` to be called with every terminal
        :class:`RequestResult` — completions, rejections, sheds, expiries,
        and batch failures alike.  Called under the engine lock, so
        listeners must be cheap and must not re-enter ``drain``."""
        with self._lock:
            self._listeners.append(fn)

    def _notify(self, result: RequestResult) -> None:
        for fn in self._listeners:
            fn(result)

    # -- admission ----------------------------------------------------------

    def submit(self, x, *, deadline_us: float | None = None,
               priority: int = 0) -> int:
        """Admit one request (a ``(H, W, C)`` image or ``(rows, H, W, C)``
        micro-batch); returns its request id.  Thread-safe.

        A request that fails admission — wrong shape, non-finite pixels,
        more rows than the largest bucket, a full queue, or (when
        ``deadline_aware``) a deadline the modeled queue ETA already blows
        — is *rejected*, not raised: its :class:`RequestResult` carries the
        typed error and the queue keeps moving.  Callers poll
        :attr:`results` (or register a listener / use the frontend)."""
        with self._lock:
            rid = self._next_id
            self._next_id += 1
            x = np.asarray(x)
            if x.ndim == 3:
                x = x[None]
            rows = int(x.shape[0]) if x.ndim == 4 else 0
            now = time.perf_counter()
            try:
                if len(self.queue) >= self.config.max_queue:
                    raise PreflightError(
                        f"queue is full ({self.config.max_queue} requests);"
                        " drain before submitting more",
                        max_queue=self.config.max_queue, field="queue",
                    )
                bucket_for(max(rows, 1), self.config.buckets)
                check_request(
                    x, self.graph, require_finite=self.config.require_finite
                )
                if self.config.deadline_aware and deadline_us is not None:
                    eta_us = self._eta_us(rows)
                    if eta_us * self.config.shed_margin > deadline_us:
                        raise DeadlineExceeded(
                            f"request shed at admission: modeled ETA"
                            f" {eta_us:.0f}us blows the {deadline_us:.0f}us"
                            " deadline",
                            request=rid, eta_us=round(eta_us, 1),
                            deadline_us=deadline_us,
                        )
            except RobustError as err:
                self.rejected += 1
                shed = isinstance(err, DeadlineExceeded)
                if shed:
                    self.resilience["shed"] += 1
                result = RequestResult(id=rid, rows=rows, error=err)
                self.results[rid] = result
                tracer = get_tracer()
                if tracer.enabled:
                    tracer.bump("serve_shed" if shed else "serve_reject")
                    tracer.record_event(
                        "serve_shed" if shed else "serve_reject",
                        request=rid, rows=rows,
                        error=type(err).__name__, message=str(err),
                    )
                self._notify(result)
                return rid
            self.queue.append(Request(
                id=rid, x=x, rows=rows, enqueue_s=now,
                deadline_us=deadline_us,
                deadline_s=(
                    now + deadline_us * 1e-6
                    if deadline_us is not None else None
                ),
                priority=priority,
            ))
            return rid

    def submit_many(self, xs) -> list[int]:
        return [self.submit(x) for x in xs]

    # -- deadline math ------------------------------------------------------

    def _calibration(self) -> float:
        """Worst observed measured-vs-modeled wall ratio across buckets
        with traffic (1.0 before any batch lands).  The 100 MHz model
        prices launches in microseconds; interpret-mode kernels take
        milliseconds — this ratio maps modeled ETAs into the wall-clock
        domain the deadlines live in."""
        ratios = []
        for b, st in self._stats.items():
            entry = self._cache.get(self._key(b))
            if entry is not None and st.batch_walls_ms:
                ratios.append(
                    percentile(st.batch_walls_ms, 50) * 1e3
                    / max(entry.slo_us, 1e-9)
                )
        return max(ratios) if ratios else 1.0

    def _eta_us(self, rows: int) -> float:
        """Modeled completion ETA for a new ``rows``-row request: the queue
        delay of the work already admitted (costed at the largest bucket's
        steady period, :func:`queue_delay_cycles`) plus the request's own
        bucket SLO, scaled by :meth:`_calibration`."""
        bucket = bucket_for(max(rows, 1), self.config.buckets)
        entry = self._entry(bucket)
        limit = max(self.config.buckets)
        queued_rows = sum(r.rows for r in self.queue)
        wait_us = 0.0
        if queued_rows:
            big = self._entry(limit)
            pending_batches = -(-queued_rows // limit)
            wait_us = queue_delay_cycles(
                pending_batches, big.compute_cycles, big.staging_cycles
            ) / DEFAULT_PARAMS.freq_mhz
        return self._calibration() * (wait_us + entry.slo_us)

    # -- plan + jit cache ---------------------------------------------------

    def _key(self, bucket: int) -> tuple:
        # the memo key mirrors auto_partition's: identical graph structure,
        # budget, bucket batch, and dtype mean identical plans
        return (self.graph, self.config.vmem_budget, bucket,
                self.compute_dtype)

    def _launch_name(self, bucket: int) -> str:
        return f"serve:{self.graph.name}:bucket{bucket}"

    def _entry(self, bucket: int) -> _PlanEntry:
        key = self._key(bucket)
        tracer = get_tracer()
        with self._lock:
            hit = key in self._cache
            if hit:
                self._cache.move_to_end(key)
                self.cache_counters["hits"] += 1
            else:
                self.cache_counters["misses"] += 1
                plan = auto_partition(
                    self.graph,
                    vmem_budget=self.config.vmem_budget,
                    batch=bucket,
                    prefer_region=self.config.prefer_region,
                    compute_dtype=self.compute_dtype,
                )
                prepared = prepare_network_params(plan, self.master_params)
                in_bytes = DTYPE_BYTES[self.compute_dtype] * bucket * (
                    self.graph.input_size ** 2 * self.graph.in_channels
                )
                self._cache[key] = _PlanEntry(
                    bucket=bucket,
                    plan=plan,
                    prepared=prepared,
                    compute_cycles=plan.modeled_cycles(),
                    staging_cycles=host_staging_cycles(in_bytes),
                )
                while len(self._cache) > self.config.plan_cache_size:
                    self._cache.popitem(last=False)
                    self.cache_counters["evictions"] += 1
                    if tracer.enabled:
                        tracer.bump("serve_cache_eviction")
            entry = self._cache[key]
        if tracer.enabled:
            tracer.bump("serve_cache_hit" if hit else "serve_cache_miss")
            tracer.record_event(
                "serve_plan_cache",
                model=self.graph.name, bucket=bucket,
                cache="hit" if hit else "miss",
                compute_dtype=self.compute_dtype,
                launches=entry.plan.n_launches(),
                slo_us=entry.slo_us,
            )
        return entry

    # -- circuit breaker ----------------------------------------------------

    def _breaker(self, bucket: int) -> CircuitBreaker | None:
        if self.config.breaker_threshold is None:
            return None
        key = self._key(bucket)
        with self._lock:
            br = self._breakers.get(key)
            if br is None:
                br = CircuitBreaker(
                    threshold=self.config.breaker_threshold,
                    cooldown_s=self.config.breaker_cooldown_s,
                )
                self._breakers[key] = br
            return br

    def _flush_breaker(self, bucket: int, br: CircuitBreaker) -> None:
        """Emit any breaker transitions not yet traced as ``serve_breaker``
        events (the observable surface the chaos CI asserts on)."""
        key = self._key(bucket)
        with self._lock:
            seen = self._breaker_emitted.get(key, 0)
            fresh = br.transitions[seen:]
            self._breaker_emitted[key] = len(br.transitions)
        if not fresh:
            return
        tracer = get_tracer()
        if tracer.enabled:
            for t in fresh:
                tracer.bump("serve_breaker_transition")
                tracer.record_event(
                    "serve_breaker",
                    model=self.graph.name, bucket=bucket,
                    from_state=t["from"], to_state=t["to"], why=t["why"],
                    pinned_rung=br.pinned_rung,
                )

    @staticmethod
    def _pin_rung(report, sentinel_tripped: bool) -> str | None:
        """The rung to pin an opening breaker to, from what this launch
        learned: sentinel trips and replan/reference fallbacks need the
        reference walk; interpret/heal fallbacks pin the interpret path;
        ``None`` (no ladder info) keeps the previous pin."""
        if sentinel_tripped:
            return "reference"
        if report is not None and report.events:
            rungs = {e.rung for e in report.events}
            if rungs <= {"heal", "interpret"}:
                return "interpret"
            return "reference"
        return None

    # -- execution ----------------------------------------------------------

    def _form_batch(self) -> list[Request] | None:
        """Pop the next run of requests that fits the largest bucket.

        FIFO by default: strictly in admission order — no peeking past the
        head to fill a bucket with later small requests, so a large request
        is never starved by a stream of singles (the fairness property the
        tests assert).  When ``deadline_aware``, expired requests are first
        completed with :class:`DeadlineExceeded` (they never occupy a
        launch), then the same no-skip packing runs over EDF order
        (priority desc, deadline asc, id asc) — the nearest deadline is
        never starved by later submissions."""
        with self._lock:
            if not self.config.deadline_aware:
                if not self.queue:
                    return None
                batch, rows = [], 0
                limit = max(self.config.buckets)
                while self.queue and rows + self.queue[0].rows <= limit:
                    req = self.queue.popleft()
                    batch.append(req)
                    rows += req.rows
                return batch
            now = time.perf_counter()
            live = []
            for req in self.queue:
                if req.deadline_s is not None and now > req.deadline_s:
                    self._expire(req, now)
                else:
                    live.append(req)
            if not live:
                self.queue = deque()
                return None
            order = sorted(live, key=lambda r: (
                -r.priority,
                r.deadline_s if r.deadline_s is not None else float("inf"),
                r.id,
            ))
            batch, rows = [], 0
            limit = max(self.config.buckets)
            for req in order:
                if rows + req.rows > limit:
                    break
                batch.append(req)
                rows += req.rows
            taken = {r.id for r in batch}
            self.queue = deque(r for r in live if r.id not in taken)
            return batch

    def _expire(self, req: Request, now: float) -> None:
        late_us = (now - req.deadline_s) * 1e6
        err = DeadlineExceeded(
            f"request {req.id} expired in queue {late_us:.0f}us past its"
            " deadline",
            request=req.id, late_us=round(late_us, 1),
            deadline_us=req.deadline_us,
        )
        result = RequestResult(id=req.id, rows=req.rows, error=err)
        self.results[req.id] = result
        self.resilience["expired"] += 1
        tracer = get_tracer()
        if tracer.enabled:
            tracer.bump("serve_expired")
            tracer.record_event(
                "serve_expired", request=req.id, rows=req.rows,
                late_us=round(late_us, 1),
            )
        self._notify(result)

    def _stage(self, batch: list[Request]):
        """Pad the batch to its bucket and start the host→device copy —
        called for bucket ``n+1`` while bucket ``n`` computes, so the copy
        overlaps compute (the double-buffered input stage).  The injected
        ``stage`` fault fires here: a staging failure surfaces before any
        device work, and the caller fails the batch typed."""
        rows = sum(r.rows for r in batch)
        bucket = bucket_for(rows, self.config.buckets)
        entry = self._entry(bucket)
        inj = get_injector()
        if inj.enabled:
            inj.fire("stage", self._launch_name(bucket))
        host = np.concatenate([r.x for r in batch], axis=0)
        x_dev = jax.device_put(
            jnp.asarray(pad_to_bucket(host, bucket), dtype=jnp.float32)
        )
        return batch, bucket, entry, x_dev

    def _next_staged(self):
        """Form and stage the next batch, failing staging-faulted batches
        typed and moving on — a poisoned batch never wedges the loop."""
        while True:
            batch = self._form_batch()
            if batch is None:
                return None
            try:
                return self._stage(batch)
            except RobustError as err:
                rows = sum(r.rows for r in batch)
                bucket = bucket_for(rows, self.config.buckets)
                self._fail_batch(batch, bucket, err)

    def _dispatch(self, entry: _PlanEntry, x_dev):
        if self.config.guarded:
            with guarding(GuardConfig(), source_params=self.master_params):
                return run_network(
                    x_dev, entry.prepared, plan=entry.plan,
                    end_skip=self.config.end_skip,
                    interpret=self.config.interpret,
                )
        return run_network(
            x_dev, entry.prepared, plan=entry.plan,
            end_skip=self.config.end_skip,
            interpret=self.config.interpret,
        )

    def _run_route(self, route: str, entry: _PlanEntry, x_dev):
        """Execute one staged bucket along ``route``; returns
        ``(logits, report)`` where ``report`` is the guarded
        :class:`~repro.robust.degrade.RunReport` (fused+guarded only).

        Routes: ``fused`` is the normal path (guarded when configured);
        ``interpret`` re-runs the same plan with interpret-mode kernels (a
        lowering/compile quarantine); ``reference`` is the node-by-node
        walk from the master params — no plan, no jit, degraded but
        correct."""
        if route == "reference":
            return reference_network(
                x_dev, self.graph, self.master_params
            ), None
        if route == "interpret":
            logits, _ = run_network(
                x_dev, entry.prepared, plan=entry.plan,
                end_skip=self.config.end_skip, interpret=True,
            )
            return logits, None
        if self.config.guarded:
            with guarding(
                GuardConfig(), source_params=self.master_params
            ) as guard:
                logits, _ = run_network(
                    x_dev, entry.prepared, plan=entry.plan,
                    end_skip=self.config.end_skip,
                    interpret=self.config.interpret,
                )
                return logits, guard.last_report
        logits, _ = run_network(
            x_dev, entry.prepared, plan=entry.plan,
            end_skip=self.config.end_skip,
            interpret=self.config.interpret,
        )
        return logits, None

    def _watchdog_threshold_ms(self, bucket: int, entry: _PlanEntry):
        """Expected batch wall for the watchdog: the max of the modeled
        SLO, the bucket's measured clean-batch p50, and
        :data:`WATCHDOG_FLOOR_MS`.  ``None`` until the bucket has one
        measured batch — the first launch calibrates (the modeled SLO
        alone is microseconds at the 100 MHz model and would flag every
        interpret-mode launch)."""
        with self._lock:
            st = self._stats.get(bucket)
            walls = list(st.batch_walls_ms) if st is not None else []
        if not walls:
            return None
        return max(
            entry.slo_us / 1e3, percentile(walls, 50), WATCHDOG_FLOOR_MS
        )

    def _fail_batch(
        self, batch: list[Request], bucket: int, err: RobustError,
        wall_ms: float | None = None,
    ) -> None:
        """Complete every request of a failed batch with the typed error —
        the batch is terminal, the queue keeps draining."""
        with self._lock:
            for req in batch:
                result = RequestResult(
                    id=req.id, rows=req.rows, bucket=bucket, error=err,
                )
                self.results[req.id] = result
                self._notify(result)
            self.resilience["failed"] += len(batch)
        tracer = get_tracer()
        if tracer.enabled:
            tracer.bump("serve_batch_error")
            tracer.record_event(
                "serve_batch_error",
                model=self.graph.name, bucket=bucket,
                requests=len(batch), error=type(err).__name__,
                message=str(err),
                wall_ms=wall_ms,
            )

    def _record(
        self, batch, bucket, entry, logits, wall_ms, *,
        route: str = "fused", calibrate: bool = True,
    ) -> None:
        done_s = time.perf_counter()
        host_logits = np.asarray(logits)
        with self._lock:
            stats = self._stats.setdefault(bucket, _BucketStats())
            stats.batches += 1
            stats.wall_ms += wall_ms
            if calibrate:
                stats.batch_walls_ms.append(wall_ms)
            row = 0
            for req in batch:
                lat_ms = (done_s - req.enqueue_s) * 1e3
                result = RequestResult(
                    id=req.id,
                    rows=req.rows,
                    bucket=bucket,
                    logits=host_logits[row: row + req.rows],
                    latency_ms=lat_ms,
                )
                self.results[req.id] = result
                row += req.rows
                stats.requests += 1
                stats.images += req.rows
                stats.latencies_ms.append(lat_ms)
                self._notify(result)
        tracer = get_tracer()
        if tracer.enabled:
            tracer.record_event(
                "serve_batch",
                model=self.graph.name, bucket=bucket,
                requests=len(batch), rows=row,
                wall_ms=wall_ms, slo_us=entry.slo_us,
                route=route,
            )

    def drain(self) -> list[RequestResult]:
        """Execute the queue to empty; returns the drained batches' results
        in completion order (failed batches included, with typed errors).

        The loop is the double-buffered pipeline: dispatch bucket ``n``
        (jax runs it asynchronously), immediately stage bucket ``n+1``'s
        padded host batch onto the device, then block on ``n`` — the
        ``n+1`` copy rides under ``n``'s compute, the host analogue of the
        kernel's revolving input prefetch.  Around that PR 9 core sit the
        resilience hooks (each a no-op unless configured/armed): injected
        queue stalls, breaker routing, the slow-launch delay, the output
        sentinel, the watchdog, and typed batch failure."""
        completed: list[RequestResult] = []
        inj = get_injector()
        with self._drain_lock:
            staged = self._next_staged()
            while staged is not None:
                if inj.enabled and inj.queue_stalled():
                    with self._lock:
                        self.resilience["stalls"] += 1
                    tracer = get_tracer()
                    if tracer.enabled:
                        tracer.bump("serve_stall")
                        tracer.record_event(
                            "serve_stall", model=self.graph.name
                        )
                    time.sleep(0.001)
                    continue
                batch, bucket, entry, x_dev = staged
                breaker = self._breaker(bucket)
                route = "fused"
                if breaker is not None and not breaker.allow():
                    route = breaker.pinned_rung or "reference"
                t0 = time.perf_counter()
                err: RobustError | None = None
                logits = report = None
                try:
                    logits, report = self._run_route(route, entry, x_dev)
                except RobustError as e:
                    err = e
                staged_next = self._next_staged()
                sentinel_tripped = False
                if err is None:
                    jax.block_until_ready(logits)
                    if inj.enabled:
                        delay = inj.launch_delay(self._launch_name(bucket))
                        if delay:
                            time.sleep(delay)
                        if route == "fused":
                            logits = inj.corrupt_output(
                                self._launch_name(bucket), logits
                            )
                    if self.config.output_sentinel and not np.isfinite(
                        np.asarray(logits, dtype=np.float32)
                    ).all():
                        sentinel_tripped = True
                        with self._lock:
                            self.resilience["sentinel_trips"] += 1
                        tracer = get_tracer()
                        if tracer.enabled:
                            tracer.bump("serve_sentinel_trip")
                            tracer.record_event(
                                "serve_sentinel",
                                model=self.graph.name, bucket=bucket,
                                route=route, action="reference_retry",
                            )
                        logits = self._run_route(
                            "reference", entry, x_dev
                        )[0]
                        jax.block_until_ready(logits)
                wall_ms = (time.perf_counter() - t0) * 1e3
                wd_tripped = False
                if (err is None
                        and self.config.watchdog_factor is not None):
                    thresh_ms = self._watchdog_threshold_ms(bucket, entry)
                    if (thresh_ms is not None and wall_ms
                            > self.config.watchdog_factor * thresh_ms):
                        wd_tripped = True
                        with self._lock:
                            self.resilience["watchdog_trips"] += 1
                        tracer = get_tracer()
                        if tracer.enabled:
                            tracer.bump("serve_watchdog_trip")
                            tracer.record_event(
                                "serve_watchdog",
                                model=self.graph.name, bucket=bucket,
                                wall_ms=wall_ms,
                                threshold_ms=(
                                    self.config.watchdog_factor * thresh_ms
                                ),
                                route=route,
                            )
                if breaker is not None and route == "fused":
                    degraded = report is not None and report.degraded
                    if (err is not None or wd_tripped or sentinel_tripped
                            or degraded):
                        breaker.record_failure(
                            rung=self._pin_rung(report, sentinel_tripped)
                        )
                    else:
                        breaker.record_success()
                    self._flush_breaker(bucket, breaker)
                if err is not None:
                    self._fail_batch(batch, bucket, err, wall_ms)
                else:
                    self._record(
                        batch, bucket, entry, logits, wall_ms,
                        route=route,
                        calibrate=not (wd_tripped or sentinel_tripped),
                    )
                completed.extend(self.results[r.id] for r in batch)
                staged = staged_next
        return completed

    def serve(self, xs) -> list[RequestResult]:
        """Submit + drain in one call; results ordered by request id
        (admission order), rejected requests included with their errors."""
        ids = self.submit_many(xs)
        self.drain()
        return [self.results[i] for i in ids]

    # -- reporting ----------------------------------------------------------

    def cache_info(self) -> dict:
        return {
            **self.cache_counters,
            "currsize": len(self._cache),
            "maxsize": self.config.plan_cache_size,
        }

    def summary(self) -> dict:
        """The bucket/SLO/throughput table as one JSON-safe dict — modeled
        (``slo_us``/``steady_us``/``modeled_cycles``) next to measured
        (``p50_ms``/``p95_ms``/``imgs_per_s``) per bucket, plus the serve
        and partition cache counters and the resilience section (shed /
        expired / failed / watchdog / sentinel / stall counts and one
        breaker snapshot per bucket) — DESIGN.md §14/§15's observable
        surface."""
        from .partition import partition_cache_info
        from .runner import jit_trace_count

        with self._lock:
            rows = []
            for bucket in sorted(self._stats):
                st = self._stats[bucket]
                entry = self._cache.get(self._key(bucket))
                row = {
                    "bucket": bucket,
                    "batches": st.batches,
                    "requests": st.requests,
                    "images": st.images,
                    "p50_ms": _percentile(st.latencies_ms, 50),
                    "p95_ms": _percentile(st.latencies_ms, 95),
                    "imgs_per_s": (
                        st.images / (st.wall_ms / 1e3) if st.wall_ms else 0.0
                    ),
                }
                if entry is not None:  # evicted entries lose model columns
                    row.update(
                        slo_us=entry.slo_us,
                        steady_us=entry.steady_us,
                        modeled_cycles=entry.compute_cycles,
                        staging_cycles=entry.staging_cycles,
                        launches=entry.plan.n_launches(),
                        hbm_bytes=entry.plan.hbm_bytes(),
                    )
                rows.append(row)
            total_images = sum(st.images for st in self._stats.values())
            total_wall_ms = sum(st.wall_ms for st in self._stats.values())
            from dataclasses import asdict

            breakers = {
                str(key[2]): asdict(br.snapshot())
                for key, br in sorted(
                    self._breakers.items(), key=lambda kv: kv[0][2]
                )
            }
            return {
                "model": self.graph.name,
                "compute_dtype": self.compute_dtype,
                "guarded": self.config.guarded,
                "buckets": rows,
                "completed": sum(
                    1 for r in self.results.values() if r.ok
                ),
                "rejected": self.rejected,
                "images": total_images,
                "imgs_per_s": (
                    total_images / (total_wall_ms / 1e3)
                    if total_wall_ms else 0.0
                ),
                "cache": {
                    "serve": self.cache_info(),
                    "partition": partition_cache_info()._asdict(),
                    "jit_traces": jit_trace_count(),
                },
                "resilience": {
                    **self.resilience,
                    "breakers": breakers,
                },
            }


# ---------------------------------------------------------------------------
# CLI: synthetic request stream
# ---------------------------------------------------------------------------


def _synthetic_stream(graph: Graph, n: int, buckets, seed: int):
    """Deterministic request mix: row counts cycle through the bucket range
    so every bucket is exercised; pixels are seeded normals."""
    rng = np.random.default_rng(seed)
    limit = max(buckets)
    sizes = [(i % limit) + 1 for i in range(n)]
    return [
        rng.standard_normal(
            (r, graph.input_size, graph.input_size, graph.in_channels)
        ).astype(np.float32)
        for r in sizes
    ]


def _wave_delta(before: dict, after: dict) -> dict:
    return {k: after[k] - before[k] for k in after}


def _cache_snapshot(engine: ServingEngine) -> dict:
    from .partition import partition_cache_info
    from .runner import jit_trace_count

    info = partition_cache_info()
    return {
        "serve_hits": engine.cache_counters["hits"],
        "serve_misses": engine.cache_counters["misses"],
        "partition_hits": info.hits,
        "partition_misses": info.misses,
        "jit_traces": jit_trace_count(),
    }


INJECT_MODES = ("slow_launch", "stage_fail", "poison", "stall")


def _armed_injector(mode: str, seed: int, breaker: int | None):
    """A :class:`FaultInjector` armed for the chosen chaos mode — fired
    during wave 2 only, so wave 1 calibrates the watchdog first."""
    from repro.robust.faults import FaultInjector

    inj = FaultInjector(seed=seed)
    if mode == "slow_launch":
        inj.slow_launch(0.25, times=max(breaker or 1, 1))
    elif mode == "stage_fail":
        inj.raise_at("stage", times=2, message="injected device_put failure")
    elif mode == "poison":
        inj.poison_output(times=2)
    elif mode == "stall":
        inj.stall_queue(3)
    return inj


def main(argv=None) -> int:
    from .graph import MODELS
    from .runner import init_network_params

    ap = argparse.ArgumentParser(
        prog="python -m repro.net.serve",
        description="Drive a synthetic request stream through the serving"
        " engine and print the bucket/SLO/throughput table.",
    )
    ap.add_argument("--model", default="lenet", choices=sorted(MODELS))
    ap.add_argument("--requests", type=int, default=32,
                    help="requests per wave (two waves are driven; the"
                    " second demonstrates plan/jit cache reuse)")
    ap.add_argument("--input", type=int, default=None,
                    help="override the model's input size")
    ap.add_argument("--dtype", default=None,
                    help="compute dtype (default: the graph's)")
    ap.add_argument("--buckets", default="1,2,4,8",
                    help="comma-separated ascending batch buckets")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--guarded", action="store_true",
                    help="run buckets under the degradation ladder")
    ap.add_argument("--dry-stream", action="store_true",
                    help="deterministic in-process stream sized for CI"
                    " smoke (interpret-mode kernels)")
    ap.add_argument("--inject", default=None, choices=INJECT_MODES,
                    help="arm a serving fault for wave 2 (wave 1 stays"
                    " clean to calibrate the watchdog); implies breaker 1,"
                    " watchdog 3, and the output sentinel unless given")
    ap.add_argument("--breaker", type=int, default=None, metavar="K",
                    help="open the per-bucket circuit breaker after K"
                    " consecutive failing launches")
    ap.add_argument("--breaker-cooldown", type=float, default=0.0,
                    metavar="S", help="breaker cooldown seconds before the"
                    " half-open probe (default 0: probe immediately)")
    ap.add_argument("--watchdog", type=float, default=None, metavar="N",
                    help="flag launches exceeding N x the expected batch"
                    " wall (modeled SLO or measured p50)")
    ap.add_argument("--deadline-us", type=float, default=None,
                    help="submit every request with this relative deadline"
                    " (enables deadline-aware EDF admission)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the summary (with per-wave cache deltas)"
                    " as JSON")
    args = ap.parse_args(argv)

    kwargs = {"input_size": args.input} if args.input else {}
    graph = MODELS[args.model](**kwargs)
    buckets = tuple(int(b) for b in args.buckets.split(","))
    breaker = args.breaker
    watchdog = args.watchdog
    sentinel = False
    if args.inject is not None:
        breaker = 1 if breaker is None else breaker
        watchdog = 3.0 if watchdog is None else watchdog
        sentinel = args.inject == "poison"
    config = ServeConfig(
        buckets=buckets,
        compute_dtype=args.dtype,
        guarded=args.guarded,
        interpret=True if args.dry_stream else None,
        deadline_aware=args.deadline_us is not None,
        breaker_threshold=breaker,
        breaker_cooldown_s=args.breaker_cooldown,
        watchdog_factor=watchdog,
        output_sentinel=sentinel,
    )
    params = init_network_params(graph, jax.random.PRNGKey(args.seed))
    engine = ServingEngine(graph, params, config)
    stream = _synthetic_stream(graph, args.requests, buckets, args.seed)

    from contextlib import nullcontext

    from repro.robust.faults import inject

    waves = []
    for wave in (1, 2):
        chaos = (
            inject(injector=_armed_injector(
                args.inject, args.seed, breaker
            ))
            if args.inject is not None and wave == 2 else nullcontext()
        )
        before = _cache_snapshot(engine)
        t0 = time.perf_counter()
        with chaos:
            for x in stream:
                engine.submit(x, deadline_us=args.deadline_us)
            engine.drain()
        wall_s = time.perf_counter() - t0
        delta = _wave_delta(before, _cache_snapshot(engine))
        delta["wall_s"] = wall_s
        waves.append(delta)

    summary = engine.summary()
    summary["waves"] = waves
    summary["submitted"] = 2 * len(stream)
    summary["terminal"] = len(engine.results)

    from repro.obs.explain import serve_table

    serve_table(summary)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2, default=str)
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
