"""Serving engine: continuous bucketed batching over the fused-pyramid runner.

``run_network`` is a batch call: fast once planned and compiled, but both
costs key on the exact batch size — every distinct request shape pays a
fresh ``auto_partition`` DP and a fresh jit trace.  Sustained traffic is the
opposite shape: many small requests, few distinct sizes.  This module turns
the runner into a service (ROADMAP's continuous-batching item):

* **Admission** — requests (single images or micro-batches) enter a FIFO
  queue through :func:`repro.robust.validate.check_request`: shape and
  finiteness are the per-request half of the preflight contract, so a
  poisoned request surfaces as a typed error *at submit* and never stalls
  or contaminates the queue (the plan/params half is validated once per
  cache entry).
* **Bucketing** — admitted rows are packed FIFO into power-of-two batch
  **buckets** (:func:`bucket_for`) and padded to the bucket size
  (:func:`pad_to_bucket`).  Batch elements are independent through every
  conv/pool/dense/global-pool op, so the real rows of a padded batch are
  **bit-identical** to running them unpadded under the same plan
  (``tests/test_serve.py`` enforces this at f32 and bf16) — padding buys
  shape reuse for free.
* **Plan + jit cache** — each bucket executes through one cache entry keyed
  ``(graph identity, vmem budget, bucket, dtype)``: the bucket-batch
  ``auto_partition`` plan (the DP costs launches at the *bucket's* batch,
  so cut points shift with bucket — see DESIGN.md §14), its prepared
  params, and the modeled latency estimate.  Plans come through the
  memoized ``auto_partition`` (its lru is the seed cache), and because all
  requests in a bucket share one padded shape, the jit executable is reused
  too — wave 2 of a bucket performs zero replans and zero retraces
  (``repro.net.runner.jit_trace_count`` is the regression hook).  The
  engine's own :class:`collections.OrderedDict` LRU bounds live entries and
  counts hits/misses/evictions next to ``partition_cache_info()``.
* **Double-buffered input staging** — while bucket *n* computes on device,
  bucket *n+1*'s padded host batch is already moving through
  ``jax.device_put`` (jax dispatch is asynchronous, so the host copy
  overlaps device compute).  The cost model twin is
  :func:`repro.core.cycle_model.serve_stream_cycles`.
* **SLO + measurement** — each bucket publishes ``slo_us`` (modeled
  cold latency: host staging + the plan's ``modeled_us()``), ``steady_us``
  (the double-buffered steady state, ``max(compute, staging)``), and
  measured p50/p95 request latency + imgs/s; with a tracer installed
  (``repro.obs.tracing``) every batch records a ``serve_batch`` event and
  the cache bumps ``serve_cache_{hit,miss,eviction}`` counters.
* **Degradation, not drops** — ``ServeConfig(guarded=True)`` runs each
  bucket under the PR 8 ladder (``repro.robust.guarding``): a VMEM miss
  replans, a numeric fault quarantines the launch to the reference path,
  and the requests still complete.

``python -m repro.net.serve --model lenet --requests 32 --dry-stream``
drives a deterministic two-wave synthetic stream and prints the
bucket/SLO/throughput table (the CI smoke contract).
"""

from __future__ import annotations

import argparse
import json
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cycle_model import (
    DEFAULT_PARAMS,
    host_staging_cycles,
    serve_stream_cycles,
)
from repro.core.dtypes import DTYPE_BYTES, canonical_dtype
from repro.core.program import VMEM_BUDGET_BYTES
from repro.obs.trace import get_tracer
from repro.robust.errors import PreflightError, RobustError
from repro.robust.guard import GuardConfig, guarding
from repro.robust.validate import check_request

from .graph import Graph
from .partition import PartitionPlan, auto_partition
from .runner import Params, prepare_network_params, run_network


def bucket_for(rows: int, buckets: tuple[int, ...]) -> int:
    """Smallest configured bucket that fits ``rows`` real rows."""
    for b in sorted(buckets):
        if rows <= b:
            return b
    raise PreflightError(
        f"request spans {rows} rows but the largest bucket is"
        f" {max(buckets)}; split micro-batches before submit",
        rows=rows, buckets=sorted(buckets),
    )


def pad_to_bucket(x, bucket: int) -> np.ndarray:
    """Zero-pad a ``(rows, H, W, C)`` batch up to ``bucket`` rows.

    Zero rows ride along through the padded launch and are sliced off
    before results are returned; the real rows' logits are bit-identical to
    the unpadded run under the same plan (batch elements never interact)."""
    x = np.asarray(x)
    rows = x.shape[0]
    if rows == bucket:
        return x
    if rows > bucket:
        raise PreflightError(
            f"cannot pad {rows} rows down to bucket {bucket}",
            rows=rows, bucket=bucket,
        )
    pad = np.zeros((bucket - rows,) + x.shape[1:], dtype=x.dtype)
    return np.concatenate([x, pad], axis=0)


@dataclass(frozen=True)
class ServeConfig:
    """Static knobs of one serving engine.

    ``buckets`` are the admissible padded batch sizes (ascending powers of
    two by convention; any ascending ints work).  ``plan_cache_size`` bounds
    the engine's plan+params LRU — evictions are counted, and because plans
    come through the memoized ``auto_partition``, a re-admitted key usually
    rebuilds from the lru without re-running the DP.  ``compute_dtype``
    ``None`` means the graph's own default.  ``guarded`` runs every bucket
    under the degradation ladder; ``require_finite`` controls the admission
    NaN/Inf scan (shape checks always run).  ``max_queue`` bounds queued
    requests — an overfull queue rejects at submit (backpressure) instead
    of growing without bound."""

    buckets: tuple[int, ...] = (1, 2, 4, 8)
    plan_cache_size: int = 16
    compute_dtype: str | None = None
    vmem_budget: int = VMEM_BUDGET_BYTES
    prefer_region: str = "largest"
    interpret: bool | None = None
    end_skip: bool = True
    guarded: bool = False
    require_finite: bool = True
    max_queue: int = 1024

    def __post_init__(self):
        if not self.buckets or list(self.buckets) != sorted(set(self.buckets)):
            raise PreflightError(
                f"buckets must be ascending and unique, got {self.buckets}",
                buckets=list(self.buckets),
            )


@dataclass(frozen=True)
class Request:
    """One admitted unit of work: ``rows`` real images awaiting a bucket."""

    id: int
    x: np.ndarray  # (rows, H, W, C), host-side
    rows: int
    enqueue_s: float


@dataclass(frozen=True)
class RequestResult:
    """Terminal state of one submitted request.

    Exactly one of ``logits``/``error`` is set: rejected requests carry the
    typed :class:`~repro.robust.errors.RobustError` the admission check
    raised (``bucket``/``latency_ms`` stay ``None``); completed requests
    carry their real rows' logits and the enqueue→complete wall clock."""

    id: int
    rows: int
    bucket: int | None = None
    logits: np.ndarray | None = None
    error: RobustError | None = None
    latency_ms: float | None = None

    @property
    def ok(self) -> bool:
        return self.error is None


@dataclass(frozen=True)
class _PlanEntry:
    """One plan+jit cache entry: everything a bucket needs to execute."""

    bucket: int
    plan: PartitionPlan
    prepared: Params
    compute_cycles: int
    staging_cycles: int

    @property
    def slo_us(self) -> float:
        """Modeled cold latency of one bucket execution: the host→device
        input copy plus the plan's launches, nothing overlapped — the
        latency bound the engine publishes per bucket."""
        return serve_stream_cycles(
            1, self.compute_cycles, self.staging_cycles, double_buffered=False
        ) / DEFAULT_PARAMS.freq_mhz

    @property
    def steady_us(self) -> float:
        """Modeled steady-state per-bucket latency under double buffering:
        ``max(compute, staging)`` — the throughput bound."""
        two = serve_stream_cycles(
            2, self.compute_cycles, self.staging_cycles, double_buffered=True
        )
        return (two - (self.compute_cycles + self.staging_cycles)) / (
            DEFAULT_PARAMS.freq_mhz
        )


@dataclass
class _BucketStats:
    requests: int = 0
    images: int = 0
    batches: int = 0
    wall_ms: float = 0.0
    latencies_ms: list = field(default_factory=list)


def _percentile(values: list, q: float) -> float:
    return float(np.percentile(np.asarray(values, dtype=np.float64), q))


class ServingEngine:
    """Continuous bucketed batching over one graph's fused-pyramid runner.

    Single-threaded by design: ``submit`` admits (or rejects) requests into
    the FIFO queue, ``drain`` forms buckets and executes them with the
    double-buffered input stage, ``summary`` renders the bucket/SLO table.
    The engine owns no device state beyond the staged batch — all heavy
    reuse lives in the plan+jit cache, so two engines over the same graph
    share compiled executables through jax's own cache.
    """

    def __init__(
        self, graph: Graph, params: Params, config: ServeConfig | None = None
    ) -> None:
        self.graph = graph
        self.config = config or ServeConfig()
        self.master_params = params
        self.compute_dtype = canonical_dtype(
            self.config.compute_dtype or graph.compute_dtype
        )
        self.queue: deque[Request] = deque()
        self.results: dict[int, RequestResult] = {}
        self._cache: OrderedDict[tuple, _PlanEntry] = OrderedDict()
        self.cache_counters = {"hits": 0, "misses": 0, "evictions": 0}
        self._stats: dict[int, _BucketStats] = {}
        self._next_id = 0
        self.rejected = 0

    # -- admission ----------------------------------------------------------

    def submit(self, x) -> int:
        """Admit one request (a ``(H, W, C)`` image or ``(rows, H, W, C)``
        micro-batch); returns its request id.

        A request that fails admission — wrong shape, non-finite pixels,
        more rows than the largest bucket, or a full queue — is *rejected*,
        not raised: its :class:`RequestResult` carries the typed error and
        the queue keeps moving.  Callers poll :attr:`results`."""
        rid = self._next_id
        self._next_id += 1
        x = np.asarray(x)
        if x.ndim == 3:
            x = x[None]
        rows = int(x.shape[0]) if x.ndim == 4 else 0
        try:
            if len(self.queue) >= self.config.max_queue:
                raise PreflightError(
                    f"queue is full ({self.config.max_queue} requests);"
                    " drain before submitting more",
                    max_queue=self.config.max_queue,
                )
            bucket_for(max(rows, 1), self.config.buckets)
            check_request(
                x, self.graph, require_finite=self.config.require_finite
            )
        except RobustError as err:
            self.rejected += 1
            self.results[rid] = RequestResult(id=rid, rows=rows, error=err)
            tracer = get_tracer()
            if tracer.enabled:
                tracer.bump("serve_reject")
                tracer.record_event(
                    "serve_reject", request=rid, rows=rows,
                    error=type(err).__name__, message=str(err),
                )
            return rid
        self.queue.append(
            Request(id=rid, x=x, rows=rows, enqueue_s=time.perf_counter())
        )
        return rid

    def submit_many(self, xs) -> list[int]:
        return [self.submit(x) for x in xs]

    # -- plan + jit cache ---------------------------------------------------

    def _key(self, bucket: int) -> tuple:
        # the memo key mirrors auto_partition's: identical graph structure,
        # budget, bucket batch, and dtype mean identical plans
        return (self.graph, self.config.vmem_budget, bucket,
                self.compute_dtype)

    def _entry(self, bucket: int) -> _PlanEntry:
        key = self._key(bucket)
        tracer = get_tracer()
        hit = key in self._cache
        if hit:
            self._cache.move_to_end(key)
            self.cache_counters["hits"] += 1
        else:
            self.cache_counters["misses"] += 1
            plan = auto_partition(
                self.graph,
                vmem_budget=self.config.vmem_budget,
                batch=bucket,
                prefer_region=self.config.prefer_region,
                compute_dtype=self.compute_dtype,
            )
            prepared = prepare_network_params(plan, self.master_params)
            in_bytes = DTYPE_BYTES[self.compute_dtype] * bucket * (
                self.graph.input_size ** 2 * self.graph.in_channels
            )
            self._cache[key] = _PlanEntry(
                bucket=bucket,
                plan=plan,
                prepared=prepared,
                compute_cycles=plan.modeled_cycles(),
                staging_cycles=host_staging_cycles(in_bytes),
            )
            while len(self._cache) > self.config.plan_cache_size:
                self._cache.popitem(last=False)
                self.cache_counters["evictions"] += 1
                if tracer.enabled:
                    tracer.bump("serve_cache_eviction")
        entry = self._cache[key]
        if tracer.enabled:
            tracer.bump("serve_cache_hit" if hit else "serve_cache_miss")
            tracer.record_event(
                "serve_plan_cache",
                model=self.graph.name, bucket=bucket,
                cache="hit" if hit else "miss",
                compute_dtype=self.compute_dtype,
                launches=entry.plan.n_launches(),
                slo_us=entry.slo_us,
            )
        return entry

    # -- execution ----------------------------------------------------------

    def _form_batch(self) -> list[Request] | None:
        """Pop the next FIFO run of requests that fits the largest bucket.

        Strictly in admission order — no peeking past the head to fill a
        bucket with later small requests, so a large request is never
        starved by a stream of singles (the fairness property the tests
        assert)."""
        if not self.queue:
            return None
        batch, rows = [], 0
        limit = max(self.config.buckets)
        while self.queue and rows + self.queue[0].rows <= limit:
            req = self.queue.popleft()
            batch.append(req)
            rows += req.rows
        return batch

    def _stage(self, batch: list[Request]):
        """Pad the batch to its bucket and start the host→device copy —
        called for bucket ``n+1`` while bucket ``n`` computes, so the copy
        overlaps compute (the double-buffered input stage)."""
        rows = sum(r.rows for r in batch)
        bucket = bucket_for(rows, self.config.buckets)
        entry = self._entry(bucket)
        host = np.concatenate([r.x for r in batch], axis=0)
        x_dev = jax.device_put(
            jnp.asarray(pad_to_bucket(host, bucket), dtype=jnp.float32)
        )
        return batch, bucket, entry, x_dev

    def _dispatch(self, entry: _PlanEntry, x_dev):
        if self.config.guarded:
            with guarding(GuardConfig(), source_params=self.master_params):
                return run_network(
                    x_dev, entry.prepared, plan=entry.plan,
                    end_skip=self.config.end_skip,
                    interpret=self.config.interpret,
                )
        return run_network(
            x_dev, entry.prepared, plan=entry.plan,
            end_skip=self.config.end_skip,
            interpret=self.config.interpret,
        )

    def _record(self, batch, bucket, entry, logits, wall_ms) -> None:
        done_s = time.perf_counter()
        host_logits = np.asarray(logits)
        stats = self._stats.setdefault(bucket, _BucketStats())
        stats.batches += 1
        stats.wall_ms += wall_ms
        row = 0
        for req in batch:
            lat_ms = (done_s - req.enqueue_s) * 1e3
            self.results[req.id] = RequestResult(
                id=req.id,
                rows=req.rows,
                bucket=bucket,
                logits=host_logits[row: row + req.rows],
                latency_ms=lat_ms,
            )
            row += req.rows
            stats.requests += 1
            stats.images += req.rows
            stats.latencies_ms.append(lat_ms)
        tracer = get_tracer()
        if tracer.enabled:
            tracer.record_event(
                "serve_batch",
                model=self.graph.name, bucket=bucket,
                requests=len(batch), rows=row,
                wall_ms=wall_ms, slo_us=entry.slo_us,
            )

    def drain(self) -> list[RequestResult]:
        """Execute the queue to empty; returns completed results in order.

        The loop is the double-buffered pipeline: dispatch bucket ``n``
        (jax runs it asynchronously), immediately stage bucket ``n+1``'s
        padded host batch onto the device, then block on ``n`` — the
        ``n+1`` copy rides under ``n``'s compute, the host analogue of the
        kernel's revolving input prefetch."""
        completed: list[RequestResult] = []
        nxt = self._form_batch()
        staged = self._stage(nxt) if nxt else None
        while staged is not None:
            batch, bucket, entry, x_dev = staged
            t0 = time.perf_counter()
            logits, _ = self._dispatch(entry, x_dev)
            nxt = self._form_batch()
            staged = self._stage(nxt) if nxt else None
            jax.block_until_ready(logits)
            wall_ms = (time.perf_counter() - t0) * 1e3
            self._record(batch, bucket, entry, logits, wall_ms)
            completed.extend(self.results[r.id] for r in batch)
        return completed

    def serve(self, xs) -> list[RequestResult]:
        """Submit + drain in one call; results ordered by request id
        (admission order), rejected requests included with their errors."""
        ids = self.submit_many(xs)
        self.drain()
        return [self.results[i] for i in ids]

    # -- reporting ----------------------------------------------------------

    def cache_info(self) -> dict:
        return {
            **self.cache_counters,
            "currsize": len(self._cache),
            "maxsize": self.config.plan_cache_size,
        }

    def summary(self) -> dict:
        """The bucket/SLO/throughput table as one JSON-safe dict — modeled
        (``slo_us``/``steady_us``/``modeled_cycles``) next to measured
        (``p50_ms``/``p95_ms``/``imgs_per_s``) per bucket, plus the serve
        and partition cache counters (DESIGN.md §14's observable surface)."""
        from .partition import partition_cache_info
        from .runner import jit_trace_count

        rows = []
        for bucket in sorted(self._stats):
            st = self._stats[bucket]
            entry = self._cache.get(self._key(bucket))
            row = {
                "bucket": bucket,
                "batches": st.batches,
                "requests": st.requests,
                "images": st.images,
                "p50_ms": _percentile(st.latencies_ms, 50),
                "p95_ms": _percentile(st.latencies_ms, 95),
                "imgs_per_s": (
                    st.images / (st.wall_ms / 1e3) if st.wall_ms else 0.0
                ),
            }
            if entry is not None:  # evicted entries lose their model columns
                row.update(
                    slo_us=entry.slo_us,
                    steady_us=entry.steady_us,
                    modeled_cycles=entry.compute_cycles,
                    staging_cycles=entry.staging_cycles,
                    launches=entry.plan.n_launches(),
                    hbm_bytes=entry.plan.hbm_bytes(),
                )
            rows.append(row)
        total_images = sum(st.images for st in self._stats.values())
        total_wall_ms = sum(st.wall_ms for st in self._stats.values())
        return {
            "model": self.graph.name,
            "compute_dtype": self.compute_dtype,
            "guarded": self.config.guarded,
            "buckets": rows,
            "completed": sum(1 for r in self.results.values() if r.ok),
            "rejected": self.rejected,
            "images": total_images,
            "imgs_per_s": (
                total_images / (total_wall_ms / 1e3) if total_wall_ms else 0.0
            ),
            "cache": {
                "serve": self.cache_info(),
                "partition": partition_cache_info()._asdict(),
                "jit_traces": jit_trace_count(),
            },
        }


# ---------------------------------------------------------------------------
# CLI: synthetic request stream
# ---------------------------------------------------------------------------


def _synthetic_stream(graph: Graph, n: int, buckets, seed: int):
    """Deterministic request mix: row counts cycle through the bucket range
    so every bucket is exercised; pixels are seeded normals."""
    rng = np.random.default_rng(seed)
    limit = max(buckets)
    sizes = [(i % limit) + 1 for i in range(n)]
    return [
        rng.standard_normal(
            (r, graph.input_size, graph.input_size, graph.in_channels)
        ).astype(np.float32)
        for r in sizes
    ]


def _wave_delta(before: dict, after: dict) -> dict:
    return {k: after[k] - before[k] for k in after}


def _cache_snapshot(engine: ServingEngine) -> dict:
    from .partition import partition_cache_info
    from .runner import jit_trace_count

    info = partition_cache_info()
    return {
        "serve_hits": engine.cache_counters["hits"],
        "serve_misses": engine.cache_counters["misses"],
        "partition_hits": info.hits,
        "partition_misses": info.misses,
        "jit_traces": jit_trace_count(),
    }


def main(argv=None) -> int:
    from .graph import MODELS
    from .runner import init_network_params

    ap = argparse.ArgumentParser(
        prog="python -m repro.net.serve",
        description="Drive a synthetic request stream through the serving"
        " engine and print the bucket/SLO/throughput table.",
    )
    ap.add_argument("--model", default="lenet", choices=sorted(MODELS))
    ap.add_argument("--requests", type=int, default=32,
                    help="requests per wave (two waves are driven; the"
                    " second demonstrates plan/jit cache reuse)")
    ap.add_argument("--input", type=int, default=None,
                    help="override the model's input size")
    ap.add_argument("--dtype", default=None,
                    help="compute dtype (default: the graph's)")
    ap.add_argument("--buckets", default="1,2,4,8",
                    help="comma-separated ascending batch buckets")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--guarded", action="store_true",
                    help="run buckets under the degradation ladder")
    ap.add_argument("--dry-stream", action="store_true",
                    help="deterministic in-process stream sized for CI"
                    " smoke (interpret-mode kernels)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the summary (with per-wave cache deltas)"
                    " as JSON")
    args = ap.parse_args(argv)

    kwargs = {"input_size": args.input} if args.input else {}
    graph = MODELS[args.model](**kwargs)
    buckets = tuple(int(b) for b in args.buckets.split(","))
    config = ServeConfig(
        buckets=buckets,
        compute_dtype=args.dtype,
        guarded=args.guarded,
        interpret=True if args.dry_stream else None,
    )
    params = init_network_params(graph, jax.random.PRNGKey(args.seed))
    engine = ServingEngine(graph, params, config)
    stream = _synthetic_stream(graph, args.requests, buckets, args.seed)

    waves = []
    for wave in (1, 2):
        before = _cache_snapshot(engine)
        t0 = time.perf_counter()
        engine.submit_many(stream)
        engine.drain()
        wall_s = time.perf_counter() - t0
        delta = _wave_delta(before, _cache_snapshot(engine))
        delta["wall_s"] = wall_s
        waves.append(delta)

    summary = engine.summary()
    summary["waves"] = waves

    from repro.obs.explain import serve_table

    serve_table(summary)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2, default=str)
        print(f"wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
