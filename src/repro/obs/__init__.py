"""Fusion observability: structured launch traces, metrics, and reports.

The plan ladder (``x_slots`` / ``w_slots`` / ``c_tiles``, resident vs
streamed vs channel-tiled) is chosen by *modeled* cycles; this package is
the substrate that records what each launch planned and what it measurably
did, so the model-vs-hardware loop can be closed (ROADMAP).  Pieces:

* :mod:`repro.obs.trace` — the :class:`TraceCollector` span/event store and
  the process-global tracer hook (:func:`get_tracer` / :func:`tracing`).
  The default tracer is a no-op whose only cost on the hot path is one
  attribute check *outside* jit (see ``net/runner.run_network``).
* :mod:`repro.obs.timeline` — Chrome-trace (``chrome://tracing`` /
  Perfetto) JSON export: each launch's modeled fill/steady/drain
  DMA-vs-MXU timeline from the cycle model rendered alongside measured
  spans, plus the schema validator the CI smoke job runs.
* :mod:`repro.obs.report` — the model-vs-measured drift report joining
  modeled cycles against measured medians per launch.
* :mod:`repro.obs.explain` — the ``python -m repro.obs.explain`` CLI: the
  partition plan as a per-launch table, optionally run + traced.

See DESIGN.md §12 for the span schema and the timeline format.
"""

from .stats import percentile, timed_stats_ms
from .timeline import chrome_trace, validate_chrome_trace, write_chrome_trace
from .trace import (
    LaunchSpan,
    TraceCollector,
    TraceEvent,
    get_tracer,
    set_tracer,
    tracing,
)

_REPORT_EXPORTS = (
    "drift_report", "drift_rows_from_bench", "drift_rows_from_spans",
)


def __getattr__(name: str):
    # lazy so `python -m repro.obs.report` doesn't import the module twice
    # (runpy would warn about the package __init__'s copy)
    if name in _REPORT_EXPORTS:
        from . import report

        return getattr(report, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "LaunchSpan",
    "TraceCollector",
    "TraceEvent",
    "chrome_trace",
    "drift_report",
    "drift_rows_from_bench",
    "drift_rows_from_spans",
    "get_tracer",
    "percentile",
    "set_tracer",
    "timed_stats_ms",
    "tracing",
    "validate_chrome_trace",
    "write_chrome_trace",
]
