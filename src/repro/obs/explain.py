"""``python -m repro.obs.explain`` — show what the planner decided and why.

Prints the auto-partition of a zoo model as a per-launch table (covered
nodes, Q, grid, regime, plan knobs, modeled HBM/VMEM bytes with budget
headroom, modeled cycles), and optionally:

* ``--trace out.json`` — export a Chrome-trace / Perfetto JSON of every
  launch's modeled fill/steady/drain DMA-vs-MXU timeline
  (:mod:`repro.obs.timeline`); with ``--run`` the measured spans of a traced
  ``run_network`` ride alongside.
* ``--run`` — execute the plan with tracing enabled (one warm-up then
  ``--reps`` traced forwards) and print the model-vs-measured drift table
  (:mod:`repro.obs.report`).
* ``--guard`` — execute the plan under the guarded runtime
  (:mod:`repro.robust`, DESIGN.md §13) and print the fallback table: which
  launches ran clean and which rung of the degradation ladder each
  degraded launch took.  ``--squeeze F`` simulates VMEM pressure (budget
  scaled by F) so the replan rung is demonstrable from the CLI.

Examples::

    PYTHONPATH=src python -m repro.obs.explain --model vgg16
    PYTHONPATH=src python -m repro.obs.explain --model lenet --trace t.json
    PYTHONPATH=src python -m repro.obs.explain --model resnet18 \\
        --dtype bfloat16 --run --trace t.json
    PYTHONPATH=src python -m repro.obs.explain --model lenet \\
        --guard --squeeze 0.002

Big models default to the same reduced interpret-friendly input sizes as
``examples/fused_cnn_inference.py`` when run; the *plan table* is always
computed at the requested (default paper) scale.
"""

from __future__ import annotations

import argparse
import sys

from repro.core.cycle_model import DEFAULT_PARAMS

# interpret-friendly --run scales (paper scale for LeNet only); the table
# itself defaults to paper scale via the graph builders
RUN_SIZE = {"lenet": 32, "alexnet": 67, "vgg16": 32, "resnet18": 32}


def _fmt_bytes(n: int) -> str:
    return f"{n / 1024:,.0f}K" if n < 32 * 1024 * 1024 else f"{n / 2**20:,.1f}M"


def plan_table(plan, vmem_budget: int, out=print) -> None:
    """Render a PartitionPlan as one row per launch (the tabular twin of the
    trace's span schema)."""
    out(
        f"{'launch':<26} {'nodes':>5} {'Q':>2} {'grid':>6} {'region':>6} "
        f"{'regime':<16} {'x/w/c':>6} {'hbm':>9} {'vmem':>9} "
        f"{'headroom':>9} {'cycles':>10} {'us':>9}"
    )
    for p in plan.pyramids:
        d = p.launch.describe(plan.batch, vmem_budget)
        out(
            f"{p.name:<26} {len(p.node_names):>5} {d['q_convs']:>2} "
            f"{d['alpha']}x{d['alpha']:<4} {d['out_region']:>6} "
            f"{d['regime']:<16} "
            f"{d['x_slots']}/{d['w_slots']}/{d['c_tiles']:<2} "
            f"{_fmt_bytes(d['hbm_bytes']):>9} "
            f"{_fmt_bytes(d['vmem_bytes']):>9} "
            f"{_fmt_bytes(d['vmem_headroom_bytes']):>9} "
            f"{d['modeled_cycles']:>10,} "
            f"{d['modeled_cycles'] / DEFAULT_PARAMS.freq_mhz:>9,.1f}"
        )
    out(
        f"total: {plan.n_launches()} launches, "
        f"{plan.hbm_bytes():,} modeled HBM bytes, "
        f"{plan.modeled_cycles():,} modeled cycles "
        f"({plan.modeled_cycles() / DEFAULT_PARAMS.freq_mhz:,.1f} us at "
        f"{DEFAULT_PARAMS.freq_mhz:g} MHz)"
    )


def serve_table(summary: dict, out=print) -> None:
    """Render a serving engine's :meth:`~repro.net.serve.ServingEngine.summary`
    as the bucket/SLO/throughput table: one row per bucket, modeled columns
    (launches, SLO, steady-state) next to measured (p50/p95, imgs/s), then
    the cache lines and — when the summary carries CLI wave deltas — the
    per-wave plan/jit reuse proof."""
    out(
        f"serving {summary['model']} dtype={summary['compute_dtype']}"
        + (" [guarded]" if summary.get("guarded") else "")
        + f": {summary['completed']} completed, {summary['rejected']}"
        f" rejected, {summary['imgs_per_s']:,.1f} imgs/s overall"
    )
    out(
        f"{'bucket':>6} {'batches':>7} {'reqs':>5} {'imgs':>5} "
        f"{'launches':>8} {'slo_us':>10} {'steady_us':>10} "
        f"{'p50_ms':>9} {'p95_ms':>9} {'imgs/s':>9}"
    )
    for row in summary["buckets"]:
        out(
            f"{row['bucket']:>6} {row['batches']:>7} {row['requests']:>5} "
            f"{row['images']:>5} "
            f"{row.get('launches', '-'):>8} "
            + (f"{row['slo_us']:>10,.1f} " if "slo_us" in row
               else f"{'-':>10} ")
            + (f"{row['steady_us']:>10,.1f} " if "steady_us" in row
               else f"{'-':>10} ")
            + f"{row['p50_ms']:>9,.2f} {row['p95_ms']:>9,.2f} "
            f"{row['imgs_per_s']:>9,.1f}"
        )
    cache = summary["cache"]
    out(
        f"plan cache: serve {cache['serve']['hits']}h/"
        f"{cache['serve']['misses']}m/{cache['serve']['evictions']}e "
        f"({cache['serve']['currsize']}/{cache['serve']['maxsize']}), "
        f"partition {cache['partition']['hits']}h/"
        f"{cache['partition']['misses']}m/"
        f"{cache['partition']['evictions']}e, "
        f"jit traces {cache['jit_traces']}"
    )
    res = summary.get("resilience")
    if res is not None:
        counters = {
            k: v for k, v in res.items()
            if k != "breakers" and v
        }
        breakers = res.get("breakers") or {}
        active = {
            b: s for b, s in breakers.items()
            if s["transitions"] or s["state"] != "closed"
        }
        if counters or active:
            out(
                "resilience: "
                + ", ".join(f"{k}={v}" for k, v in counters.items())
                if counters else "resilience:"
            )
            for b, s in sorted(active.items(), key=lambda kv: int(kv[0])):
                pin = f" pinned={s['pinned_rung']}" if s["pinned_rung"] else ""
                out(
                    f"  breaker bucket {b}: {s['state']}"
                    f" ({s['opens']} opens, {s['transitions']} transitions,"
                    f" {s['failures']}/{s['threshold']} failures){pin}"
                )
    for i, wave in enumerate(summary.get("waves", []), start=1):
        out(
            f"wave {i}: +{wave['serve_misses']} plans, "
            f"+{wave['jit_traces']} jit traces, "
            f"{wave['serve_hits']} serve cache hits, "
            f"{wave['partition_misses']} partition misses "
            f"({wave['wall_s']:.2f}s)"
        )


def fallback_table(report, out=print) -> None:
    """Render a guarded run's :class:`~repro.robust.degrade.RunReport`:
    one row per fallback event, plus the degraded-plan detail (the chained
    sub-launches a replan substituted for the planned launch)."""
    out(
        f"guarded: {report.clean_launches}/{report.launches} launches clean"
        + (
            f", fallbacks {report.fallback_counts()}"
            if report.degraded else ", no fallbacks"
        )
    )
    if not report.degraded:
        return
    out(f"{'launch':<26} {'rung':<12} reason")
    for e in report.events:
        out(f"{e.launch:<26} {e.rung:<12} {e.reason}")
        subs = e.detail.get("sub_launches")
        if subs:
            out(
                f"{'':<26} {'':<12} degraded plan: "
                + " -> ".join(subs)
                + f" (budget {_fmt_bytes(e.detail['budget'])})"
            )


def main(argv: list[str] | None = None) -> int:
    from repro.core.program import VMEM_BUDGET_BYTES
    from repro.net.graph import MODELS
    from repro.net.partition import auto_partition, partition_cache_info

    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--model", choices=sorted(MODELS), default="lenet")
    ap.add_argument("--dtype", choices=("float32", "bfloat16"),
                    default="float32")
    ap.add_argument("--input-size", type=int, default=None,
                    help="spatial input size (default: the model's paper "
                         "scale; --run defaults to a reduced scale instead)")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--vmem-budget", type=int, default=VMEM_BUDGET_BYTES)
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Perfetto/chrome://tracing JSON of the "
                         "modeled (and, with --run, measured) timelines")
    ap.add_argument("--run", action="store_true",
                    help="execute the plan with tracing enabled and report "
                         "model-vs-measured drift")
    ap.add_argument("--reps", type=int, default=3,
                    help="traced forwards after the warm-up (with --run)")
    ap.add_argument("--guard", action="store_true",
                    help="execute the plan under the guarded runtime and "
                         "print the fallback table (DESIGN.md §13)")
    ap.add_argument("--squeeze", type=float, default=None, metavar="F",
                    help="with --guard: simulate VMEM pressure by scaling "
                         "the budget by F (0 < F <= 1) via the fault "
                         "injector, demonstrating the replan rung")
    args = ap.parse_args(argv)

    size = args.input_size
    if size is None and (args.run or args.guard):
        size = RUN_SIZE[args.model]
    kwargs = {"compute_dtype": args.dtype}
    if size is not None:
        kwargs["input_size"] = size
    graph = MODELS[args.model](**kwargs)

    plan = auto_partition(
        graph, batch=args.batch, vmem_budget=args.vmem_budget
    )
    print(
        f"{graph.name}: input {graph.input_size}x{graph.input_size}, "
        f"batch {args.batch}, dtype {plan.compute_dtype}, "
        f"VMEM budget {_fmt_bytes(args.vmem_budget)}"
    )
    plan_table(plan, args.vmem_budget)
    info = partition_cache_info()
    print(
        f"partition cache: {info.hits} hits / {info.misses} misses "
        f"({info.currsize} plans cached)"
    )

    if args.guard:
        import contextlib

        import jax

        from repro.net.runner import (
            init_network_params,
            prepare_network_params,
            run_network,
        )
        from repro.robust import GuardConfig, guarding, inject

        master = init_network_params(graph, jax.random.PRNGKey(0))
        params = prepare_network_params(plan, master)
        x = jax.random.normal(
            jax.random.PRNGKey(1),
            (args.batch, graph.input_size, graph.input_size,
             graph.in_channels),
        )
        squeeze = contextlib.nullcontext()
        if args.squeeze is not None:
            squeeze = inject(seed=0)
        print("\nguarded run"
              + (f" (VMEM squeezed x{args.squeeze})" if args.squeeze
                 is not None else ""))
        with guarding(GuardConfig(), source_params=master) as guard:
            with squeeze as inj:
                if inj is not None:
                    inj.squeeze_budget(args.squeeze)
                logits, _ = run_network(x, params, plan=plan)
        jax.block_until_ready(logits)
        fallback_table(guard.last_report)

    collector = None
    if args.run:
        import jax

        from repro.net.runner import (
            init_network_params,
            prepare_network_params,
            run_network,
            skip_fractions,
        )
        from repro.obs.report import (
            drift_report,
            drift_rows_from_spans,
            format_report,
        )
        from repro.obs.trace import tracing

        params = prepare_network_params(
            plan, init_network_params(graph, jax.random.PRNGKey(0))
        )
        x = jax.random.normal(
            jax.random.PRNGKey(1),
            (args.batch, graph.input_size, graph.input_size,
             graph.in_channels),
        )
        logits, _ = run_network(x, params, plan=plan)  # untraced warm-up
        jax.block_until_ready(logits)
        print(f"\nrunning {args.reps} traced forwards "
              f"(interpret={jax.default_backend() != 'tpu'}) ...")
        with tracing() as collector:
            for _ in range(args.reps):
                _, skips = run_network(x, params, plan=plan)
        frac = skip_fractions(skips)
        for name, f in frac.items():
            if any(v > 0 for v in f):
                print(f"END skips {name}: "
                      + ", ".join(f"L{i}={v:.0%}" for i, v in enumerate(f)))
        print()
        format_report(drift_report(drift_rows_from_spans(collector.spans)))

    if args.trace:
        from repro.obs.timeline import chrome_trace, write_chrome_trace

        trace = chrome_trace(
            collector,
            launches=[(p.name, p.launch) for p in plan.pyramids],
        )
        write_chrome_trace(args.trace, trace)
        print(f"\nwrote {args.trace} "
              f"({len(trace['traceEvents'])} events — load in "
              "ui.perfetto.dev or chrome://tracing)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
