"""Model-vs-measured drift report: is the cycle model still predictive?

The partitioner picks cuts and regimes by modeled cycles alone; this report
joins those modeled costs against measured wall-clock medians per launch and
flags the launches whose modeled-vs-measured ratio deviates from the fleet
median — the seed of measured autotuning (ROADMAP "close the
model-vs-hardware loop").

The *absolute* ratio is expected to be far from 1 off-TPU (interpret mode
runs orders of magnitude slower than the 100 MHz cycle model), so drift is
defined **relatively**: the fleet-median ratio is the calibration constant,
and a launch is flagged when its own ratio falls outside
``[median / factor, median * factor]``.  A flagged launch is one the model
prices wrongly *relative to its peers* — exactly the launches a measured
autotuner should revisit first.

Inputs: spans from a traced ``run_network``
(:func:`drift_rows_from_spans`) or a ``BENCH_pyramid.json``
(:func:`drift_rows_from_bench`).  CLI::

    PYTHONPATH=src python -m repro.obs.report --bench BENCH_pyramid.json
"""

from __future__ import annotations

import argparse
import json
import statistics
import sys

from repro.core.cycle_model import DEFAULT_PARAMS

FLAG_FACTOR = 3.0


def _modeled_ms(cycles: float, freq_mhz: float = DEFAULT_PARAMS.freq_mhz):
    return cycles / (freq_mhz * 1e3)


def drift_rows_from_spans(spans) -> list[dict]:
    """One row per distinct launch from traced spans: the measured median of
    that launch's repetitions against its modeled cost."""
    groups: dict[tuple, list] = {}
    for s in spans:
        key = (s.model, s.name, s.regime, s.compute_dtype, s.batch)
        groups.setdefault(key, []).append(s)
    rows = []
    for (model, name, regime, dtype, batch), ss in groups.items():
        measured = statistics.median(s.duration_ms for s in ss)
        modeled = ss[0].modeled_cycles
        rows.append(
            {
                "launch": f"{model}/{name}",
                "regime": regime,
                "compute_dtype": dtype,
                "batch": batch,
                "reps": len(ss),
                "modeled_cycles": modeled,
                "modeled_ms": _modeled_ms(modeled),
                "measured_ms": measured,
            }
        )
    return rows


def drift_rows_from_bench(bench: dict) -> list[dict]:
    """Joinable (modeled, measured) pairs from a ``BENCH_pyramid.json``.

    Launch rows under ``kernel_dataflow.launches`` carry ``modeled_cycles``;
    measured medians come from the ``kernel_dataflow.wallclock`` section
    (the LeNet Q=2 kernel, interpret and — on a TPU host — compiled) and
    from the end-to-end workload sections, which record ``modeled_cycles``
    alongside their wall clocks since PR 7.  Rows missing either side are
    skipped, so the report runs on both old and new benchmark files."""
    rows: list[dict] = []
    kd = bench.get("kernel_dataflow", {})
    wall = kd.get("wallclock", {})
    lenet = kd.get("launches", {}).get("lenet_q2")
    if lenet:
        for mode in ("interpret", "compiled"):
            ms = wall.get(f"{mode}_ms")
            if ms is None:
                continue
            rows.append(
                {
                    "launch": f"kernel/lenet_q2 ({mode})",
                    "regime": lenet.get("regime", "?"),
                    "compute_dtype": lenet.get("compute_dtype", "float32"),
                    "batch": 1,
                    "reps": wall.get("reps", 1),
                    "modeled_cycles": lenet["modeled_cycles"],
                    "modeled_ms": _modeled_ms(lenet["modeled_cycles"]),
                    "measured_ms": ms,
                }
            )
    for name, wl in bench.get("workloads", {}).items():
        variants = [("", wl)]
        if isinstance(wl.get("bf16"), dict):
            variants.append(("_bf16", wl["bf16"]))
        for suffix, row in variants:
            cycles, ms = row.get("modeled_cycles"), row.get("wallclock_ms")
            if cycles is None or ms is None:
                continue
            rows.append(
                {
                    "launch": f"workload/{name}{suffix}",
                    "regime": row.get("regime", "plan"),
                    "compute_dtype": (
                        "bfloat16" if suffix else "float32"
                    ),
                    "batch": wl.get("batch", 1),
                    "reps": wl.get("wallclock_reps", 1),
                    "modeled_cycles": cycles,
                    "modeled_ms": _modeled_ms(cycles),
                    "measured_ms": ms,
                }
            )
    return rows


def drift_report(rows: list[dict], flag_factor: float = FLAG_FACTOR) -> dict:
    """Attach per-row ratios and drift flags; compute the fleet median.

    Each row gains ``ratio`` (measured / modeled — the launch's private
    "slowdown constant"), ``drift`` (ratio / fleet median) and ``flagged``
    (drift outside ``[1/flag_factor, flag_factor]``).  Returns
    ``{"rows", "median_ratio", "flag_factor", "flagged"}``."""
    rows = [dict(r) for r in rows]
    ratios = []
    for r in rows:
        r["ratio"] = (
            r["measured_ms"] / r["modeled_ms"] if r["modeled_ms"] else float("inf")
        )
        ratios.append(r["ratio"])
    median = statistics.median(ratios) if ratios else 0.0
    flagged = []
    for r in rows:
        r["drift"] = r["ratio"] / median if median else 0.0
        r["flagged"] = not (1.0 / flag_factor <= r["drift"] <= flag_factor)
        if r["flagged"]:
            flagged.append(r["launch"])
    return {
        "rows": rows,
        "median_ratio": median,
        "flag_factor": flag_factor,
        "flagged": flagged,
    }


def format_report(report: dict, out=print) -> None:
    rows = report["rows"]
    if not rows:
        out("drift report: no joinable (modeled, measured) launches")
        return
    out(
        f"{'launch':<36} {'regime':<16} {'dtype':<9} {'modeled_ms':>11} "
        f"{'measured_ms':>11} {'ratio':>10} {'drift':>7}  flag"
    )
    for r in sorted(rows, key=lambda r: -r["drift"]):
        out(
            f"{r['launch']:<36} {r['regime']:<16} {r['compute_dtype']:<9} "
            f"{r['modeled_ms']:>11.4f} {r['measured_ms']:>11.4f} "
            f"{r['ratio']:>10.1f} {r['drift']:>7.2f}  "
            f"{'DRIFT' if r['flagged'] else 'ok'}"
        )
    out(
        f"fleet median measured/modeled ratio: {report['median_ratio']:.1f} "
        f"(flag factor {report['flag_factor']:g}; "
        f"{len(report['flagged'])} flagged)"
    )


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", default="BENCH_pyramid.json",
                    help="benchmark JSON to join modeled vs measured from")
    ap.add_argument("--flag-factor", type=float, default=FLAG_FACTOR,
                    help="relative deviation from the fleet median ratio "
                         "that flags a launch (default 3.0)")
    args = ap.parse_args(argv)
    with open(args.bench) as f:
        bench = json.load(f)
    report = drift_report(drift_rows_from_bench(bench), args.flag_factor)
    format_report(report)
    return 0


if __name__ == "__main__":
    sys.exit(main())
