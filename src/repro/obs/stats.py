"""Shared wall-clock statistics helpers.

Percentile math used to live twice — ``net/serve.py`` computed request
latency p50/p95 through ``np.percentile`` while ``benchmarks/run.py``
re-implemented the same linear interpolation in stdlib for its timed-rep
stats dicts.  One definition lives here so the serving summary and the
benchmark JSON agree on what "p95" means (linear interpolation between
closest ranks, the numpy default), and so new consumers (the serving
front end's deadline accounting) do not grow a third copy.

Import-light on purpose: stdlib only, no numpy/jax — the serving admission
path calls :func:`percentile` per drain and the benchmark harness calls it
between timed reps; neither should pay an import or an array round-trip
for a handful of floats.
"""

from __future__ import annotations

import statistics
import time

__all__ = ["percentile", "timed_stats_ms"]


def percentile(values, q: float) -> float:
    """Linear-interpolated ``q``-th percentile of ``values`` (0 <= q <= 100).

    Matches ``np.percentile``'s default (linear interpolation between the
    two closest ranks) for any non-empty sequence of floats.  Raises
    ``ValueError`` on an empty sequence — callers decide what an absent
    sample means (the serving summary only renders buckets with traffic).
    """
    xs = sorted(float(v) for v in values)
    if not xs:
        raise ValueError("percentile of empty sequence")
    idx = q / 100.0 * (len(xs) - 1)
    lo = int(idx)
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (xs[hi] - xs[lo]) * (idx - lo)


def timed_stats_ms(fn, reps: int = 5) -> dict:
    """Wall-clock stats over ``reps`` timed calls of ``fn`` (which must
    block until its results are ready), after one untimed warm-up call that
    absorbs jit compilation — single-shot numbers are scheduler noise.

    Returns ``{"p50_ms", "p95_ms", "reps"}``; benchmark wall-clock metrics
    record this dict alongside their median scalar so the trajectory
    carries tail latency too.
    """
    fn()  # warm-up: jit cache + device transfer
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e3)
    return {
        "p50_ms": statistics.median(times),
        "p95_ms": percentile(times, 95.0),
        "reps": reps,
    }
