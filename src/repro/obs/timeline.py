"""Chrome-trace (``chrome://tracing`` / Perfetto) export of fusion launches.

Two kinds of track are rendered into one JSON Event Trace:

* **Modeled** — one process per launch, two threads (``MXU`` and ``DMA``),
  holding the cycle model's fill/steady/drain bars
  (:meth:`~repro.core.program.LaunchPlan.modeled_timeline` for the grid's
  input halo-tile stream vs the per-cell pyramid bodies, plus the per-cell
  weight-movement detail of
  :meth:`~repro.core.program.LaunchPlan.body_detail_timeline`).  Cycles are
  converted to microseconds at the cycle model's clock (100 MHz default), so
  pipeline-overlap claims — "the halo DMA hides behind the MXU cascade" —
  become visually inspectable bars.
* **Measured** — one thread of wall-clock spans from a
  :class:`~repro.obs.trace.TraceCollector` (a traced ``run_network``), with
  every planned knob and modeled cost attached as event ``args``, plus the
  collector's point events (cache hits/misses, skip stats) as instants.

The trace loads directly in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``.  :func:`validate_chrome_trace` checks the subset of
the Trace Event Format this module emits — the CI smoke job runs it on a
freshly exported trace before uploading the artifact.
"""

from __future__ import annotations

import dataclasses
import json

from repro.core.cycle_model import DEFAULT_PARAMS

# pid layout: measured spans + instant events live on MEASURED_PID; each
# modeled launch gets its own process starting here (one per launch keeps
# Perfetto's per-process track grouping readable for deep plans)
MEASURED_PID = 1
MODELED_PID0 = 1000

_LANE_TID = {"mxu": 0, "dma": 1}
_LANE_NAME = {"mxu": "MXU (compute)", "dma": "DMA (HBM)"}


def _meta(pid: int, name: str, tids: dict[int, str]) -> list[dict]:
    evs = [
        {
            "ph": "M",
            "name": "process_name",
            "pid": pid,
            "tid": 0,
            "args": {"name": name},
        }
    ]
    for tid, tname in tids.items():
        evs.append(
            {
                "ph": "M",
                "name": "thread_name",
                "pid": pid,
                "tid": tid,
                "args": {"name": tname},
            }
        )
    return evs


def modeled_launch_events(
    name: str,
    launch,
    pid: int,
    *,
    freq_mhz: float = DEFAULT_PARAMS.freq_mhz,
    max_cells: int = 64,
) -> list[dict]:
    """Complete ("X") events of one launch's modeled timeline: the grid-level
    DMA-vs-MXU bars, and — when the per-cell body has internal weight
    movement (streamed regimes) — the cell-0 detail on a second thread pair.
    ``ts``/``dur`` are microseconds at ``freq_mhz``."""
    scale = 1.0 / freq_mhz  # cycles -> us
    events = _meta(
        pid,
        f"modeled: {name} [{launch.regime}]",
        {
            0: _LANE_NAME["mxu"],
            1: _LANE_NAME["dma"],
            2: "cell 0 MXU (weight detail)",
            3: "cell 0 DMA (weight detail)",
        },
    )
    args = launch.describe()
    for seg in launch.modeled_timeline(max_cells=max_cells):
        events.append(
            {
                "ph": "X",
                "name": seg.label,
                "cat": "modeled",
                "pid": pid,
                "tid": _LANE_TID[seg.lane],
                "ts": seg.start * scale,
                "dur": seg.duration * scale,
                "args": args,
            }
        )
    detail = launch.body_detail_timeline()
    if launch.streamed and detail:
        # align the detail with cell 0's body: it starts after the first
        # halo-tile fetch in both the serial and pipelined grid schedules
        off = launch.program.input_dma_cycles()
        for seg in detail:
            events.append(
                {
                    "ph": "X",
                    "name": seg.label,
                    "cat": "modeled-detail",
                    "pid": pid,
                    "tid": 2 + _LANE_TID[seg.lane],
                    "ts": (off + seg.start) * scale,
                    "dur": seg.duration * scale,
                    "args": {"regime": launch.regime},
                }
            )
    return events


def measured_events(collector) -> list[dict]:
    """Wall-clock spans + instant events of a collector, on one process.

    Timestamps are rebased to the earliest span/event so the trace starts at
    ~0; span ``args`` carry the full span schema, so every modeled quantity
    is clickable next to its measured bar."""
    spans = list(collector.spans)
    events = list(collector.events)
    if not spans and not events:
        return []
    t0 = min(
        [s.start_s for s in spans] + [e.ts_s for e in events]
    )
    out = _meta(
        MEASURED_PID,
        "measured (wall clock)",
        {0: "launch spans", 1: "events"},
    )
    for s in spans:
        out.append(
            {
                "ph": "X",
                "name": f"{s.model}/{s.name} [{s.regime}]",
                "cat": "measured",
                "pid": MEASURED_PID,
                "tid": 0,
                "ts": (s.start_s - t0) * 1e6,
                "dur": s.duration_ms * 1e3,
                "args": dataclasses.asdict(s),
            }
        )
    for e in events:
        out.append(
            {
                "ph": "i",
                "name": e.name,
                "cat": "event",
                "pid": MEASURED_PID,
                "tid": 1,
                "ts": (e.ts_s - t0) * 1e6,
                "s": "p",
                "args": dict(e.args),
            }
        )
    return out


def chrome_trace(
    collector=None,
    *,
    launches=(),
    freq_mhz: float = DEFAULT_PARAMS.freq_mhz,
    max_cells: int = 64,
) -> dict:
    """Build the full Trace Event Format dict.

    ``launches`` is an iterable of ``(name, LaunchPlan)`` pairs to render as
    modeled tracks (e.g. ``[(p.name, p.launch) for p in plan.pyramids]``);
    ``collector`` adds the measured tracks.  Either side may be omitted —
    ``repro.obs.explain`` without ``--run`` exports modeled-only traces.
    """
    events: list[dict] = []
    for i, (name, launch) in enumerate(launches):
        events.extend(
            modeled_launch_events(
                name, launch, MODELED_PID0 + i,
                freq_mhz=freq_mhz, max_cells=max_cells,
            )
        )
    if collector is not None:
        events.extend(measured_events(collector))
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "source": "repro.obs",
            "freq_mhz": freq_mhz,
            "note": "modeled bars are cycle-model time; measured bars are "
                    "wall clock — compare shapes, not absolute scales",
        },
    }


def validate_chrome_trace(trace: dict) -> list[str]:
    """Check ``trace`` against the subset of the Chrome Trace Event Format
    this module emits; returns a list of problems (empty = loadable).  Run
    by the CI smoke job on the exported artifact and by the tests."""
    problems: list[str] = []
    if not isinstance(trace, dict):
        return ["trace must be a JSON object"]
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents must be a list"]
    if not events:
        problems.append("traceEvents is empty")
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "M", "i", "B", "E"):
            problems.append(f"{where}: unknown ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            problems.append(f"{where}: missing name")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                problems.append(f"{where}: {key} must be an int")
        if ph == "X":
            for key in ("ts", "dur"):
                v = ev.get(key)
                if not isinstance(v, (int, float)) or v < 0:
                    problems.append(f"{where}: {key} must be >= 0")
        if ph == "i" and not isinstance(ev.get("ts"), (int, float)):
            problems.append(f"{where}: instant event needs ts")
        if ph == "M" and "args" not in ev:
            problems.append(f"{where}: metadata event needs args")
    try:
        json.dumps(trace)
    except (TypeError, ValueError) as e:
        problems.append(f"not JSON-serializable: {e}")
    return problems


def write_chrome_trace(path: str, trace: dict) -> None:
    """Validate then write the trace JSON; raises ``ValueError`` with the
    problem list if the trace would not load."""
    problems = validate_chrome_trace(trace)
    if problems:
        raise ValueError("invalid chrome trace: " + "; ".join(problems))
    with open(path, "w") as f:
        json.dump(trace, f, indent=1)
