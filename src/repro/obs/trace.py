"""Structured launch tracing: spans, events, counters, and the global hook.

One :class:`LaunchSpan` is recorded per fused-pyramid launch — the plan's
static knobs and modeled costs (what the planner promised) next to the
measured wall clock (what the launch did).  :class:`TraceEvent` covers
everything that is not a launch: ``auto_partition`` cache hits/misses,
per-level END-skip counts, whole-forward timings.

The collector is deliberately dumb — append-only lists plus a counter dict
— so instrumented code stays cheap and every export/analysis concern lives
in :mod:`repro.obs.timeline` / :mod:`repro.obs.report`.

The process-global tracer defaults to :data:`NULL_TRACER`, whose
``enabled`` is ``False``: instrumented call sites check that one attribute
and take their uninstrumented fast path, so tracing-off adds zero work
inside jit-compiled code (the check happens outside the jit boundary; the
jit cache is keyed exactly as before).  Enable collection with::

    from repro.obs import tracing

    with tracing() as collector:
        run_network(x, params, plan=plan)
    print(collector.spans)
"""

from __future__ import annotations

import contextlib
import time
from dataclasses import dataclass, field


@dataclass(frozen=True)
class LaunchSpan:
    """One fused-pyramid launch: planned knobs + modeled costs + measurement.

    ``start_s`` is :func:`time.perf_counter` at launch start (comparable
    only within one process); ``duration_ms`` is the measured wall clock of
    the launch with its results blocked until ready — in interpret mode the
    first call includes jit tracing, so callers wanting steady-state numbers
    warm up first (``repro.obs.explain --run`` does).  The modeled fields
    are the exact quantities the partitioner optimized, copied from the
    :class:`~repro.core.program.LaunchPlan` so model-vs-measured joins never
    re-derive them.
    """

    name: str  # pyramid name, e.g. "CL1..MPL2"
    model: str  # graph name, e.g. "lenet"
    regime: str  # resident / streamed_w2 / streamed_w2_c4 / ...
    out_region: int
    alpha: int
    q_convs: int
    x_slots: int
    w_slots: int
    c_tiles: int
    batch: int
    compute_dtype: str
    streamed: bool
    hbm_bytes: int  # modeled off-chip traffic of the launch (batch-scaled)
    vmem_bytes: int  # modeled resident working set
    modeled_cycles: int  # pipeline-aware cycle model (batch-scaled)
    modeled_us: float  # modeled_cycles at the cycle model's 100 MHz
    start_s: float
    duration_ms: float


@dataclass(frozen=True)
class TraceEvent:
    """A point event: cache hit/miss, skip stats, forward-level timing."""

    name: str
    ts_s: float
    args: dict


class TraceCollector:
    """Append-only span/event store with named counters.

    ``enabled`` is class-level ``True`` so the instrumented fast-path check
    (``get_tracer().enabled``) costs one attribute load either way.
    """

    enabled = True

    def __init__(self) -> None:
        self.spans: list[LaunchSpan] = []
        self.events: list[TraceEvent] = []
        self.counters: dict[str, int] = {}

    def record_span(self, span: LaunchSpan) -> None:
        self.spans.append(span)

    def record_event(self, name: str, **args) -> None:
        self.events.append(
            TraceEvent(name=name, ts_s=time.perf_counter(), args=args)
        )

    def bump(self, counter: str, n: int = 1) -> None:
        self.counters[counter] = self.counters.get(counter, 0) + n


class _NullTracer:
    """The zero-overhead default: nothing is recorded, nothing is kept.

    Instrumented sites gate on ``enabled`` before doing any span/event work,
    but the record methods exist (as no-ops) so a site that doesn't bother
    gating stays correct."""

    enabled = False
    spans: tuple = ()
    events: tuple = ()
    counters: dict = {}

    def record_span(self, span: LaunchSpan) -> None:
        pass

    def record_event(self, name: str, **args) -> None:
        pass

    def bump(self, counter: str, n: int = 1) -> None:
        pass


NULL_TRACER = _NullTracer()

_tracer = NULL_TRACER


def get_tracer():
    """The process-global tracer: :data:`NULL_TRACER` unless a collector was
    installed via :func:`set_tracer` / :func:`tracing`."""
    return _tracer


def set_tracer(tracer) -> None:
    """Install ``tracer`` globally (``None`` restores the no-op default)."""
    global _tracer
    _tracer = NULL_TRACER if tracer is None else tracer


@contextlib.contextmanager
def tracing(collector: TraceCollector | None = None):
    """Scope a collector as the global tracer; yields the collector.

    Nesting restores the previous tracer on exit, so a traced benchmark can
    call traced helpers without clobbering the outer collection.
    """
    col = TraceCollector() if collector is None else collector
    prev = get_tracer()
    set_tracer(col)
    try:
        yield col
    finally:
        set_tracer(prev)


@dataclass
class SpanTimer:
    """Tiny helper for measuring one span body: ``start()`` ... ``stop()``
    returns (start_s, duration_ms)."""

    start_s: float = field(default=0.0)

    def start(self) -> SpanTimer:
        self.start_s = time.perf_counter()
        return self

    def stop_ms(self) -> float:
        return (time.perf_counter() - self.start_s) * 1e3
