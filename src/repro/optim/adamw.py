"""Sharded AdamW in pure JAX.

Moments are kept in a configurable dtype (``cfg.moment_dtype``): fp32 by
default; bf16 for the 480B-class MoE so params+moments fit a single pod's
HBM (DESIGN.md §6).  Moment trees inherit the parameter sharding — the spec
tree is reused, so the optimizer state is exactly as distributed as the
model.

Gradient compression (int8 + error feedback) is composed in
:mod:`repro.optim.grad_compress` *before* the update — the all-reduce then
moves 1/4 of the bytes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray  # scalar int32
    mu: Any  # first moment tree
    nu: Any  # second moment tree


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def init_abstract(self, abstract_params) -> AdamWState:
        zeros = lambda p: jax.ShapeDtypeStruct(p.shape, self.moment_dtype)
        return AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=jax.tree.map(zeros, abstract_params),
            nu=jax.tree.map(zeros, abstract_params),
        )

    def update(self, grads, state: AdamWState, params, lr_scale=1.0):
        step = state.step + 1
        # global-norm clip in fp32
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)
            )
        )
        clip = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))

        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)
        lr = self.lr * lr_scale

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * clip
            m32 = m.astype(jnp.float32) * self.b1 + g * (1 - self.b1)
            v32 = v.astype(jnp.float32) * self.b2 + jnp.square(g) * (1 - self.b2)
            mhat = m32 / b1c
            vhat = v32 / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return (
                new_p.astype(p.dtype),
                m32.astype(self.moment_dtype),
                v32.astype(self.moment_dtype),
            )

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)
