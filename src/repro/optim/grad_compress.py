"""Gradient compression: int8 block quantization with error feedback.

The distributed-optimization trick for bandwidth-bound meshes: gradients are
quantized to int8 (per-block absmax scaling) before the data-parallel
all-reduce, cutting cross-pod collective bytes 4x (2x vs bf16); the
quantization residual is carried in an error-feedback buffer and re-added
next step, which keeps SGD/Adam convergence (Seide et al., 1-bit SGD line of
work).

Composition: under ``jit`` the all-reduce is implicit in the sharded grad
computation, so ``compress -> psum-in-int8 -> decompress`` is expressed as a
custom reduction in :func:`compressed_mean` for shard_map-style use, and as a
quantize/dequantize pair around the optimizer update for pjit use (XLA then
moves int8, not fp32, across the 'pod' axis for the terms it reduces late).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class CompressState(NamedTuple):
    error: Any  # error-feedback tree (same shapes as grads, bf16)


def init_state(params) -> CompressState:
    return CompressState(
        error=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
    )


def _quantize(g: jnp.ndarray):
    flat = g.reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[: int(jnp.prod(jnp.array(shape)))].reshape(shape)


def compress_grads(grads, state: CompressState):
    """Quantize grads (with error feedback added) to int8; return
    (dequantized grads for the update, new error state)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
        q, scale = _quantize(g32)
        deq = _dequantize(q, scale, g.shape)
        new_e = (g32 - deq).astype(jnp.bfloat16)
        return deq, new_e

    out = jax.tree.map(one, grads, state.error)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, CompressState(error=err)


def compressed_mean(g: jnp.ndarray, axis_name: str):
    """shard_map building block: int8 all-reduce mean over ``axis_name``."""
    q, scale = _quantize(g.astype(jnp.float32))
    # reduce in int32 to avoid overflow, carry scales alongside
    total = jax.lax.psum(q.astype(jnp.int32) * scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    flat = (total / n).reshape(-1)[: g.size]
    return flat.reshape(g.shape).astype(g.dtype)
