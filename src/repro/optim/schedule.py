"""LR schedules: linear warmup + cosine decay (the production default)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int = 200, total: int = 10_000,
                  floor: float = 0.1):
    """Multiplier in [floor, 1]: linear warmup then cosine to floor."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(warmup, 1), 1.0)
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return warm * cos
