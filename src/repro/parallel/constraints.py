"""Activation sharding constraints via a process-level mesh registry.

XLA's SPMD propagation occasionally picks pathological activation layouts
(observed: batch-replicated f32 logits all-reduced over the fsdp axis —
12.5 GiB/device — instead of gathering a 52 MiB weight).  Model code calls
``constrain(x, "batch", None, "vocab")`` at the few decision points that
matter; the launcher registers the active (mesh, rules) pair before tracing.
Outside a registered mesh (unit tests on 1 device) constraints are no-ops,
so model code stays mesh-agnostic.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding

from .sharding import ShardingRules, partition_spec

_ACTIVE: list[tuple[Mesh, ShardingRules]] = []


class mesh_rules:
    """Context manager registering (mesh, rules) for `constrain`."""

    def __init__(self, mesh: Mesh, rules: ShardingRules):
        self.pair = (mesh, rules)

    def __enter__(self):
        _ACTIVE.append(self.pair)
        return self

    def __exit__(self, *exc):
        _ACTIVE.pop()
        return False


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Pin activation sharding by logical axis names (no-op if unregistered)."""
    if not _ACTIVE:
        return x
    mesh, rules = _ACTIVE[-1]
    spec = partition_spec(x.shape, tuple(logical), mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
