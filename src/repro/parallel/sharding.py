"""Logical-axis sharding rules: DP (fsdp) x TP (tensor) x EP (expert) x pods.

Every parameter/cache leaf carries a tuple of logical axis names (see
:mod:`repro.models.params`).  Rules map logical names to mesh axes; the
resolver turns (shape, axes, mesh) into a NamedSharding with two safety
valves needed by real architectures:

* divisibility fallback — a dim that does not divide by its mesh-axis extent
  drops that mapping (replicates) rather than relying on GSPMD padding;
  e.g. glm4's 2 KV heads cannot shard 16-way, arctic's 56 heads cannot
  either, minicpm3's 73448 vocab divides by neither 16 nor 32.  For heads we
  deliberately accept replication of the (small) KV projections instead of
  padded sharding so the roofline's collective bytes stay honest.
* one-mesh-axis-once — if two logical dims of one tensor resolve to the same
  mesh axis, the later one is dropped (a mesh axis can shard one dim only).

Default rule set (production mesh (pod, data, model)):

  batch/fsdp      -> ('pod', 'data')   # DP + FSDP parameter sharding
  tensor-ish dims -> ('model',)        # TP: heads / mlp / vocab / experts
  cache_seq       -> ('data',) for long-context decode (sequence sharding)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    # data-parallel / fsdp family
    "batch": ("pod", "data"),
    "embed": ("pod", "data"),  # fsdp shard of the non-TP weight dim
    "layers": (),
    # tensor-parallel family
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "lora": ("model",),
    # serving
    "cache_seq": (),  # overridden to ('data',) for long-context decode
    # activations
    "seq": (),
    "act_embed": (),
}


@dataclass(frozen=True)
class ShardingRules:
    rules: dict[str, tuple[str, ...]] = field(default_factory=lambda: dict(DEFAULT_RULES))

    def override(self, **kw: tuple[str, ...]) -> "ShardingRules":
        d = dict(self.rules)
        d.update(kw)
        return ShardingRules(d)

    def mesh_axes_for(self, logical: str | None, mesh: Mesh) -> tuple[str, ...]:
        if logical is None:
            return ()
        axes = self.rules.get(logical, ())
        return tuple(a for a in axes if a in mesh.axis_names)


def _axis_size(mesh: Mesh, names: tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[n] for n in names])) if names else 1


def partition_spec(
    shape: tuple[int, ...],
    logical_axes: tuple[str | None, ...],
    mesh: Mesh,
    rules: ShardingRules,
) -> PartitionSpec:
    """Resolve one tensor's logical axes to a PartitionSpec."""
    used: set[str] = set()
    entries: list[Any] = []
    for dim, logical in zip(shape, logical_axes):
        names = rules.mesh_axes_for(logical, mesh)
        names = tuple(n for n in names if n not in used)
        size = _axis_size(mesh, names)
        if not names or size <= 1 or dim % size != 0:
            entries.append(None)  # divisibility fallback: replicate
            continue
        used.update(names)
        entries.append(names if len(names) > 1 else names[0])
    while entries and entries[-1] is None:
        entries.pop()  # trailing Nones are implicit
    return PartitionSpec(*entries)


def spec_shardings(spec_tree, mesh: Mesh, rules: ShardingRules):
    """P-spec tree -> NamedSharding tree (params and caches alike)."""
    from repro.models.params import P

    return jax.tree.map(
        lambda s: NamedSharding(mesh, partition_spec(s.shape, s.axes, mesh, rules)),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_sharding(mesh: Mesh, rules: ShardingRules, ndim: int = 2):
    """Sharding for (B, S, ...) token batches: batch over DP axes."""
    names = rules.mesh_axes_for("batch", mesh)
    spec = PartitionSpec(names if len(names) > 1 else (names[0] if names else None))
    return NamedSharding(mesh, spec)


def shard_batch_spec(
    shape: tuple[int, ...], mesh: Mesh, rules: ShardingRules,
    logical: tuple[str | None, ...],
) -> NamedSharding:
    return NamedSharding(mesh, partition_spec(shape, logical, mesh, rules))


# canonical rule variants -----------------------------------------------------

def rules_for(step: str, *, long_context: bool = False) -> ShardingRules:
    """Rule set per step kind (train / prefill / decode)."""
    r = ShardingRules()
    if step == "decode":
        if long_context:
            # batch=1: shard the cache sequence over data AND model
            # (context parallelism); the pod axis replicates (B=1)
            return r.override(cache_seq=("data", "model"), batch=("pod",))
        # kv_heads rarely divide the 16-way model axis; shard the cache
        # sequence over 'model' instead (context-parallel serving) — the
        # resolver gives 'model' to cache_seq first, kv_heads then drops
        return r.override(cache_seq=("model",))
    return r
