"""Guarded inference runtime: preflight validation, numeric sentinels, a
graceful-degradation ladder, and deterministic fault injection.

The fused-pyramid path is planned by models and executed by one jit graph —
fast, but brittle: a bad input, a NaN-poisoned weight, a VMEM miss, or a
lowering failure surfaces as an opaque deep traceback.  This package wraps
``run_network`` end to end (DESIGN.md §13):

* :mod:`repro.robust.errors` — the typed error hierarchy
  (:class:`PreflightError`, :class:`BudgetError`, :class:`NumericError`,
  ...) every other layer raises instead of bare asserts.
* :mod:`repro.robust.validate` — :func:`preflight`: structural checks on
  graph/params/inputs (shape, dtype, channel chaining, finite params,
  plan-vs-budget headroom) before any launch.
* :mod:`repro.robust.guard` — the process-global guard flag
  (:func:`guarding` mirrors ``repro.obs.tracing``: off by default, one
  static check outside jit) plus the jit-compatible per-launch numeric
  sentinels.
* :mod:`repro.robust.degrade` — :func:`run_network_guarded`: the
  degradation ladder.  Compile/lowering failure retries ``interpret=True``;
  a budget violation replans the pyramid under a shrunken budget (tighter
  cuts, chained launches); a numeric fault quarantines the launch to the
  node-by-node reference segment.  Every fallback is recorded in the
  returned :class:`RunReport` and as an ``obs`` trace event.
* :mod:`repro.robust.faults` — the seeded fault-injection harness the chaos
  suite uses to prove every rung terminates at the reference path (and, for
  the serving chaos suite, slow launches / staging failures / queue stalls).
* :mod:`repro.robust.breaker` — the per-key circuit breaker the serving
  engine uses to pin a repeatedly-failing (graph, bucket, dtype) key to its
  last-good degraded rung for a cooldown window.

Only :mod:`repro.robust.errors` is imported eagerly (it is dependency-free
and ``repro.core`` raises from it); everything else loads lazily so
``import repro.core.program`` cannot recurse back through this package.
"""

from .errors import (
    BudgetError,
    DeadlineExceeded,
    FaultInjected,
    NumericError,
    PlanError,
    PreflightError,
    RobustError,
)

_LAZY = {
    "preflight": "validate",
    "check_request": "validate",
    "GuardConfig": "guard",
    "get_guard": "guard",
    "guarding": "guard",
    "sentinel_stats": "guard",
    "FallbackEvent": "degrade",
    "RunReport": "degrade",
    "run_network_guarded": "degrade",
    "FaultInjector": "faults",
    "corrupt_params": "faults",
    "get_injector": "faults",
    "inject": "faults",
    "CircuitBreaker": "breaker",
    "BreakerSnapshot": "breaker",
}


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        mod = importlib.import_module(f".{_LAZY[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "BreakerSnapshot",
    "BudgetError",
    "CircuitBreaker",
    "DeadlineExceeded",
    "FallbackEvent",
    "FaultInjected",
    "FaultInjector",
    "GuardConfig",
    "NumericError",
    "PlanError",
    "PreflightError",
    "RobustError",
    "RunReport",
    "check_request",
    "corrupt_params",
    "get_guard",
    "get_injector",
    "guarding",
    "inject",
    "preflight",
    "run_network_guarded",
    "sentinel_stats",
]
