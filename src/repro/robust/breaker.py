"""Per-key circuit breaker: stop re-running a path that keeps failing.

The degradation ladder (:mod:`repro.robust.degrade`) makes one launch
survive one fault — but it pays the failed fused attempt *every time*.
Under sustained failure (a miscompiling bucket shape, a poisoned cache
entry, VMEM pressure that will not clear), retrying the fused path per
batch turns a degraded-but-correct service into a slow one.  The serving
engine therefore keeps one :class:`CircuitBreaker` per plan-cache key
(graph, bucket, dtype) and routes launches by its state:

* **closed** — healthy: run the normal (fused / guarded) path.  Each
  failure (a launch whose guarded run carried ``FallbackEvent``s, a
  watchdog trip, or a typed error that escaped to the engine) increments a
  consecutive-failure count; :attr:`threshold` consecutive failures open
  the breaker.  Any success resets the count.
* **open** — failing: skip the fused path entirely and serve from the
  **pinned rung** — the last rung that produced a good result for this key
  (recorded from the guarded run's fallback events), or the reference path
  when nothing gentler is known.  After :attr:`cooldown_s` seconds the
  next launch moves the breaker to half-open.
* **half-open** — probing: exactly one launch retries the normal path.
  Success closes the breaker (and clears the pin); failure re-opens it and
  restarts the cooldown.

Transitions are appended to :attr:`transitions` and — when a tracer is
installed — recorded as ``serve_breaker`` events, so ``summary()`` /
``obs.explain serve_table`` can show *why* a bucket is degraded.  The
clock is injectable for deterministic tests.

The breaker is deliberately engine-agnostic: it never runs anything, it
only answers :meth:`allow` ("may the fused path run?") and consumes
:meth:`record_success` / :meth:`record_failure`.  The serving engine owns
what "the pinned rung" executes (interpret retry or reference walk).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"

#: rung order the serving engine degrades through when the breaker pins a
#: key: gentler first.  "fused" is the healthy path, not a pin target.
PIN_RUNGS = ("interpret", "reference")


@dataclass(frozen=True)
class BreakerSnapshot:
    """One JSON-safe view of a breaker, for ``summary()``/explain."""

    state: str
    failures: int
    threshold: int
    pinned_rung: str | None
    opens: int
    transitions: int


@dataclass
class CircuitBreaker:
    """Consecutive-failure circuit breaker with rung pinning.

    ``threshold`` consecutive failures open the breaker for ``cooldown_s``
    seconds; ``clock`` defaults to ``time.monotonic`` and is injectable so
    tests drive the cooldown without sleeping.
    """

    threshold: int = 3
    cooldown_s: float = 5.0
    clock: callable = time.monotonic
    state: str = CLOSED
    failures: int = 0
    pinned_rung: str | None = None
    opened_at: float | None = None
    opens: int = 0
    transitions: list = field(default_factory=list)

    def __post_init__(self):
        if self.threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {self.threshold}")
        if self.cooldown_s < 0:
            raise ValueError(
                f"cooldown_s must be >= 0, got {self.cooldown_s}"
            )

    # -- queries -------------------------------------------------------------

    def allow(self) -> bool:
        """May the normal (fused) path run now?

        ``True`` when closed, and when an open breaker's cooldown has
        elapsed — in which case the breaker moves to half-open and this
        launch is the probe.  ``False`` while open (serve the pinned rung)
        and while a half-open probe is already outstanding (serve the
        pinned rung until the probe resolves)."""
        if self.state == CLOSED:
            return True
        if self.state == OPEN:
            if self.clock() - self.opened_at >= self.cooldown_s:
                self._transition(HALF_OPEN, "cooldown elapsed")
                return True
            return False
        # HALF_OPEN: the probe was already granted; concurrent launches
        # stay on the pinned rung until record_success/record_failure
        return False

    # -- signals -------------------------------------------------------------

    def record_success(self) -> None:
        """A normal-path launch completed clean: reset, close, unpin."""
        self.failures = 0
        if self.state != CLOSED:
            self._transition(CLOSED, "probe succeeded")
            self.pinned_rung = None

    def record_failure(self, *, rung: str | None = None) -> None:
        """A normal-path launch failed (fallback events, watchdog trip, or
        typed error).  ``rung`` names the gentlest rung that still produced
        a good result this launch (from the guarded run's fallback events);
        it becomes the pin when the breaker opens.  ``None`` keeps the
        previous pin (or falls through to the engine's reference default).
        """
        if rung is not None:
            self.pinned_rung = rung
        if self.state == HALF_OPEN:
            self._reopen("probe failed")
            return
        self.failures += 1
        if self.state == CLOSED and self.failures >= self.threshold:
            self._reopen(f"{self.failures} consecutive failures")

    # -- internals -----------------------------------------------------------

    def _reopen(self, why: str) -> None:
        self.opened_at = self.clock()
        self.opens += 1
        self._transition(OPEN, why)

    def _transition(self, to: str, why: str) -> None:
        self.transitions.append(
            {"from": self.state, "to": to, "why": why, "at_s": self.clock()}
        )
        self.state = to

    def snapshot(self) -> BreakerSnapshot:
        return BreakerSnapshot(
            state=self.state,
            failures=self.failures,
            threshold=self.threshold,
            pinned_rung=self.pinned_rung,
            opens=self.opens,
            transitions=len(self.transitions),
        )
