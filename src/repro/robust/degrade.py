"""The graceful-degradation ladder: guarded ``run_network`` execution.

:func:`run_network_guarded` runs the same plan-driven forward loop as the
jit fast path (``repro.net.runner._forward``), eagerly, with each fused
launch wrapped in a bounded ladder of fallbacks.  Every rung trades
performance for the guarantee that the forward *finishes with correct
logits*; the bottom rung is the node-by-node reference path, which is
always available because its only requirements are the graph and finite
params.  The rungs, top to bottom:

1. **fused launch** — the planned Pallas launch, unchanged.
2. **interpret retry** — a compile/lowering/runtime failure retries the
   same launch once with ``interpret=True`` (the Mosaic-free Pallas
   interpreter; slow but immune to lowering bugs).
3. **replan** — a :class:`BudgetError` (the planned working set no longer
   fits, e.g. under simulated VMEM pressure) re-cuts the failing pyramid
   under a shrunken budget via
   :func:`repro.net.partition.replan_pyramid` — tighter cuts, a chain of
   smaller launches — up to ``GuardConfig.max_replans`` times, each retry
   shrinking the budget by ``budget_shrink``.
4. **reference quarantine** — a numeric-sentinel trip (NaN/Inf or
   magnitude blow-up in a launch output) or exhaustion of the rungs above
   quarantines the launch: the covered nodes are recomputed with the
   plain-op reference path, and the sentinel walk localizes the first
   offending level when the fault reproduces there.

A quarantined or replanned launch reports a neutral all-zeros END-skip map
for its pyramid key (shape ``(B, 1, 1, Q)``) so downstream skip accounting
stays well-formed; the real per-sub-launch skip fractions ride in the
:class:`RunReport` event detail.

Every fallback is recorded twice: as a :class:`FallbackEvent` in the
returned report (stored on ``guard.last_report``) and — when a tracer is
installed — as an ``obs`` ``"degrade"`` trace event, so the drift report
and Perfetto timeline show *where* the run left the fast path.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from .errors import BudgetError, NumericError
from .guard import sentinel_stats, sentinel_trips

_FLAT = "_flat/"


@dataclass(frozen=True)
class FallbackEvent:
    """One rung taken: which launch degraded, to what, and why."""

    launch: str
    rung: str  # "heal" | "interpret" | "replan" | "reference" | "reference_full"
    reason: str
    detail: dict = field(default_factory=dict)

    def describe(self) -> str:
        extra = ", ".join(f"{k}={v}" for k, v in self.detail.items())
        return (
            f"{self.launch}: -> {self.rung} ({self.reason})"
            + (f" [{extra}]" if extra else "")
        )


@dataclass
class RunReport:
    """What one guarded forward did: rungs taken, launches run clean."""

    model: str = ""
    batch: int = 0
    compute_dtype: str = ""
    launches: int = 0
    clean_launches: int = 0
    events: list = field(default_factory=list)

    @property
    def degraded(self) -> bool:
        return bool(self.events)

    def fallback_counts(self) -> dict[str, int]:
        counts: dict[str, int] = {}
        for e in self.events:
            counts[e.rung] = counts.get(e.rung, 0) + 1
        return counts

    def summary(self) -> str:
        head = (
            f"guarded run[{self.model}] batch={self.batch}"
            f" dtype={self.compute_dtype}: {self.clean_launches}/"
            f"{self.launches} launches clean"
        )
        if not self.events:
            return head + ", no fallbacks"
        lines = [head] + [f"  {e.describe()}" for e in self.events]
        return "\n".join(lines)


def _zero_skip(batch: int, q_convs: int) -> jnp.ndarray:
    # neutral END-skip map for a launch that did not run fused: nothing
    # skipped, one grid cell per level slot
    return jnp.zeros((batch, 1, 1, q_convs), dtype=jnp.int32)


def _reference_walk(x_in, pyr, graph, params, jdt, magnitude_limit=None):
    """Recompute a pyramid's covered nodes with the plain-op reference path.

    Returns ``(y, first_bad_level)`` where ``first_bad_level`` is the index
    (within the pyramid's conv levels) whose output first trips the
    sentinel, or ``None`` when the recompute is clean — i.e. the original
    fault did not reproduce and was the kernel execution itself.
    """
    from repro.net.runner import _conv_node, _pool_node

    y = x_in
    level = -1
    first_bad = None
    for nm in pyr.node_names:
        n = graph.node(nm)
        if n.op == "conv":
            level += 1
            w, b = params[nm]
            y = _conv_node(y, n, w.astype(jdt), b.astype(jdt))
        else:
            y = _pool_node(y, n)
        if first_bad is None:
            if sentinel_trips(sentinel_stats(y), magnitude_limit) is not None:
                first_bad = level
    return y, first_bad


def _run_subplan(x_in, subs, params, graph, cdt, *, end_skip, interpret,
                 vmem_budget):
    """Execute a replanned pyramid chain: each sub-pyramid as its own fused
    launch, per-level weight tensors (the pre-flattened arrays belong to the
    original plan's pyramids, not these)."""
    from repro.kernels.fused_conv.ops import fused_pyramid

    y = x_in
    sub_skips = {}
    for sp in subs:
        conv_names = [m for m in sp.node_names if graph.node(m).op == "conv"]
        y, sk = fused_pyramid(
            y,
            [params[m][0] for m in conv_names],
            [params[m][1] for m in conv_names],
            spec=sp.spec,
            out_region=sp.launch.out_region,
            streamed=sp.launch.streamed,
            w_slots=sp.launch.w_slots if sp.launch.streamed else None,
            x_slots=sp.launch.x_slots,
            c_tiles=sp.launch.c_tiles,
            relu=sp.relu,
            end_skip=end_skip,
            interpret=interpret,
            vmem_budget=vmem_budget,
            weights_flat=None,
            compute_dtype=cdt,
        )
        sub_skips[sp.name] = sk
    return y, sub_skips


def _skip_fracs(sub_skips: dict) -> dict[str, list[float]]:
    return {
        name: [float(f) for f in
               np.asarray(s, dtype=np.float64).mean(axis=(0, 1, 2))]
        for name, s in sub_skips.items()
    }


def run_network_guarded(
    x,
    params,
    *,
    plan,
    end_skip: bool = True,
    interpret: bool | None = None,
    dtype: str | None = None,
    guard=None,
):
    """Guarded twin of :func:`repro.net.runner.run_network`.

    Same signature and return contract ``(logits, skips)``; runs eagerly
    (launch by launch, like the traced path) with preflight validation up
    front, the fault injector consulted at each stage boundary, numeric
    sentinels on every launch output, and the degradation ladder answering
    failures.  The :class:`RunReport` lands on ``guard.last_report``.
    """
    from repro.net.runner import _forward, prepare_network_params
    from repro.obs.trace import get_tracer

    from .faults import get_injector
    from .guard import get_guard
    from .validate import nonfinite_param_nodes, preflight

    guard = get_guard() if guard is None else guard
    cfg = guard.config
    injector = get_injector()
    tracer = get_tracer()
    graph = plan.graph
    batch = int(x.shape[0])
    report = RunReport(model=graph.name, batch=batch,
                       launches=plan.n_launches())

    def record(event: FallbackEvent) -> None:
        report.events.append(event)
        if tracer.enabled:
            tracer.record_event(
                "degrade", model=graph.name, launch=event.launch,
                rung=event.rung, reason=event.reason, **event.detail,
            )

    # -- preflight (with one bounded healing attempt) -----------------------
    if cfg.preflight:
        try:
            cdt = preflight(x, params, plan=plan, dtype=dtype)
        except NumericError as e:
            if not (cfg.heal_params and guard.source_params is not None):
                raise
            healed = prepare_network_params(plan, guard.source_params, dtype)
            still_bad = nonfinite_param_nodes(healed)
            if still_bad:
                raise NumericError(
                    "params still non-finite after reloading from source;"
                    " the master copy is corrupt too",
                    nodes=still_bad,
                ) from e
            record(FallbackEvent(
                launch="<preflight>", rung="heal",
                reason="non-finite params reloaded from source",
                detail={"nodes": e.context.get("nodes", [])},
            ))
            params = healed
            cdt = preflight(x, params, plan=plan, dtype=dtype)
    else:
        from repro.core.dtypes import canonical_dtype

        cdt = canonical_dtype(plan.compute_dtype if dtype is None else dtype)
    from repro.core.dtypes import jnp_dtype

    jdt = jnp_dtype(cdt)
    report.compute_dtype = cdt

    # the effective budget a launch must fit at run time: the plan's own
    # budget scaled by any injected VMEM squeeze
    effective_budget = int(plan.vmem_budget * injector.vmem_factor)

    def reference_rung(pyr, x_in, reason, detail=None):
        y, bad_level = _reference_walk(
            x_in, pyr, graph, params, jdt, cfg.magnitude_limit
        )
        d = dict(detail or {})
        d["level"] = bad_level if bad_level is not None else "kernel-only"
        record(FallbackEvent(
            launch=pyr.name, rung="reference", reason=reason, detail=d,
        ))
        if bad_level is not None:
            # the fault reproduces in the reference math: the data/params
            # themselves blow up at that level — not recoverable by any
            # execution path
            raise NumericError(
                f"launch {pyr.name}: level {bad_level} output is non-finite"
                " (or over the magnitude limit) even on the reference path",
                launch=pyr.name, level=bad_level,
            )
        return y, _zero_skip(batch, pyr.q_convs)

    def replan_rung(pyr, call, x_in, reason):
        from repro.net.partition import replan_pyramid

        budget = effective_budget
        for attempt in range(cfg.max_replans):
            try:
                subs = replan_pyramid(
                    graph, pyr, vmem_budget=budget, batch=batch,
                    compute_dtype=cdt,
                )
                bad = [sp.name for sp in subs
                       if sp.launch.vmem_bytes() > budget]
                if bad:
                    raise BudgetError(
                        f"replan of {pyr.name} still exceeds"
                        f" {budget} bytes", launch=bad[0],
                    )
                y, sub_skips = _run_subplan(
                    x_in, subs, params, graph, cdt, end_skip=end_skip,
                    interpret=interpret, vmem_budget=budget,
                )
                record(FallbackEvent(
                    launch=pyr.name, rung="replan", reason=reason,
                    detail={
                        "attempt": attempt + 1,
                        "budget": budget,
                        "sub_launches": [sp.name for sp in subs],
                        "sub_skip_fractions": _skip_fracs(sub_skips),
                    },
                ))
                return y, _zero_skip(batch, pyr.q_convs)
            except (BudgetError, ValueError):
                budget = int(budget * cfg.budget_shrink)
        return reference_rung(
            pyr, x_in, f"replan exhausted after {cfg.max_replans} attempts",
            detail={"original_reason": reason},
        )

    def guarded_wrapper(pyr, call, x_in):
        # -- plan stage: injected faults + the run-time budget check -------
        try:
            injector.fire("plan", pyr.name)
            vmem = pyr.launch.vmem_bytes()
            if vmem > effective_budget:
                raise BudgetError(
                    f"launch {pyr.name} needs {vmem} bytes,"
                    f" {effective_budget} available",
                    launch=pyr.name, vmem_bytes=vmem,
                    vmem_budget=effective_budget,
                )
        except BudgetError as e:
            return replan_rung(pyr, call, x_in, str(e))
        except Exception as e:  # injected plan fault
            return reference_rung(pyr, x_in, f"plan stage failed: {e}")

        # -- compile/run stages: fused launch, one interpret retry ---------
        try:
            injector.fire("compile", pyr.name)
            injector.fire("run", pyr.name)
            y, skip = call()
        except BudgetError as e:
            return replan_rung(pyr, call, x_in, str(e))
        except Exception as first:
            try:
                injector.fire("compile", pyr.name)
                injector.fire("run", pyr.name)
                y, skip = call(interpret=True)
                record(FallbackEvent(
                    launch=pyr.name, rung="interpret",
                    reason=f"launch failed: {first}",
                ))
            except Exception as second:
                return reference_rung(
                    pyr, x_in,
                    f"interpret retry failed too: {second}",
                    detail={"first_error": str(first)},
                )
            else:
                y = injector.corrupt_output(pyr.name, y)
                if cfg.sentinel:
                    trip = sentinel_trips(
                        sentinel_stats(y), cfg.magnitude_limit
                    )
                    if trip is not None:
                        return reference_rung(
                            pyr, x_in, f"sentinel tripped: {trip}"
                        )
                return y, skip

        # -- numeric sentinel on the clean fused output --------------------
        y = injector.corrupt_output(pyr.name, y)
        if cfg.sentinel:
            trip = sentinel_trips(sentinel_stats(y), cfg.magnitude_limit)
            if trip is not None:
                return reference_rung(
                    pyr, x_in, f"sentinel tripped: {trip}"
                )
        report.clean_launches += 1
        return y, skip

    logits, skips = _forward(
        x, params, plan=plan, end_skip=end_skip, interpret=interpret,
        cdt=cdt, launch_wrapper=guarded_wrapper,
    )

    # -- final logits sentinel: faults in the plain-op head ----------------
    if cfg.sentinel:
        trip = sentinel_trips(sentinel_stats(logits), None)
        if trip is not None:
            from repro.net.runner import reference_network

            logits = reference_network(
                x.astype(jdt), graph,
                {k: v for k, v in params.items() if not k.startswith(_FLAT)},
            )
            record(FallbackEvent(
                launch="<head>", rung="reference_full",
                reason=f"logits sentinel tripped: {trip}",
            ))
            if sentinel_trips(sentinel_stats(logits), None) is not None:
                raise NumericError(
                    "logits are non-finite even on the full reference path",
                    launch="<head>",
                )

    if tracer.enabled:
        tracer.record_event(
            "guarded_run", model=graph.name, batch=batch, compute_dtype=cdt,
            launches=report.launches, clean_launches=report.clean_launches,
            fallbacks=report.fallback_counts(),
        )
    guard.last_report = report
    return logits, skips
