"""Typed error hierarchy of the guarded inference runtime.

Every failure mode the fused-pyramid path can hit is classified here so
callers (and the degradation ladder in :mod:`repro.robust.degrade`) can
dispatch on *what went wrong* instead of parsing a traceback:

* :class:`PreflightError` — the request itself is malformed: shapes, dtypes,
  missing or mis-prepared params, plan/graph disagreement.  Subclasses
  ``ValueError`` because that is what the structural validators historically
  raised — existing ``except ValueError`` call sites keep working.
* :class:`PlanError` — a plan-construction contract was violated (a chain
  that does not start with a conv, an output region that does not tile the
  map).  A :class:`PreflightError` subclass: a broken plan is a broken
  request.
* :class:`BudgetError` — a working set does not fit the VMEM budget (at plan
  time or at launch time).  Also a ``ValueError`` subclass for the same
  compatibility reason.  The degradation ladder answers this rung by
  replanning under a shrunken budget.
* :class:`NumericError` — non-finite or out-of-magnitude values: poisoned
  weights at preflight, a NaN/Inf launch output caught by a runtime
  sentinel.  Subclasses ``FloatingPointError``.  Carries the offending
  ``nodes`` / ``launch`` / ``level`` so the fault is localized, not just
  detected.
* :class:`DeadlineExceeded` — a serving request missed its deadline (shed
  at admission or expired in the queue).  Subclasses ``TimeoutError``.
* :class:`FaultInjected` — raised only by the deterministic fault harness
  (:mod:`repro.robust.faults`); never by production code.

This module is import-light on purpose (stdlib only): ``repro.core`` and
``repro.kernels`` raise these errors, and the heavy robust modules import
those packages back — keeping the hierarchy dependency-free breaks the
cycle.
"""

from __future__ import annotations


class RobustError(Exception):
    """Base of every typed error the guarded runtime raises.

    ``context`` keys (node, launch, stage, ...) ride along machine-readable;
    the message is built once so ``str(e)`` shows them too.
    """

    def __init__(self, message: str, **context):
        self.context = context
        if context:
            detail = ", ".join(f"{k}={v!r}" for k, v in context.items())
            message = f"{message} [{detail}]"
        super().__init__(message)


class PreflightError(RobustError, ValueError):
    """The request is structurally invalid: shape/dtype/param/plan
    disagreement caught before any kernel launch."""


class PlanError(PreflightError):
    """A plan-construction contract was violated (tile-program compiler or
    launch-planner preconditions)."""


class BudgetError(RobustError, ValueError):
    """A working set (or every candidate launch regime) exceeds the VMEM
    budget.  The degradation ladder replans under a shrunken budget; direct
    callers see which launch/spec failed via ``context``."""


class NumericError(RobustError, FloatingPointError):
    """Non-finite (or out-of-magnitude) values detected — in params at
    preflight (``context['nodes']``) or in a launch output by a runtime
    sentinel (``context['launch']`` / ``context['level']``)."""


class DeadlineExceeded(RobustError, TimeoutError):
    """A serving request's deadline passed before (or instead of) useful
    work: shed at admission because the modeled queue delay already blows
    the deadline (``context['eta_us']`` vs ``context['deadline_us']``), or
    expired in the queue and completed without occupying a launch
    (``context['late_us']``).  Subclasses ``TimeoutError`` — a blown
    deadline is a timeout, whatever stage noticed it."""


class FaultInjected(RobustError, RuntimeError):
    """An exception planted by the deterministic fault-injection harness
    (:mod:`repro.robust.faults`).  ``context['stage']`` names the stage it
    fired at (``plan`` / ``compile`` / ``run`` / ``stage``)."""
