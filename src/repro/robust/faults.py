"""Deterministic, seeded fault injection for the guarded runtime.

The chaos suite (``tests/test_chaos.py``) must *prove* the degradation
ladder: every fault class terminates at a successful forward whose logits
match the reference.  That needs faults that are injectable on demand,
deterministic under a seed, and scoped to a named launch — this module is
that harness.  Nothing here runs in production: the injector defaults to
:data:`NULL_INJECTOR` (``enabled = False``) and only the guarded runner
consults it.

Fault classes (mirroring the ladder's rungs):

* :func:`corrupt_params` — NaN/Inf corruption of a named node's weights at
  seeded positions (pure function over a params dict; models a poisoned
  staging copy).  Caught by the preflight finite-params check.
* ``FaultInjector.squeeze_budget`` — a simulated VMEM squeeze: the guarded
  runner multiplies the plan's budget by this factor, so launches that
  planned clean now violate it → the replan rung fires genuinely.
* ``FaultInjector.raise_at`` — a planted exception at a named stage
  (``plan`` / ``compile`` / ``run``) of a named launch, firing a bounded
  number of times (default once, so the retry rung can succeed; more to
  force the fall-through to the reference path).
* ``FaultInjector.poison_output`` — overwrite seeded positions of a named
  launch's output with NaN/Inf after the kernel ran (models a kernel
  miscompute).  Caught by the runtime numeric sentinel → quarantine.

Serving fault classes (consumed by ``net/serve.py``'s engine, proved by
``tests/test_serve_chaos.py``):

* ``FaultInjector.slow_launch`` — a stuck launch: the host sleeps before
  consuming a matching launch's result.  Caught by the serving watchdog
  (wall clock vs N× modeled SLO) → escalation + breaker failure.
* ``raise_at("stage", ...)`` — a host→device staging (``jax.device_put``)
  failure; the affected batch fails typed, the queue keeps draining.
* ``FaultInjector.stall_queue`` — the drain loop skips scheduling turns
  (bounded); requests stay queued, nothing is lost or reordered.

Use::

    from repro.robust import inject

    with inject(seed=0) as inj:
        inj.raise_at("compile", launch="CL1..MPL2")
        inj.squeeze_budget(0.05)
        ... run guarded ...
    print(inj.fired)   # deterministic fire log
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass, field

import numpy as np

from .errors import FaultInjected

# "stage" is the serving engine's host→device staging copy; the guarded
# runner itself only consults plan/compile/run
STAGES = ("plan", "compile", "run", "stage")


def _match(pattern: str | None, launch: str) -> bool:
    return pattern is None or pattern == launch or pattern in launch


def corrupt_params(
    params: dict,
    node: str,
    *,
    kind: str = "nan",
    fraction: float = 0.05,
    seed: int = 0,
) -> dict:
    """A new params dict with ``node``'s weight tensor corrupted at seeded
    positions (``max(1, fraction * size)`` of them) — NaN or Inf per
    ``kind``.  The input dict is not mutated; every other entry is shared.

    Flattened streamed-weight entries (``"_flat/..."``) are rebuilt by
    :func:`repro.net.runner.prepare_network_params`, not here — corrupt the
    master params and re-prepare, or corrupt the prepared dict directly to
    model staging-copy corruption.
    """
    import jax.numpy as jnp

    if node not in params:
        raise KeyError(f"no params for node {node!r}; have {sorted(params)}")
    if kind not in ("nan", "inf"):
        raise ValueError(f"kind must be 'nan' or 'inf', got {kind!r}")
    w, b = params[node]
    flat = np.asarray(w, dtype=np.float32).reshape(-1).copy()
    rng = np.random.default_rng(seed)
    n_bad = max(1, int(fraction * flat.size))
    idx = rng.choice(flat.size, size=n_bad, replace=False)
    flat[idx] = np.nan if kind == "nan" else np.inf
    bad = jnp.asarray(flat.reshape(np.asarray(w).shape), dtype=w.dtype)
    out = dict(params)
    out[node] = (bad, b)
    return out


@dataclass
class _PlannedRaise:
    stage: str
    launch: str | None
    times: int
    message: str


@dataclass
class _PlannedPoison:
    launch: str | None
    kind: str
    times: int


@dataclass
class _PlannedDelay:
    launch: str | None
    delay_s: float
    times: int


@dataclass
class FaultInjector:
    """Armed faults + a deterministic fire log.

    The guarded runner calls :meth:`fire` at each stage boundary and
    :meth:`corrupt_output` on each launch result; with nothing armed both
    are no-ops.  All randomness (poison positions) derives from ``seed``.
    """

    seed: int = 0
    enabled: bool = True
    vmem_factor: float = 1.0
    raises: list = field(default_factory=list)
    poisons: list = field(default_factory=list)
    delays: list = field(default_factory=list)
    stalls: int = 0
    fired: list = field(default_factory=list)

    # -- arming ------------------------------------------------------------

    def raise_at(
        self,
        stage: str,
        *,
        launch: str | None = None,
        times: int = 1,
        message: str = "injected fault",
    ) -> None:
        """Arm an exception at ``stage`` for launches matching ``launch``
        (substring; ``None`` = every launch), firing ``times`` times."""
        if stage not in STAGES:
            raise ValueError(f"stage must be one of {STAGES}, got {stage!r}")
        self.raises.append(_PlannedRaise(stage, launch, times, message))

    def poison_output(
        self, *, launch: str | None = None, kind: str = "nan", times: int = 1
    ) -> None:
        """Arm output corruption of matching launches: seeded positions of
        the result tensor become NaN/Inf ``times`` times."""
        if kind not in ("nan", "inf"):
            raise ValueError(f"kind must be 'nan' or 'inf', got {kind!r}")
        self.poisons.append(_PlannedPoison(launch, kind, times))

    def squeeze_budget(self, factor: float) -> None:
        """Simulate VMEM pressure: the guarded runner scales the plan's
        budget by ``factor`` (0 < factor <= 1) when checking each launch."""
        if not 0 < factor <= 1:
            raise ValueError(f"factor must be in (0, 1], got {factor}")
        self.vmem_factor = factor

    def slow_launch(
        self,
        delay_s: float,
        *,
        launch: str | None = None,
        times: int = 1,
    ) -> None:
        """Arm a stuck launch: matching launches sleep ``delay_s`` seconds
        on the host before their result is consumed, firing ``times``
        times.  The serving watchdog must notice the wall clock blowing
        past the modeled SLO and escalate."""
        if delay_s <= 0:
            raise ValueError(f"delay_s must be positive, got {delay_s}")
        self.delays.append(_PlannedDelay(launch, delay_s, times))

    def stall_queue(self, times: int = 1) -> None:
        """Arm ``times`` drain-loop stalls: the serving drain loop skips a
        scheduling turn per stall (work stays queued, nothing is lost) —
        models a scheduler hiccup that must not hang or drop requests."""
        if times < 1:
            raise ValueError(f"times must be >= 1, got {times}")
        self.stalls += times

    # -- consumption (guarded runner only) ---------------------------------

    def fire(self, stage: str, launch: str) -> None:
        """Raise the armed :class:`FaultInjected` for (stage, launch) if any
        remains, decrementing its fire count."""
        for pr in self.raises:
            if pr.times > 0 and pr.stage == stage and _match(pr.launch, launch):
                pr.times -= 1
                self.fired.append((stage, launch, "raise"))
                raise FaultInjected(pr.message, stage=stage, launch=launch)

    def launch_delay(self, launch: str) -> float:
        """Seconds the armed stuck-launch fault wants ``launch`` delayed
        (0.0 when nothing matches); decrements the fire count."""
        for pd in self.delays:
            if pd.times > 0 and _match(pd.launch, launch):
                pd.times -= 1
                self.fired.append(("slow", launch, f"{pd.delay_s}s"))
                return pd.delay_s
        return 0.0

    def queue_stalled(self) -> bool:
        """Consume one armed drain-loop stall if any remain."""
        if self.stalls > 0:
            self.stalls -= 1
            self.fired.append(("stall", "<queue>", "skip"))
            return True
        return False

    def corrupt_output(self, launch: str, y):
        """Return ``y`` with seeded poison applied if armed for ``launch``,
        else ``y`` unchanged."""
        import jax.numpy as jnp

        for pp in self.poisons:
            if pp.times > 0 and _match(pp.launch, launch):
                pp.times -= 1
                self.fired.append(("output", launch, f"poison_{pp.kind}"))
                flat = np.asarray(y, dtype=np.float32).reshape(-1).copy()
                rng = np.random.default_rng(self.seed)
                idx = rng.choice(flat.size, size=max(1, flat.size // 64),
                                 replace=False)
                flat[idx] = np.nan if pp.kind == "nan" else np.inf
                return jnp.asarray(
                    flat.reshape(np.asarray(y).shape), dtype=y.dtype
                )
        return y


class _NullInjector:
    """No faults armed, nothing recorded — the production default."""

    enabled = False
    vmem_factor = 1.0
    fired: tuple = ()

    def fire(self, stage: str, launch: str) -> None:
        pass

    def launch_delay(self, launch: str) -> float:
        return 0.0

    def queue_stalled(self) -> bool:
        return False

    def corrupt_output(self, launch: str, y):
        return y


NULL_INJECTOR = _NullInjector()

_injector = NULL_INJECTOR


def get_injector():
    """The process-global injector: :data:`NULL_INJECTOR` unless a
    :class:`FaultInjector` is scoped via :func:`inject`."""
    return _injector


def set_injector(injector) -> None:
    """Install ``injector`` globally (``None`` restores the no-op)."""
    global _injector
    _injector = NULL_INJECTOR if injector is None else injector


@contextlib.contextmanager
def inject(seed: int = 0, injector: FaultInjector | None = None):
    """Scope a :class:`FaultInjector` as the process injector; yields it.
    Nesting restores the previous injector on exit."""
    inj = FaultInjector(seed=seed) if injector is None else injector
    prev = get_injector()
    set_injector(inj)
    try:
        yield inj
    finally:
        set_injector(prev)
