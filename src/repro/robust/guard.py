"""Runtime guard flag and numeric sentinels.

The guard mirrors the PR 7 tracer exactly (``repro.obs.trace``): a
process-global object whose ``enabled`` attribute is the *one* check
``run_network`` makes, outside jit, before dispatching.  With the default
:data:`NULL_GUARD` the jit fast path is byte-identical to the unguarded
runner — guards off cost one attribute load per call and nothing inside the
compiled graph.  Enable with::

    from repro.robust import GuardConfig, guarding

    with guarding(GuardConfig()) as guard:
        logits, skips = run_network(x, params, plan=plan)
    print(guard.last_report.summary())

The sentinels themselves (:func:`sentinel_stats`) are cheap jit-compatible
reductions — an all-finite flag and the max magnitude of a launch output —
evaluated per launch by the guarded runner so a NaN/Inf is localized to the
offending launch (and, via the reference walk in
:mod:`repro.robust.degrade`, to the offending level) instead of surfacing
as poisoned logits three launches later.
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass


@dataclass(frozen=True)
class GuardConfig:
    """Static knobs of the guarded runtime.

    ``magnitude_limit`` — max ``|value|`` a launch output may carry before
    the numeric sentinel trips (``None`` = finiteness only).  A tight limit
    turns slow overflow into a quarantined launch instead of inf logits.

    ``max_replans`` — bounded retry count of the budget rung: each retry
    shrinks the effective VMEM budget by ``budget_shrink`` and replans the
    failing pyramid (tighter cuts, chained launches) before giving up to the
    reference path.

    ``preflight`` / ``sentinel`` — toggle the validation pass and the
    per-launch numeric checks independently (both on by default when
    guarding is enabled at all).

    ``heal_params`` — when the preflight finds non-finite params and
    ``guarding(..., source_params=...)`` supplied a clean master copy,
    rebuild the prepared params from it once instead of raising.
    """

    magnitude_limit: float | None = None
    max_replans: int = 2
    budget_shrink: float = 0.5
    preflight: bool = True
    sentinel: bool = True
    heal_params: bool = True


class GuardRuntime:
    """An installed guard: config + the clean param source (for healing)
    + the last run's :class:`~repro.robust.degrade.RunReport`."""

    enabled = True

    def __init__(self, config: GuardConfig | None = None, source_params=None):
        self.config = config if config is not None else GuardConfig()
        self.source_params = source_params
        self.last_report = None


class _NullGuard:
    """Guards off: ``run_network`` sees ``enabled = False`` and takes the
    unchanged jit fast path."""

    enabled = False
    config = GuardConfig()
    source_params = None
    last_report = None


NULL_GUARD = _NullGuard()

_guard = NULL_GUARD


def get_guard():
    """The process-global guard: :data:`NULL_GUARD` unless a
    :class:`GuardRuntime` was installed via :func:`guarding`."""
    return _guard


def set_guard(guard) -> None:
    """Install ``guard`` globally (``None`` restores the off default)."""
    global _guard
    _guard = NULL_GUARD if guard is None else guard


@contextlib.contextmanager
def guarding(config: GuardConfig | None = None, *, source_params=None):
    """Scope a :class:`GuardRuntime` as the process guard; yields it.

    ``source_params`` is the clean (master, f32) params dict used to heal
    corrupted prepared params at preflight.  Nesting restores the previous
    guard on exit, like ``repro.obs.tracing``.
    """
    rt = GuardRuntime(config, source_params)
    prev = get_guard()
    set_guard(rt)
    try:
        yield rt
    finally:
        set_guard(prev)


def sentinel_stats(y) -> dict:
    """The per-launch numeric sentinel: jit-compatible scalar reductions.

    Returns ``{"finite": all-finite bool, "max_abs": max |y|}`` as 0-d jnp
    arrays — two cheap reductions over a tile the launch just produced, so
    running them guarded adds one pass over data already in cache.  The
    guarded runner hosts-reads them per launch (it is eager by
    construction); jit callers can fold them into a compiled graph
    unchanged.
    """
    import jax.numpy as jnp

    yf = y.astype(jnp.float32)
    return {
        "finite": jnp.all(jnp.isfinite(yf)),
        "max_abs": jnp.max(jnp.abs(yf)),
    }


def sentinel_trips(stats: dict, magnitude_limit: float | None) -> str | None:
    """Classify host-side sentinel stats: ``None`` when clean, else a short
    reason string (``"non-finite"`` / ``"magnitude"``)."""
    if not bool(stats["finite"]):
        return "non-finite"
    if magnitude_limit is not None and float(stats["max_abs"]) > magnitude_limit:
        return "magnitude"
    return None
