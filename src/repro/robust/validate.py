"""Preflight validation: reject a bad request before any kernel launch.

``run_network``'s jit fast path assumes its inputs are exactly what the
plan was built for; when they are not, the failure is a shape error or
assert deep inside the Pallas kernel wrapper — far from the mistake.  The
:func:`preflight` pass re-checks the whole contract up front and raises the
typed errors of :mod:`repro.robust.errors`, each naming the offending node
or launch:

* **structure** — input rank/spatial/channel agreement with the graph, the
  plan covering real conv/pool nodes of its own graph (channel chaining
  inside each pyramid was already proven at ``FusionSpec`` construction);
* **params** — every conv/dense node has a ``(w, b)`` pair of the right
  shape; pre-flattened streamed-weight arrays (``"_flat/..."``) match their
  pyramid's level weight counts and the run dtype, and are absent for
  non-streamed pyramids (the resident kernel would reject them);
* **dtype** — the requested compute dtype is known *and* executable
  (``EXEC_DTYPES``: int8 is modeled-only and must fail here, not as a
  kernel ``NotImplementedError``);
* **numerics** — all params finite (:class:`NumericError` listing the
  poisoned nodes — the check that catches weight corruption before it
  poisons a forward);
* **budget** — every planned launch's modeled working set fits the VMEM
  budget (:class:`BudgetError` naming the launch; the degradation ladder
  answers this rung by replanning).

The pass is eager host-side work proportional to the number of nodes, run
only when guards are on (or when called directly) — the unguarded jit path
never pays for it.
"""

from __future__ import annotations

import warnings

import jax.numpy as jnp
import numpy as np

from repro.core.dtypes import EXEC_DTYPES, canonical_dtype, jnp_dtype

from .errors import BudgetError, NumericError, PreflightError

# key prefix of pre-flattened streamed-weight arrays (mirrors net/runner)
_FLAT = "_flat/"


def _resolve_dtype(plan, dtype) -> str:
    try:
        cdt = canonical_dtype(plan.compute_dtype if dtype is None else dtype)
    except KeyError as e:
        raise PreflightError(
            f"unknown compute dtype: {e.args[0]}", dtype=str(dtype)
        ) from e
    if cdt not in EXEC_DTYPES:
        raise PreflightError(
            f"compute dtype {cdt!r} is modeled but not executable; the fused"
            f" kernels run {EXEC_DTYPES} (int8 needs the quantized-pyramid"
            " epilogue — see ROADMAP)",
            dtype=cdt,
        )
    return cdt


def _check_input(x, graph) -> None:
    # every rejection names the offending field machine-readably: serving
    # callers surface ``err.context["field"]`` to the client
    if getattr(x, "ndim", None) != 4:
        raise PreflightError(
            f"input must be a (B, H, W, C) batch, got shape"
            f" {getattr(x, 'shape', None)}",
            graph=graph.name, field="rank",
        )
    b, h, w, c = x.shape
    if b < 1:
        raise PreflightError(
            "input batch is empty", graph=graph.name, field="batch",
        )
    if h != graph.input_size or w != graph.input_size:
        raise PreflightError(
            f"input spatial dims {h}x{w} do not match graph"
            f" {graph.name}'s {graph.input_size}x{graph.input_size}",
            graph=graph.name, field="spatial",
        )
    if c != graph.in_channels:
        raise PreflightError(
            f"input has {c} channels, graph {graph.name} expects"
            f" {graph.in_channels}",
            graph=graph.name, field="channels",
        )


def _fits_f32(arr: np.ndarray) -> bool:
    """Do all (finite) wide-float values survive the cast to float32?"""
    with np.errstate(over="ignore"), warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return bool(np.isfinite(arr.astype(np.float32)).all())


def check_request(x, graph, *, require_finite: bool = True) -> None:
    """Admission-time validation of one serving request against a graph.

    The per-request subset of :func:`preflight`: the plan/params half of the
    contract is validated once per (model, bucket) when the serving engine
    builds a cache entry, but *every* request body is untrusted — shape
    agreement with the graph and (``require_finite``) input finiteness are
    the two properties a queued request can individually violate.  Raises
    :class:`PreflightError` on shape/dtype problems and
    :class:`NumericError` on NaN/Inf pixels (or f64 values that overflow
    the f32 compute dtype), both cheap O(input) host-side checks (numpy,
    never a jax dispatch — admission runs per request on the serving hot
    path), so a poisoned request is rejected at the queue door instead of
    inside a padded bucket where its rows would sit next to healthy
    traffic.  Every rejection's ``context`` carries a ``field`` key naming
    the offending property (``rank`` / ``batch`` / ``spatial`` /
    ``channels`` / ``dtype`` / ``values`` / ``range``).
    """
    _check_input(x, graph)
    if not require_finite:
        return
    # scan in the native dtype first so an f64 request with NaN/Inf pixels
    # is named as non-finite (field="values"), not as an f32 cast artifact;
    # non-contiguous views are fine — numpy reductions never require
    # contiguity (the engine's concatenate copies later anyway)
    arr = np.asarray(x)
    if arr.dtype == object or not (
        np.issubdtype(arr.dtype, np.floating)
        or np.issubdtype(arr.dtype, np.integer)
        or np.issubdtype(arr.dtype, np.bool_)
    ):
        raise PreflightError(
            f"request input dtype {arr.dtype} is not numeric"
            f" (graph {graph.name})",
            graph=graph.name, field="dtype",
        )
    if np.issubdtype(arr.dtype, np.floating):
        if not np.isfinite(arr).all():
            raise NumericError(
                f"request input carries non-finite values"
                f" (graph {graph.name})",
                graph=graph.name, field="values",
            )
        if arr.dtype.itemsize > 4 and not _fits_f32(arr):
            # finite in f64 but overflows the f32 the kernels compute in —
            # admitting it would poison the padded bucket with Infs
            raise NumericError(
                f"request input is finite in {arr.dtype} but overflows"
                f" float32, the serving compute dtype"
                f" (graph {graph.name})",
                graph=graph.name, field="range",
            )


def _check_plan_structure(plan) -> None:
    graph = plan.graph
    names = {n.name for n in graph.nodes}
    for pyr in plan.pyramids:
        for nm in pyr.node_names:
            if nm not in names:
                raise PreflightError(
                    f"plan pyramid {pyr.name} covers node {nm!r} which is not"
                    f" in graph {graph.name}",
                    launch=pyr.name,
                )
            op = graph.node(nm).op
            if op not in ("conv", "pool"):
                raise PreflightError(
                    f"plan pyramid {pyr.name} covers node {nm!r} of op"
                    f" {op!r}; pyramids fuse conv/pool chains only",
                    launch=pyr.name, node=nm,
                )


def _check_params(params, plan, cdt: str) -> None:
    from repro.net.graph import infer_shapes

    graph = plan.graph
    shapes = infer_shapes(graph)
    jdt = jnp_dtype(cdt)
    for n in graph.nodes:
        if n.op not in ("conv", "dense"):
            continue
        if n.name not in params:
            raise PreflightError(
                f"missing params for node {n.name!r} of graph {graph.name}",
                node=n.name,
            )
        w, b = params[n.name]
        c_in = shapes[n.inputs[0]].channels
        want_w = (n.K, n.K, c_in, n.n_out) if n.op == "conv" else (c_in, n.n_out)
        if tuple(w.shape) != want_w:
            raise PreflightError(
                f"node {n.name!r}: weight shape {tuple(w.shape)} does not"
                f" match the graph's {want_w}",
                node=n.name,
            )
        if tuple(b.shape) != (n.n_out,):
            raise PreflightError(
                f"node {n.name!r}: bias shape {tuple(b.shape)} does not match"
                f" ({n.n_out},)",
                node=n.name,
            )
        if not (jnp.issubdtype(w.dtype, jnp.floating)
                and jnp.issubdtype(b.dtype, jnp.floating)):
            raise PreflightError(
                f"node {n.name!r}: params must be floating"
                f" (got {w.dtype}/{b.dtype}); integer params need the"
                " quantized path",
                node=n.name,
            )
    covered_flats = set()
    for pyr in plan.pyramids:
        key = _FLAT + pyr.name
        covered_flats.add(key)
        flat = params.get(key)
        if flat is None:
            continue  # runner falls back to per-level tensors
        if not pyr.launch.streamed:
            raise PreflightError(
                f"pre-flattened weights {key!r} present but pyramid"
                f" {pyr.name} is not streamed — the resident kernel reads"
                " per-level tensors; re-prepare with the current plan",
                launch=pyr.name,
            )
        if flat.dtype != jdt:
            raise PreflightError(
                f"pre-flattened weights {key!r} are {flat.dtype} but the run"
                f" computes {cdt}; params were prepared at a different dtype"
                " — re-run prepare_network_params at the run dtype",
                launch=pyr.name, dtype=cdt,
            )
        want = sum(pyr.launch.program.level_weight_counts())
        if flat.size != want:
            raise PreflightError(
                f"pre-flattened weights {key!r} hold {flat.size} values,"
                f" launch program expects {want}; params were prepared for a"
                " different plan",
                launch=pyr.name,
            )
    stale = [
        k for k in params
        if k.startswith(_FLAT) and k not in covered_flats
    ]
    if stale:
        raise PreflightError(
            f"params carry pre-flattened weights for pyramids not in this"
            f" plan: {sorted(stale)}; re-prepare with the current plan",
            launch=stale[0][len(_FLAT):],
        )


def nonfinite_param_nodes(params) -> list[str]:
    """Names of param entries (nodes and ``"_flat/..."`` arrays) carrying
    any non-finite value — the preflight numeric check, exposed so the
    healing rung can name what it reloads."""
    bad = []
    for key, val in params.items():
        arrs = (val,) if key.startswith(_FLAT) else val
        for arr in arrs:
            if not bool(jnp.all(jnp.isfinite(arr.astype(jnp.float32)))):
                bad.append(key)
                break
    return bad


def _check_budget(plan, vmem_budget: int) -> None:
    over = [
        (p.name, p.launch.vmem_bytes())
        for p in plan.pyramids
        if p.launch.vmem_bytes() > vmem_budget
    ]
    if over:
        name, vmem = over[0]
        raise BudgetError(
            f"{len(over)} planned launch(es) exceed the {vmem_budget}-byte"
            f" VMEM budget; first: {name} needs {vmem} bytes",
            launch=name, vmem_bytes=vmem, vmem_budget=vmem_budget,
        )


def preflight(
    x,
    params,
    *,
    plan,
    dtype: str | None = None,
    vmem_budget: int | None = None,
    check_budget: bool = True,
) -> str:
    """Validate a ``run_network`` request end to end; returns the resolved
    canonical compute dtype.

    Raises :class:`PreflightError` on structural/dtype problems,
    :class:`NumericError` (with ``context['nodes']``) on non-finite params,
    and :class:`BudgetError` when a planned launch no longer fits
    ``vmem_budget`` (default: the plan's own budget).  The checks run in
    that order so the most actionable error surfaces first.
    """
    cdt = _resolve_dtype(plan, dtype)
    _check_input(x, plan.graph)
    _check_plan_structure(plan)
    _check_params(params, plan, cdt)
    bad = nonfinite_param_nodes(params)
    if bad:
        raise NumericError(
            f"non-finite values in params of {len(bad)} node(s):"
            f" {sorted(bad)}",
            nodes=sorted(bad),
        )
    if check_budget:
        _check_budget(
            plan, plan.vmem_budget if vmem_budget is None else vmem_budget
        )
    return cdt
