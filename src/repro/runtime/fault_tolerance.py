"""Fault tolerance: heartbeats, failure detection, restart policy, elastic
rescale planning.

On a real multi-pod deployment this wraps ``jax.distributed`` + the cluster
scheduler; here the control-plane logic is implemented and unit-tested
against a simulated cluster so the policy is exercised end to end:

* every host heartbeats; a coordinator marks hosts dead after
  ``timeout_s`` without one;
* on failure: pick the restart plan — same-size restart from the newest
  complete checkpoint, or an **elastic downsize** to the largest feasible
  mesh if spares are unavailable (mesh candidates preserve the model axis,
  shrink the data axis — the checkpoint restores onto any of them via the
  resharding restore path in :mod:`repro.checkpoint.checkpointer`);
* deterministic data replay: the pipeline is a pure function of step, so
  the restored run re-consumes exactly the post-checkpoint batches.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class HostState:
    host_id: int
    last_heartbeat: float
    alive: bool = True


@dataclass
class FaultTolerantCluster:
    n_hosts: int
    timeout_s: float = 30.0
    clock: callable = time.monotonic
    hosts: dict[int, HostState] = field(default_factory=dict)

    def __post_init__(self):
        now = self.clock()
        self.hosts = {
            i: HostState(i, now) for i in range(self.n_hosts)
        }

    def heartbeat(self, host_id: int):
        h = self.hosts[host_id]
        h.last_heartbeat = self.clock()
        h.alive = True

    def check(self) -> list[int]:
        """Mark and return hosts that missed the heartbeat window."""
        now = self.clock()
        dead = []
        for h in self.hosts.values():
            if h.alive and now - h.last_heartbeat > self.timeout_s:
                h.alive = False
            if not h.alive:
                dead.append(h.host_id)
        return dead

    @property
    def alive_count(self) -> int:
        return sum(h.alive for h in self.hosts.values())


@dataclass(frozen=True)
class RestartPlan:
    kind: str  # "same_size" | "elastic_downsize" | "halt"
    mesh_shape: tuple[int, ...]
    restore_step: int | None
    replay_from: int | None  # first data step to re-consume


def plan_restart(
    *,
    alive_hosts: int,
    hosts_per_replica: int,
    base_mesh: tuple[int, ...],  # (data, model) in units of hosts x chips
    spare_hosts: int,
    latest_checkpoint: int | None,
) -> RestartPlan:
    """Decide the post-failure topology.

    The model axis is preserved (param sharding must stay valid);
    the data axis shrinks to the largest power-of-two that the surviving
    hosts support when no spares can backfill.  When the survivors cannot
    hold even one model replica (``capacity < model_ax``) no downsized mesh
    exists: the plan is an explicit ``"halt"`` (empty mesh, checkpoint
    preserved for a later restart) rather than a bogus 1-replica mesh the
    cluster cannot actually place.
    """
    data_ax, model_ax = base_mesh
    needed = data_ax * model_ax // hosts_per_replica
    if alive_hosts + spare_hosts >= needed:
        return RestartPlan(
            kind="same_size",
            mesh_shape=base_mesh,
            restore_step=latest_checkpoint,
            replay_from=None if latest_checkpoint is None else latest_checkpoint + 1,
        )
    capacity = alive_hosts * hosts_per_replica
    if capacity < model_ax:
        # infeasible: not enough surviving chips for one model replica —
        # halt and wait for backfill instead of planning a mesh that the
        # elastic loop below would silently report as (1, model_ax)
        return RestartPlan(
            kind="halt",
            mesh_shape=(0, model_ax),
            restore_step=latest_checkpoint,
            replay_from=None,
        )
    # elastic: shrink data axis to the largest feasible power of two
    new_data = 1
    while new_data * 2 * model_ax <= capacity:
        new_data *= 2
    return RestartPlan(
        kind="elastic_downsize",
        mesh_shape=(new_data, model_ax),
        restore_step=latest_checkpoint,
        replay_from=None if latest_checkpoint is None else latest_checkpoint + 1,
    )
