"""Continuous-batching serving scheduler (control plane).

Production serving multiplexes many requests over fixed-shape decode slots:
requests arrive with a prompt, occupy a batch slot while decoding, and free
it on completion — the decode step itself stays a single compiled function
(fixed batch, fixed max_seq, per-slot position indices).

The scheduler is pure control logic (device-free, unit-tested):

* slot allocation with admission by prompt length (a prompt must fit in the
  remaining cache);
* per-slot position tracking feeding ``decode_step``'s ``cache_index`` (the
  model supports per-call scalar positions; batched serving drives one step
  per position cohort — slots at the same position batch together);
* preemption policy: when the queue starves, the longest-running request
  past ``preempt_after`` tokens can be evicted to a re-queue (its state is
  recoverable from its token history — deterministic recompute, the same
  trade USEFUSE makes for overlap tiles: recompute beats buffering when
  buffers are the scarce resource);
* fairness: FIFO admission with an anti-starvation bump for requests
  waiting longer than ``max_wait_steps``.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field


@dataclass
class Request:
    rid: int
    prompt_len: int
    max_new_tokens: int
    arrived_step: int = 0
    generated: int = 0
    slot: int | None = None

    @property
    def done(self) -> bool:
        return self.generated >= self.max_new_tokens

    @property
    def position(self) -> int:
        return self.prompt_len + self.generated


@dataclass
class BatchScheduler:
    n_slots: int
    max_seq: int
    preempt_after: int = 1024
    max_wait_steps: int = 64

    queue: deque = field(default_factory=deque)
    active: dict[int, Request] = field(default_factory=dict)  # slot -> req
    step: int = 0
    completed: list[int] = field(default_factory=list)
    preempted: int = 0

    # -- admission -----------------------------------------------------------

    def submit(self, req: Request):
        req.arrived_step = self.step
        if req.prompt_len + req.max_new_tokens > self.max_seq:
            raise ValueError(
                f"request {req.rid} needs {req.prompt_len + req.max_new_tokens}"
                f" > max_seq {self.max_seq}"
            )
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [s for s in range(self.n_slots) if s not in self.active]

    def admit(self) -> list[Request]:
        """Fill free slots FIFO; anti-starvation: preempt for requests that
        waited beyond max_wait_steps when no slot frees up naturally."""
        admitted = []
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            req.slot = slot
            self.active[slot] = req
            admitted.append(req)
        if self.queue and not self._free_slots():
            head = self.queue[0]
            if self.step - head.arrived_step > self.max_wait_steps:
                victim = max(
                    (r for r in self.active.values()
                     if r.generated >= self.preempt_after),
                    key=lambda r: r.generated,
                    default=None,
                )
                if victim is not None:
                    self._preempt(victim)
                    head = self.queue.popleft()
                    head.slot = victim.slot if victim.slot is not None else (
                        self._free_slots()[0]
                    )
                    # victim.slot was freed by _preempt
                    head.slot = self._free_slots()[0]
                    self.active[head.slot] = head
                    admitted.append(head)
        return admitted

    def _preempt(self, req: Request):
        assert req.slot is not None
        del self.active[req.slot]
        req.slot = None
        req.generated = 0  # deterministic recompute on re-admission
        self.preempted += 1
        self.queue.append(req)

    # -- decode loop ---------------------------------------------------------

    def tick(self) -> dict[int, int]:
        """One decode step: returns {slot: position} for the active cohort,
        advances generation counters, retires finished requests."""
        self.step += 1
        cohort = {s: r.position for s, r in self.active.items()}
        finished = []
        for s, r in self.active.items():
            r.generated += 1
            if r.done:
                finished.append(s)
        for s in finished:
            self.completed.append(self.active[s].rid)
            del self.active[s]
        return cohort

    @property
    def utilization(self) -> float:
        return len(self.active) / self.n_slots if self.n_slots else 0.0
