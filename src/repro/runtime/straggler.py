"""Straggler mitigation: step-time outlier detection + mitigation plan.

Synchronous SPMD training runs at the speed of the slowest participant.  The
detector keeps an EWMA + variance of per-host step times and flags hosts
whose time exceeds ``mean + k * std`` for ``patience`` consecutive steps.
Mitigations, in escalation order:

1. ``rebalance_input``  — shift data-loading work off the slow host (the
   deterministic pipeline makes shard reassignment trivial);
2. ``exclude_next_rescale`` — mark the host so the next elastic event
   (checkpoint boundary) drops it, rather than paying a mid-step stop;
3. ``immediate_restart``  — only when the slowdown exceeds ``hard_ratio``x
   the fleet mean (e.g. a flapping HBM), worth the restart cost.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class StragglerDetector:
    n_hosts: int
    alpha: float = 0.1  # EWMA factor
    k: float = 3.0  # flag threshold in stddevs
    patience: int = 5
    hard_ratio: float = 2.0

    mean: list[float] = field(default_factory=list)
    var: list[float] = field(default_factory=list)
    strikes: list[int] = field(default_factory=list)

    def __post_init__(self):
        self.mean = [0.0] * self.n_hosts
        self.var = [0.0] * self.n_hosts
        self.strikes = [0] * self.n_hosts
        self._warm = [False] * self.n_hosts

    def observe(self, step_times: list[float]) -> dict[int, str]:
        """Feed per-host step times; returns {host: mitigation} decisions."""
        fleet_mean = sum(step_times) / len(step_times)
        decisions: dict[int, str] = {}
        for h, t in enumerate(step_times):
            if not self._warm[h]:
                self.mean[h], self.var[h], self._warm[h] = t, 0.0, True
                continue
            # compare against the PRE-update baseline, and keep flagged
            # samples out of the EWMA — a straggler must not normalize its
            # own slowness into the baseline
            std = max(self.var[h] ** 0.5, 0.02 * self.mean[h], 1e-6)
            slow = t > self.mean[h] + self.k * std and t > fleet_mean * 1.2
            if not slow:
                d = t - self.mean[h]
                self.mean[h] += self.alpha * d
                self.var[h] = (1 - self.alpha) * (self.var[h] + self.alpha * d * d)
            self.strikes[h] = self.strikes[h] + 1 if slow else 0
            if t > fleet_mean * self.hard_ratio and self.strikes[h] >= self.patience:
                decisions[h] = "immediate_restart"
            elif self.strikes[h] >= self.patience:
                decisions[h] = "exclude_next_rescale"
            elif self.strikes[h] == max(self.patience // 2, 1):
                decisions[h] = "rebalance_input"
        return decisions
