"""Shared test fixtures and optional-dependency shims.

``hypothesis`` is a dev-only dependency (declared in requirements-dev.txt /
pyproject's ``dev`` extra).  When it is absent, importing any property-test
module used to error the *entire* collection.  Instead, install a minimal
stub into ``sys.modules`` before collection: modules import cleanly, and
every ``@given``-decorated test skips with a clear reason while the plain
tests in the same files still run.
"""

from __future__ import annotations

import sys
import types

import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:

    class _AnyStrategy:
        """Stands in for strategy builders: any call or attribute access
        returns itself, so composed expressions like
        ``st.lists(st.integers(0, 9), min_size=1)`` trace through."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    _any = _AnyStrategy()

    def _given(*args, **kwargs):
        def deco(fn):
            def skipped(*a, **k):
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")

            skipped.__name__ = getattr(fn, "__name__", "hypothesis_test")
            skipped.__doc__ = fn.__doc__
            return skipped

        return deco

    def _settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    _mod = types.ModuleType("hypothesis")
    _mod.given = _given
    _mod.settings = _settings
    _mod.assume = lambda *a, **k: True
    _mod.note = lambda *a, **k: None
    _mod.HealthCheck = _any
    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _any  # PEP 562 module fallback
    _mod.strategies = _st
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _st
