"""Output-channel-tiled fusion grid (the ``c_tiles`` PR):

* bitwise parity — the channel-tiled ``(B, alpha, alpha, c_tiles)`` grid
  must be bit-identical to the untiled ``c_tiles=1`` path across Q=1/3/4,
  resident and streamed weights, both ``w_slots`` regimes, both ``x_slots``
  regimes, the END cascade (all-dead and mixed live/dead tiles), ``alpha ==
  1`` grids, and the ``weights=None`` pre-flattened streamed API;
* the planner ladder — ResNet-18 b7 (whose two 9.4 MB weight levels bust
  double-buffered streaming untiled) now lands on the channel-tiled
  ``streamed w_slots=2`` rung with ``pipeline_cycles_saved > 0`` at ``alpha
  == 1``, the regime PR 4's cross-cell prefetch could not touch;
* the k-axis cost model — ``channel_tiled_body_cycles`` fill/steady/drain
  timeline, the ds1 mid/last compute split, HBM-traffic invariance of
  channel tiling, and VMEM accounting of the slice slots;
* zoo-wide feasibility — ``plan_launch`` never returns a plan whose
  ``vmem_bytes()`` exceeds the budget it was given (hypothesis sweep over
  random budgets plus the default-budget zoo);
* the hypothesis regime sweep — random Q in 1..4 pyramids, random
  ``(x_slots, w_slots, c_tiles)``, bitwise equal to the resident untiled
  serial path;
* the ``weights_flat`` + ``stream_weights=False`` ValueError (previously
  silently ignored).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cnn_models import (
    ALEXNET_FUSION,
    LENET5_FUSION,
    VGG_FUSION,
    resnet18_fusions,
)
from repro.core.cycle_model import (
    channel_tiled_body_cycles,
    ds1_cycles_per_movement,
    ds1_split_cycles_per_movement,
)
from repro.core.executor import init_pyramid_params
from repro.core.fusion import FusedLevel, FusionSpec
from repro.core.program import (
    VMEM_BUDGET_BYTES,
    compile_program,
    plan_launch,
)
from repro.kernels.fused_conv.ops import flatten_weights, fused_pyramid
from repro.net.graph import lenet5
from repro.net.partition import auto_partition
from repro.net.runner import (
    init_network_params,
    prepare_network_params,
    run_network,
)

KEY = jax.random.PRNGKey(0)

VGG_SMALL = dataclasses.replace(VGG_FUSION, input_size=32)

Q1_CHAIN = FusionSpec(
    levels=(FusedLevel("conv", K=3, S=1, pad=1, n_in=3, n_out=8),),
    input_size=12,
)

# conv+pool, conv, conv — the odd-Q chain of the dataflow suites
Q3_CHAIN = FusionSpec(
    levels=(
        FusedLevel("conv", K=3, S=1, pad=1, n_in=2, n_out=6),
        FusedLevel("pool", K=2, S=2, pad=0, n_in=6, n_out=6),
        FusedLevel("conv", K=3, S=1, pad=1, n_in=6, n_out=8),
        FusedLevel("conv", K=3, S=1, pad=0, n_in=8, n_out=4),
    ),
    input_size=20,
)

ZOO_SPECS = {
    "lenet": LENET5_FUSION,
    "alexnet": ALEXNET_FUSION,
    "vgg_blocks12": VGG_FUSION,
    **{f"resnet18_b{i}": s for i, s in enumerate(resnet18_fusions())},
}


def _inputs(spec, batch=1, seed=1):
    return jax.random.normal(
        jax.random.PRNGKey(seed),
        (batch, spec.input_size, spec.input_size, spec.levels[0].n_in),
    )


def _run(spec, x, region, *, biases=None, **kw):
    p = init_pyramid_params(spec, KEY)
    return fused_pyramid(
        x, p.weights, biases if biases is not None else p.biases, spec=spec,
        out_region=region, **kw,
    )


@pytest.mark.slow
class TestChannelTiledParity:
    """c_tiles > 1 must be bit-identical to the untiled path — same MXU
    inputs per channel block, only the movement schedule differs."""

    CASES = {
        "q1": (Q1_CHAIN, 3, 2),
        "q3": (Q3_CHAIN, 4, 2),
        "q4_vgg": (VGG_SMALL, 4, 4),
    }

    @pytest.mark.parametrize("name", sorted(CASES))
    @pytest.mark.parametrize("streamed", [False, True])
    @pytest.mark.parametrize("w_slots", [1, 2])
    def test_tiled_matches_untiled_bitwise(self, name, streamed, w_slots):
        spec, region, ct = self.CASES[name]
        x = _inputs(spec, batch=2)
        y0, s0 = _run(spec, x, region, x_slots=1)
        y1, s1 = _run(
            spec, x, region, x_slots=2, streamed=streamed,
            w_slots=w_slots if streamed else None, c_tiles=ct,
        )
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y0))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s0))

    def test_finest_legal_tiling(self):
        """c_tiles == Cout/2: the finest legal slicing (two channels per k;
        one-channel slices are excluded — the degenerate one-column dot
        reassociates and would break bit parity)."""
        spec, region = Q1_CHAIN, 3
        ct = compile_program(spec, region).c_tile_options()[-1]
        assert ct == spec.levels[-1].n_out // 2
        x = _inputs(spec)
        y0, s0 = _run(spec, x, region, x_slots=1)
        y1, s1 = _run(spec, x, region, streamed=True, w_slots=2, c_tiles=ct)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y0))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s0))

    def test_one_channel_slices_rejected(self):
        with pytest.raises(AssertionError, match=">= 2 channels"):
            _run(Q1_CHAIN, _inputs(Q1_CHAIN), 3, streamed=True, w_slots=2,
                 c_tiles=8)

    def test_alpha1_grid(self):
        """alpha == 1 + c_tiles > 1: the k axis is the only multi-step grid
        dimension — exactly the launches channel tiling exists for."""
        spec = LENET5_FUSION
        out_size = spec.feature_sizes()[-1]
        assert compile_program(spec, out_size).alpha == 1
        x = _inputs(spec, batch=2)
        y0, s0 = _run(spec, x, out_size, x_slots=1)
        y1, s1 = _run(
            spec, x, out_size, streamed=True, w_slots=2, c_tiles=4
        )
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y0))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s0))

    def test_end_cascade_all_dead(self):
        """All-zero input with non-positive biases: every level >= 1 of every
        cell skips; the per-k slice fetches drain unconditionally and the
        flag vector (written once at k == 0) must match the untiled path."""
        spec = VGG_SMALL
        p = init_pyramid_params(spec, KEY)
        bs = [b - 10.0 for b in p.biases]
        x = jnp.zeros((2, spec.input_size, spec.input_size, 3))
        y0, s0 = _run(spec, x, 4, biases=bs, x_slots=1)
        y1, s1 = _run(
            spec, x, 4, biases=bs, x_slots=2, streamed=True, w_slots=2,
            c_tiles=4,
        )
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y0))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s0))
        assert (np.asarray(s1)[..., 1:] == 1).all(), "cascade must skip all"

    def test_end_cascade_mixed_live_dead(self):
        """Sparse input mixes live and dead tiles per cell: the last level's
        k-invariant liveness predicate must agree with the untiled flags."""
        spec = LENET5_FUSION
        p = init_pyramid_params(spec, KEY)
        bs = [p.biases[0] - 0.5, p.biases[1] + 0.3]
        blob = spec.input_size // 3
        x = jnp.zeros(
            (1, spec.input_size, spec.input_size, 1)
        ).at[:, :blob, :blob, :].set(5.0)
        y0, s0 = fused_pyramid(
            x, p.weights, bs, spec=spec, out_region=1, x_slots=1
        )
        y1, s1 = fused_pyramid(
            x, p.weights, bs, spec=spec, out_region=1, streamed=True,
            w_slots=2, c_tiles=2,
        )
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y0))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s0))
        frac = float(np.asarray(s0)[..., 1].mean())
        assert 0.0 < frac < 1.0, "test needs mixed live/dead tiles"

    def test_weights_none_preflattened(self):
        """Streamed channel-tiled launches recover the last level's 4D
        tensor from the flat array when only weights_flat is supplied."""
        spec = Q3_CHAIN
        p = init_pyramid_params(spec, KEY)
        x = _inputs(spec)
        y0, s0 = fused_pyramid(
            x, p.weights, p.biases, spec=spec, out_region=4, x_slots=1
        )
        y1, s1 = fused_pyramid(
            x, None, p.biases, spec=spec, out_region=4, streamed=True,
            w_slots=2, c_tiles=2, weights_flat=flatten_weights(p.weights),
        )
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y0))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s0))

    def test_run_network_with_channel_tiled_plan(self):
        """The runner threads c_tiles from the plan: a LeNet plan pinned to
        the channel-tiled streamed regime is bit-identical end to end."""
        graph = lenet5()
        plan = auto_partition(graph)
        tiled = dataclasses.replace(
            plan,
            pyramids=tuple(
                dataclasses.replace(
                    p,
                    launch=dataclasses.replace(
                        p.launch, streamed=True, w_slots=2,
                        c_tiles=p.launch.program.c_tile_options()[0],
                    ),
                )
                for p in plan.pyramids
            ),
        )
        params = init_network_params(graph, KEY)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 32, 1))
        y0, _ = run_network(x, params, plan=plan)
        y1, _ = run_network(
            x, prepare_network_params(tiled, params), plan=tiled
        )
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y0))


@st.composite
def conv_chain(draw):
    """Random conv(/pool) pyramid, Q in 1..4 convs, sized for interpret-mode
    kernel launches (small spatial dims, composite channel counts so the
    last level has nontrivial Cout divisors)."""
    size = draw(st.integers(10, 18))
    q = draw(st.integers(1, 4))
    levels = []
    c = draw(st.integers(1, 3))
    cur = size
    for qi in range(q):
        K = draw(st.integers(1, 3))
        S = draw(st.integers(1, 2))
        pad = draw(st.integers(0, max(0, K // 2)))
        nxt = (cur + 2 * pad - K) // S + 1
        if nxt < 2:
            break
        c2 = draw(st.sampled_from([2, 4, 6, 8]))
        levels.append(FusedLevel("conv", K, S, pad, c, c2))
        c, cur = c2, nxt
        if cur >= 4 and draw(st.booleans()):
            levels.append(FusedLevel("pool", 2, 2, 0, c, c))
            cur = (cur - 2) // 2 + 1
    if not levels:
        levels = [FusedLevel("conv", 3, 1, 1, c, 4)]
    return FusionSpec(levels=tuple(levels), input_size=size)


@pytest.mark.slow
class TestRegimeSweepProperty:
    @given(
        conv_chain(),
        st.integers(1, 2),  # x_slots
        st.integers(1, 2),  # w_slots
        st.integers(0, 3),  # c_tiles divisor index
        st.integers(0, 50),
    )
    @settings(max_examples=10, deadline=None)
    def test_any_regime_matches_resident_untiled(
        self, spec, x_slots, w_slots, ct_idx, seed
    ):
        """THE parity invariant of the channel-tiled grid: every
        (x_slots, w_slots, c_tiles) combination computes bitwise what the
        resident untiled serial kernel computes."""
        out_size = spec.feature_sizes()[-1]
        if out_size < 1:
            return
        region = next(r for r in range(2, 0, -1) if out_size % r == 0)
        divisors = (1,) + compile_program(spec, region).c_tile_options()
        c_tiles = divisors[min(ct_idx, len(divisors) - 1)]
        params = init_pyramid_params(spec, jax.random.PRNGKey(seed))
        x = jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (1, spec.input_size, spec.input_size, spec.levels[0].n_in),
        )
        y0, s0 = fused_pyramid(
            x, params.weights, params.biases, spec=spec, out_region=region,
            x_slots=1,
        )
        y1, s1 = fused_pyramid(
            x, params.weights, params.biases, spec=spec, out_region=region,
            x_slots=x_slots, streamed=True, w_slots=w_slots, c_tiles=c_tiles,
        )
        np.testing.assert_array_equal(
            np.asarray(y1), np.asarray(y0),
            err_msg=f"spec={spec} region={region} x={x_slots} w={w_slots}"
                    f" ct={c_tiles}",
        )
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s0))


class TestPlannerLadder:
    def test_b7_selects_channel_tiled_double_buffer(self):
        """Acceptance: ResNet-18 b7 — untiled double-buffered streaming
        busts VMEM, so the ladder lands on channel-tiled w_slots=2, and the
        k-axis pipeline saves cycles at alpha == 1 (the launch PR 4's
        cross-cell prefetch could not touch)."""
        lp = plan_launch(resnet18_fusions()[7])
        assert lp.streamed and lp.w_slots == 2 and lp.c_tiles > 1
        assert lp.program.alpha == 1 and lp.x_slots == 1
        prog = lp.program
        assert prog.vmem_stream_bytes(2) > VMEM_BUDGET_BYTES
        assert (
            prog.vmem_stream_bytes(2, 1, lp.c_tiles) <= VMEM_BUDGET_BYTES
        )
        # coarsest feasible slicing: no smaller c_tiles fits two slots
        for ct in prog.c_tile_options():
            if ct >= lp.c_tiles:
                break
            assert prog.vmem_stream_bytes(2, 1, ct) > VMEM_BUDGET_BYTES
        blocking = dataclasses.replace(lp, x_slots=1, w_slots=1)
        assert lp.modeled_cycles() < blocking.modeled_cycles()
        untiled_w1 = dataclasses.replace(lp, w_slots=1, c_tiles=1)
        assert lp.modeled_cycles() <= untiled_w1.modeled_cycles()

    def test_pinned_w_slots_adopts_feasible_c_tiles(self):
        """A caller pinning only w_slots=2 on a spec whose untiled double
        buffer busts VMEM must land on the planner's channel-tiled rung
        instead of dying on the working-set assert (resolve_stream_regime
        is the single rung-order source shared with plan_launch)."""
        prog = plan_launch(resnet18_fusions()[7]).program
        ws, ct = prog.resolve_stream_regime(VMEM_BUDGET_BYTES, 1, 2, None)
        assert ws == 2 and ct > 1
        assert prog.vmem_stream_bytes(ws, 1, ct) <= VMEM_BUDGET_BYTES
        # fully-open knobs reproduce plan_launch's own choice
        lp = plan_launch(resnet18_fusions()[7])
        assert prog.resolve_stream_regime(VMEM_BUDGET_BYTES, 1) == (
            lp.w_slots, lp.c_tiles,
        )
        # pinned values pass through untouched
        assert prog.resolve_stream_regime(VMEM_BUDGET_BYTES, 1, 1, 8) == (1, 8)

    def test_vmem_model_counts_mid_scratch(self):
        """The channel-tiled kernel carries a persistent mid-pyramid scratch
        for Q > 1 (live alongside the transient mid tile at k == 0); the
        byte models must charge it so a near-budget plan cannot overflow
        real VMEM."""
        prog = plan_launch(resnet18_fusions()[7]).program
        last = prog.levels[-1]
        carry = 4 * last.in_size ** 2 * last.n_in
        untiled_tiles = prog.vmem_bytes(1) - 4 * prog.weight_floats()
        tiled_tiles = prog.vmem_bytes(1, 2) - 4 * prog.weight_floats()
        shrunk_out = 4 * (
            last.out_size ** 2 * (last.n_out - last.n_out // 2)
        )
        assert tiled_tiles == untiled_tiles - shrunk_out + carry
        # Q=1 chains have no mid pyramid to carry
        prog1 = compile_program(Q1_CHAIN, 3)
        assert prog1.vmem_bytes(1, 2) < prog1.vmem_bytes(1)

    def test_untiled_double_buffer_still_preferred_when_it_fits(self):
        """The channel-tiled rung sits below plain w_slots=2: chains whose
        two largest-level copies fit keep c_tiles == 1."""
        for spec in (VGG_FUSION, resnet18_fusions()[0]):
            lp = plan_launch(spec)
            if lp.streamed and lp.w_slots == 2:
                assert lp.c_tiles == 1

    def test_c_tile_options_are_divisors_with_two_channel_floor(self):
        prog = plan_launch(Q3_CHAIN).program
        n_out = Q3_CHAIN.levels[-1].n_out
        assert prog.c_tile_options() == tuple(
            c for c in range(2, n_out // 2 + 1) if n_out % c == 0
        )
        assert all(n_out // c >= 2 for c in prog.c_tile_options())

    def test_regime_label(self):
        lp = plan_launch(resnet18_fusions()[7])
        assert lp.regime == f"streamed_w2_c{lp.c_tiles}"
        assert dataclasses.replace(lp, streamed=False).regime == "resident"
        assert (
            dataclasses.replace(lp, w_slots=1, c_tiles=1).regime
            == "streamed_w1"
        )

    @pytest.mark.parametrize("name", sorted(ZOO_SPECS))
    def test_zoo_plans_respect_default_budget(self, name):
        """Zoo-wide acceptance: plan_launch never hands out a plan whose
        own VMEM accounting exceeds the budget it was given."""
        lp = plan_launch(ZOO_SPECS[name])
        assert lp is not None
        assert lp.vmem_bytes() <= VMEM_BUDGET_BYTES

    @given(st.sampled_from(sorted(ZOO_SPECS)), st.integers(14, 24))
    @settings(max_examples=40, deadline=None)
    def test_zoo_plans_respect_any_budget(self, name, budget_log2):
        """The same invariant under random budgets from 16 KiB to 16 MiB:
        every returned plan fits, across every ladder rung."""
        budget = 1 << budget_log2
        lp = plan_launch(ZOO_SPECS[name], vmem_budget=budget)
        if lp is not None:
            assert lp.vmem_bytes() <= budget


class TestChannelTiledCostModel:
    def test_body_timeline_phases(self):
        """Blocking pays every slice fetch; pipelined exposes only the fill
        behind the mid pyramid and the steady-state max."""
        # compute_mid=10, compute_last=40, dma_mid=5, dma_slice=7, ct=4
        assert channel_tiled_body_cycles(
            10, 40, 5, 7, 4, pipelined=False
        ) == 5 + 10 + 4 * (7 + 10)
        assert channel_tiled_body_cycles(
            10, 40, 5, 7, 4, pipelined=True
        ) == 5 + max(10, 7) + 10 + 3 * max(10, 7)

    def test_pipelined_saving_is_min_terms(self):
        for cm, cl, dm, dk, ct in [(10, 40, 5, 7, 4), (3, 100, 0, 50, 2),
                                   (0, 8, 9, 1, 8)]:
            serial = channel_tiled_body_cycles(cm, cl, dm, dk, ct,
                                               pipelined=False)
            pipe = channel_tiled_body_cycles(cm, cl, dm, dk, ct,
                                             pipelined=True)
            ck = -(-cl // ct)
            assert serial - pipe == min(cm, dk) + (ct - 1) * min(ck, dk)
            assert pipe <= serial

    @pytest.mark.parametrize("name", sorted(ZOO_SPECS))
    def test_ds1_split_sums_to_total(self, name):
        spec = ZOO_SPECS[name]
        mid, last = ds1_split_cycles_per_movement(spec)
        assert mid + last == ds1_cycles_per_movement(spec)
        assert last > 0
        if spec.q_convs == 1:
            assert mid == 0

    def test_hbm_traffic_invariant_under_tiling(self):
        """Channel tiling re-schedules weight movement, it never adds HBM
        traffic: each k reads 1/c_tiles of the slice across c_tiles steps."""
        lp = plan_launch(resnet18_fusions()[7])
        prog = lp.program
        for ct in (1, 2, 4, 8):
            assert prog.hbm_bytes(2, streamed=True, c_tiles=ct) == \
                prog.hbm_bytes(2, streamed=True)
        untiled = dataclasses.replace(lp, w_slots=1, c_tiles=1)
        assert lp.hbm_bytes(4) == untiled.hbm_bytes(4)

    def test_vmem_slice_accounting(self):
        """Among channel-tiled options vmem_stream_bytes shrinks
        monotonically in c_tiles (smaller slice slots + smaller last-level
        working tile; the mid-scratch carry is c_tiles-invariant), and
        slice_bytes is the per-k DMA granule.  (No monotonicity across the
        1 -> 2 boundary: tiling swaps the shared revolving slots for a
        blocking mid slot + sliced slots + the carry, which can exceed the
        untiled set when the mid level rivals the last — the ladder relies
        on feasibility only.)"""
        prog = plan_launch(resnet18_fusions()[7]).program
        opts = prog.c_tile_options()
        sizes = [prog.vmem_stream_bytes(2, 1, ct) for ct in opts]
        assert all(a >= b for a, b in zip(sizes, sizes[1:]))
        lp = plan_launch(resnet18_fusions()[7])
        cnt = prog.level_weight_counts()[-1]
        assert lp.slice_bytes() == 4 * cnt // lp.c_tiles
        assert dataclasses.replace(lp, streamed=False).slice_bytes() == 0

    def test_partition_dp_consumes_channel_tiled_cost(self):
        """The DP's plan objects carry c_tiles and their summed cycle model
        matches the per-launch channel-tiled bodies."""
        from repro.net.graph import MODELS

        plan = auto_partition(MODELS["resnet18"]())
        tiled = [p for p in plan.pyramids if p.launch.c_tiles > 1]
        assert tiled, "resnet18's b7 pyramid should be channel-tiled"
        assert plan.modeled_cycles() == sum(
            p.launch.modeled_cycles(plan.batch) for p in plan.pyramids
        )
        assert "streamed_w2_c" in plan.summary()


class TestWeightsFlatValueError:
    def test_resident_launch_rejects_weights_flat(self):
        """stream_weights=False used to silently drop weights_flat; it now
        raises so plan/caller disagreements surface immediately."""
        spec = LENET5_FUSION
        p = init_pyramid_params(spec, KEY)
        x = _inputs(spec)
        with pytest.raises(ValueError, match="stream_weights=False"):
            fused_pyramid(
                x, p.weights, p.biases, spec=spec, out_region=1,
                streamed=False, weights_flat=flatten_weights(p.weights),
            )

    def test_streamed_launch_still_accepts_weights_flat(self):
        spec = LENET5_FUSION
        p = init_pyramid_params(spec, KEY)
        x = _inputs(spec)
        y0, _ = fused_pyramid(
            x, p.weights, p.biases, spec=spec, out_region=1, streamed=True
        )
        y1, _ = fused_pyramid(
            x, p.weights, p.biases, spec=spec, out_region=1, streamed=True,
            weights_flat=flatten_weights(p.weights),
        )
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y0))
