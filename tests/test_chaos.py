"""Chaos suite: seeded fault injection proving the degradation ladder.

Every fault class the guarded runtime claims to absorb is injected here
deterministically (``repro.robust.faults``) against LeNet and a reduced
ResNet-18, and every case must terminate at a successful forward whose
logits match the reference oracle — with the rung that fired visible in
the :class:`RunReport` and, when a tracer is installed, as ``"degrade"``
trace events.  This is the acceptance test of DESIGN.md §13: no fault
class may escape as a crash or as silently wrong logits.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.net.graph import MODELS
from repro.net.partition import auto_partition
from repro.net.runner import (
    init_network_params,
    prepare_network_params,
    reference_network,
    run_network,
)
from repro.obs import tracing
from repro.robust import (
    GuardConfig,
    NumericError,
    corrupt_params,
    guarding,
    inject,
)

# LeNet's single fused pyramid: 50 kB resident.  These factors of the
# 16 MiB budget bracket the replan rung: GENTLE leaves ~33 kB (the fused
# launch fails, the layerwise split fits), HARSH leaves ~1.7 kB (nothing
# fits, the ladder must bottom out at the reference path).
SQUEEZE_GENTLE = 0.002
SQUEEZE_HARSH = 0.0001


def _setup(model):
    if model == "lenet":
        g = MODELS["lenet"]()
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 1))
    else:
        g = MODELS["resnet18"](input_size=32, num_classes=10)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3))
    params = init_network_params(g, jax.random.PRNGKey(0))
    plan = auto_partition(g, batch=x.shape[0])
    prepped = prepare_network_params(plan, params)
    ref = reference_network(x, g, params)
    return g, x, params, plan, prepped, ref


@pytest.fixture(scope="module")
def lenet():
    return _setup("lenet")


@pytest.fixture(scope="module")
def resnet():
    return _setup("resnet18")


def _assert_correct(y, ref, tag=""):
    err = float(jnp.max(jnp.abs(y - ref)))
    assert err < 1e-4, f"{tag}: logits diverge from reference by {err}"


class TestWeightCorruption:
    @pytest.mark.parametrize("kind", ["nan", "inf"])
    def test_corrupt_weights_healed_from_source(self, lenet, kind):
        g, x, params, plan, prepped, ref = lenet
        bad = corrupt_params(prepped, "CL1", kind=kind, seed=3)
        with guarding(GuardConfig(), source_params=params) as guard:
            y, _ = run_network(x, bad, plan=plan)
        _assert_correct(y, ref, f"heal-{kind}")
        rep = guard.last_report
        assert rep.fallback_counts() == {"heal": 1}
        assert rep.events[0].detail["nodes"] == ["CL1"]

    def test_corrupt_weights_without_source_raise(self, lenet):
        g, x, params, plan, prepped, ref = lenet
        bad = corrupt_params(prepped, "CL2", kind="nan", seed=3)
        with guarding(GuardConfig()):
            with pytest.raises(NumericError) as ei:
                run_network(x, bad, plan=plan)
        assert ei.value.context["nodes"] == ["CL2"]

    def test_corrupt_source_too_raises(self, lenet):
        """Healing is bounded: when the master copy is corrupt as well, the
        run must fail loudly, not loop."""
        g, x, params, plan, prepped, ref = lenet
        bad_prep = corrupt_params(prepped, "CL1", seed=3)
        bad_src = corrupt_params(params, "CL1", seed=3)
        with guarding(GuardConfig(), source_params=bad_src):
            with pytest.raises(NumericError, match="master copy"):
                run_network(x, bad_prep, plan=plan)

    def test_corruption_is_deterministic(self, lenet):
        g, x, params, plan, prepped, ref = lenet
        a = corrupt_params(prepped, "CL1", kind="nan", seed=7)
        b = corrupt_params(prepped, "CL1", kind="nan", seed=7)
        np.testing.assert_array_equal(
            np.isnan(np.asarray(a["CL1"][0], dtype=np.float32)),
            np.isnan(np.asarray(b["CL1"][0], dtype=np.float32)),
        )


class TestOutputPoisoning:
    @pytest.mark.parametrize("kind", ["nan", "inf"])
    def test_poisoned_launch_quarantined(self, lenet, kind):
        """A kernel miscompute (poisoned launch output) trips the numeric
        sentinel; the launch is quarantined to the reference segment and
        the logits stay correct."""
        g, x, params, plan, prepped, ref = lenet
        with guarding(GuardConfig(), source_params=params) as guard:
            with inject(seed=0) as inj:
                inj.poison_output(kind=kind)
                y, skips = run_network(x, prepped, plan=plan)
        _assert_correct(y, ref, f"poison-{kind}")
        rep = guard.last_report
        assert rep.fallback_counts() == {"reference": 1}
        assert "sentinel tripped: non-finite" in rep.events[0].reason
        # the fault did not reproduce on the reference walk: kernel-only
        assert rep.events[0].detail["level"] == "kernel-only"
        # quarantined launches report a neutral zero skip map
        q = plan.pyramids[0]
        assert np.asarray(skips[q.name]).sum() == 0

    def test_magnitude_sentinel(self, lenet):
        """A tight magnitude limit quarantines a launch whose output is
        finite but implausibly large — here the 'blow-up' is the injected
        Inf replaced by the limit check on a clean output."""
        g, x, params, plan, prepped, ref = lenet
        with guarding(
            GuardConfig(magnitude_limit=1e-6), source_params=params
        ) as guard:
            with pytest.raises(NumericError, match="even on the reference"):
                # every real activation exceeds 1e-6, and so does the
                # reference recompute: the fault is localized to a level
                # and surfaced, not swallowed
                run_network(x, prepped, plan=plan)
        rep = guard.last_report  # report not stored on raise
        assert rep is None

    def test_poison_specific_resnet_launch(self, resnet):
        g, x, params, plan, prepped, ref = resnet
        target = plan.pyramids[3].name
        with guarding(GuardConfig(), source_params=params) as guard:
            with inject(seed=0) as inj:
                inj.poison_output(launch=target, kind="nan")
                y, _ = run_network(x, prepped, plan=plan)
        _assert_correct(y, ref, "resnet-poison")
        rep = guard.last_report
        assert rep.fallback_counts() == {"reference": 1}
        assert rep.events[0].launch == target
        assert rep.clean_launches == plan.n_launches() - 1


class TestBudgetSqueeze:
    def test_squeeze_replans_to_chained_launches(self, lenet):
        g, x, params, plan, prepped, ref = lenet
        with guarding(GuardConfig(), source_params=params) as guard:
            with inject(seed=0) as inj:
                inj.squeeze_budget(SQUEEZE_GENTLE)
                y, _ = run_network(x, prepped, plan=plan)
        _assert_correct(y, ref, "squeeze")
        rep = guard.last_report
        assert rep.fallback_counts() == {"replan": 1}
        ev = rep.events[0]
        assert len(ev.detail["sub_launches"]) >= 2  # tighter cuts: a chain
        assert ev.detail["budget"] <= int(
            plan.vmem_budget * SQUEEZE_GENTLE
        )

    def test_harsh_squeeze_bottoms_out_at_reference(self, lenet):
        g, x, params, plan, prepped, ref = lenet
        cfg = GuardConfig(max_replans=2)
        with guarding(cfg, source_params=params) as guard:
            with inject(seed=0) as inj:
                inj.squeeze_budget(SQUEEZE_HARSH)
                y, _ = run_network(x, prepped, plan=plan)
        _assert_correct(y, ref, "squeeze-harsh")
        rep = guard.last_report
        assert rep.fallback_counts() == {"reference": 1}
        assert "replan exhausted" in rep.events[0].reason

    def test_squeeze_resnet(self, resnet):
        """The multi-pyramid plan degrades only the launches that no longer
        fit; everything else stays on the fast path."""
        g, x, params, plan, prepped, ref = resnet
        vmems = sorted(p.launch.vmem_bytes() for p in plan.pyramids)
        # squeeze to just under the largest working set: only the biggest
        # launch(es) go over budget (next-largest distinct size still fits)
        below = [v for v in vmems if v < vmems[-1]]
        target = (vmems[-1] + (below[-1] if below else 0)) // 2
        factor = target / plan.vmem_budget
        effective = int(plan.vmem_budget * factor)
        n_over = sum(1 for v in vmems if v > effective)
        assert 1 <= n_over < len(vmems)
        with guarding(GuardConfig(), source_params=params) as guard:
            with inject(seed=0) as inj:
                inj.squeeze_budget(factor)
                y, _ = run_network(x, prepped, plan=plan)
        _assert_correct(y, ref, "resnet-squeeze")
        rep = guard.last_report
        degraded = sum(rep.fallback_counts().values())
        assert degraded == n_over
        assert rep.clean_launches == plan.n_launches() - n_over


class TestStageFaults:
    def test_plan_fault_goes_to_reference(self, lenet):
        g, x, params, plan, prepped, ref = lenet
        with guarding(GuardConfig(), source_params=params) as guard:
            with inject(seed=0) as inj:
                inj.raise_at("plan")
                y, _ = run_network(x, prepped, plan=plan)
        _assert_correct(y, ref, "plan-fault")
        assert guard.last_report.fallback_counts() == {"reference": 1}

    @pytest.mark.parametrize("stage", ["compile", "run"])
    def test_transient_fault_retries_interpret(self, lenet, stage):
        """A single-shot compile/run failure retries once with
        interpret=True and succeeds — the fused output still lands."""
        g, x, params, plan, prepped, ref = lenet
        with guarding(GuardConfig(), source_params=params) as guard:
            with inject(seed=0) as inj:
                inj.raise_at(stage)
                y, _ = run_network(x, prepped, plan=plan)
        _assert_correct(y, ref, f"{stage}-fault")
        rep = guard.last_report
        assert rep.fallback_counts() == {"interpret": 1}
        assert inj.fired == [(stage, plan.pyramids[0].name, "raise")]

    def test_persistent_fault_falls_to_reference(self, lenet):
        g, x, params, plan, prepped, ref = lenet
        with guarding(GuardConfig(), source_params=params) as guard:
            with inject(seed=0) as inj:
                inj.raise_at("run", times=4)
                y, _ = run_network(x, prepped, plan=plan)
        _assert_correct(y, ref, "persistent-fault")
        rep = guard.last_report
        assert rep.fallback_counts() == {"reference": 1}
        assert "interpret retry failed too" in rep.events[0].reason

    def test_resnet_stage_fault_on_named_launch(self, resnet):
        g, x, params, plan, prepped, ref = resnet
        target = plan.pyramids[5].name
        with guarding(GuardConfig(), source_params=params) as guard:
            with inject(seed=0) as inj:
                inj.raise_at("run", launch=target, times=4)
                y, _ = run_network(x, prepped, plan=plan)
        _assert_correct(y, ref, "resnet-stage-fault")
        rep = guard.last_report
        assert [e.launch for e in rep.events] == [target]


class TestObservability:
    def test_rungs_visible_as_trace_events(self, lenet):
        g, x, params, plan, prepped, ref = lenet
        with tracing() as collector:
            with guarding(GuardConfig(), source_params=params):
                with inject(seed=0) as inj:
                    inj.poison_output(kind="nan")
                    y, _ = run_network(x, prepped, plan=plan)
        _assert_correct(y, ref, "traced-poison")
        degrades = [e for e in collector.events if e.name == "degrade"]
        assert len(degrades) == 1
        assert degrades[0].args["rung"] == "reference"
        assert degrades[0].args["launch"] == plan.pyramids[0].name
        summary = [e for e in collector.events if e.name == "guarded_run"]
        assert summary and summary[0].args["fallbacks"] == {"reference": 1}

    def test_clean_guarded_run_emits_summary_only(self, lenet):
        g, x, params, plan, prepped, ref = lenet
        with tracing() as collector:
            with guarding(GuardConfig(), source_params=params):
                y, _ = run_network(x, prepped, plan=plan)
        _assert_correct(y, ref, "traced-clean")
        assert not [e for e in collector.events if e.name == "degrade"]
        summary = [e for e in collector.events if e.name == "guarded_run"]
        assert summary[0].args["clean_launches"] == plan.n_launches()


class TestGuardOffUnaffected:
    def test_injector_ignored_without_guard(self, lenet):
        """Armed faults are consumed only by the guarded runner: the plain
        jit path never consults the injector."""
        g, x, params, plan, prepped, ref = lenet
        base, _ = run_network(x, prepped, plan=plan)
        with inject(seed=0) as inj:
            inj.poison_output(kind="nan")
            inj.raise_at("run", times=99)
            y, _ = run_network(x, prepped, plan=plan)
        assert not inj.fired
        assert float(jnp.max(jnp.abs(y - base))) == 0.0

    def test_determinism_across_repeats(self, lenet):
        """Same seed, same faults, same rungs, same logits — twice."""
        g, x, params, plan, prepped, ref = lenet

        def once():
            with guarding(GuardConfig(), source_params=params) as guard:
                with inject(seed=5) as inj:
                    inj.poison_output(kind="inf")
                    inj.squeeze_budget(SQUEEZE_GENTLE)
                    y, _ = run_network(x, prepped, plan=plan)
            return np.asarray(y), guard.last_report.fallback_counts(), \
                list(inj.fired)

        y1, f1, log1 = once()
        y2, f2, log2 = once()
        np.testing.assert_array_equal(y1, y2)
        assert f1 == f2 and log1 == log2
