"""Cycle-model tests: Eqs. (3)-(4) against the paper's Tables 1-2."""

import pytest

from repro.core.cnn_models import (
    ALEXNET_FUSION,
    LENET5_FUSION,
    PAPER_OPS,
    VGG_FUSION,
)
from repro.core.cycle_model import (
    evaluate_design,
    single_layer_result,
)
from repro.core.fusion import plan_fusion


def _plan(name):
    spec = {"lenet": LENET5_FUSION, "alexnet": ALEXNET_FUSION, "vgg": VGG_FUSION}[name]
    region = {"lenet": 1, "alexnet": 1, "vgg": None}[name]
    return spec, plan_fusion(spec, out_region=region)


class TestDS1Exact:
    """Eq. (3) must reproduce Table 1 fused durations EXACTLY with the
    paper-consistent parameters n=8, delta_OLM=delta_OLA=2, MP=2."""

    @pytest.mark.parametrize(
        "net,paper_us",
        [("lenet", 13.75), ("alexnet", 63.99), ("vgg", 11.79)],
    )
    def test_fused_duration(self, net, paper_us):
        spec, plan = _plan(net)
        res = evaluate_design("ds1", spec, plan, PAPER_OPS[(net, "Fused")])
        assert res.duration_us == pytest.approx(paper_us, abs=1e-9)

    @pytest.mark.parametrize(
        "net,paper_us", [("alexnet", 29.97), ("vgg", 2.52)]
    )
    def test_conv1_rows(self, net, paper_us):
        spec, plan = _plan(net)
        res = single_layer_result("ds1", spec, plan, 0, PAPER_OPS[(net, "CONV1")])
        assert res.duration_us == pytest.approx(paper_us, abs=1e-9)

    def test_lenet_conv1_known_mismatch(self):
        """The paper's LeNet CONV1 row (5 us) is inconsistent with its own
        Eq. (3) under any MP>=0 (documented in EXPERIMENTS.md); our model
        gives 6.25 us.  Pin the value so regressions are visible."""
        spec, plan = _plan("lenet")
        res = single_layer_result("ds1", spec, plan, 0, PAPER_OPS[("lenet", "CONV1")])
        assert res.duration_us == pytest.approx(6.25, abs=1e-9)

    @pytest.mark.parametrize(
        "net,paper_gops",
        [("lenet", 86.10), ("alexnet", 5150.0), ("vgg", 799800.0)],
    )
    def test_fused_performance(self, net, paper_gops):
        """Eq. (2): ops / duration (paper lists LeNet in GOPS, others TOPS)."""
        spec, plan = _plan(net)
        res = evaluate_design("ds1", spec, plan, PAPER_OPS[(net, "Fused")])
        assert res.gops == pytest.approx(paper_gops, rel=0.01)


class TestDS2Close:
    """Eq. (4) reproduces Table 2 within ~2% (the residue is the paper's
    unstated Acc/MP accounting; see EXPERIMENTS.md)."""

    @pytest.mark.parametrize(
        "net,paper_us,tol",
        [("lenet", 128.25, 0.02), ("alexnet", 1210.0, 0.005), ("vgg", 39.40, 0.01)],
    )
    def test_fused_duration(self, net, paper_us, tol):
        spec, plan = _plan(net)
        res = evaluate_design("ds2", spec, plan, PAPER_OPS[(net, "Fused")])
        assert res.duration_us == pytest.approx(paper_us, rel=tol)


class TestBaselines:
    def test_conventional_model_pinned(self):
        """Documented divergence (EXPERIMENTS.md §Paper-tables): under our
        clean conventional model (pipelined 1-cycle adder-tree levels) the
        conventional spatial baseline is cycle-competitive with Eq. (3); the
        paper's measured baseline durations (e.g. LeNet 25.75us vs our
        model's ~8.25us) include RTL-level overheads it does not specify.
        Pin our model's ratios so regressions are visible."""
        spec, plan = _plan("lenet")
        conv = evaluate_design("baseline_spatial", spec, plan, 1)
        ds1 = evaluate_design("ds1", spec, plan, 1)
        assert conv.cycles == 25 * 33  # (8+5+0+2)+(8+5+3+2) per movement
        assert ds1.cycles == 25 * 55

    def test_online_with_end_beats_conventional(self):
        """The paper's realized advantage (Fig. 14): END terminates ~half of
        all SOP digit cycles early, which only the MSDF design can exploit.
        With the measured ~50% effective-cycle saving, DS-1+END must beat the
        conventional baseline on every network."""
        end_cycle_factor = 0.5  # reproduced independently in test_end_detect
        for net in ["lenet", "alexnet", "vgg"]:
            spec, plan = _plan(net)
            ds1 = evaluate_design("ds1", spec, plan, 1)
            conv = evaluate_design("baseline_spatial", spec, plan, 1)
            assert ds1.cycles * end_cycle_factor < conv.cycles

    def test_uniform_stride_beats_naive_stride(self):
        """Baselines 1-2 (tile stride = conv stride) pay quadratically more
        movements; uniform stride must win by >2x on every network."""
        for net in ["lenet", "alexnet", "vgg"]:
            spec, plan = _plan(net)
            uni = evaluate_design("ds1", spec, plan, 1, uniform_stride=True)
            naive = evaluate_design("ds1", spec, plan, 1, uniform_stride=False)
            assert naive.cycles / uni.cycles > 2.0

    def test_ds2_uses_fewer_units_more_cycles(self):
        for net in ["lenet", "alexnet", "vgg"]:
            spec, plan = _plan(net)
            ds1 = evaluate_design("ds1", spec, plan, 1)
            ds2 = evaluate_design("ds2", spec, plan, 1)
            assert ds2.cycles > ds1.cycles


class TestIntensity:
    def test_lenet_oi_improvement_exact(self):
        from repro.core.intensity import intensity_improvement

        spec, plan = _plan("lenet")
        assert intensity_improvement(spec, plan) == pytest.approx(8.2, abs=0.05)

    def test_oi_ordering(self):
        """Fused-uniform OI > fused-naive OI and > unfused OI, everywhere."""
        from repro.core.intensity import fused_bytes, unfused_bytes

        for net in ["lenet", "alexnet", "vgg"]:
            spec, plan = _plan(net)
            assert fused_bytes(spec, plan) < fused_bytes(spec, plan, uniform=False)
            assert fused_bytes(spec, plan) < unfused_bytes(spec)
