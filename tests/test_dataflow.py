"""Kernel memory-movement contracts (the halo-tiled dataflow PR):

* halo-tile byte model — per-launch input HBM traffic is
  ``alpha^2 * tile0^2 * C`` (tile + halo), not the retired whole-image
  ``alpha^2 * Hp * Wp * C``; ``launch_dataflow`` components sum to
  ``TileProgram.hbm_bytes`` so the OI bridge and the partitioner DP consume
  the same model;
* halo-tile correctness at image borders — per-grid-cell DMA fetches match
  the reference on edge tiles (i=0, i=alpha-1), strided + pooled levels, and
  batch > 1 (the manual DMA indexes the batch axis itself);
* streamed double-buffer parity — the two-slot prefetch pipeline is
  bit-identical to resident weights and to the single-slot fallback across
  Q=2/3/4, including END-cascade and mixed live/dead tiles (the speculative
  prefetch-drain and on-demand-fetch paths);
* the ``interpret=None`` resolver and the pre-flattened-weights fast path.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import resolve_interpret
from repro.core.cnn_models import LENET5_FUSION, VGG_FUSION, resnet18_fusions
from repro.core.executor import (
    PyramidParams,
    init_pyramid_params,
    reference_forward,
)
from repro.core.fusion import FusedLevel, FusionSpec
from repro.core.intensity import launch_dataflow
from repro.core.program import (
    VMEM_BUDGET_BYTES,
    compile_program,
    plan_launch,
)
from repro.kernels.fused_conv.ops import flatten_weights, fused_pyramid
from repro.net.graph import lenet5, vgg16
from repro.net.partition import auto_partition
from repro.net.runner import (
    init_network_params,
    prepare_network_params,
    run_network,
)

KEY = jax.random.PRNGKey(0)

VGG_SMALL = dataclasses.replace(VGG_FUSION, input_size=32)

# conv+pool, conv, conv — strided pool epilogue plus an unpadded tail level
Q3_CHAIN = FusionSpec(
    levels=(
        FusedLevel("conv", K=3, S=1, pad=1, n_in=2, n_out=6),
        FusedLevel("pool", K=2, S=2, pad=0, n_in=6, n_out=6),
        FusedLevel("conv", K=3, S=1, pad=1, n_in=6, n_out=8),
        FusedLevel("conv", K=3, S=1, pad=0, n_in=8, n_out=4),
    ),
    input_size=20,
)

# strided conv (S=2) + pool: exercises non-unit o_step masking at borders
STRIDED_CHAIN = FusionSpec(
    levels=(
        FusedLevel("conv", K=3, S=2, pad=1, n_in=3, n_out=8),
        FusedLevel("pool", K=2, S=2, pad=0, n_in=8, n_out=8),
        FusedLevel("conv", K=3, S=1, pad=1, n_in=8, n_out=4),
    ),
    input_size=24,
)


def _inputs(spec, batch=1, seed=1):
    return jax.random.normal(
        jax.random.PRNGKey(seed),
        (batch, spec.input_size, spec.input_size, spec.levels[0].n_in),
    )


class TestHaloByteModel:
    def test_vgg16_input_traffic_drops_to_halo_tiles(self):
        """Acceptance: VGG-16 blocks 1-2 at 224^2 — modeled per-launch input
        HBM traffic is alpha^2*tile0^2*C*4 (halo-only overlap), down from the
        whole-image alpha^2*Hp*Wp*C*4."""
        lp = plan_launch(VGG_FUSION)
        prog = lp.program
        c0 = prog.levels[0].n_in
        halo = 4 * prog.alpha ** 2 * prog.tile0 ** 2 * c0
        whole = 4 * prog.alpha ** 2 * prog.padded_input ** 2 * c0
        assert prog.input_hbm_bytes() == halo
        assert prog.input_hbm_bytes(whole_image=True) == whole
        assert prog.alpha > 1 and halo < whole  # a real multi-cell reduction

    @pytest.mark.parametrize("streamed", [False, True])
    def test_launch_dataflow_components_sum_to_hbm_bytes(self, streamed):
        """The OI-bridge byte breakdown and the DP's cost model agree."""
        for spec in (LENET5_FUSION, VGG_FUSION, resnet18_fusions()[7]):
            prog = plan_launch(spec).program
            for batch in (1, 3):
                flow = launch_dataflow(prog, batch, streamed=streamed)
                total = (
                    flow["input_bytes_halo"]
                    + flow["weight_bytes"]
                    + flow["output_bytes"]
                    + flow["skip_bytes"]
                )
                assert total == prog.hbm_bytes(batch, streamed=streamed)

    def test_partitioner_consumes_halo_model(self):
        """The auto plan's modeled HBM is the sum of its launches' halo-model
        traffic — the DP optimizes the dataflow the kernel actually runs."""
        plan = auto_partition(vgg16())
        total = sum(
            p.launch.program.hbm_bytes(1, streamed=p.launch.streamed)
            for p in plan.pyramids
        )
        assert plan.hbm_bytes() == total
        halo_in = sum(
            p.launch.program.input_hbm_bytes(1) for p in plan.pyramids
        )
        whole_in = sum(
            p.launch.program.input_hbm_bytes(1, whole_image=True)
            for p in plan.pyramids
        )
        assert halo_in <= whole_in

    def test_double_buffer_costed_as_overlap(self):
        """Cycle model: double-buffered streaming (w_slots=2) is never slower
        than the blocking single slot, and resident pays no DMA term."""
        spec = resnet18_fusions()[7]
        lp = plan_launch(spec)
        assert lp.streamed
        db = dataclasses.replace(lp, w_slots=2)
        sb = dataclasses.replace(lp, w_slots=1)
        res = dataclasses.replace(lp, streamed=False, w_slots=1)
        assert db.modeled_cycles() <= sb.modeled_cycles()
        assert res.modeled_cycles() <= db.modeled_cycles()

    def test_stream_slots_ladder(self):
        """plan_launch prefers resident, then 2-slot streaming, then
        channel-tiled 2-slot streaming, then 1-slot; ResNet-18's 512-channel
        block cannot hold two whole copies of one 9.4 MB weight level in
        16 MiB, but two quarter slices fit — it lands on the channel-tiled
        double-buffered rung instead of the blocking single slot."""
        lp = plan_launch(resnet18_fusions()[7])
        assert lp.streamed and lp.w_slots == 2 and lp.c_tiles > 1
        # region preference stays primary: the largest region fits this
        # rung, so a smaller region must not be chosen to afford more slots
        assert lp.out_region == lp.spec.feature_sizes()[-1]
        prog = lp.program
        assert prog.vmem_stream_bytes(2) > VMEM_BUDGET_BYTES
        assert prog.vmem_stream_bytes(2, 1, lp.c_tiles) <= VMEM_BUDGET_BYTES
        assert prog.vmem_stream_bytes(1) <= VMEM_BUDGET_BYTES
        # the blocking single slot remains the terminal rung: under a budget
        # where even the finest channel slices bust two slots, w_slots == 1
        floor = prog.vmem_stream_bytes(1)
        tight = plan_launch(resnet18_fusions()[7], vmem_budget=floor)
        if tight is not None and tight.streamed and tight.c_tiles == 1:
            assert tight.w_slots == 1
        # a small chain that streams fits both slots untiled: 2 is chosen
        tiny = plan_launch(LENET5_FUSION, vmem_budget=40_000)
        if tiny is not None and tiny.streamed:
            assert tiny.w_slots == 2


@pytest.mark.slow
class TestHaloBorders:
    """Per-grid-cell halo DMA vs the monolithic reference at image borders:
    every (i, j) cell — including i=0 / i=alpha-1 edge tiles whose halos land
    in padding — must reproduce the reference exactly."""

    @pytest.mark.parametrize(
        "spec,region",
        [(Q3_CHAIN, 1), (Q3_CHAIN, 2), (STRIDED_CHAIN, 1), (STRIDED_CHAIN, 3)],
        ids=["q3_r1", "q3_r2", "strided_r1", "strided_r3"],
    )
    def test_edge_tiles_match_reference(self, spec, region):
        prog = compile_program(spec, region)
        assert prog.alpha > 1, "border test needs a multi-cell grid"
        p = init_pyramid_params(spec, KEY)
        x = _inputs(spec, batch=2)
        y, _ = fused_pyramid(
            x, p.weights, p.biases, spec=spec, out_region=region
        )
        ref = reference_forward(x, spec, PyramidParams(p.weights, p.biases))
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)

    def test_batch_axis_dma_indexing(self):
        """Batch elements differ; the manual halo DMA must index batch b —
        a constant-index bug would smear batch 0 over the whole output."""
        spec = Q3_CHAIN
        p = init_pyramid_params(spec, KEY)
        x = jnp.stack(
            [jnp.zeros((20, 20, 2)), jnp.ones((20, 20, 2)), _inputs(spec)[0]]
        )
        y, _ = fused_pyramid(x, p.weights, p.biases, spec=spec, out_region=2)
        ref = reference_forward(x, spec, PyramidParams(p.weights, p.biases))
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)
        assert not np.allclose(np.asarray(y)[0], np.asarray(y)[1])


@pytest.mark.slow
class TestStreamedDoubleBufferParity:
    """The double-buffered weight pipeline must be bit-identical to resident
    weights — same MXU inputs, only the movement schedule differs."""

    CASES = {
        "lenet_q2": (LENET5_FUSION, 1),
        "odd_q3": (Q3_CHAIN, 4),
        "vgg_q4": (VGG_SMALL, 4),
    }

    @pytest.mark.parametrize("name", sorted(CASES))
    @pytest.mark.parametrize("w_slots", [1, 2])
    def test_streamed_matches_resident_bitwise(self, name, w_slots):
        spec, region = self.CASES[name]
        p = init_pyramid_params(spec, KEY)
        x = _inputs(spec)
        y_res, s_res = fused_pyramid(
            x, p.weights, p.biases, spec=spec, out_region=region,
            streamed=False,
        )
        y_str, s_str = fused_pyramid(
            x, p.weights, p.biases, spec=spec, out_region=region,
            streamed=True, w_slots=w_slots,
        )
        np.testing.assert_array_equal(np.asarray(y_str), np.asarray(y_res))
        np.testing.assert_array_equal(np.asarray(s_str), np.asarray(s_res))

    def test_end_cascade_under_double_buffer(self):
        """Full END cascade with the prefetch pipeline: skipped levels take
        the drain path, output stays bit-identical, flags all set."""
        spec = Q3_CHAIN
        p = init_pyramid_params(spec, KEY)
        bs = [b - 10.0 for b in p.biases]
        x = _inputs(spec)
        y_res, _ = fused_pyramid(
            x, p.weights, bs, spec=spec, out_region=4, streamed=False
        )
        y_db, skip = fused_pyramid(
            x, p.weights, bs, spec=spec, out_region=4, streamed=True,
            w_slots=2,
        )
        np.testing.assert_array_equal(np.asarray(y_db), np.asarray(y_res))
        skip = np.asarray(skip)
        assert (skip[..., 1] == 1).all() and (skip[..., 2] == 1).all()

    def test_mixed_live_dead_tiles_under_double_buffer(self):
        """Sparse input yields a mix of live and dead tiles: exercises the
        speculative-prefetch drain (live level feeding a dead one) and the
        on-demand fetch (dead level feeding a live one via a positive-bias
        constant tile)."""
        spec = LENET5_FUSION
        p = init_pyramid_params(spec, KEY)
        bs = [p.biases[0] - 0.5, p.biases[1] + 0.3]
        blob = spec.input_size // 3
        x = jnp.zeros(
            (1, spec.input_size, spec.input_size, 1)
        ).at[:, :blob, :blob, :].set(5.0)
        y_res, s_res = fused_pyramid(
            x, p.weights, bs, spec=spec, out_region=1, streamed=False
        )
        y_db, s_db = fused_pyramid(
            x, p.weights, bs, spec=spec, out_region=1, streamed=True,
            w_slots=2,
        )
        np.testing.assert_array_equal(np.asarray(y_db), np.asarray(y_res))
        np.testing.assert_array_equal(np.asarray(s_db), np.asarray(s_res))
        frac = float(np.asarray(s_res)[..., 1].mean())
        assert 0.0 < frac < 1.0, "test needs mixed live/dead tiles"


class TestInterpretResolver:
    def test_explicit_values_pass_through(self):
        assert resolve_interpret(True) is True
        assert resolve_interpret(False) is False

    def test_none_resolves_from_backend(self):
        expect = jax.default_backend() != "tpu"
        assert resolve_interpret(None) is expect
        assert resolve_interpret() is expect


class TestPreflattenedWeights:
    def test_flatten_weights_matches_per_launch_concat(self):
        p = init_pyramid_params(Q3_CHAIN, KEY)
        flat = flatten_weights(p.weights)
        expect = jnp.concatenate(
            [jnp.asarray(w, jnp.float32).reshape(-1) for w in p.weights]
        )
        np.testing.assert_array_equal(np.asarray(flat), np.asarray(expect))

    def test_kernel_accepts_preflattened(self):
        spec = Q3_CHAIN
        p = init_pyramid_params(spec, KEY)
        x = _inputs(spec)
        y0, s0 = fused_pyramid(
            x, p.weights, p.biases, spec=spec, out_region=4, streamed=True
        )
        y1, s1 = fused_pyramid(
            x, p.weights, p.biases, spec=spec, out_region=4, streamed=True,
            weights_flat=flatten_weights(p.weights),
        )
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y0))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s0))

    def test_prepare_network_params_roundtrip(self):
        """run_network with pre-flattened params == without, and only
        streamed pyramids gain a _flat/ entry."""
        graph = lenet5()
        plan = auto_partition(graph, vmem_budget=40_000)
        params = init_network_params(graph, KEY)
        prepped = prepare_network_params(plan, params)
        n_streamed = sum(p.launch.streamed for p in plan.pyramids)
        assert len(prepped) == len(params) + n_streamed
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 32, 1))
        y0, _ = run_network(x, params, plan=plan)
        y1, _ = run_network(x, prepped, plan=plan)
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y0))
