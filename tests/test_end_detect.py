"""END (Algorithm 2) tests: soundness, coverage, zero accuracy loss."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.end_detect import end_scan, end_statistics
from repro.core.online_arith import to_digits

RNG = np.random.default_rng(7)


class TestEndSoundness:
    def test_never_flags_positive(self):
        """Algorithm 2 must be exact: a flagged stream is strictly negative.
        This is the paper's 'no accuracy loss' claim."""
        x = RNG.uniform(-0.99, 0.99, (4096,)).astype(np.float32)
        det, _ = end_scan(to_digits(x, 16))
        det = np.asarray(det)
        assert not np.any(det & (x >= 0))

    def test_detects_most_negatives(self):
        x = RNG.uniform(-0.99, 0.99, (4096,)).astype(np.float32)
        det = np.asarray(end_scan(to_digits(x, 16))[0])
        neg = x < 0
        # only values in (-2^-16, 0) can escape within a 16-digit budget
        assert det[neg].mean() > 0.99

    def test_detection_cycle_tracks_magnitude(self):
        """Strongly negative values must terminate earlier: the firing digit
        is ~ -log2(-value) + O(1)."""
        vals = np.float32([-0.5, -0.25, -0.125, -0.0625])
        det, cyc = end_scan(to_digits(vals, 16))
        assert np.all(np.asarray(det))
        cyc = np.asarray(cyc)
        assert np.all(np.diff(cyc) >= 0)  # smaller magnitude -> later firing
        assert cyc[0] <= 3

    def test_tiny_negative_undetermined(self):
        """Values in (-2^-T, 0) never trip the test: the paper's
        'undetermined' residue — they are exactly the zero-after-ReLU cases
        that cost full cycles but no accuracy."""
        vals = np.float32([-(2.0 ** -20)])
        det, cyc = end_scan(to_digits(vals, 16))
        assert not bool(det[0])
        assert int(cyc[0]) == 16

    # integer-derived floats: hypothesis float strategies reject XLA's
    # FTZ/DAZ FPU mode (see tests/test_online_arith.py)
    @given(st.lists(st.integers(-9900, 9900), min_size=1, max_size=64))
    @settings(max_examples=50, deadline=None)
    def test_soundness_property(self, ints):
        x = np.asarray(ints, np.float32) / 10000.0
        det, cyc = end_scan(to_digits(x, 18))
        det, cyc = np.asarray(det), np.asarray(cyc)
        # soundness: no false positives
        assert not np.any(det & (x >= 0))
        # the prefix at the firing cycle proves negativity with margin
        for i in np.nonzero(det)[0]:
            assert x[i] < 0


class TestEndStats:
    def test_stats_fields(self):
        x = RNG.normal(0, 0.3, (2048,)).astype(np.float32).clip(-0.99, 0.99)
        st_ = end_statistics(to_digits(x, 16), jnp.asarray(x))
        assert st_.total == 2048
        assert st_.detected <= st_.negative
        assert st_.undetermined == st_.negative - st_.detected
        assert 0.0 <= st_.cycle_savings < 1.0
        # zero-mean inputs: about half negative, nearly all detected
        assert 0.35 < st_.detected_frac < 0.65
        assert st_.cycle_savings > 0.2
