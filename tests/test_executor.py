"""Fused executor vs monolithic reference — exactness on all networks."""

import jax
import numpy as np
import pytest

from repro.core.cnn_models import (
    ALEXNET_FUSION,
    LENET5_FUSION,
    VGG_FUSION,
    resnet18_fusions,
)
from repro.core.executor import (
    conv_windows,
    fused_forward,
    init_pyramid_params,
    reference_forward,
)
from repro.core.fusion import FusedLevel, FusionSpec, lockstep_plan

KEY = jax.random.PRNGKey(0)


def _check(spec, region, batch=1, tol=1e-5):
    params = init_pyramid_params(spec, KEY)
    x = jax.random.normal(
        jax.random.PRNGKey(1),
        (batch, spec.input_size, spec.input_size, spec.levels[0].n_in),
    )
    ref = reference_forward(x, spec, params)
    fused = fused_forward(x, spec, params, lockstep_plan(spec, region))
    assert ref.shape == fused.shape
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref), atol=tol)


class TestFusedEqualsReference:
    def test_lenet(self):
        _check(LENET5_FUSION, 1)

    def test_lenet_batch(self):
        _check(LENET5_FUSION, 2, batch=3)

    def test_alexnet(self):
        _check(ALEXNET_FUSION, 1, tol=1e-4)

    def test_vgg_region19(self):
        _check(VGG_FUSION, 19, tol=1e-4)

    @pytest.mark.parametrize("blk", [0, 2, 4, 6])
    def test_resnet_blocks(self, blk):
        _check(resnet18_fusions()[blk], 4, tol=1e-4)

    def test_strided_inner_conv(self):
        spec = FusionSpec(
            levels=(
                FusedLevel("conv", 3, 2, 1, 2, 4),
                FusedLevel("conv", 3, 1, 1, 4, 4),
            ),
            input_size=17,
        )
        _check(spec, 3)

    def test_no_relu_mode(self):
        spec = LENET5_FUSION
        params = init_pyramid_params(spec, KEY)
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 32, 1))
        ref = reference_forward(x, spec, params, relu=False)
        fused = fused_forward(x, spec, params, lockstep_plan(spec, 1), relu=False)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(ref), atol=1e-5)


class TestConvWindows:
    def test_window_shape_and_content(self):
        spec = LENET5_FUSION
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, 32, 1))
        win, n = conv_windows(x, spec, level=0)
        assert n == 28 * 28
        assert win.shape == (2, 28 * 28, 25)
        # first window must equal the top-left 5x5 patch
        np.testing.assert_allclose(
            np.asarray(win[0, 0]), np.asarray(x[0, :5, :5, 0]).reshape(-1), atol=1e-6
        )

    def test_subsampling(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, 32, 1))
        win, n = conv_windows(x, LENET5_FUSION, level=0, max_windows=100)
        assert win.shape[1] == 100
