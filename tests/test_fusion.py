"""Fusion-planner tests: Eq. (1), Algorithms 3-4, paper-value reproduction."""

from hypothesis import given, settings, strategies as st

from repro.core.cnn_models import (
    ALEXNET_FUSION,
    LENET5_FUSION,
    VGG_FUSION,
    resnet18_fusions,
)
from repro.core.fusion import (
    FusedLevel,
    FusionSpec,
    lockstep_plan,
    plan_fusion,
    receptive_window,
    tile_sizes,
    uniform_tile_stride,
)


class TestTileSizes:
    def test_lenet_paper_example(self):
        """§3.3.1 worked example: 1x1 output -> 2,6,12,16 going up."""
        assert tile_sizes(LENET5_FUSION, 1) == [16, 12, 6, 2, 1]

    def test_alexnet_region1(self):
        assert tile_sizes(ALEXNET_FUSION, 1) == [67, 15, 7, 3, 1]

    def test_eq1_single_level(self):
        spec = FusionSpec(levels=(FusedLevel("conv", K=5, S=2),), input_size=32)
        # D_l = (D_o - 1)*S + K
        assert tile_sizes(spec, 4) == [(4 - 1) * 2 + 5, 4]


class TestUniformStride:
    """Algorithm 4 must reproduce the paper's alpha values."""

    def test_lenet_alpha_5(self):
        plan = plan_fusion(LENET5_FUSION, out_region=1)
        assert plan.alpha == 5
        # paper: S^T_2 = 2 for CL2 (6x6 tile) at alpha=5
        assert plan.levels[2].stride == 2
        assert plan.levels[0].stride == 4

    def test_alexnet_alpha_9(self):
        plan = plan_fusion(ALEXNET_FUSION, out_region=1)
        assert plan.alpha == 9
        assert plan.levels[0].tile == 67 and plan.levels[0].stride == 20

    def test_vgg_alpha_3(self):
        plan = plan_fusion(VGG_FUSION)
        assert plan.alpha == 3
        assert plan.out_region == 19

    def test_naive_stride_rejected_for_lenet(self):
        """The paper's motivating example: S^T = H-K+S = 12 at CL1 gives a
        non-integer alpha (7/3 scaled ... 16/12 not integral) and must not be
        selected."""
        plan = uniform_tile_stride(LENET5_FUSION, 1)
        assert plan.levels[0].stride != 12

    def test_coverage_exact(self):
        """Strides tile each conv level exactly: span == (alpha-1)*stride."""
        for spec, r in [(LENET5_FUSION, 1), (ALEXNET_FUSION, 1)]:
            plan = plan_fusion(spec, out_region=r)
            for lvl, ls in zip(spec.levels, plan.levels):
                if lvl.kind != "conv":
                    continue
                assert ls.ifm - ls.tile == (plan.alpha - 1) * ls.stride

    def test_no_skip_bound(self):
        for spec, r in [(LENET5_FUSION, 1), (ALEXNET_FUSION, 1)]:
            plan = plan_fusion(spec, out_region=r)
            for lvl, ls in zip(spec.levels, plan.levels):
                if lvl.kind == "conv":
                    assert ls.stride <= ls.tile - lvl.K + lvl.S

    def test_resnet18_all_blocks_plannable(self):
        for spec in resnet18_fusions():
            plan = plan_fusion(spec)
            assert plan.alpha >= 1


@st.composite
def random_chain(draw):
    """Random small conv/pool chains with consistent channel counts."""
    n_levels = draw(st.integers(1, 3))
    levels = []
    c = draw(st.integers(1, 4))
    size = draw(st.integers(16, 48))
    for i in range(n_levels):
        kind = draw(st.sampled_from(["conv", "conv", "pool"]))
        if kind == "conv":
            K = draw(st.integers(1, 5))
            S = draw(st.integers(1, 2))
            pad = draw(st.integers(0, K // 2))
            c2 = draw(st.integers(1, 4))
            levels.append(FusedLevel("conv", K, S, pad, c, c2))
            c = c2
        else:
            K = draw(st.integers(2, 3))
            levels.append(FusedLevel("pool", K, K, 0, c, c))
    return FusionSpec(levels=tuple(levels), input_size=size)


class TestProperties:
    @given(random_chain(), st.integers(1, 4))
    @settings(max_examples=60, deadline=None)
    def test_receptive_window_covers_output(self, spec, region):
        """Every level's window must be computable and ordered, and the
        level-0 window size must equal the Eq. (1) tile size minus the pads
        accumulated along the chain (receptive_window is the padded-exact
        variant of tile_sizes)."""
        out = spec.feature_sizes()[-1]
        if out < 1:
            return
        region = min(region, out)
        wins = receptive_window(spec, 0, region)
        assert len(wins) == len(spec.levels)
        for (lo, size), lvl in zip(wins, spec.levels):
            assert size >= lvl.K or lvl.kind == "pool"

    @given(random_chain())
    @settings(max_examples=60, deadline=None)
    def test_lockstep_plan_covers_output(self, spec):
        out = spec.feature_sizes()[-1]
        if out < 1:
            return
        plan = lockstep_plan(spec, min(3, out))
        covered = set()
        for s in plan.starts:
            covered.update(range(s, s + plan.out_region))
        assert covered == set(range(out))

    @given(random_chain(), st.integers(1, 6))
    @settings(max_examples=60, deadline=None)
    def test_uniform_alpha_when_found_is_consistent(self, spec, region):
        out = spec.feature_sizes()[-1]
        if out < 1:
            return
        region = min(region, out)
        plan = uniform_tile_stride(spec, region)
        if plan is None:
            return
        for lvl, ls in zip(spec.levels, plan.levels):
            if lvl.kind != "conv":
                continue
            assert (ls.ifm - ls.tile) % ls.stride == 0 if ls.stride else True
            if ls.stride:
                assert (ls.ifm - ls.tile) // ls.stride + 1 == plan.alpha
