"""End-to-end system tests: training loop + checkpoint/restart + analyzer."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np


class TestTrainLoop:
    def test_loss_decreases_and_restart_is_exact(self):
        """Train a reduced model; restart from a mid-run checkpoint must
        reproduce the exact final state (deterministic pipeline + exact
        restore)."""
        from repro.launch.train import train

        with tempfile.TemporaryDirectory() as d:
            losses = train(
                "deepseek_7b", steps=30, reduced=True, seq_len=64,
                global_batch=4, ckpt_dir=d, ckpt_every=15, log_every=100,
            )
            assert np.isfinite(losses).all()
            assert np.mean(losses[-4:]) < np.mean(losses[:4])  # learning

            # resume from the step-15 checkpoint; replay must match exactly
            resumed = train(
                "deepseek_7b", steps=30, reduced=True, seq_len=64,
                global_batch=4, ckpt_dir=d, ckpt_every=100, resume=True,
                log_every=100,
            )
            np.testing.assert_allclose(
                resumed[-1], losses[-1], rtol=1e-4,
                err_msg="restart-replay diverged from the original run",
            )

    def test_serving_generates(self):
        from repro.launch.serve import serve

        gen, tps = serve("phi4_mini_3_8b", batch=2, prompt_len=4, new_tokens=6)
        assert gen.shape == (2, 6)
        assert tps > 0


class TestHloAnalysis:
    def test_scan_trip_counts_recovered(self):
        """The analyzer must multiply while-body flops by the trip count
        (XLA's cost_analysis famously does not)."""
        from repro.launch.hloanalysis import analyze_hlo

        def body(x, w):
            return jnp.tanh(x @ w), None

        def f(x, ws):
            y, _ = jax.lax.scan(body, x, ws)
            return y

        x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        ws = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
        compiled = jax.jit(f).lower(x, ws).compile()
        cost = analyze_hlo(compiled.as_text())
        expect = 10 * 2 * 64 * 128 * 128
        assert abs(cost.flops - expect) / expect < 0.05
        # XLA's own count misses the factor of 10
        from repro.launch.hloanalysis import xla_cost_dict

        xla = xla_cost_dict(compiled).get("flops", 0)
        assert xla < cost.flops / 5

    def test_nested_scan(self):
        from repro.launch.hloanalysis import analyze_hlo

        def inner(c, w):
            return c @ w, None

        def outer(c, ws):
            c, _ = jax.lax.scan(inner, c, ws)
            return c, None

        def f(x, ws):
            y, _ = jax.lax.scan(outer, x, jnp.broadcast_to(ws, (3,) + ws.shape))
            return y

        x = jnp.ones((16, 32))
        ws = jnp.ones((4, 32, 32))
        compiled = jax.jit(f).lower(x, ws).compile()
        cost = analyze_hlo(compiled.as_text())
        expect = 3 * 4 * 2 * 16 * 32 * 32
        assert abs(cost.flops - expect) / expect < 0.05


class TestDataPipelineLearnable:
    def test_bigram_structure_present(self):
        """The synthetic stream embeds a learnable bigram rule (the training
        examples rely on it to show loss decrease)."""
        from repro.data.pipeline import DataConfig, batch_at

        cfg = DataConfig(vocab=1000, seq_len=512, global_batch=4)
        t = batch_at(cfg, 0)["tokens"]
        pred = (t[:, :-1] * 31 + 7) % cfg.vocab
        frac = (t[:, 1:] == pred).mean()
        assert 0.35 < frac < 0.65  # ~half the transitions follow the rule
