"""Direct unit tests for the operational-intensity model (core/intensity.py):
byte accounting across strides and paddings, naive-vs-uniform improvement,
and the paper's LeNet-5 headline number."""

import pytest

from repro.core.cnn_models import LENET5_FUSION
from repro.core.cycle_model import naive_alpha
from repro.core.fusion import FusedLevel, FusionSpec, plan_fusion
from repro.core.intensity import (
    fused_bytes,
    intensity_improvement,
    unfused_bytes,
    weight_bytes,
)


def _spec(levels, size):
    return FusionSpec(levels=tuple(levels), input_size=size)


class TestUnfusedBytes:
    def test_stride1_no_pad_hand_computed(self):
        # 8x8x2 -> conv3x3 -> 6x6x4 -> pool2x2 -> 3x3x4
        spec = _spec(
            [FusedLevel("conv", 3, 1, 0, 2, 4), FusedLevel("pool", 2, 2, 0, 4, 4)],
            8,
        )
        w = 3 * 3 * 2 * 4
        expect = (8 * 8 * 2 + 6 * 6 * 4) + (6 * 6 * 4 + 3 * 3 * 4) + w
        assert unfused_bytes(spec) == expect

    def test_stride2_with_pad(self):
        # 9x9x3 -> conv3x3/S2/pad1 -> 5x5x6: maps are charged at their
        # UNPADDED sizes (pad rows never cross off-chip)
        spec = _spec([FusedLevel("conv", 3, 2, 1, 3, 6)], 9)
        assert spec.feature_sizes() == [9, 5]
        assert unfused_bytes(spec) == 9 * 9 * 3 + 5 * 5 * 6 + 3 * 3 * 3 * 6

    def test_bytes_per_val_scales_everything(self):
        spec = _spec([FusedLevel("conv", 3, 1, 1, 1, 2)], 6)
        assert unfused_bytes(spec, bytes_per_val=4) == 4 * unfused_bytes(spec)

    def test_weight_bytes_counts_convs_only(self):
        spec = _spec(
            [FusedLevel("conv", 5, 1, 0, 2, 3), FusedLevel("pool", 2, 2, 0, 3, 3)],
            12,
        )
        assert weight_bytes(spec) == 5 * 5 * 2 * 3


class TestFusedBytes:
    def test_uniform_formula_hand_computed(self):
        # 12x12x2 -> conv3x3 -> 10 -> pool2 -> 5; out_region 1 => alpha 5
        spec = _spec(
            [FusedLevel("conv", 3, 1, 0, 2, 4), FusedLevel("pool", 2, 2, 0, 4, 4)],
            12,
        )
        plan = plan_fusion(spec, out_region=1)
        h1 = plan.levels[0].tile
        expect = (
            plan.alpha ** 2 * h1 * h1 * 2  # tile reads
            + 5 * 5 * 4                    # final map write
            + 3 * 3 * 2 * 4                # weights once
        )
        assert fused_bytes(spec, plan) == expect

    def test_naive_stride_reads_more(self):
        spec = LENET5_FUSION
        plan = plan_fusion(spec, out_region=1)
        assert naive_alpha(plan) > plan.alpha
        assert fused_bytes(spec, plan, uniform=False) > fused_bytes(spec, plan)

    @pytest.mark.parametrize("S,pad", [(1, 0), (1, 1), (2, 0), (2, 1)])
    def test_fused_beats_unfused_across_strides_and_pads(self, S, pad):
        """Fusion's point: once the chain is deep enough that intermediate
        maps dominate, fused traffic undercuts layer-by-layer."""
        levels = [
            FusedLevel("conv", 3, S, pad, 2, 8),
            FusedLevel("conv", 3, 1, 1, 8, 8),
            FusedLevel("conv", 3, 1, 1, 8, 8),
        ]
        spec = _spec(levels, 20)
        plan = plan_fusion(spec)
        assert fused_bytes(spec, plan) < unfused_bytes(spec)


class TestIntensityImprovement:
    def test_lenet_reproduces_paper_8_2x(self):
        plan = plan_fusion(LENET5_FUSION, out_region=1)
        assert intensity_improvement(LENET5_FUSION, plan) == pytest.approx(
            8.2, abs=0.05
        )

    def test_improvement_is_naive_over_uniform(self):
        spec = _spec(
            [FusedLevel("conv", 3, 1, 0, 1, 4), FusedLevel("conv", 3, 1, 0, 4, 4)],
            16,
        )
        plan = plan_fusion(spec)
        imp = intensity_improvement(spec, plan)
        assert imp == pytest.approx(
            fused_bytes(spec, plan, uniform=False) / fused_bytes(spec, plan)
        )
        assert imp >= 1.0
