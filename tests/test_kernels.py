"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
with shape/dtype sweeps per the kernel-deliverable contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cnn_models import ALEXNET_FUSION, LENET5_FUSION
from repro.core.executor import init_pyramid_params
from repro.core.fusion import FusedLevel, FusionSpec
from repro.kernels.fused_conv.ops import fused_conv2
from repro.kernels.fused_conv.ref import fused_conv2_ref
from repro.kernels.online_sop.ops import online_sop_end
from repro.kernels.online_sop.ref import online_sop_end_ref

RNG = np.random.default_rng(3)
KEY = jax.random.PRNGKey(0)


class TestOnlineSopKernel:
    @pytest.mark.parametrize("m", [9, 25, 121, 363])
    @pytest.mark.parametrize("batch", [(7,), (3, 50)])
    def test_matches_ref_shapes(self, m, batch):
        x = (RNG.uniform(-0.9, 0.9, batch + (m,)) / m).astype(np.float32)
        y = (RNG.uniform(-0.9, 0.9, (m,))).astype(np.float32) / max(1, m // 8)
        sop_k, cyc_k, det_k = online_sop_end(jnp.asarray(x), jnp.asarray(y), 14)
        sop_r, cyc_r, det_r = online_sop_end_ref(jnp.asarray(x), jnp.asarray(y), 14)
        np.testing.assert_allclose(
            np.asarray(sop_k), np.asarray(sop_r), atol=1e-5
        )
        assert (np.asarray(det_k) == np.asarray(det_r)).all()
        assert (np.asarray(cyc_k) == np.asarray(cyc_r)).all()

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        x = (RNG.uniform(-0.5, 0.5, (64, 25)) / 25).astype(np.float32)
        y = RNG.uniform(-0.5, 0.5, (25,)).astype(np.float32) / 4
        sop_k, _, det_k = online_sop_end(
            jnp.asarray(x, dtype), jnp.asarray(y, dtype), 12
        )
        exact = (x * y).sum(-1)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(sop_k), exact, atol=tol)

    def test_end_soundness_on_kernel(self):
        """Kernel-side Algorithm 2 must never flag a non-negative SOP."""
        x = (RNG.uniform(-0.9, 0.9, (2048, 25)) / 25).astype(np.float32)
        y = RNG.uniform(-0.9, 0.9, (25,)).astype(np.float32) / 4
        sop, _, det = online_sop_end(jnp.asarray(x), jnp.asarray(y), 16)
        sop, det = np.asarray(sop), np.asarray(det)
        assert not np.any(det & (sop >= 0))
        assert det[sop < -1e-3].mean() > 0.95  # detects clear negatives

    def test_n_digits_sweep(self):
        x = (RNG.uniform(-0.9, 0.9, (128, 9)) / 9).astype(np.float32)
        y = RNG.uniform(-0.9, 0.9, (9,)).astype(np.float32) / 2
        for nd in (8, 12, 20):
            _, cyc, det = online_sop_end(jnp.asarray(x), jnp.asarray(y), nd)
            assert int(np.asarray(cyc).max()) <= nd


def _run_fused(spec, region, batch=1, end_skip=True, key=KEY, bias_shift=0.0):
    p = init_pyramid_params(spec, key)
    b1 = p.biases[0] + bias_shift
    x = jax.random.normal(
        jax.random.PRNGKey(1),
        (batch, spec.input_size, spec.input_size, spec.levels[0].n_in),
    )
    out, skip = fused_conv2(
        x, p.weights[0], b1, p.weights[1], p.biases[1],
        spec=spec, out_region=region, end_skip=end_skip,
    )
    ref = fused_conv2_ref(x, spec, p.weights[0], b1, p.weights[1], p.biases[1])
    return np.asarray(out), np.asarray(ref), np.asarray(skip)


class TestFusedConvKernel:
    def test_lenet_exact(self):
        out, ref, _ = _run_fused(LENET5_FUSION, 1, batch=2)
        np.testing.assert_allclose(out, ref, atol=1e-4)

    @pytest.mark.parametrize("region", [1, 13])
    def test_alexnet_regions(self, region):
        out, ref, _ = _run_fused(ALEXNET_FUSION, region)
        np.testing.assert_allclose(out, ref, atol=1e-4)

    @pytest.mark.parametrize(
        "k1,s1,p1,k2,s2,p2,size,region",
        [
            (3, 1, 1, 3, 1, 1, 16, 4),
            (5, 2, 0, 3, 1, 1, 21, 3),
            (3, 1, 1, 5, 1, 2, 12, 6),
            (1, 1, 0, 3, 2, 1, 15, 4),
        ],
    )
    def test_shape_sweep(self, k1, s1, p1, k2, s2, p2, size, region):
        spec = FusionSpec(
            levels=(
                FusedLevel("conv", k1, s1, p1, 3, 8),
                FusedLevel("conv", k2, s2, p2, 8, 4),
            ),
            input_size=size,
        )
        out_size = spec.feature_sizes()[-1]
        if out_size % region:
            pytest.skip("region does not tile output")
        out, ref, _ = _run_fused(spec, region)
        np.testing.assert_allclose(out, ref, atol=1e-4)

    def test_pool_variants(self):
        spec = FusionSpec(
            levels=(
                FusedLevel("conv", 3, 1, 1, 2, 6),
                FusedLevel("pool", 3, 2, 0, 6, 6),
                FusedLevel("conv", 3, 1, 1, 6, 8),
                FusedLevel("pool", 2, 2, 0, 8, 8),
            ),
            input_size=23,
        )
        out, ref, _ = _run_fused(spec, 1)
        np.testing.assert_allclose(out, ref, atol=1e-4)

    def test_end_skip_fires_and_stays_exact(self):
        """Strongly negative conv1 bias makes whole level-1 tiles zero after
        ReLU; the kernel must (a) fire skips and (b) remain bit-exact."""
        out, ref, skip = _run_fused(LENET5_FUSION, 1, bias_shift=-10.0)
        assert skip.sum() == skip.size  # every tile skipped
        np.testing.assert_allclose(out, ref, atol=1e-5)

    def test_end_skip_partial(self):
        """Spatially localized activity: tiles away from the active blob have
        all-zero post-ReLU level-1 tiles and skip; tiles over the blob
        compute — both paths must stay exact."""
        spec = LENET5_FUSION
        p = init_pyramid_params(spec, KEY)
        b1 = p.biases[0] - 0.5  # dead zones without input drive
        x = jnp.zeros((1, 32, 32, 1)).at[:, :8, :8, :].set(5.0)
        out, skip = fused_conv2(
            x, p.weights[0], b1, p.weights[1], p.biases[1],
            spec=spec, out_region=1,
        )
        ref = fused_conv2_ref(x, spec, p.weights[0], b1, p.weights[1], p.biases[1])
        skip = np.asarray(skip)
        assert 0 < skip.sum() < skip.size
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)

    def test_no_relu_disables_skip(self):
        spec = LENET5_FUSION
        p = init_pyramid_params(spec, KEY)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 1))
        out, skip = fused_conv2(
            x, p.weights[0], p.biases[0], p.weights[1], p.biases[1],
            spec=spec, out_region=1, relu=False,
        )
        ref = fused_conv2_ref(
            x, spec, p.weights[0], p.biases[0], p.weights[1], p.biases[1],
            relu=False,
        )
        assert skip.sum() == 0
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


class TestFusedPyramidChain:
    def test_vgg_q4_chained_matches_single_launch(self):
        """The historical 2+2 chained path (USEFUSE's FPGA granularity,
        forced via ``max_convs_per_chunk=2``) and the new single-launch
        Q=4 path must both match the monolithic reference."""
        from repro.core.cnn_models import VGG_FUSION
        from repro.core.executor import reference_forward, PyramidParams
        from repro.kernels.fused_conv.ops import fused_pyramid_chain
        import dataclasses

        # reduced-size VGG-shaped chain (full 224x224 is slow in interpret)
        spec = dataclasses.replace(VGG_FUSION, input_size=32)
        p = init_pyramid_params(spec, KEY)
        x = jax.random.normal(jax.random.PRNGKey(5), (1, 32, 32, 3))
        y, skips = fused_pyramid_chain(
            x, p.weights, p.biases, spec=spec, out_regions=[8, 4],
            max_convs_per_chunk=2,
        )
        ref = reference_forward(x, spec, PyramidParams(p.weights, p.biases))
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-3)
        assert len(skips) == 2

        y1, skips1 = fused_pyramid_chain(x, p.weights, p.biases, spec=spec)
        assert len(skips1) == 1, "VGG Q=4 must fit one kernel launch"
        np.testing.assert_allclose(np.asarray(y1), np.asarray(ref), atol=1e-3)
