"""End-to-end launch validation: the dry-run lowers and compiles a real
(arch x shape x mesh) cell in a subprocess (512 forced host devices), and
the roofline analyzer consumes its output."""

import json
import subprocess
import sys
import tempfile
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


@pytest.mark.parametrize(
    "arch,shape",
    [("mamba2_780m", "decode_32k"), ("hymba_1_5b", "long_500k")],
)
def test_dryrun_cell_subprocess(arch, shape):
    with tempfile.TemporaryDirectory() as d:
        out = Path(d) / "dryrun.json"
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", arch, "--shape", shape, "--mesh", "single",
             "--out", str(out)],
            # JAX_PLATFORMS=cpu: the dry-run compiles on forced host devices;
            # without it jax probes for TPU hardware and hangs on TPU images
            env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
                 "JAX_PLATFORMS": "cpu"},
            capture_output=True, text=True, timeout=420, cwd=str(REPO),
        )
        assert proc.returncode == 0, proc.stdout + proc.stderr
        recs = json.loads(out.read_text())
        assert len(recs) == 1
        rec = recs[0]
        assert rec["status"] == "ok", rec
        assert rec["chips"] == 256
        assert rec["fits_hbm"] is True
        assert rec["hlo"]["flops_per_device"] > 0

        # roofline consumes the record
        from repro.launch.roofline import analyze_record

        row = analyze_record(rec)
        assert row.dominant in ("compute", "memory", "collective")
        assert row.bound() > 0
