"""Per-architecture smoke tests (deliverable f): every assigned arch as a
reduced family-preserving config, one forward/train step and one decode step
on CPU, asserting shapes and no NaNs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.model import forward, init_params, lm_loss
from repro.models.serving import decode_step, init_caches, prefill_cross_caches

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, t=17):
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (b, t), 0, cfg.vocab)
    }
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.vis_seq, cfg.d_model), jnp.bfloat16
        )
    if cfg.kind == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_no_nans(self, arch):
        cfg = get_config(arch).reduced()
        params = init_params(cfg, KEY)
        batch = _batch(cfg)
        logits, aux, _ = forward(
            cfg,
            params,
            batch["tokens"],
            vision=batch.get("vision"),
            frames=batch.get("frames"),
        )
        assert logits.shape == (2, 17, cfg.vocab)
        assert not bool(jnp.isnan(logits).any())

    def test_train_step_loss_finite_and_decreases(self, arch):
        """One SGD step must produce a finite loss that moves."""
        cfg = get_config(arch).reduced()
        params = init_params(cfg, KEY)
        batch = _batch(cfg)

        loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, batch))(params)
        assert np.isfinite(float(loss))
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        assert np.isfinite(float(gnorm)) and float(gnorm) > 0
        params2 = jax.tree.map(lambda p, g: p - 0.05 * g.astype(p.dtype), params, grads)
        loss2 = lm_loss(cfg, params2, batch)
        assert np.isfinite(float(loss2))
        assert float(loss2) < float(loss)  # a small step descends

    def test_decode_step(self, arch):
        cfg = get_config(arch).reduced()
        params = init_params(cfg, KEY)
        batch = _batch(cfg, t=1)
        caches = init_caches(cfg, 2, 32)
        caches = prefill_cross_caches(
            cfg, params, caches,
            vision=batch.get("vision"), frames=batch.get("frames"),
        )
        logits, new_caches = decode_step(
            cfg, params, batch["tokens"], caches, jnp.int32(0),
            vision=batch.get("vision"),
        )
        assert logits.shape == (2, cfg.vocab)
        assert not bool(jnp.isnan(logits).any())
        assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


class TestDecodeConsistency:
    """Token-by-token decode must reproduce the full forward pass."""

    @pytest.mark.parametrize(
        "arch", ["deepseek_7b", "minicpm3_4b", "mamba2_780m", "hymba_1_5b",
                 "whisper_large_v3"]
    )
    def test_decode_matches_forward(self, arch):
        cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
        params = init_params(cfg, KEY)
        T = 10
        batch = _batch(cfg, t=T)
        batch = {k: (v.astype(jnp.float32) if v.dtype == jnp.bfloat16 else v)
                 for k, v in batch.items()}
        full, _, _ = forward(
            cfg, params, batch["tokens"], chunked=False,
            vision=batch.get("vision"), frames=batch.get("frames"),
        )
        caches = init_caches(cfg, 2, T)
        caches = prefill_cross_caches(
            cfg, params, caches,
            vision=batch.get("vision"), frames=batch.get("frames"),
        )
        for t in range(T):
            lg, caches = decode_step(
                cfg, params, batch["tokens"][:, t : t + 1], caches,
                jnp.int32(t), vision=batch.get("vision"),
            )
            np.testing.assert_allclose(
                np.asarray(lg), np.asarray(full[:, t]), atol=2e-4,
                err_msg=f"{arch} step {t}",
            )

    def test_moe_decode_matches_without_drops(self):
        """Capacity-drop composition differs between batched forward and
        decode (inherent to dropped-token MoE); with drops disabled the
        paths must agree exactly."""
        cfg = dataclasses.replace(
            get_config("qwen2_moe_a2_7b").reduced(),
            dtype="float32", capacity_factor=8.0,
        )
        params = init_params(cfg, KEY)
        T = 8
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, T), 0, cfg.vocab)
        full, _, _ = forward(cfg, params, toks, chunked=False)
        caches = init_caches(cfg, 2, T)
        for t in range(T):
            lg, caches = decode_step(cfg, params, toks[:, t : t + 1], caches, jnp.int32(t))
            np.testing.assert_allclose(
                np.asarray(lg), np.asarray(full[:, t]), atol=2e-4
            )


class TestChunkedAttention:
    def test_chunked_equals_dense_prefill(self):
        """The 32k-prefill code path (flash chunks) on a reduced config."""
        cfg = dataclasses.replace(
            get_config("glm4_9b").reduced(), dtype="float32", attn_chunk=16
        )
        params = init_params(cfg, KEY)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)
        a, _, _ = forward(cfg, params, toks, chunked=False)
        b, _, _ = forward(cfg, params, toks, chunked=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
