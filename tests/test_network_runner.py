"""End-to-end `run_network` vs the monolithic JAX reference (float32
atol 1e-4): LeNet-5 at paper scale, ResNet-18 (reduced input, full channel
plan — padded stem pool, residual adds, projection shortcuts, streamed
512-channel pair), VGG-16 topology at reduced scale, and END skip stats."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.net.graph import lenet5, resnet18, vgg16
from repro.net.partition import auto_partition, layerwise_partition
from repro.net.runner import (
    init_network_params,
    reference_network,
    run_network,
    skip_fractions,
)

KEY = jax.random.PRNGKey(0)


def _run_and_check(graph, batch=2, atol=1e-4, plan=None, seed=1):
    params = init_network_params(graph, KEY)
    x = jax.random.normal(
        jax.random.PRNGKey(seed),
        (batch, graph.input_size, graph.input_size, graph.in_channels),
    )
    if plan is None:
        plan = auto_partition(graph, batch=batch)
    logits, skips = run_network(x, params, plan=plan)
    ref = reference_network(x, graph, params)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref), atol=atol)
    return plan, skips


@pytest.mark.slow
class TestEndToEndParity:
    def test_lenet5_paper_scale(self):
        plan, skips = _run_and_check(lenet5())
        assert plan.n_launches() == 1  # whole backbone is one pyramid

    def test_resnet18_reduced_scale(self):
        """The acceptance network: residual adds, projection shortcuts and
        the full channel plan (64..512), reduced spatially for interpret
        mode.  Matches the monolithic reference within 1e-4."""
        graph = resnet18(input_size=32, num_classes=10)
        plan, skips = _run_and_check(graph)
        assert plan.n_launches() >= 10
        # every pyramid emitted a skip map with one flag per conv level
        for p in plan.pyramids:
            assert skips[p.name].shape[-1] == p.q_convs

    def test_vgg16_topology_reduced_scale(self):
        graph = vgg16(input_size=32, num_classes=10)
        _run_and_check(graph)

    def test_layerwise_plan_same_logits(self):
        """Partitioning is semantics-free: layer-by-layer and auto plans
        produce identical logits."""
        graph = lenet5()
        params = init_network_params(graph, KEY)
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 32, 1))
        auto, _ = run_network(x, params, plan=auto_partition(graph))
        layer, _ = run_network(x, params, plan=layerwise_partition(graph))
        np.testing.assert_allclose(
            np.asarray(auto), np.asarray(layer), atol=1e-5
        )

    def test_stem_with_padded_pool_matches(self):
        """ResNet's conv7x7/2 + maxpool3x3/2(pad 1) stem as one fused launch:
        the padded-pool epilogue (zeros == -inf for post-ReLU data) is exact."""
        graph = resnet18(input_size=64, num_classes=10)
        params = init_network_params(graph, KEY)
        x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 64, 3))
        plan = auto_partition(graph)
        stem = plan.pyramid_at("conv1")
        assert stem is not None and stem.node_names == ("conv1", "maxpool")
        _run_and_check(graph, batch=1, plan=plan)


class TestSkipStatistics:
    def test_dead_input_cascades_through_lenet(self):
        """A zero image with negative biases: every level past the first
        skips, and the fractions report it."""
        graph = lenet5()
        params = init_network_params(graph, KEY)
        params = {
            k: (w, b - 10.0) if k in ("CL1", "CL2") else (w, b)
            for k, (w, b) in params.items()
        }
        x = jnp.zeros((1, 32, 32, 1))
        plan = auto_partition(graph)
        _, skips = run_network(x, params, plan=plan)
        frac = skip_fractions(skips)
        name = plan.pyramids[0].name
        assert frac[name][0] == 0.0  # level 0 never skips
        assert frac[name][1] == 1.0

    def test_dense_input_no_skips(self):
        graph = lenet5()
        params = init_network_params(graph, KEY)
        x = jax.random.normal(jax.random.PRNGKey(4), (1, 32, 32, 1))
        plan = auto_partition(graph)
        _, skips = run_network(x, params, plan=plan)
        for fr in skip_fractions(skips).values():
            assert fr[0] == 0.0


class TestParamsAndShapes:
    def test_init_covers_all_parametric_nodes(self):
        graph = resnet18(input_size=32, num_classes=10)
        params = init_network_params(graph, KEY)
        want = {n.name for n in graph.nodes if n.op in ("conv", "dense")}
        assert set(params) == want
        w, b = params["FC"]
        assert w.shape == (512, 10) and b.shape == (10,)

    def test_logits_shape_follows_num_classes(self):
        graph = lenet5(num_classes=7)
        params = init_network_params(graph, KEY)
        x = jnp.zeros((3, 32, 32, 1))
        logits, _ = run_network(x, params, plan=auto_partition(graph, batch=3))
        assert logits.shape == (3, 7)
