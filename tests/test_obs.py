"""Observability subsystem (DESIGN.md §12): tracer dispatch and overhead,
traced-run span schema, END-skip count events vs reference dead tiles,
timeline/cycle-model consistency, Chrome-trace export across the zoo, the
drift report, partition-cache counters, and the benchmark satellites
(p50/p95 stats, regression diff table)."""

import json
import pathlib
import sys

import jax
import numpy as np
import pytest

from repro.core.cnn_models import LENET5_FUSION, VGG_FUSION, resnet18_fusions
from repro.core.cycle_model import timeline_end
from repro.core.program import plan_launch
from repro.net.graph import MODELS, lenet5
from repro.net.partition import (
    auto_partition,
    clear_partition_cache,
    partition_cache_info,
)
from repro.net import runner
from repro.net.runner import (
    init_network_params,
    prepare_network_params,
    run_network,
)
from repro.obs.report import (
    drift_report,
    drift_rows_from_bench,
    drift_rows_from_spans,
)
from repro.obs.timeline import chrome_trace, validate_chrome_trace
from repro.obs.trace import NULL_TRACER, get_tracer, tracing

from test_pyramid_kernel import _expected_skip_maps

REPO = pathlib.Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:
    # benchmarks/ is a namespace package at the repo root (run via
    # ``python -m benchmarks.run``); make it importable for the satellites
    sys.path.insert(0, str(REPO))

KEY = jax.random.PRNGKey(0)


def _traced_lenet(batch=2, reps=1, bias_shift=0.0, sparse=False):
    """One traced LeNet forward (plus optional extra reps) returning
    (collector, plan, skips, raw_params, x)."""
    import jax.numpy as jnp

    graph = lenet5()
    raw = init_network_params(graph, KEY)
    if bias_shift:
        raw = {k: (w, b + bias_shift) for k, (w, b) in raw.items()}
    if sparse:
        blob = graph.input_size // 3
        x = jnp.zeros((batch, graph.input_size, graph.input_size, 1))
        x = x.at[:, :blob, :blob, :].set(5.0)
    else:
        x = jax.random.normal(
            jax.random.PRNGKey(1),
            (batch, graph.input_size, graph.input_size, 1),
        )
    plan = auto_partition(graph, batch=batch)
    params = prepare_network_params(plan, raw)
    with tracing() as collector:
        for _ in range(reps):
            _, skips = run_network(x, params, plan=plan)
    return collector, plan, skips, raw, x


class TestTracerDispatch:
    def test_default_tracer_is_noop(self):
        t = get_tracer()
        assert t is NULL_TRACER and not t.enabled

    def test_disabled_tracing_uses_unchanged_jit_path(self, monkeypatch):
        """With the no-op tracer the public run_network must hit the jit
        fast path without even touching the traced implementation — the
        dispatch check is the *only* tracing cost when disabled."""

        def boom(*a, **k):
            raise AssertionError("traced path must not run")

        monkeypatch.setattr(runner, "_run_network_traced", boom)
        graph = lenet5()
        raw = init_network_params(graph, KEY)
        plan = auto_partition(graph, batch=1)
        params = prepare_network_params(plan, raw)
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 1))
        logits, _ = run_network(x, params, plan=plan)
        assert logits.shape == (1, 10)

    def test_traced_path_matches_jit_path(self):
        """Tracing changes scheduling (eager launch-by-launch), never
        numerics: same logits either way."""
        graph = lenet5()
        raw = init_network_params(graph, KEY)
        plan = auto_partition(graph, batch=2)
        params = prepare_network_params(plan, raw)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 1))
        fast, _ = run_network(x, params, plan=plan)
        with tracing():
            traced, _ = run_network(x, params, plan=plan)
        np.testing.assert_allclose(
            np.asarray(fast), np.asarray(traced), atol=1e-6
        )

    def test_tracing_context_restores_previous(self):
        with tracing() as outer:
            with tracing() as inner:
                assert get_tracer() is inner
            assert get_tracer() is outer
        assert get_tracer() is NULL_TRACER


class TestTracedSpans:
    def test_spans_have_modeled_and_measured_fields(self):
        collector, plan, _, _, _ = _traced_lenet(batch=2, reps=2)
        assert len(collector.spans) == 2 * plan.n_launches()
        for s in collector.spans:
            assert s.model == "lenet" and s.name
            assert s.regime and s.compute_dtype == "float32"
            assert s.hbm_bytes > 0 and s.vmem_bytes > 0
            assert s.modeled_cycles > 0 and s.modeled_us > 0
            assert s.duration_ms > 0 and s.start_s > 0
            assert s.batch == 2 and s.alpha > 0 and s.q_convs > 0

    def test_run_network_summary_event(self):
        collector, plan, _, _, _ = _traced_lenet(batch=1, reps=1)
        summaries = [e for e in collector.events if e.name == "run_network"]
        assert len(summaries) == 1
        args = summaries[0].args
        assert args["launches"] == plan.n_launches()
        assert args["wallclock_ms"] > 0
        assert args["modeled_cycles"] == plan.modeled_cycles()


class TestEndSkipEvents:
    def test_skip_counts_match_reference_dead_tiles(self):
        """End-to-end satellite: the runner's per-level END-skip counts must
        equal the reference count of post-ReLU all-zero tiles, per batch
        element, on a seeded sparse input with mixed live/dead tiles.

        LeNet's auto plan covers the whole 5x5 output in one movement
        (alpha=1), so the pyramid is re-planned at out_region=1 — a 5x5
        movement grid whose border tiles go dead under the sparse blob."""
        import dataclasses

        import jax.numpy as jnp

        graph = lenet5()
        raw = init_network_params(graph, KEY)
        raw = {k: (w, b - 0.5) for k, (w, b) in raw.items()}
        blob = graph.input_size // 3
        x = jnp.zeros((2, graph.input_size, graph.input_size, 1))
        x = x.at[:, :blob, :blob, :].set(5.0)
        plan = auto_partition(graph, batch=2)
        assert len(plan.pyramids) == 1  # LeNet fuses its whole conv trunk
        pyr = dataclasses.replace(
            plan.pyramids[0],
            launch=plan_launch(
                plan.pyramids[0].spec, prefer_region="smallest"
            ),
        )
        assert pyr.launch.out_region == 1
        plan = dataclasses.replace(plan, pyramids=(pyr,))
        params = prepare_network_params(plan, raw)
        with tracing() as collector:
            _, skips = run_network(x, params, plan=plan)
        conv_names = [
            m for m in pyr.node_names if plan.graph.node(m).op == "conv"
        ]
        weights = [np.asarray(raw[m][0]) for m in conv_names]
        biases = [np.asarray(raw[m][1]) for m in conv_names]
        got = np.asarray(skips[pyr.name])
        expected = np.stack(
            [
                _expected_skip_maps(
                    pyr.spec, weights, biases, x[b : b + 1],
                    pyr.launch.out_region,
                )[0]
                for b in range(x.shape[0])
            ]
        )
        np.testing.assert_array_equal(got, expected)
        assert 0 < expected[..., 1].sum() < expected[..., 1].size, (
            "test needs mixed live/dead tiles to be meaningful"
        )
        # and the traced event aggregates the same counts
        evs = [e for e in collector.events if e.name == "end_skip_counts"]
        assert len(evs) == 1 and evs[0].args["launch"] == pyr.name
        assert evs[0].args["per_level"] == [
            int(c) for c in expected.sum(axis=(0, 1, 2))
        ]
        assert evs[0].args["cells"] == expected[..., 0].size


class TestTimelines:
    SPECS = {
        "lenet_q2": LENET5_FUSION,
        "vgg_q4": VGG_FUSION,
        "resnet18_b7": resnet18_fusions()[7],
    }

    @pytest.mark.parametrize("name", sorted(SPECS))
    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_timeline_end_equals_modeled_cycles(self, name, dtype):
        """The exported timeline is a *twin* of the cycle model: its last
        bar ends exactly at modeled_cycles (and the per-cell detail at
        body_cycles), at any elision level."""
        import dataclasses

        lp = plan_launch(self.SPECS[name], compute_dtype=dtype)
        for launch in (lp, dataclasses.replace(lp, x_slots=1, w_slots=1)):
            assert timeline_end(
                launch.modeled_timeline()
            ) == launch.modeled_cycles()
            assert timeline_end(
                launch.modeled_timeline(max_cells=4)
            ) == launch.modeled_cycles()
            detail = launch.body_detail_timeline()
            assert timeline_end(detail) == launch.body_cycles()
            for seg in launch.modeled_timeline():
                assert seg.lane in ("mxu", "dma")
                assert seg.start >= 0 and seg.duration >= 0


class TestChromeTrace:
    @pytest.mark.parametrize("model", sorted(MODELS))
    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_zoo_modeled_trace_validates(self, model, dtype):
        """Acceptance: a Perfetto-loadable trace for every zoo model at
        both compute dtypes (modeled tracks are analytic — no kernels)."""
        plan = auto_partition(MODELS[model](), compute_dtype=dtype)
        trace = chrome_trace(
            launches=[(p.name, p.launch) for p in plan.pyramids]
        )
        assert validate_chrome_trace(trace) == []
        xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
        assert len(xs) > 0
        assert all(e["cat"] in ("modeled", "modeled-detail") for e in xs)

    def test_measured_trace_round_trips(self, tmp_path):
        from repro.obs.timeline import write_chrome_trace

        collector, plan, _, _, _ = _traced_lenet(batch=1, reps=1)
        trace = chrome_trace(
            collector, launches=[(p.name, p.launch) for p in plan.pyramids]
        )
        assert validate_chrome_trace(trace) == []
        cats = {e.get("cat") for e in trace["traceEvents"]}
        assert {"modeled", "measured", "event"} <= cats
        path = tmp_path / "trace.json"
        write_chrome_trace(str(path), trace)
        assert validate_chrome_trace(json.loads(path.read_text())) == []

    def test_validator_rejects_malformed(self):
        assert validate_chrome_trace({"traceEvents": [{"ph": "Z"}]})
        assert validate_chrome_trace({"traceEvents": "nope"})
        bad_span = {"ph": "X", "name": "s", "pid": 1, "tid": 0, "ts": -1,
                    "dur": 1}
        assert validate_chrome_trace({"traceEvents": [bad_span]})


class TestDriftReport:
    def test_rows_from_traced_spans(self):
        collector, plan, _, _, _ = _traced_lenet(batch=1, reps=3)
        rows = drift_rows_from_spans(collector.spans)
        assert len(rows) == plan.n_launches()  # reps collapse to medians
        for r in rows:
            assert r["reps"] == 3
            assert r["modeled_ms"] > 0 and r["measured_ms"] > 0
        rep = drift_report(rows)
        assert rep["median_ratio"] > 0
        assert all("drift" in r and "flagged" in r for r in rep["rows"])

    def test_committed_bench_file_joins(self):
        """Acceptance: the drift report runs on BENCH_pyramid.json data."""
        with open(REPO / "BENCH_pyramid.json") as f:
            bench = json.load(f)
        rows = drift_rows_from_bench(bench)
        assert len(rows) >= 1
        rep = drift_report(rows)
        assert rep["median_ratio"] > 0

    def test_outlier_is_flagged(self):
        def row(name, measured):
            return {
                "launch": name, "regime": "resident",
                "compute_dtype": "float32", "batch": 1, "reps": 3,
                "modeled_cycles": 1000, "modeled_ms": 0.01,
                "measured_ms": measured,
            }

        rows = [row("a", 1.0), row("b", 1.1), row("c", 0.9),
                row("d", 50.0)]
        rep = drift_report(rows, flag_factor=3.0)
        assert rep["flagged"] == ["d"]

    def test_old_bench_files_skip_gracefully(self):
        """Workload rows without modeled_cycles (pre-PR-7 files) are
        skipped, not crashed on."""
        bench = {"workloads": {"old": {"wallclock_ms": 1.0}}}
        assert drift_rows_from_bench(bench) == []


class TestPartitionCacheCounters:
    def test_counters_track_hits_and_reset_on_clear(self):
        clear_partition_cache()
        info = partition_cache_info()
        assert info.hits == 0 and info.misses == 0
        g = lenet5()
        p1 = auto_partition(g, batch=3)
        p2 = auto_partition(g, batch=3)
        assert p1 is p2  # cached plan object
        info = partition_cache_info()
        assert info.misses >= 1 and info.hits >= 1
        assert info.currsize >= 1
        clear_partition_cache()
        info = partition_cache_info()
        assert info.hits == 0 and info.misses == 0 and info.currsize == 0

    def test_cache_events_traced(self):
        clear_partition_cache()
        g = lenet5()
        with tracing() as collector:
            auto_partition(g, batch=3)
            auto_partition(g, batch=3)
        evs = [e for e in collector.events if e.name == "auto_partition"]
        assert [e.args["cache"] for e in evs] == ["miss", "hit"]
        assert all(e.args["model"] == "lenet" for e in evs)


class TestExplainCLI:
    def test_table_and_trace_for_lenet(self, tmp_path, capsys):
        from repro.obs.explain import main

        out = tmp_path / "t.json"
        assert main(["--model", "lenet", "--trace", str(out)]) == 0
        text = capsys.readouterr().out
        assert "regime" in text and "partition cache" in text
        assert validate_chrome_trace(json.loads(out.read_text())) == []

    @pytest.mark.parametrize("model", sorted(MODELS))
    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_zoo_tables_render(self, model, dtype, capsys):
        """Acceptance: the plan table renders for every zoo model at both
        dtypes (analytic — no --run)."""
        from repro.obs.explain import main

        assert main(["--model", model, "--dtype", dtype]) == 0
        text = capsys.readouterr().out
        assert "total:" in text and "launches" in text


class TestBenchmarkSatellites:
    def test_timed_stats_keys_and_ordering(self):
        from benchmarks.run import _percentile_ms, _timed_stats_ms

        stats = _timed_stats_ms(lambda: None, reps=7)
        assert set(stats) == {"p50_ms", "p95_ms", "reps"}
        assert stats["reps"] == 7
        assert 0 <= stats["p50_ms"] <= stats["p95_ms"]
        assert _percentile_ms([1.0, 2.0, 3.0, 4.0], 50.0) == 2.5
        assert _percentile_ms([1.0, 2.0, 3.0, 4.0], 100.0) == 4.0
        assert _percentile_ms([5.0], 95.0) == 5.0

    @staticmethod
    def _mini_bench(hbm=100.0, cycles=50.0):
        return {
            "kernel_dataflow": {
                "launches": {
                    "w1": {
                        "hbm_bytes_total": hbm,
                        "modeled_cycles": cycles,
                        "input_bytes_halo": 10,
                        "slice_bytes": 0,
                    }
                }
            },
            "partition": {
                "m": {
                    "auto": {"hbm_bytes": 1000, "modeled_latency_us": 5.0},
                    "auto_bf16": {"hbm_bytes": 500,
                                  "modeled_latency_us": 3.0},
                }
            },
        }

    def test_diff_table_statuses(self):
        from benchmarks.check_regression import compare, diff_table

        base = self._mini_bench()
        cur = self._mini_bench(hbm=200.0, cycles=40.0)
        rows = {r["metric"]: r for r in diff_table(cur, base, 0.10)}
        assert len(rows) == 8  # every gated metric gets a row
        assert rows["kernel_dataflow/w1/hbm_bytes_total"]["status"] == "FAIL"
        assert rows["kernel_dataflow/w1/modeled_cycles"]["status"] == (
            "improved"
        )
        assert rows["partition/m/auto/hbm_bytes"]["status"] == "ok"
        assert rows["kernel_dataflow/w1/hbm_bytes_total"]["threshold"] == (
            pytest.approx(110.0)
        )
        assert len(compare(cur, base, 0.10)) == 1

    def test_diff_table_missing_metric(self):
        from benchmarks.check_regression import compare, diff_table

        base = self._mini_bench()
        cur = self._mini_bench()
        del cur["kernel_dataflow"]["launches"]["w1"]["slice_bytes"]
        rows = {r["metric"]: r for r in diff_table(cur, base, 0.10)}
        row = rows["kernel_dataflow/w1/slice_bytes"]
        assert row["status"] == "MISSING" and row["current"] is None
        assert any("missing" in line for line in compare(cur, base, 0.10))

    def test_format_diff_table_renders_every_row(self, capsys):
        from benchmarks.check_regression import diff_table, format_diff_table

        base = self._mini_bench()
        cur = self._mini_bench(hbm=200.0)
        format_diff_table(diff_table(cur, base, 0.10))
        text = capsys.readouterr().out
        assert text.count("\n") == 9  # header + 8 metric rows
        assert "FAIL" in text and "ok" in text
