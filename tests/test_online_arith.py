"""Online (MSDF) arithmetic tests: Algorithm 1, adders, SOP trees."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.online_arith import (
    from_digits,
    online_add,
    online_mul_sp,
    online_sop,
    prefix_values,
    sop_digits_fast,
    to_digits,
)

RNG = np.random.default_rng(42)


class TestEncodeDecode:
    def test_roundtrip(self):
        x = RNG.uniform(-0.999, 0.999, (256,)).astype(np.float32)
        d = to_digits(x, 20)
        assert np.all(np.isin(np.asarray(d), [-1.0, 0.0, 1.0]))
        np.testing.assert_allclose(from_digits(d), x, atol=2.0 ** -20)

    def test_digit_bound_invariant(self):
        """Prefix error of a valid SD stream is < 2**-j after j digits."""
        x = RNG.uniform(-0.99, 0.99, (64,)).astype(np.float32)
        d = to_digits(x, 16)
        pref = np.asarray(prefix_values(d))
        for j in range(16):
            assert np.all(np.abs(pref[:, j] - x) <= 2.0 ** -(j + 1) + 1e-6)

    # NOTE: hypothesis float strategies are unusable here — XLA sets FTZ/DAZ
    # FPU flags on import, which hypothesis detects and rejects.  Floats are
    # derived from integer strategies instead (same coverage, exact values).
    @given(st.lists(st.integers(-9999, 9999), min_size=1, max_size=32))
    @settings(max_examples=40, deadline=None)
    def test_roundtrip_property(self, ints):
        x = np.asarray(ints, np.float32) / 10000.0
        np.testing.assert_allclose(
            np.asarray(from_digits(to_digits(x, 18))), x, atol=2.0 ** -17
        )


class TestOnlineMultiplier:
    def test_algorithm1_vs_product(self):
        x = RNG.uniform(-0.99, 0.99, (128,)).astype(np.float32)
        y = RNG.uniform(-0.99, 0.99, (128,)).astype(np.float32)
        z = online_mul_sp(to_digits(x, 16), jnp.asarray(y), 20)
        np.testing.assert_allclose(
            np.asarray(from_digits(z)), x * y, atol=2.0 ** -14
        )

    def test_output_digits_valid(self):
        x = RNG.uniform(-0.9, 0.9, (64,)).astype(np.float32)
        y = RNG.uniform(-0.9, 0.9, (64,)).astype(np.float32)
        z = np.asarray(online_mul_sp(to_digits(x, 12), jnp.asarray(y), 16))
        assert np.all(np.isin(z, [-1.0, 0.0, 1.0]))

    def test_msdf_prefix_converges(self):
        """MSDF property: each output prefix approximates the product to
        within one unit in its last place — the enabling fact for END."""
        x = RNG.uniform(-0.9, 0.9, (64,)).astype(np.float32)
        y = RNG.uniform(-0.9, 0.9, (64,)).astype(np.float32)
        z = online_mul_sp(to_digits(x, 16), jnp.asarray(y), 16)
        pref = np.asarray(prefix_values(z))
        target = x * y
        for j in range(2, 16):
            assert np.all(np.abs(pref[:, j] - target) <= 2.0 ** -(j) + 1e-5)

    @given(st.integers(-9500, 9500), st.integers(-9500, 9500))
    @settings(max_examples=60, deadline=None)
    def test_multiplier_property(self, xi, yi):
        xv, yv = xi / 10000.0, yi / 10000.0
        x = np.float32([xv])
        y = np.float32([yv])
        z = from_digits(online_mul_sp(to_digits(x, 16), jnp.asarray(y), 20))
        assert abs(float(z[0]) - np.float32(xv) * np.float32(yv)) <= 2.0 ** -14


class TestOnlineAdder:
    def test_add_scaled(self):
        a = RNG.uniform(-0.9, 0.9, (128,)).astype(np.float32)
        b = RNG.uniform(-0.9, 0.9, (128,)).astype(np.float32)
        s = from_digits(online_add(to_digits(a, 16), to_digits(b, 16)))
        np.testing.assert_allclose(np.asarray(s), (a + b) / 2, atol=2.0 ** -14)


class TestSop:
    def test_tree_matches_dot(self):
        x = RNG.uniform(-0.9, 0.9, (32, 9)).astype(np.float32)
        y = RNG.uniform(-0.9, 0.9, (32, 9)).astype(np.float32)
        dig, depth = online_sop(to_digits(x, 14), jnp.asarray(y), 18)
        got = np.asarray(from_digits(dig)) * 2.0 ** depth
        np.testing.assert_allclose(got, (x * y).sum(-1), atol=2.0 ** -8)

    def test_fast_path_signs_agree_with_tree(self):
        from repro.core.end_detect import end_scan

        x = RNG.uniform(-0.9, 0.9, (256, 9)).astype(np.float32)
        y = RNG.uniform(-0.9, 0.9, (256, 9)).astype(np.float32)
        dig_tree, _ = online_sop(to_digits(x, 12), jnp.asarray(y), 16)
        dig_fast, _ = sop_digits_fast(jnp.asarray(x), jnp.asarray(y), 16)
        det_t, cyc_t = end_scan(dig_tree)
        det_f, cyc_f = end_scan(dig_fast)
        det_t, det_f = np.asarray(det_t), np.asarray(det_f)
        assert (det_t == det_f).mean() >= 0.98
        both = det_t & det_f
        if both.any():
            assert np.abs(np.asarray(cyc_t)[both] - np.asarray(cyc_f)[both]).max() <= 2
