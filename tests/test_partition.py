"""Auto-partitioner: DP optimality vs brute force, VMEM-budget respect,
residual-cut legality, channel-chain validation, and the VGG-16 acceptance
comparison (auto <= layer-by-layer and <= paper's blocks-1-2 fusion)."""

import pytest

from repro.core.fusion import FusedLevel, FusionSpec
from repro.core.program import VMEM_BUDGET_BYTES, plan_launch
from repro.net.graph import (
    MODELS,
    Segment,
    fusable_segments,
    infer_shapes,
    resnet18,
    vgg16,
)
from repro.net.partition import (
    auto_partition,
    brute_force_segment,
    layerwise_partition,
    paper_partition,
    partition_segment,
)


def _chain_segment(channels, size, k=3, pad=1, pools=()):
    """Linear conv chain (optional pools after given conv indices) as a
    Segment, for direct DP testing without a whole graph."""
    from repro.net.graph import Node

    nodes, prev = [], "in"
    for i, ch in enumerate(channels):
        nodes.append(Node("conv", f"c{i}", (prev,), K=k, S=1, pad=pad, n_out=ch))
        prev = f"c{i}"
        if i in pools:
            nodes.append(Node("pool", f"p{i}", (prev,), K=2, S=2))
            prev = f"p{i}"
    return Segment(nodes=tuple(nodes), input_size=size, in_channels=2, relu=True)


class TestChannelChainValidation:
    """Satellite: malformed chains fail at FusionSpec construction with a
    named level, not deep inside the kernel wrapper."""

    def test_conv_channel_mismatch_raises(self):
        with pytest.raises(ValueError, match="CONVB.*does not chain.*8"):
            FusionSpec(
                levels=(
                    FusedLevel("conv", 3, 1, 1, 2, 8, name="CONVA"),
                    FusedLevel("conv", 3, 1, 1, 4, 4, name="CONVB"),
                ),
                input_size=8,
            )

    def test_pool_must_preserve_channels(self):
        with pytest.raises(ValueError, match="pools preserve channels"):
            FusionSpec(
                levels=(
                    FusedLevel("conv", 3, 1, 1, 2, 8),
                    FusedLevel("pool", 2, 2, 0, 8, 4),
                ),
                input_size=8,
            )

    def test_pool_must_consume_previous_channels(self):
        with pytest.raises(ValueError, match="does not chain"):
            FusionSpec(
                levels=(
                    FusedLevel("conv", 3, 1, 1, 2, 8),
                    FusedLevel("pool", 2, 2, 0, 4, 4),
                ),
                input_size=8,
            )

    def test_empty_chain_raises(self):
        with pytest.raises(ValueError, match="at least one level"):
            FusionSpec(levels=(), input_size=8)

    def test_unknown_kind_raises(self):
        with pytest.raises(ValueError, match="unknown level kind"):
            FusionSpec(
                levels=(FusedLevel("norm", 3, 1, 1, 2, 2),), input_size=8
            )


class TestSegmentDP:
    BUDGETS = [64 * 1024, 256 * 1024, 1024 * 1024]

    @pytest.mark.parametrize("budget", BUDGETS)
    @pytest.mark.parametrize(
        "channels,size,pools",
        [
            ((8, 8, 8), 16, ()),
            ((4, 16, 16, 8), 20, (1,)),
            ((16, 32, 32), 12, (0,)),
            ((8, 8, 8, 8, 8), 24, (2,)),
        ],
    )
    def test_dp_matches_brute_force(self, channels, size, pools, budget):
        """DP minimum == exhaustive minimum over all 2^(G-1) cut sets."""
        seg = _chain_segment(channels, size, pools=pools)
        bf = brute_force_segment(seg, vmem_budget=budget)
        try:
            launches = partition_segment(seg, vmem_budget=budget)
        except ValueError:
            assert bf[0] == float("inf")
            return
        hbm = sum(lp.hbm_bytes(1) for lp in launches)
        cyc = sum(lp.modeled_cycles(1) for lp in launches)
        assert (hbm, cyc) == (pytest.approx(bf[0]), pytest.approx(bf[1]))

    def test_launches_tile_the_segment(self):
        seg = _chain_segment((8, 8, 16), 16, pools=(1,))
        launches = partition_segment(seg, vmem_budget=256 * 1024)
        total_levels = sum(len(lp.spec.levels) for lp in launches)
        assert total_levels == len(seg.nodes)

    def test_infeasible_group_raises_clearly(self):
        seg = _chain_segment((64, 64), 32)
        with pytest.raises(ValueError, match="fits no launch regime"):
            partition_segment(seg, vmem_budget=1024)

    def test_max_convs_1_is_layerwise(self):
        seg = _chain_segment((8, 8, 8), 16)
        launches = partition_segment(seg, max_convs=1)
        assert len(launches) == 3
        assert all(lp.spec.q_convs == 1 for lp in launches)


class TestWholeGraphPartitions:
    @pytest.mark.parametrize("name", sorted(MODELS))
    def test_vmem_budget_respected(self, name):
        """Every chosen launch — streamed or resident — fits the budget."""
        plan = auto_partition(MODELS[name]())
        for p in plan.pyramids:
            assert p.launch.vmem_bytes() <= VMEM_BUDGET_BYTES, p.name

    @pytest.mark.parametrize("name", sorted(MODELS))
    def test_pyramids_cover_all_segment_nodes_exactly_once(self, name):
        graph = MODELS[name]()
        seen = []
        for p in auto_partition(graph).pyramids:
            seen.extend(p.node_names)
        want = [n for s in fusable_segments(graph) for n in s.node_names]
        assert sorted(seen) == sorted(want)
        assert len(seen) == len(set(seen))

    def test_residual_joins_are_cut_points(self):
        """No pyramid spans an add / fork: every pyramid's nodes lie inside
        one fusable segment of the ResNet graph."""
        graph = resnet18()
        seg_of = {
            n: i
            for i, s in enumerate(fusable_segments(graph))
            for n in s.node_names
        }
        for p in auto_partition(graph).pyramids:
            owners = {seg_of[n] for n in p.node_names}
            assert len(owners) == 1, p.name
        # adds and relus are never inside any pyramid
        covered = auto_partition(graph).covered()
        for n in graph.nodes:
            if n.op in ("add", "relu"):
                assert n.name not in covered

    def test_projection_shortcuts_are_solo_pyramids(self):
        plan = auto_partition(resnet18())
        projs = [p for p in plan.pyramids if p.node_names[0].endswith("_proj")]
        assert len(projs) == 3
        for p in projs:
            assert p.q_convs == 1 and p.relu is False

    def test_vgg16_acceptance_auto_beats_both_baselines(self):
        """The PR's acceptance comparison: modeled HBM of the auto plan <=
        layer-by-layer AND <= the paper's hand-picked blocks-1-2 fusion."""
        g = vgg16()
        auto = auto_partition(g).hbm_bytes()
        assert auto <= layerwise_partition(g).hbm_bytes()
        assert auto <= paper_partition(g).hbm_bytes()

    @pytest.mark.parametrize("name", sorted(MODELS))
    def test_auto_never_worse_than_layerwise_or_paper(self, name):
        g = MODELS[name]()
        auto = auto_partition(g).hbm_bytes()
        assert auto <= layerwise_partition(g).hbm_bytes()
        assert auto <= paper_partition(g).hbm_bytes()

    def test_paper_partition_vgg_head_is_blocks_1_2(self):
        plan = paper_partition(vgg16())
        head = plan.pyramids[0]
        assert head.q_convs == 4
        assert head.node_names == (
            "CONV1", "CONV2", "POOL1", "CONV3", "CONV4", "POOL2"
        )

    def test_min_vmem_budget_is_tight(self):
        """Partitioning succeeds at the reported floor and fails below it."""
        from repro.net.partition import min_vmem_budget

        g = resnet18(input_size=32, num_classes=10)
        floor = min_vmem_budget(g)
        plan = auto_partition(g, vmem_budget=floor)
        for p in plan.pyramids:
            assert p.launch.vmem_bytes() <= floor
        with pytest.raises(ValueError, match="fits no launch regime"):
            auto_partition(g, vmem_budget=floor - 1)

    def test_smallest_region_preference(self):
        """prefer_region='smallest' yields maximal tile grids (finer END
        granularity) without changing pyramid legality."""
        g = MODELS["lenet"]()
        big = auto_partition(g)
        small = auto_partition(g, prefer_region="smallest")
        assert small.covered() == big.covered()
        for p in small.pyramids:
            assert p.launch.out_region == 1
            assert p.launch.vmem_bytes() <= VMEM_BUDGET_BYTES

    def test_batch_scales_hbm(self):
        g = vgg16()
        h1 = auto_partition(g, batch=1).hbm_bytes()
        h8 = auto_partition(g, batch=8).hbm_bytes()
        assert h1 < h8 < 8 * h1  # weights are read once, maps scale with B


class TestGraphValidation:
    def test_bad_reference_raises(self):
        from repro.net.graph import Graph, Node

        with pytest.raises(ValueError, match="not an earlier node"):
            Graph(
                "bad", 8, 1,
                (
                    Node("input", "x"),
                    Node("conv", "c", ("nope",), K=3, S=1, pad=1, n_out=4),
                ),
            )

    def test_shrunk_to_nothing_raises(self):
        from repro.net.graph import Graph, Node

        with pytest.raises(ValueError, match="leaves no"):
            Graph(
                "bad", 4, 1,
                (
                    Node("input", "x"),
                    Node("conv", "c", ("x",), K=7, S=2, n_out=4),
                ),
            )

    def test_add_shape_mismatch_raises(self):
        from repro.net.graph import Graph, Node

        with pytest.raises(ValueError, match="add operands disagree"):
            Graph(
                "bad", 8, 1,
                (
                    Node("input", "x"),
                    Node("conv", "a", ("x",), K=3, S=1, pad=1, n_out=4),
                    Node("conv", "b", ("x",), K=3, S=2, pad=1, n_out=4),
                    Node("add", "s", ("a", "b")),
                ),
            )

    def test_zoo_shapes(self):
        shp = infer_shapes(vgg16())
        assert shp["POOL5"].size == 7 and shp["POOL5"].channels == 512
        shp = infer_shapes(resnet18())
        assert shp["maxpool"].size == 56
        assert shp["b7_relu"].size == 7 and shp["b7_relu"].channels == 512

    def test_streamed_regime_appears_at_full_scale(self):
        """ResNet-18's 512-channel pair busts resident VMEM and the planner
        must fall back to streamed weights, never over budget."""
        plan = auto_partition(resnet18())
        b7 = [p for p in plan.pyramids if p.node_names[0] == "b7_convA"]
        assert b7 and b7[0].launch.streamed
        lp = plan_launch(b7[0].spec)
        assert lp.program.vmem_bytes() > VMEM_BUDGET_BYTES
        assert lp.vmem_bytes() <= VMEM_BUDGET_BYTES
