"""Software-pipelined fusion pyramid (the cross-cell prefetch PR):

* bitwise parity — the revolving two-slot input landing buffer (``x_slots=2``)
  must be bit-identical to the serial fetch-then-compute path (``x_slots=1``)
  for Q=1 and Q=4, batch > 1, a 1x1 grid (``alpha=1``: no successor cell to
  prefetch), and an all-zero input whose END cascade skips every level >= 1
  (skipped cells still issue their successor's prefetch);
* the pipeline-aware cycle model — ``grid_pipeline_cycles`` timeline
  (warm-up fill, steady state, drain), pipelined <= serial on every zoo
  workload, equality at ``alpha == 1``, VMEM accounting of the extra landing
  slot, and the ``plan_launch`` ladder pinning ``x_slots``;
* the memoized ``auto_partition`` (same plan object back, distinct keys
  distinct) and the ``weights=None`` streamed-flat API cleanup.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cnn_models import (
    ALEXNET_FUSION,
    LENET5_FUSION,
    VGG_FUSION,
    resnet18_fusions,
)
from repro.core.cycle_model import grid_pipeline_cycles
from repro.core.executor import init_pyramid_params
from repro.core.fusion import FusedLevel, FusionSpec
from repro.core.program import compile_program, plan_launch
from repro.kernels.fused_conv.ops import flatten_weights, fused_pyramid
from repro.net.graph import MODELS, lenet5
from repro.net.partition import (
    auto_partition,
    clear_partition_cache,
    partition_cache_info,
)

KEY = jax.random.PRNGKey(0)

VGG_SMALL = dataclasses.replace(VGG_FUSION, input_size=32)

Q1_CHAIN = FusionSpec(
    levels=(FusedLevel("conv", K=3, S=1, pad=1, n_in=3, n_out=8),),
    input_size=12,
)

# conv+pool, conv, conv — at out_region=4 its input halo tile outweighs the
# largest weight level, the regime where w/x slot feasibility interact
Q3_CHAIN = FusionSpec(
    levels=(
        FusedLevel("conv", K=3, S=1, pad=1, n_in=2, n_out=6),
        FusedLevel("pool", K=2, S=2, pad=0, n_in=6, n_out=6),
        FusedLevel("conv", K=3, S=1, pad=1, n_in=6, n_out=8),
        FusedLevel("conv", K=3, S=1, pad=0, n_in=8, n_out=4),
    ),
    input_size=20,
)

ZOO_SPECS = {
    "lenet": LENET5_FUSION,
    "alexnet": ALEXNET_FUSION,
    "vgg_blocks12": VGG_FUSION,
    **{f"resnet18_b{i}": s for i, s in enumerate(resnet18_fusions())},
}


def _inputs(spec, batch=1, seed=1):
    return jax.random.normal(
        jax.random.PRNGKey(seed),
        (batch, spec.input_size, spec.input_size, spec.levels[0].n_in),
    )


def _run(spec, x, region, *, x_slots, streamed=False, w_slots=None,
         biases=None):
    p = init_pyramid_params(spec, KEY)
    return fused_pyramid(
        x, p.weights, biases if biases is not None else p.biases, spec=spec,
        out_region=region, x_slots=x_slots, streamed=streamed,
        w_slots=w_slots,
    )


@pytest.mark.slow
class TestPipelinedParity:
    """x_slots=2 must be bit-identical to x_slots=1 — same MXU inputs, only
    the input-tile movement schedule differs."""

    CASES = {
        "q1": (Q1_CHAIN, 3),
        "q2_lenet": (LENET5_FUSION, 1),
        "q4_vgg": (VGG_SMALL, 4),
    }

    @pytest.mark.parametrize("name", sorted(CASES))
    @pytest.mark.parametrize("batch", [1, 3])
    def test_pipelined_matches_serial_bitwise(self, name, batch):
        spec, region = self.CASES[name]
        x = _inputs(spec, batch=batch)
        y1, s1 = _run(spec, x, region, x_slots=1)
        y2, s2 = _run(spec, x, region, x_slots=2)
        np.testing.assert_array_equal(np.asarray(y2), np.asarray(y1))
        np.testing.assert_array_equal(np.asarray(s2), np.asarray(s1))

    @pytest.mark.parametrize("w_slots", [1, 2])
    def test_pipelined_with_streamed_weights(self, w_slots):
        """Both DMA pipelines at once: revolving input landing buffer plus
        double-buffered (or blocking) weight streaming."""
        spec, region = VGG_SMALL, 4
        x = _inputs(spec, batch=2)
        y_res, s_res = _run(spec, x, region, x_slots=1)
        y_pipe, s_pipe = _run(
            spec, x, region, x_slots=2, streamed=True, w_slots=w_slots
        )
        np.testing.assert_array_equal(np.asarray(y_pipe), np.asarray(y_res))
        np.testing.assert_array_equal(np.asarray(s_pipe), np.asarray(s_res))

    def test_alpha1_no_successor_cell(self):
        """A 1x1 grid has no successor: the pipelined kernel degenerates to
        warm-up + compute and must still match (per batch element)."""
        spec = LENET5_FUSION
        out_size = spec.feature_sizes()[-1]
        assert compile_program(spec, out_size).alpha == 1
        x = _inputs(spec, batch=2)
        y1, s1 = _run(spec, x, out_size, x_slots=1)
        y2, s2 = _run(spec, x, out_size, x_slots=2)
        np.testing.assert_array_equal(np.asarray(y2), np.asarray(y1))
        np.testing.assert_array_equal(np.asarray(s2), np.asarray(s1))

    def test_all_zero_input_end_skips_every_level(self):
        """An all-zero image with non-positive biases END-skips every level
        >= 1 of every cell; skipped cells must still chain the successor
        prefetch (a stalled pipeline would deadlock/mismatch)."""
        spec = VGG_SMALL
        p = init_pyramid_params(spec, KEY)
        bs = [b - 10.0 for b in p.biases]
        x = jnp.zeros((2, spec.input_size, spec.input_size, 3))
        y1, s1 = fused_pyramid(
            x, p.weights, bs, spec=spec, out_region=4, x_slots=1
        )
        y2, s2 = fused_pyramid(
            x, p.weights, bs, spec=spec, out_region=4, x_slots=2
        )
        np.testing.assert_array_equal(np.asarray(y2), np.asarray(y1))
        np.testing.assert_array_equal(np.asarray(s2), np.asarray(s1))
        assert (np.asarray(s2)[..., 1:] == 1).all(), "cascade must skip all"

    def test_batch_boundary_chain_reset(self):
        """Batch elements differ; the prefetch chain resets at every batch
        boundary, so no batch element may see its neighbour's tiles."""
        spec = LENET5_FUSION
        p = init_pyramid_params(spec, KEY)
        x = jnp.stack(
            [jnp.zeros((32, 32, 1)), jnp.ones((32, 32, 1)), _inputs(spec)[0]]
        )
        y1, _ = fused_pyramid(x, p.weights, p.biases, spec=spec, out_region=1,
                              x_slots=1)
        y2, _ = fused_pyramid(x, p.weights, p.biases, spec=spec, out_region=1,
                              x_slots=2)
        np.testing.assert_array_equal(np.asarray(y2), np.asarray(y1))
        assert not np.allclose(np.asarray(y2)[0], np.asarray(y2)[1])


class TestPipelineCycleModel:
    def test_timeline_phases(self):
        """warm-up fill + drain + steady state: the pipelined timeline is
        fill + body + (cells-1)*max(body, fill)."""
        assert grid_pipeline_cycles(4, 10, 3, pipelined=False) == 4 * 13
        assert grid_pipeline_cycles(4, 10, 3, pipelined=True) == 3 + 10 + 3 * 10
        # DMA-bound grid: compute hides behind the fetch instead
        assert grid_pipeline_cycles(4, 3, 10, pipelined=True) == 10 + 3 + 3 * 10
        # degenerate single-cell grid: nothing to overlap
        assert grid_pipeline_cycles(1, 10, 3, pipelined=True) == 13
        assert grid_pipeline_cycles(1, 10, 3, pipelined=False) == 13

    def test_saving_is_min_term(self):
        serial = grid_pipeline_cycles(9, 7, 5, pipelined=False)
        pipe = grid_pipeline_cycles(9, 7, 5, pipelined=True)
        assert serial - pipe == (9 - 1) * min(7, 5)

    @pytest.mark.parametrize("name", sorted(ZOO_SPECS))
    def test_pipelined_never_slower_on_zoo(self, name):
        """Acceptance: modeled_cycles(pipelined) <= serial model on every zoo
        workload, strictly better whenever there is a successor cell."""
        lp = plan_launch(ZOO_SPECS[name])
        assert lp is not None
        pipe = dataclasses.replace(lp, x_slots=2)
        serial = dataclasses.replace(lp, x_slots=1)
        for batch in (1, 4):
            assert pipe.modeled_cycles(batch) <= serial.modeled_cycles(batch)
            if lp.program.alpha > 1:
                assert pipe.modeled_cycles(batch) < serial.modeled_cycles(batch)
            else:
                assert pipe.modeled_cycles(batch) == serial.modeled_cycles(batch)

    def test_serial_model_charges_input_dma(self):
        """The serial regime now costs (input_dma + body) per cell — the
        input fetch is no longer modeled as free."""
        lp = plan_launch(VGG_FUSION)
        serial = dataclasses.replace(lp, x_slots=1)
        cells = lp.program.alpha ** 2
        body_only = serial.modeled_cycles() - cells * lp.program.input_dma_cycles()
        assert body_only > 0
        assert serial.modeled_cycles() > body_only

    def test_vmem_accounts_extra_landing_slot(self):
        prog = plan_launch(VGG_FUSION).program
        c0 = prog.levels[0].n_in
        extra = 4 * prog.tile0 ** 2 * c0
        assert prog.vmem_bytes(2) - prog.vmem_bytes(1) == extra
        assert (
            prog.vmem_stream_bytes(1, 2) - prog.vmem_stream_bytes(1, 1) == extra
        )

    def test_plan_launch_pins_x_slots(self):
        """Ladder: multi-cell grids that fit the extra slot get x_slots=2;
        a 1x1 grid pins x_slots=1 (nothing to prefetch)."""
        vgg = plan_launch(VGG_FUSION)
        assert vgg.program.alpha > 1 and vgg.x_slots == 2
        lenet = plan_launch(LENET5_FUSION)
        assert lenet.program.alpha == 1 and lenet.x_slots == 1

    def test_pinned_x_slots_derives_jointly_feasible_w_slots(self):
        """With x_slots pinned to 2 and w_slots left to derive, the derived
        weight regime must be feasible *jointly* with the extra landing slot:
        under a budget where (w=2, x=2) busts but (w=1, x=2) fits, the
        launch must fall back to w_slots=1 instead of dying on the VMEM
        assert."""
        region = 4
        prog = compile_program(Q3_CHAIN, region)
        budget = prog.vmem_stream_bytes(1, 2)
        assert prog.vmem_stream_bytes(2, 1) <= budget  # x1 accounting says w2
        assert prog.vmem_stream_bytes(2, 2) > budget  # but jointly it busts
        p = init_pyramid_params(Q3_CHAIN, KEY)
        x = _inputs(Q3_CHAIN)
        y, s = fused_pyramid(
            x, p.weights, p.biases, spec=Q3_CHAIN, out_region=region,
            streamed=True, x_slots=2, vmem_budget=budget,
        )
        y_ref, s_ref = fused_pyramid(
            x, p.weights, p.biases, spec=Q3_CHAIN, out_region=region,
            streamed=False,
        )
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(s_ref))

    def test_pinned_x_slots_flows_into_stream_decision(self):
        """With x_slots pinned to 2 and streamed left to derive, the
        resident-vs-streamed decision must charge the extra landing slot:
        under a budget where resident+x2 busts but streamed+x2 fits, the
        launch must stream instead of dying on the VMEM assert."""
        region = 4
        prog = compile_program(Q3_CHAIN, region)
        budget = prog.vmem_bytes(2) - 4
        assert prog.vmem_bytes(1) <= budget  # x1 accounting says resident
        assert prog.vmem_stream_bytes(1, 2) <= budget  # streamed+x2 fits
        p = init_pyramid_params(Q3_CHAIN, KEY)
        x = _inputs(Q3_CHAIN)
        y, s = fused_pyramid(
            x, p.weights, p.biases, spec=Q3_CHAIN, out_region=region,
            x_slots=2, vmem_budget=budget,
        )
        y_ref, s_ref = fused_pyramid(
            x, p.weights, p.biases, spec=Q3_CHAIN, out_region=region,
        )
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(s_ref))

    def test_with_input_pipeline_respects_buildability(self):
        """The serial-vs-pipelined benchmark comparison uses the planner's
        own ladder rule: alpha == 1 or a busted landing slot returns the
        plan unchanged."""
        vgg = plan_launch(VGG_FUSION)
        assert vgg.with_input_pipeline().x_slots == 2
        lenet = plan_launch(LENET5_FUSION)  # alpha == 1
        assert lenet.with_input_pipeline() is lenet
        # a budget with no headroom for the extra slot keeps x_slots=1
        serial = dataclasses.replace(vgg, x_slots=1)
        assert serial.with_input_pipeline(serial.vmem_bytes()) is serial

    def test_partition_dp_consumes_pipelined_cost(self):
        """The DP's latency tiebreaker sums the launches' pipeline-aware
        cycles (not a stale serial model)."""
        plan = auto_partition(MODELS["vgg16"]())
        assert plan.modeled_cycles() == sum(
            p.launch.modeled_cycles(plan.batch) for p in plan.pyramids
        )
        serial = sum(
            dataclasses.replace(p.launch, x_slots=1).modeled_cycles(plan.batch)
            for p in plan.pyramids
        )
        assert plan.modeled_cycles() <= serial


class TestPartitionMemoization:
    def test_same_key_returns_same_plan_object(self):
        clear_partition_cache()
        g = lenet5()
        p1 = auto_partition(g)
        p2 = auto_partition(g)
        assert p1 is p2  # cache hit: identical object, stable jit identity
        info = partition_cache_info()
        assert info.hits >= 1 and info.misses >= 1

    def test_structurally_equal_graphs_share_a_plan(self):
        """Graphs are frozen dataclasses: two independently-built but equal
        graphs hash alike, so the DP runs once for both."""
        clear_partition_cache()
        p1 = auto_partition(lenet5())
        p2 = auto_partition(lenet5())
        assert p1 is p2

    def test_distinct_keys_distinct_plans(self):
        g = lenet5()
        p1 = auto_partition(g)
        p2 = auto_partition(g, batch=4)
        p3 = auto_partition(g, vmem_budget=40_000)
        assert p1 is not p2 and p1 is not p3
        assert p2.batch == 4 and p3.vmem_budget == 40_000


class TestWeightsNoneAPI:
    def test_streamed_flat_only(self):
        spec = LENET5_FUSION
        p = init_pyramid_params(spec, KEY)
        x = _inputs(spec)
        y0, s0 = fused_pyramid(
            x, p.weights, p.biases, spec=spec, out_region=1, streamed=True
        )
        y1, s1 = fused_pyramid(
            x, None, p.biases, spec=spec, out_region=1, streamed=True,
            weights_flat=flatten_weights(p.weights),
        )
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y0))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s0))

    def test_weights_none_requires_streamed_flat(self):
        spec = LENET5_FUSION
        p = init_pyramid_params(spec, KEY)
        x = _inputs(spec)
        with pytest.raises(AssertionError, match="weights=None"):
            fused_pyramid(
                x, None, p.biases, spec=spec, out_region=1, streamed=False
            )
        with pytest.raises(AssertionError, match="weights=None"):
            fused_pyramid(
                x, None, p.biases, spec=spec, out_region=1, streamed=True
            )
