"""Low-precision fused dataflow (DESIGN.md §11): bf16 end-to-end.

* bf16 parity — every dataflow regime (resident / streamed x1 / x2 /
  channel-tiled / ``weights=None`` pre-flattened) produces **bit-identical**
  bf16 outputs: the f32-accumulate-then-cast contract makes the movement
  schedule invisible at any dtype, exactly as at f32;
* bf16 accuracy — each regime is bit-close to the f32 reference (operand
  rounding only), END skip maps are dtype-invariant, and the END cascade
  fires identically at bf16;
* byte-model scaling — modeled HBM/VMEM/slice bytes of random Q=1-4
  pyramids scale exactly with ``DTYPE_BYTES`` (int32 skip flags excepted),
  as a hypothesis sweep plus a deterministic seeded fallback that runs even
  where hypothesis is stubbed;
* cycle-model scaling — DMA terms scale with bytes, MXU compute cycles
  divide by the dtype's throughput factor, bf16 plans are modeled strictly
  cheaper;
* the plan ladder re-tiers — a pyramid that must stream at f32 goes
  resident at bf16 under the same budget, and the partition DP plans the
  network accordingly;
* end-to-end — ``run_network(..., dtype=jnp.bfloat16)`` runs LeNet within
  the documented logit tolerance (the CI smoke contract), and int8 remains
  model-only (kernels raise).
"""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dtypes import (
    DTYPE_BYTES,
    canonical_dtype,
    dtype_bytes,
    jnp_dtype,
    mxu_throughput,
)
from repro.core.cycle_model import mxu_scaled_cycles
from repro.core.executor import init_pyramid_params
from repro.core.fusion import FusedLevel, FusionSpec
from repro.core.intensity import launch_dataflow
from repro.core.program import compile_program, plan_launch
from repro.kernels.fused_conv.ops import flatten_weights, fused_pyramid
from repro.net.graph import MODELS, lenet5
from repro.net.partition import auto_partition
from repro.net.runner import (
    bf16_logit_tol,
    init_network_params,
    prepare_network_params,
    reference_network,
    run_network,
)

KEY = jax.random.PRNGKey(0)

Q2_CHAIN = FusionSpec(
    levels=(
        FusedLevel("conv", K=3, S=1, pad=0, n_in=3, n_out=8),
        FusedLevel("pool", K=2, S=2, pad=0, n_in=8, n_out=8),
        FusedLevel("conv", K=3, S=1, pad=0, n_in=8, n_out=16),
    ),
    input_size=16,
)


def _inputs(spec, batch=1, seed=1):
    return jax.random.normal(
        jax.random.PRNGKey(seed),
        (batch, spec.input_size, spec.input_size, spec.levels[0].n_in),
    )


def _run(spec, x, region, *, biases=None, **kw):
    p = init_pyramid_params(spec, KEY)
    return fused_pyramid(
        x, p.weights, biases if biases is not None else p.biases, spec=spec,
        out_region=region, **kw,
    )


def _random_spec(rng: random.Random) -> FusionSpec:
    """Seeded random Q=1-4 pyramid with positive output sizes."""
    size = rng.randrange(10, 24)
    c = rng.randrange(1, 4)
    cur, levels = size, []
    for _ in range(rng.randrange(1, 5)):
        if levels and levels[-1].kind == "conv" and rng.random() < 0.3:
            if (cur - 2) // 2 + 1 < 2:
                continue
            levels.append(FusedLevel("pool", 2, 2, 0, c, c))
            cur = (cur - 2) // 2 + 1
        else:
            K = rng.randrange(1, 4)
            pad = rng.randrange(0, K // 2 + 1)
            nxt = cur + 2 * pad - K + 1
            if nxt < 2:
                continue
            c2 = rng.randrange(2, 8)
            levels.append(FusedLevel("conv", K, 1, pad, c, c2))
            c, cur = c2, nxt
    if not any(l.kind == "conv" for l in levels):
        levels = [FusedLevel("conv", 3, 1, 1, c, 4)]
    return FusionSpec(levels=tuple(levels), input_size=size)


def _assert_byte_scaling(spec: FusionSpec) -> None:
    """Every byte model scales exactly with bytes_per_val (int32 END flags
    excepted, which stay 4 bytes at any compute dtype)."""
    region = spec.feature_sizes()[-1]
    progs = {
        d: compile_program(spec, region, compute_dtype=d)
        for d in ("float32", "bfloat16", "int8")
    }
    base = progs["float32"]
    flags = DTYPE_BYTES["int32"] * base.alpha ** 2 * base.q_convs
    for d, prog in progs.items():
        r = DTYPE_BYTES[d] / DTYPE_BYTES["float32"]
        assert prog.bytes_per_val == DTYPE_BYTES[d]
        assert prog.input_hbm_bytes(1) == base.input_hbm_bytes(1) * r
        assert prog.vmem_bytes(2, 1) == base.vmem_bytes(2, 1) * r
        assert prog.vmem_stream_bytes(2, 2) == base.vmem_stream_bytes(2, 2) * r
        for streamed in (False, True):
            assert (
                prog.hbm_bytes(1, streamed=streamed) - flags
                == (base.hbm_bytes(1, streamed=streamed) - flags) * r
            )
            flow = launch_dataflow(prog, streamed=streamed)
            assert flow["skip_bytes"] == DTYPE_BYTES["int32"] * (
                prog.alpha ** 2 * prog.q_convs
            )
            assert (
                flow["input_bytes_halo"] + flow["weight_bytes"]
                + flow["output_bytes"] + flow["skip_bytes"]
                == prog.hbm_bytes(1, streamed=streamed)
            )


class TestBF16KernelParity:
    """All bf16 dataflow regimes are bit-identical to each other and
    bit-close to the f32 reference."""

    def _all_regimes(self, spec, x, region, c_tiles):
        p = init_pyramid_params(spec, KEY)
        flat = flatten_weights(p.weights, "bfloat16")
        runs = {
            "resident": _run(spec, x, region, compute_dtype="bfloat16"),
            "stream_x1": _run(
                spec, x, region, streamed=True, w_slots=1, x_slots=1,
                compute_dtype="bfloat16",
            ),
            "stream_x2": _run(
                spec, x, region, streamed=True, w_slots=2, x_slots=2,
                compute_dtype="bfloat16",
            ),
            "ktiled": _run(
                spec, x, region, streamed=True, w_slots=2, c_tiles=c_tiles,
                compute_dtype="bfloat16",
            ),
            "flat": fused_pyramid(
                x, None, p.biases, spec=spec, out_region=region,
                streamed=True, w_slots=2, weights_flat=flat,
                compute_dtype="bfloat16",
            ),
        }
        return runs

    @pytest.mark.parametrize("batch", [1, 2])
    def test_regimes_bitwise_identical(self, batch):
        x = _inputs(Q2_CHAIN, batch=batch)
        runs = self._all_regimes(Q2_CHAIN, x, 5, c_tiles=2)
        y0, s0 = runs.pop("resident")
        assert y0.dtype == jnp.bfloat16
        for name, (y, s) in runs.items():
            np.testing.assert_array_equal(
                np.asarray(y0), np.asarray(y), err_msg=name
            )
            np.testing.assert_array_equal(
                np.asarray(s0), np.asarray(s), err_msg=name
            )

    def test_bit_close_to_f32(self):
        x = _inputs(Q2_CHAIN)
        y32, s32 = _run(Q2_CHAIN, x, 5)
        y16, s16 = _run(Q2_CHAIN, x, 5, compute_dtype="bfloat16")
        # skip maps are dtype-invariant; outputs differ by operand rounding
        np.testing.assert_array_equal(np.asarray(s32), np.asarray(s16))
        err = float(jnp.max(jnp.abs(y32 - y16.astype(jnp.float32))))
        scale = float(jnp.max(jnp.abs(y32)))
        assert err <= 0.02 * max(scale, 1.0), (err, scale)

    def test_end_cascade_fires_at_bf16(self):
        """A dead input (zero image, biases <= 0, so level 0's post-ReLU
        tile is all zero) must skip levels >= 1 at bf16 exactly as at f32."""
        spec = Q2_CHAIN
        x = jnp.zeros((1, spec.input_size, spec.input_size,
                       spec.levels[0].n_in))
        biases = [-0.1 * jnp.ones((l.n_out,)) for l in spec.levels
                  if l.kind == "conv"]
        for kw in ({}, {"streamed": True, "w_slots": 2},
                   {"streamed": True, "w_slots": 2, "c_tiles": 2}):
            _, skip = _run(
                spec, x, 1, biases=biases, compute_dtype="bfloat16", **kw
            )
            assert np.asarray(skip)[..., 1:].all(), kw

    def test_weights_flat_dtype_mismatch_rejected(self):
        p = init_pyramid_params(Q2_CHAIN, KEY)
        flat32 = flatten_weights(p.weights, "float32")
        with pytest.raises(AssertionError, match="weights_flat dtype"):
            fused_pyramid(
                _inputs(Q2_CHAIN), None, p.biases, spec=Q2_CHAIN,
                out_region=5, streamed=True, w_slots=2, weights_flat=flat32,
                compute_dtype="bfloat16",
            )

    def test_int8_is_model_only(self):
        with pytest.raises(NotImplementedError, match="int8"):
            _run(Q2_CHAIN, _inputs(Q2_CHAIN), 5, compute_dtype="int8")


class TestDtypeTable:
    def test_canonical_accepts_names_and_jnp_dtypes(self):
        assert canonical_dtype("bfloat16") == "bfloat16"
        assert canonical_dtype(jnp.bfloat16) == "bfloat16"
        assert canonical_dtype(np.float32) == "float32"
        assert dtype_bytes(jnp.bfloat16) == 2
        assert jnp_dtype("bfloat16") == jnp.bfloat16

    def test_unknown_dtype_fails_at_plan_time(self):
        with pytest.raises(KeyError, match="float16"):
            canonical_dtype("float16")
        with pytest.raises(KeyError):
            compile_program(Q2_CHAIN, 5, compute_dtype="float64")

    def test_mxu_throughput_factors(self):
        assert mxu_throughput("float32") == 1
        assert mxu_throughput("bfloat16") == 2
        assert mxu_throughput("int8") == 4
        assert mxu_scaled_cycles(101, "bfloat16") == 51  # ceil division
        assert mxu_scaled_cycles(101, "float32") == 101


class TestByteModelScaling:
    """Modeled bytes scale exactly with bytes_per_val — the property that
    keeps the planner's f32/bf16 comparisons honest."""

    def test_seeded_random_pyramids(self):
        rng = random.Random(1234)
        for _ in range(40):
            _assert_byte_scaling(_random_spec(rng))

    @given(st.integers(0, 10_000))
    @settings(max_examples=60, deadline=None)
    def test_hypothesis_random_pyramids(self, seed):
        _assert_byte_scaling(_random_spec(random.Random(seed)))

    def test_slice_bytes_scale(self):
        lp32 = plan_launch(Q2_CHAIN)
        lp16 = plan_launch(Q2_CHAIN, compute_dtype="bfloat16")
        if lp32.c_tiles == lp16.c_tiles:
            assert lp16.slice_bytes() * 2 == lp32.slice_bytes()


class TestCycleModelScaling:
    def test_bf16_strictly_cheaper(self):
        lp32 = plan_launch(Q2_CHAIN)
        lp16 = plan_launch(Q2_CHAIN, compute_dtype="bfloat16")
        assert lp16.modeled_cycles(1) < lp32.modeled_cycles(1)
        assert lp16.hbm_bytes(1) < lp32.hbm_bytes(1)

    def test_input_dma_cycles_halve(self):
        p32 = compile_program(Q2_CHAIN, 5)
        p16 = compile_program(Q2_CHAIN, 5, compute_dtype="bfloat16")
        # ceil-divided, so allow the +-1 rounding of halved byte counts
        assert p16.input_dma_cycles() <= -(-p32.input_dma_cycles() // 2) + 1


class TestPlanReTiering:
    """Halved bytes flip regimes: a pyramid that busts VMEM resident at f32
    fits resident at bf16 under the same budget."""

    # weights ~ 3*3*64*64*2 convs = 294912 floats = 1.15 MiB f32
    FAT = FusionSpec(
        levels=(
            FusedLevel("conv", K=3, S=1, pad=1, n_in=64, n_out=64),
            FusedLevel("conv", K=3, S=1, pad=1, n_in=64, n_out=64),
        ),
        input_size=16,
    )

    def _budget(self):
        # between the bf16 and f32 resident working sets of the best region
        lo = min(
            compile_program(self.FAT, r, compute_dtype="bfloat16").vmem_bytes()
            for r in (1, 2, 4, 8, 16)
        )
        hi = min(
            compile_program(self.FAT, r).vmem_bytes()
            for r in (1, 2, 4, 8, 16)
        )
        assert lo < hi
        return (lo + hi) // 2

    def test_streamed_flips_resident(self):
        budget = self._budget()
        lp32 = plan_launch(self.FAT, vmem_budget=budget)
        lp16 = plan_launch(
            self.FAT, vmem_budget=budget, compute_dtype="bfloat16"
        )
        assert lp32 is None or lp32.streamed
        assert lp16 is not None and not lp16.streamed

    def test_partition_dp_is_dtype_aware(self):
        g = lenet5(input_size=32)
        p32 = auto_partition(g, batch=1)
        p16 = auto_partition(g, batch=1, compute_dtype="bfloat16")
        assert p32.compute_dtype == "float32"
        assert p16.compute_dtype == "bfloat16"
        assert p16 is not p32
        assert p16.hbm_bytes() * 2 <= p32.hbm_bytes() + 4 * 1024  # flag slack
        # a graph built bf16 plans bf16 by default
        g16 = lenet5(input_size=32, compute_dtype="bfloat16")
        assert auto_partition(g16, batch=1).compute_dtype == "bfloat16"


class TestNetworkBF16:
    """The CI smoke contract: LeNet end-to-end at bf16 within the
    documented logit tolerance of the f32 reference."""

    def test_lenet_bf16_within_tolerance(self):
        g = lenet5(input_size=32, num_classes=10)
        x = _inputs_net(g, batch=2)
        params = init_network_params(g, KEY)
        ref = reference_network(x, g, params)
        plan = auto_partition(g, batch=2, compute_dtype="bfloat16")
        prepped = prepare_network_params(plan, params)
        logits, _ = run_network(x, prepped, plan=plan)
        assert logits.dtype == jnp.bfloat16
        err = float(jnp.max(jnp.abs(logits.astype(jnp.float32) - ref)))
        assert err <= bf16_logit_tol(ref), (err, bf16_logit_tol(ref))

    @pytest.mark.slow
    @pytest.mark.parametrize("model", sorted(MODELS))
    def test_zoo_bf16_within_tolerance(self, model):
        # the acceptance sweep: every zoo model end-to-end at bf16 stays
        # within the documented logit tolerance of its f32 reference
        # (reduced spatial scale so interpret mode stays tractable; the
        # partitioner and kernels are the same code as paper scale)
        size = 32 if model != "alexnet" else 67
        g = MODELS[model](input_size=size, num_classes=10)
        x = _inputs_net(g, batch=1)
        params = init_network_params(g, KEY)
        ref = reference_network(x, g, params)
        plan = auto_partition(g, batch=1, compute_dtype="bfloat16")
        prepped = prepare_network_params(plan, params)
        logits, _ = run_network(x, prepped, plan=plan)
        assert logits.dtype == jnp.bfloat16
        err = float(jnp.max(jnp.abs(logits.astype(jnp.float32) - ref)))
        assert err <= bf16_logit_tol(ref), (model, err, bf16_logit_tol(ref))

    def test_dtype_override_accepts_jnp_dtype(self):
        g = lenet5(input_size=32, num_classes=10)
        x = _inputs_net(g, batch=1)
        params = init_network_params(g, KEY)
        plan = auto_partition(g, batch=1, compute_dtype="bfloat16")
        prepped = prepare_network_params(plan, params)
        a, _ = run_network(x, prepped, plan=plan, dtype=jnp.bfloat16)
        b, _ = run_network(x, prepped, plan=plan)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _inputs_net(graph, batch=1, seed=3):
    return jax.random.normal(
        jax.random.PRNGKey(seed),
        (batch, graph.input_size, graph.input_size, graph.in_channels),
    )
