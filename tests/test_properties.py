"""System-invariant property tests (hypothesis).

* random fusion pyramids: fused tile execution == monolithic execution
* MoE dispatch: capacity accounting, routing exactness without drops
* chunked CE == naive CE
* fused_conv kernel VMEM budget honored for planned configs
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.executor import (
    fused_forward,
    init_pyramid_params,
    reference_forward,
)
from repro.core.fusion import FusedLevel, FusionSpec, lockstep_plan


@st.composite
def runnable_chain(draw):
    """Random conv/pool chain guaranteed to have positive output size."""
    size = draw(st.integers(12, 28))
    n_levels = draw(st.integers(1, 3))
    levels = []
    c = draw(st.integers(1, 3))
    cur = size
    for _ in range(n_levels):
        kind = draw(st.sampled_from(["conv", "conv", "pool"]))
        if kind == "conv":
            K = draw(st.integers(1, 4))
            S = draw(st.integers(1, 2))
            pad = draw(st.integers(0, max(0, K // 2)))
            nxt = (cur + 2 * pad - K) // S + 1
            if nxt < 2:
                continue
            c2 = draw(st.integers(1, 4))
            levels.append(FusedLevel("conv", K, S, pad, c, c2))
            c, cur = c2, nxt
        else:
            K = draw(st.integers(2, 3))
            nxt = (cur - K) // K + 1
            if nxt < 2:
                continue
            levels.append(FusedLevel("pool", K, K, 0, c, c))
            cur = nxt
    if not levels:
        levels = [FusedLevel("conv", 3, 1, 1, c, 2)]
    return FusionSpec(levels=tuple(levels), input_size=size)


class TestFusedExecutorProperty:
    @given(runnable_chain(), st.integers(1, 4), st.integers(0, 100))
    @settings(max_examples=25, deadline=None)
    def test_fused_equals_reference_on_random_pyramids(self, spec, region, seed):
        """THE system invariant: any fusion plan computes exactly what the
        monolithic network computes."""
        out_size = spec.feature_sizes()[-1]
        if out_size < 1:
            return
        region = min(region, out_size)
        params = init_pyramid_params(spec, jax.random.PRNGKey(seed))
        x = jax.random.normal(
            jax.random.PRNGKey(seed + 1),
            (1, spec.input_size, spec.input_size, spec.levels[0].n_in),
        )
        ref = reference_forward(x, spec, params)
        fused = fused_forward(x, spec, params, lockstep_plan(spec, region))
        np.testing.assert_allclose(
            np.asarray(fused), np.asarray(ref), atol=2e-4,
            err_msg=f"spec={spec} region={region}",
        )


class TestMoEInvariants:
    def _route(self, T, E, k, capacity, seed=0):
        from repro.models.moe import dispatch_combine, route_topk

        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (1, T, 8))
        logits = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, T, E))
        idx, w = route_topk(logits, k)
        disp, comb = dispatch_combine(x, idx, w, E, capacity)
        return x, idx, w, disp, comb

    @given(st.integers(8, 64), st.integers(2, 8), st.integers(1, 2),
           st.integers(0, 20))
    @settings(max_examples=30, deadline=None)
    def test_capacity_never_exceeded(self, T, E, k, seed):
        """Each (expert, slot) is claimed by at most one token: the combine
        tensor (G,T,E,C) has at most one nonzero along T per (e,c)."""
        cap = max(1, T * k // E)
        x, idx, w, disp, comb = self._route(T, E, k, cap, seed)
        occupancy = (np.asarray(comb) > 1e-9).sum(axis=1)  # (G, E, C)
        assert occupancy.max() <= 1

    @given(st.integers(8, 48), st.integers(2, 8), st.integers(1, 2),
           st.integers(0, 10))
    @settings(max_examples=30, deadline=None)
    def test_no_drops_means_exact_routing(self, T, E, k, seed):
        """With capacity >= T*k no token is dropped: combine weights per
        token sum to 1 (softmax over selected experts)."""
        x, idx, w, disp, comb = self._route(T, E, k, T * k, seed)
        weight_per_token = np.asarray(comb.sum(axis=(2, 3)))  # (G, T)
        np.testing.assert_allclose(weight_per_token, 1.0, atol=1e-5)

    def test_dropped_tokens_lose_weight(self):
        x, idx, w, disp, comb = self._route(64, 2, 2, 1, seed=3)
        weight_per_token = np.asarray(comb.sum(axis=(2, 3)))
        assert weight_per_token.min() < 0.999  # someone got dropped


class TestChunkedCE:
    def test_matches_naive_ce(self):
        from repro.configs import get_config
        from repro.models.model import chunked_ce, hidden_forward, init_params, logits_fn

        cfg = dataclasses.replace(get_config("deepseek_7b").reduced(), dtype="float32")
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0, cfg.vocab)
        hidden, _, _ = hidden_forward(cfg, params, toks[:, :-1])
        targets = toks[:, 1:]
        loss_chunked = chunked_ce(cfg, params, hidden, targets, chunk=8)
        logits = logits_fn(cfg, params, hidden).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        naive = -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()
        np.testing.assert_allclose(
            float(loss_chunked), float(naive), rtol=1e-5
        )

    def test_chunk_size_invariance(self):
        from repro.configs import get_config
        from repro.models.model import chunked_ce, hidden_forward, init_params

        cfg = dataclasses.replace(get_config("phi4_mini_3_8b").reduced(), dtype="float32")
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 25), 0, cfg.vocab)
        hidden, _, _ = hidden_forward(cfg, params, toks[:, :-1])
        losses = [
            float(chunked_ce(cfg, params, hidden, toks[:, 1:], chunk=c))
            for c in (3, 8, 24)
        ]
        np.testing.assert_allclose(losses, losses[0], rtol=1e-5)
