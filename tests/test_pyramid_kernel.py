"""Variadic fusion-pyramid kernel: single-launch parity across depths
(Q=2/3/4, strided ResNet blocks), cascaded END skip flags vs reference
intermediates and Algorithm-2 END detection, and VMEM-driven chunking."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cnn_models import (
    LENET5_FUSION,
    VGG_FUSION,
    resnet18_fusions,
)
from repro.core.end_detect import end_scan
from repro.core.executor import (
    PyramidParams,
    _conv2d,
    fused_forward,
    init_pyramid_params,
    reference_forward,
)
from repro.core.fusion import FusedLevel, FusionSpec, lockstep_plan
from repro.core.online_arith import to_digits
from repro.core.program import compile_program, pick_out_region
from repro.kernels.fused_conv.ops import (
    fused_pyramid,
    fused_pyramid_chain,
    plan_chunks,
)

KEY = jax.random.PRNGKey(0)

VGG_SMALL = dataclasses.replace(VGG_FUSION, input_size=32)  # Q=4, fast in interpret

# synthetic odd-Q chain: conv+pool, conv, conv (Q=3) — the shape the old
# 2-conv kernel could not express and the old chain rejected outright
Q3_CHAIN = FusionSpec(
    levels=(
        FusedLevel("conv", K=3, S=1, pad=1, n_in=2, n_out=6),
        FusedLevel("pool", K=2, S=2, pad=0, n_in=6, n_out=6),
        FusedLevel("conv", K=3, S=1, pad=1, n_in=6, n_out=8),
        FusedLevel("conv", K=3, S=1, pad=0, n_in=8, n_out=4),
    ),
    input_size=20,
)

# (spec, out_region, atol) — the acceptance set: each must run as ONE launch
PARITY_CASES = {
    "lenet_q2": (LENET5_FUSION, 1, 1e-5),
    "odd_q3": (Q3_CHAIN, 4, 1e-5),
    "vgg_q4": (VGG_SMALL, 4, 1e-5),
    "resnet18_strided_blk": (resnet18_fusions()[2], 14, 1e-4),
}


def _inputs(spec, batch=1, seed=1):
    return jax.random.normal(
        jax.random.PRNGKey(seed),
        (batch, spec.input_size, spec.input_size, spec.levels[0].n_in),
    )


class TestSingleLaunchParity:
    @pytest.mark.parametrize("name", sorted(PARITY_CASES))
    def test_kernel_vs_fused_vs_reference(self, name):
        """Kernel == fused executor == monolithic reference, one launch."""
        spec, region, atol = PARITY_CASES[name]
        assert len(plan_chunks(spec)) == 1, "must fit a single kernel launch"
        p = init_pyramid_params(spec, KEY)
        x = _inputs(spec)
        y, skip = fused_pyramid(x, p.weights, p.biases, spec=spec, out_region=region)
        ref = reference_forward(x, spec, PyramidParams(p.weights, p.biases))
        fused = fused_forward(
            x, spec, PyramidParams(p.weights, p.biases), lockstep_plan(spec, region)
        )
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=atol)
        np.testing.assert_allclose(np.asarray(fused), np.asarray(ref), atol=atol)
        alpha = spec.feature_sizes()[-1] // region
        assert skip.shape == (1, alpha, alpha, spec.q_convs)  # per-level maps

    def test_full_scale_specs_plan_single_launch(self):
        """At paper scale (224^2 VGG, all ResNet-18 blocks) the compiler still
        finds a VMEM-feasible single-launch program — no forced chunking."""
        assert len(plan_chunks(VGG_FUSION)) == 1
        for spec in resnet18_fusions():
            assert len(plan_chunks(spec)) == 1

    def test_resnet_last_block_streams_weights(self):
        """ResNet-18's 512-channel block busts resident VMEM (two 3x3x512x512
        weight tensors alone > 16 MiB) but fits with per-level streaming, and
        the streamed kernel stays exact."""
        spec = resnet18_fusions()[7]
        region = pick_out_region(spec)
        prog = compile_program(spec, region)
        assert prog.vmem_bytes() > 16 * 1024 * 1024
        assert prog.vmem_stream_bytes() < 16 * 1024 * 1024
        p = init_pyramid_params(spec, KEY)
        x = _inputs(spec)
        y, _ = fused_pyramid(x, p.weights, p.biases, spec=spec, out_region=region)
        ref = reference_forward(x, spec, PyramidParams(p.weights, p.biases))
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4)


def _conv_group_ends(spec):
    """Spec-level index just past each conv's group (conv + trailing pools)."""
    ends, cur = [], 0
    for l, lvl in enumerate(spec.levels):
        if lvl.kind == "conv" and cur:
            ends.append(cur)
        cur = l + 1
    ends.append(cur)
    return ends


def _expected_skip_maps(spec, weights, biases, x, region):
    """Dead-tile maps from reference intermediates: the kernel must flag conv
    level l+1 exactly where the post-level-l tile (mask + pool applied, i.e.
    the window of the reference map clipped to the valid range) is all zero."""
    prog = compile_program(spec, region)
    ends = _conv_group_ends(spec)
    maps = []
    for ci, end in enumerate(ends):
        sub = FusionSpec(levels=spec.levels[:end], input_size=spec.input_size)
        params = PyramidParams(list(weights[: ci + 1]), list(biases[: ci + 1]))
        maps.append(np.asarray(reference_forward(x, sub, params)))
    expected = np.zeros((prog.alpha, prog.alpha, prog.q_convs), np.int32)
    for l in range(prog.q_convs - 1):
        p = prog.levels[l]
        if p.pool is not None:
            ob, os_, n, valid = p.pool_o_base, p.pool_o_step, p.pool_out, p.pool_valid
        else:
            ob, os_, n, valid = p.o_base, p.o_step, p.out_size, p.valid
        for i in range(prog.alpha):
            for j in range(prog.alpha):
                r0, c0 = ob + i * os_, ob + j * os_
                sub = maps[l][
                    0,
                    max(r0, 0) : min(r0 + n, valid),
                    max(c0, 0) : min(c0 + n, valid),
                    :,
                ]
                if sub.size == 0 or sub.max() <= 0.0:
                    expected[i, j, l + 1] = 1
    return expected, prog


class TestEndCascade:
    def test_full_cascade_all_levels_skip(self):
        """Strongly negative biases kill every level: level 1's input tile is
        all zero, its closed form relu(b) is zero too, so the cascade
        short-circuits the whole remaining pyramid — and stays bit-exact."""
        spec = Q3_CHAIN
        p = init_pyramid_params(spec, KEY)
        bs = [b - 10.0 for b in p.biases]
        x = _inputs(spec)
        y, skip = fused_pyramid(x, p.weights, bs, spec=spec, out_region=4)
        ref = reference_forward(x, spec, PyramidParams(p.weights, bs))
        skip = np.asarray(skip)
        assert (skip[..., 0] == 0).all()  # level 0 always computes
        assert (skip[..., 1] == 1).all()
        assert (skip[..., 2] == 1).all()  # cascaded: const tile is zero too
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)

    @pytest.mark.parametrize(
        "spec,region,shift",
        [(LENET5_FUSION, 1, -0.5), (Q3_CHAIN, 1, -0.4)],
        ids=["lenet_q2", "odd_q3"],
    )
    def test_skip_flags_match_reference_dead_tiles(self, spec, region, shift):
        """Per-level skip flags == dead-tile maps from reference
        intermediates, on spatially sparse input with mixed live/dead tiles;
        output stays exact on both paths."""
        p = init_pyramid_params(spec, KEY)
        bs = [b + shift for b in p.biases]
        blob = spec.input_size // 3
        x = jnp.zeros(
            (1, spec.input_size, spec.input_size, spec.levels[0].n_in)
        ).at[:, :blob, :blob, :].set(5.0)
        y, skip = fused_pyramid(x, p.weights, bs, spec=spec, out_region=region)
        expected, _ = _expected_skip_maps(spec, p.weights, bs, x, region)
        np.testing.assert_array_equal(np.asarray(skip)[0], expected)
        assert 0 < expected[..., 1].sum() < expected[..., 1].size, (
            "test needs mixed live/dead tiles to be meaningful"
        )
        ref = reference_forward(x, spec, PyramidParams(p.weights, bs))
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)

    def test_skip_flags_agree_with_end_detect(self):
        """A tile skips at level 1 iff no SOP of conv level 0 in its window is
        positive — exactly the population Algorithm 2 (END) classifies.  The
        kernel's skip count must equal the count of tiles whose every SOP is
        END-detected-negative or non-positive, and END must stay sound."""
        spec = LENET5_FUSION
        p = init_pyramid_params(spec, KEY)
        bs = [p.biases[0] - 0.5, p.biases[1]]
        blob = spec.input_size // 3
        x = jnp.zeros((1, spec.input_size, spec.input_size, 1))
        x = x.at[:, :blob, :blob, :].set(5.0)
        region = 1
        _, skip = fused_pyramid(x, p.weights, bs, spec=spec, out_region=region)
        skip = np.asarray(skip)[0]
        prog = compile_program(spec, region)
        lvl0, p0 = prog.levels[0], spec.levels[0]
        # pre-ReLU SOPs of conv level 0 over the whole map
        z0 = np.asarray(_conv2d(x, p.weights[0], bs[0], p0.S, p0.pad))[0]
        end_dead = np.zeros((prog.alpha, prog.alpha), np.int32)
        for i in range(prog.alpha):
            for j in range(prog.alpha):
                r0 = lvl0.o_base + i * lvl0.o_step
                c0 = lvl0.o_base + j * lvl0.o_step
                sub = z0[
                    max(r0, 0) : min(r0 + lvl0.out_size, lvl0.valid),
                    max(c0, 0) : min(c0 + lvl0.out_size, lvl0.valid),
                    :,
                ].reshape(-1)
                if sub.size == 0:
                    end_dead[i, j] = 1
                    continue
                scale = 2.0 * max(1.0, float(np.abs(sub).max()))
                det, _ = end_scan(to_digits(jnp.asarray(sub / scale), 24))
                det = np.asarray(det)
                # Algorithm 2 soundness: a flagged SOP is strictly negative
                assert not np.any(det & (sub >= 0))
                # tile is END-dead iff every SOP is detected-negative or <= 0
                end_dead[i, j] = int(np.all(det | (sub <= 0)))
        np.testing.assert_array_equal(skip[..., 1], end_dead)
        assert skip[..., 1].sum() == end_dead.sum()
        assert 0 < end_dead.sum() < end_dead.size


class TestChainChunking:
    def test_odd_q_single_chunk_regression(self):
        """Regression for the old hard error: `fused_pyramid_chain` asserted
        an even conv count, so any odd-Q chain died.  Odd Q now runs — as a
        single launch when VMEM allows."""
        p = init_pyramid_params(Q3_CHAIN, KEY)
        x = _inputs(Q3_CHAIN)
        y, skips = fused_pyramid_chain(x, p.weights, p.biases, spec=Q3_CHAIN)
        assert len(skips) == 1 and skips[0].shape[-1] == 3
        ref = reference_forward(x, Q3_CHAIN, PyramidParams(p.weights, p.biases))
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)

    def test_odd_q_capped_chunks_leave_remainder(self):
        """With an explicit Q=2 cap the odd conv becomes a final Q=1 chunk
        instead of a hard error."""
        p = init_pyramid_params(Q3_CHAIN, KEY)
        x = _inputs(Q3_CHAIN)
        chunks = plan_chunks(Q3_CHAIN, max_convs_per_chunk=2)
        assert [c.q_convs for c in chunks] == [2, 1]
        y, skips = fused_pyramid_chain(
            x, p.weights, p.biases, spec=Q3_CHAIN, max_convs_per_chunk=2
        )
        assert len(skips) == 2
        ref = reference_forward(x, Q3_CHAIN, PyramidParams(p.weights, p.biases))
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)

    def test_infeasible_budget_raises_clearly(self):
        """A budget too small for even one conv group is a planning error,
        not a crash inside the launch with circular 'go chunk' advice."""
        with pytest.raises(ValueError, match="does not fit .* even alone"):
            plan_chunks(LENET5_FUSION, vmem_budget=1024)

    def test_tiny_vmem_budget_forces_chunking(self):
        """The chain chunks exactly when the budget forces it: a budget too
        small for the fused working set splits the chain, and the chunked
        result still matches the reference."""
        spec = Q3_CHAIN
        single = plan_chunks(spec)
        assert len(single) == 1
        out_size = spec.feature_sizes()[-1]
        budget = min(
            compile_program(spec, r).vmem_stream_bytes()
            for r in range(1, out_size + 1)
            if out_size % r == 0
        ) - 1
        forced = plan_chunks(spec, vmem_budget=budget)
        assert len(forced) > 1
        p = init_pyramid_params(spec, KEY)
        x = _inputs(spec)
        y, skips = fused_pyramid_chain(
            x, p.weights, p.biases, spec=spec, vmem_budget=budget
        )
        assert len(skips) == len(forced)
        ref = reference_forward(x, spec, PyramidParams(p.weights, p.biases))
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)
