"""Guarded-runtime unit tests: typed errors, preflight validation, guard
dispatch, and the replan entry point (DESIGN.md §13).

The chaos suite (``tests/test_chaos.py``) proves the degradation ladder end
to end; this file pins the pieces: every preflight rejection carries a
typed error naming the offending node/launch, the error hierarchy stays
compatible with the historical ``ValueError`` call sites, and — critically
— with guards off ``run_network`` dispatches to the unchanged jit fast
path.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.core.fusion import FusedLevel, FusionSpec
from repro.core.program import compile_program, plan_launch
from repro.net.graph import MODELS, Node, Segment, fusable_segments
from repro.net.partition import (
    auto_partition, partition_segment, replan_pyramid,
)
from repro.net.runner import (
    _head_op,
    init_network_params,
    prepare_network_params,
    run_network,
)
from repro.robust import (
    BudgetError,
    GuardConfig,
    NumericError,
    PlanError,
    PreflightError,
    RobustError,
    guarding,
    preflight,
)
from repro.robust.faults import corrupt_params
from repro.robust.guard import get_guard, sentinel_stats, sentinel_trips


@pytest.fixture(scope="module")
def lenet_setup():
    g = MODELS["lenet"]()
    params = init_network_params(g, jax.random.PRNGKey(0))
    plan = auto_partition(g, batch=2)
    prepped = prepare_network_params(plan, params)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 1))
    return g, params, plan, prepped, x


class TestErrorHierarchy:
    def test_valueerror_compat(self):
        """Typed errors must keep historical except-clauses working."""
        assert issubclass(PreflightError, ValueError)
        assert issubclass(BudgetError, ValueError)
        assert issubclass(PlanError, PreflightError)
        assert issubclass(NumericError, FloatingPointError)
        assert issubclass(PreflightError, RobustError)

    def test_context_rides_in_message_and_attr(self):
        e = PreflightError("bad node", node="CL1", graph="lenet")
        assert e.context == {"node": "CL1", "graph": "lenet"}
        assert "CL1" in str(e) and "lenet" in str(e)


class TestPreflight:
    def test_clean_setup_passes(self, lenet_setup):
        g, params, plan, prepped, x = lenet_setup
        assert preflight(x, prepped, plan=plan) == "float32"

    def test_bad_input_rank(self, lenet_setup):
        g, params, plan, prepped, x = lenet_setup
        with pytest.raises(PreflightError, match="B, H, W, C"):
            preflight(x[0], prepped, plan=plan)

    def test_bad_spatial(self, lenet_setup):
        g, params, plan, prepped, x = lenet_setup
        with pytest.raises(PreflightError, match="spatial"):
            preflight(x[:, :16], prepped, plan=plan)

    def test_bad_channels(self, lenet_setup):
        g, params, plan, prepped, x = lenet_setup
        bad = jnp.concatenate([x, x], axis=-1)
        with pytest.raises(PreflightError, match="channels"):
            preflight(bad, prepped, plan=plan)

    def test_unknown_dtype(self, lenet_setup):
        g, params, plan, prepped, x = lenet_setup
        with pytest.raises(PreflightError, match="unknown compute dtype"):
            preflight(x, prepped, plan=plan, dtype="float8_e4m3")

    def test_int8_is_modeled_only(self, lenet_setup):
        """int8 hits the EXEC_DTYPES gate at preflight, not as a kernel
        NotImplementedError three layers down."""
        g, params, plan, prepped, x = lenet_setup
        with pytest.raises(PreflightError, match="not executable"):
            preflight(x, prepped, plan=plan, dtype="int8")

    def test_missing_node_params(self, lenet_setup):
        g, params, plan, prepped, x = lenet_setup
        short = {k: v for k, v in prepped.items() if k != "CL2"}
        with pytest.raises(PreflightError, match="missing params.*CL2"):
            preflight(x, short, plan=plan)

    def test_wrong_weight_shape(self, lenet_setup):
        g, params, plan, prepped, x = lenet_setup
        w, b = prepped["CL1"]
        bad = dict(prepped)
        bad["CL1"] = (w[..., :-1], b)
        with pytest.raises(PreflightError, match="weight shape"):
            preflight(x, bad, plan=plan)

    def test_nonfinite_params_localized(self, lenet_setup):
        g, params, plan, prepped, x = lenet_setup
        bad = corrupt_params(prepped, "CL2", kind="inf")
        with pytest.raises(NumericError) as ei:
            preflight(x, bad, plan=plan)
        assert ei.value.context["nodes"] == ["CL2"]

    def test_flat_dtype_mismatch(self, lenet_setup):
        """Params prepared at one dtype, run requested at another: the
        pre-flattened streamed arrays give it away at preflight.  A tight
        budget forces a streamed launch even on LeNet."""
        g, params, plan, prepped, x = lenet_setup
        tight = auto_partition(g, batch=2, vmem_budget=10_000)
        assert any(p.launch.streamed for p in tight.pyramids)
        t_prepped = prepare_network_params(tight, params)  # f32 flats
        with pytest.raises(PreflightError, match="different dtype"):
            preflight(x, t_prepped, plan=tight, dtype="bfloat16")

    def test_flat_size_mismatch(self, lenet_setup):
        """Params prepared for a different plan: the flat array length does
        not match the launch program's weight counts."""
        g, params, plan, prepped, x = lenet_setup
        tight = auto_partition(g, batch=2, vmem_budget=10_000)
        t_prepped = prepare_network_params(tight, params)
        streamed = next(p for p in tight.pyramids if p.launch.streamed)
        key = "_flat/" + streamed.name
        t_prepped = dict(t_prepped)
        t_prepped[key] = t_prepped[key][:-3]
        with pytest.raises(PreflightError, match="different plan"):
            preflight(x, t_prepped, plan=tight)

    def test_stale_flat_entries(self, lenet_setup):
        g, params, plan, prepped, x = lenet_setup
        stale = dict(prepped)
        stale["_flat/NOPE..NADA"] = jnp.zeros((8,), jnp.float32)
        with pytest.raises(PreflightError, match="not in this plan"):
            preflight(x, stale, plan=plan)

    def test_flat_for_resident_pyramid_conflicts(self, lenet_setup):
        """weights_flat belongs to streamed launches; a flat entry for a
        resident pyramid means params and plan disagree."""
        g, params, plan, prepped, x = lenet_setup
        resident = [p for p in plan.pyramids if not p.launch.streamed]
        if not resident:
            pytest.skip("no resident pyramid in this plan")
        bad = dict(prepped)
        bad["_flat/" + resident[0].name] = jnp.zeros((8,), jnp.float32)
        with pytest.raises(PreflightError, match="not streamed"):
            preflight(x, bad, plan=plan)

    def test_budget_headroom(self, lenet_setup):
        g, params, plan, prepped, x = lenet_setup
        with pytest.raises(BudgetError) as ei:
            preflight(x, prepped, plan=plan, vmem_budget=1024)
        assert ei.value.context["vmem_budget"] == 1024

    def test_run_network_guarded_preflights(self, lenet_setup):
        """The guarded runner rejects a dtype-mismatched request with the
        typed error, end to end through run_network."""
        g, params, plan, prepped, x = lenet_setup
        with guarding(GuardConfig()):
            with pytest.raises(PreflightError, match="not executable"):
                run_network(x, prepped, plan=plan, dtype="int8")


class TestTypedErrorsReplaceAsserts:
    def test_head_op_unhandled(self):
        n = Node("pool", "P1", ("x",), K=2, S=2)
        with pytest.raises(PreflightError, match="P1"):
            _head_op({}, n, {})

    def test_compile_program_pool_first(self):
        spec = FusionSpec(
            levels=(FusedLevel("pool", K=2, S=2, pad=0, n_in=4, n_out=4),),
            input_size=8,
        )
        with pytest.raises(PlanError, match="start with a conv"):
            compile_program(spec, 4)

    def test_compile_program_region_must_tile(self):
        g = MODELS["lenet"]()
        seg = fusable_segments(g)[0]
        with pytest.raises(PlanError, match="must tile"):
            compile_program(seg.spec(), 3)  # lenet's 5x5 output: 5 % 3 != 0

    def test_plan_launch_prefer_region_typo(self):
        g = MODELS["lenet"]()
        seg = fusable_segments(g)[0]
        with pytest.raises(PreflightError, match="prefer_region"):
            plan_launch(seg.spec(), prefer_region="biggest")

    def test_partition_infeasible_budget(self):
        g = MODELS["lenet"]()
        seg = fusable_segments(g)[0]
        with pytest.raises(BudgetError, match="fits no launch regime"):
            partition_segment(seg, vmem_budget=256)
        # and the historical except-clause still catches it
        with pytest.raises(ValueError):
            partition_segment(seg, vmem_budget=256)


class TestReplanPyramid:
    def test_tighter_budget_chains_launches(self, lenet_setup):
        g, params, plan, prepped, x = lenet_setup
        pyr = plan.pyramids[0]
        budget = pyr.launch.vmem_bytes() * 2 // 3
        subs = replan_pyramid(g, pyr, vmem_budget=budget, batch=2)
        # sub-pyramids tile the original chain exactly, each under budget
        covered = tuple(n for sp in subs for n in sp.node_names)
        assert covered == pyr.node_names
        assert all(sp.launch.vmem_bytes() <= budget for sp in subs)
        assert len(subs) >= 2

    def test_exhausted_budget_raises(self, lenet_setup):
        g, params, plan, prepped, x = lenet_setup
        with pytest.raises(BudgetError):
            replan_pyramid(g, plan.pyramids[0], vmem_budget=128, batch=2)


class TestGuardDispatch:
    def test_guard_off_takes_jit_fast_path(self, lenet_setup, monkeypatch):
        """With no guard installed, run_network must not touch the guarded
        path at all — same contract as tracing-off."""
        g, params, plan, prepped, x = lenet_setup
        import repro.robust.degrade as degrade

        def boom(*a, **k):
            raise AssertionError("guarded path must not run")

        monkeypatch.setattr(degrade, "run_network_guarded", boom)
        assert not get_guard().enabled
        logits, skips = run_network(x, prepped, plan=plan)
        assert logits.shape == (2, 10)

    def test_guard_on_reports(self, lenet_setup):
        g, params, plan, prepped, x = lenet_setup
        base, _ = run_network(x, prepped, plan=plan)
        with guarding(GuardConfig()) as guard:
            y, skips = run_network(x, prepped, plan=plan)
        rep = guard.last_report
        assert rep is not None and not rep.degraded
        assert rep.clean_launches == rep.launches == plan.n_launches()
        assert float(jnp.max(jnp.abs(y - base))) == 0.0
        assert set(skips) == {p.name for p in plan.pyramids}

    def test_guarding_nests_and_restores(self):
        assert not get_guard().enabled
        with guarding(GuardConfig(max_replans=1)) as outer:
            assert get_guard() is outer
            with guarding(GuardConfig(max_replans=5)) as inner:
                assert get_guard() is inner
            assert get_guard() is outer
        assert not get_guard().enabled


class TestSentinels:
    def test_clean_tensor(self):
        stats = sentinel_stats(jnp.ones((4, 4)))
        assert sentinel_trips(stats, None) is None
        assert float(stats["max_abs"]) == 1.0

    def test_nan_and_inf_trip(self):
        bad = jnp.ones((4,)).at[2].set(jnp.nan)
        assert sentinel_trips(sentinel_stats(bad), None) == "non-finite"
        worse = jnp.ones((4,)).at[1].set(jnp.inf)
        assert sentinel_trips(sentinel_stats(worse), None) == "non-finite"

    def test_magnitude_limit(self):
        big = jnp.full((4,), 1e6)
        assert sentinel_trips(sentinel_stats(big), None) is None
        assert sentinel_trips(sentinel_stats(big), 1e3) == "magnitude"

    def test_bf16_cast_safe(self):
        stats = sentinel_stats(jnp.ones((4,), jnp.bfloat16))
        assert sentinel_trips(stats, None) is None


class TestSegmentReluThreading:
    def test_replan_preserves_relu_mode(self):
        """resnet18 shortcut pyramids are relu-free; a replan must not
        reintroduce the activation."""
        g = MODELS["resnet18"](input_size=32, num_classes=10)
        plan = auto_partition(g, batch=1)
        no_relu = [p for p in plan.pyramids if not p.relu]
        assert no_relu, "expected relu-free shortcut pyramids"
        pyr = no_relu[0]
        subs = replan_pyramid(
            g, pyr, vmem_budget=plan.vmem_budget, batch=1
        )
        assert all(not sp.relu for sp in subs)

    def test_segment_requires_relu_field(self):
        g = MODELS["lenet"]()
        seg = fusable_segments(g)[0]
        assert isinstance(seg, Segment) and seg.relu is True
