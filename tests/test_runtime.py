"""Distributed-runtime tests: optimizer, data determinism, checkpointing,
fault tolerance, straggler detection, gradient compression, sharding rules."""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, Pipeline, batch_at
from repro.optim.adamw import AdamW
from repro.optim.grad_compress import compress_grads, init_state
from repro.optim.schedule import warmup_cosine
from repro.parallel.sharding import ShardingRules, partition_spec
from repro.runtime.fault_tolerance import FaultTolerantCluster, plan_restart
from repro.runtime.straggler import StragglerDetector


class TestAdamW:
    def test_descends_quadratic(self):
        opt = AdamW(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state = opt.update(grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_moment_dtype(self):
        opt = AdamW(moment_dtype=jnp.bfloat16)
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        state = opt.init(params)
        assert state.mu["w"].dtype == jnp.bfloat16
        params2, state2 = opt.update({"w": jnp.ones(4)}, state, params)
        assert state2.mu["w"].dtype == jnp.bfloat16
        assert params2["w"].dtype == jnp.bfloat16

    def test_grad_clip(self):
        opt = AdamW(lr=1e-3, grad_clip=1.0)
        params = {"w": jnp.zeros((2,))}
        state = opt.init(params)
        p1, _ = opt.update({"w": jnp.array([1e6, 0.0])}, state, params)
        assert np.isfinite(np.asarray(p1["w"])).all()


class TestSchedule:
    def test_warmup_then_decay(self):
        lrs = [float(warmup_cosine(jnp.int32(s), warmup=10, total=100))
               for s in range(100)]
        assert lrs[0] < lrs[9] <= 1.0  # warmup ascends
        assert lrs[99] < lrs[50] < lrs[11]  # cosine descends
        assert lrs[99] >= 0.1 - 1e-6  # floor


class TestData:
    def test_deterministic_across_restarts(self):
        cfg = DataConfig(vocab=1000, seq_len=16, global_batch=4)
        a = batch_at(cfg, 7)["tokens"]
        b = batch_at(cfg, 7)["tokens"]
        np.testing.assert_array_equal(a, b)
        assert not np.array_equal(a, batch_at(cfg, 8)["tokens"])

    def test_host_sharding_disjoint_streams(self):
        c0 = DataConfig(vocab=1000, seq_len=16, global_batch=8, n_hosts=2, host_id=0)
        c1 = dataclasses.replace(c0, host_id=1)
        assert c0.host_batch == 4
        assert not np.array_equal(batch_at(c0, 0)["tokens"], batch_at(c1, 0)["tokens"])

    def test_pipeline_prefetch_order(self):
        cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
        pipe = Pipeline(cfg, start_step=0)
        b0 = next(pipe)
        b1 = next(pipe)
        pipe.close()
        np.testing.assert_array_equal(b0["tokens"], batch_at(cfg, 0)["tokens"])
        np.testing.assert_array_equal(b1["tokens"], batch_at(cfg, 1)["tokens"])

    def test_tokens_in_vocab(self):
        cfg = DataConfig(vocab=311, seq_len=32, global_batch=4)
        t = batch_at(cfg, 3)["tokens"]
        assert t.min() >= 0 and t.max() < 311


class TestCheckpointer:
    def test_save_restore_roundtrip(self):
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            tree = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
            ck.save(5, tree, blocking=True)
            assert ck.latest_complete() == 5
            out = ck.restore(5, tree)
            np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(8.0))
            assert out["b"]["c"].dtype == jnp.bfloat16

    def test_corruption_detected(self):
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            tree = {"a": jnp.arange(4.0)}
            ck.save(1, tree, blocking=True)
            # corrupt the shard
            import pathlib

            f = next(pathlib.Path(d).glob("step_*/*a*.npy"))
            f.write_bytes(b"garbage" * 10)
            with pytest.raises(IOError):
                ck.restore(1, tree)

    def test_gc_keeps_latest(self):
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d, keep=2)
            tree = {"a": jnp.zeros(2)}
            for s in (1, 2, 3, 4):
                ck.save(s, tree, blocking=True)
            assert ck.latest_complete() == 4
            import pathlib

            dirs = sorted(pathlib.Path(d).glob("step_*"))
            assert len(dirs) == 2

    def test_async_save(self):
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(9, {"a": jnp.ones(16)})
            ck.wait()
            assert ck.latest_complete() == 9


class TestFaultTolerance:
    def test_heartbeat_timeout(self):
        t = [0.0]
        cluster = FaultTolerantCluster(n_hosts=4, timeout_s=10, clock=lambda: t[0])
        t[0] = 8.0
        for h in (0, 1, 2):
            cluster.heartbeat(h)
        t[0] = 16.0  # host 3's last beat (t=0) is now 16s stale; 0-2 are 8s
        dead = cluster.check()
        assert dead == [3]
        assert cluster.alive_count == 3

    def test_restart_same_size_with_spares(self):
        plan = plan_restart(
            alive_hosts=63, hosts_per_replica=8, base_mesh=(16, 16),
            spare_hosts=2, latest_checkpoint=1000,
        )
        assert plan.kind == "same_size"
        assert plan.replay_from == 1001

    def test_elastic_downsize_without_spares(self):
        plan = plan_restart(
            alive_hosts=20, hosts_per_replica=8, base_mesh=(16, 16),
            spare_hosts=0, latest_checkpoint=500,
        )
        assert plan.kind == "elastic_downsize"
        data_ax, model_ax = plan.mesh_shape
        assert model_ax == 16  # model axis preserved (sharding stays valid)
        assert data_ax * model_ax <= 20 * 8
        assert data_ax & (data_ax - 1) == 0  # power of two

    def test_halt_when_no_model_replica_fits(self):
        """Survivors can't hold even one model replica: the plan must be an
        explicit halt, not a bogus (1, model_ax) mesh the cluster cannot
        place (capacity 8 chips < model axis 16)."""
        plan = plan_restart(
            alive_hosts=1, hosts_per_replica=8, base_mesh=(16, 16),
            spare_hosts=0, latest_checkpoint=700,
        )
        assert plan.kind == "halt"
        assert plan.mesh_shape == (0, 16)
        assert plan.restore_step == 700  # checkpoint kept for backfill
        assert plan.replay_from is None  # nothing will consume data

    def test_elastic_boundary_exactly_one_replica(self):
        """capacity == model_ax is the smallest feasible elastic mesh:
        exactly one data replica, not a halt."""
        plan = plan_restart(
            alive_hosts=2, hosts_per_replica=8, base_mesh=(16, 16),
            spare_hosts=0, latest_checkpoint=None,
        )
        assert plan.kind == "elastic_downsize"
        assert plan.mesh_shape == (1, 16)
        assert plan.replay_from is None

    def test_heartbeat_revives_marked_host(self):
        t = [0.0]
        cluster = FaultTolerantCluster(n_hosts=2, timeout_s=5,
                                       clock=lambda: t[0])
        t[0] = 10.0
        cluster.heartbeat(0)
        assert cluster.check() == [1]
        cluster.heartbeat(1)  # late beat: the host is back
        assert cluster.check() == []
        assert cluster.alive_count == 2

    def test_elastic_restore_resharding(self):
        """A checkpoint saved under one mesh restores onto a smaller one."""
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            tree = {"w": jnp.arange(16.0).reshape(4, 4)}
            ck.save(3, tree, blocking=True)
            mesh = jax.make_mesh((1, 1), ("data", "model"))
            from jax.sharding import NamedSharding, PartitionSpec

            sh = {"w": NamedSharding(mesh, PartitionSpec(None, None))}
            out = ck.restore(3, tree, shardings=sh)
            np.testing.assert_array_equal(
                np.asarray(out["w"]), np.arange(16.0).reshape(4, 4)
            )


class TestStraggler:
    def test_flags_persistent_straggler(self):
        det = StragglerDetector(n_hosts=4, patience=3)
        decisions = {}
        for step in range(20):
            times = [1.0, 1.0, 1.0, 1.0]
            if step >= 8:
                times[2] = 3.5  # host 2 degrades
            decisions.update(det.observe(times))
        assert 2 in decisions
        assert decisions[2] in ("exclude_next_rescale", "immediate_restart")

    def test_no_false_positives_on_noise(self):
        rng = np.random.default_rng(0)
        det = StragglerDetector(n_hosts=8, patience=5)
        bad = {}
        for _ in range(50):
            times = list(1.0 + 0.02 * rng.standard_normal(8))
            bad.update(det.observe(times))
        assert not bad

    def test_mitigations_escalate_in_order(self):
        """A persistent slow host walks the ladder: rebalance first, then
        exclude-at-next-rescale once patience runs out."""
        det = StragglerDetector(n_hosts=4, patience=4)
        seen = []
        for step in range(12):
            times = [1.0, 1.0, 1.0, 1.0]
            if step >= 2:
                times[1] = 1.8  # slow but under hard_ratio * fleet mean
            for host, action in det.observe(times).items():
                assert host == 1
                seen.append(action)
        assert "rebalance_input" in seen
        assert "exclude_next_rescale" in seen
        assert "immediate_restart" not in seen
        assert seen.index("rebalance_input") < seen.index(
            "exclude_next_rescale"
        )

    def test_hard_straggler_restarts(self):
        """A 4x slowdown (past hard_ratio of the fleet mean) escalates to
        immediate restart once patience is exhausted."""
        det = StragglerDetector(n_hosts=4, patience=3)
        decisions = {}
        for step in range(10):
            times = [1.0, 1.0, 1.0, 1.0]
            if step >= 2:
                times[3] = 4.0
            decisions.update(det.observe(times))
        assert decisions.get(3) == "immediate_restart"


class TestGradCompression:
    def test_error_feedback_preserves_sum(self):
        """With error feedback, quantization error does not accumulate:
        the running sum of dequantized grads tracks the true sum."""
        rng = np.random.default_rng(0)
        params = {"w": jnp.zeros((512,))}
        state = init_state(params)
        true_sum = np.zeros(512)
        deq_sum = np.zeros(512)
        for _ in range(30):
            g = {"w": jnp.asarray(rng.normal(0, 1, 512), jnp.float32)}
            true_sum += np.asarray(g["w"])
            deq, state = compress_grads(g, state)
            deq_sum += np.asarray(deq["w"])
        err = np.abs(true_sum - deq_sum).max()
        scale = np.abs(true_sum).max()
        assert err < 0.05 * scale + 0.1

    def test_quantization_bounded_error_per_step(self):
        g = {"w": jnp.asarray(np.linspace(-3, 3, 1024), jnp.float32)}
        deq, _ = compress_grads(g, init_state(g))
        err = np.abs(np.asarray(deq["w"]) - np.asarray(g["w"])).max()
        assert err <= 3.0 / 127 + 1e-5


class TestShardingRules:
    def test_divisibility_fallback(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # single-device mesh: everything replicates (axis size 1)
        spec = partition_spec((8, 64), ("batch", "mlp"), mesh, ShardingRules())
        assert spec == jax.sharding.PartitionSpec()

    @given(st.integers(1, 128), st.integers(1, 128))
    @settings(max_examples=30, deadline=None)
    def test_spec_never_overshards(self, d0, d1):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = partition_spec((d0, d1), ("embed", "mlp"), mesh, ShardingRules())
        # on a 1x1 mesh nothing may be sharded
        assert all(e is None for e in spec)
