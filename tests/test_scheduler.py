"""Continuous-batching scheduler tests."""

import pytest

from repro.runtime.scheduler import BatchScheduler, Request


def _drain(sched, max_steps=10_000):
    steps = 0
    while (sched.active or sched.queue) and steps < max_steps:
        sched.admit()
        sched.tick()
        steps += 1
    return steps


class TestScheduler:
    def test_all_requests_complete_fifo(self):
        s = BatchScheduler(n_slots=4, max_seq=128)
        for i in range(10):
            s.submit(Request(rid=i, prompt_len=8, max_new_tokens=16))
        _drain(s)
        assert sorted(s.completed) == list(range(10))

    def test_admission_rejects_oversized(self):
        s = BatchScheduler(n_slots=2, max_seq=32)
        with pytest.raises(ValueError):
            s.submit(Request(rid=0, prompt_len=30, max_new_tokens=10))

    def test_slots_reused(self):
        s = BatchScheduler(n_slots=2, max_seq=64)
        for i in range(6):
            s.submit(Request(rid=i, prompt_len=4, max_new_tokens=8))
        _drain(s)
        assert len(s.completed) == 6

    def test_utilization_high_under_load(self):
        s = BatchScheduler(n_slots=4, max_seq=256)
        for i in range(16):
            s.submit(Request(rid=i, prompt_len=4, max_new_tokens=32))
        utils = []
        while s.active or s.queue:
            s.admit()
            utils.append(s.utilization)  # post-admission occupancy
            s.tick()
        # drop the drain-out tail: under load every slot stays busy
        loaded = utils[: len(utils) * 3 // 4]
        assert min(loaded) == 1.0

    def test_positions_advance_per_slot(self):
        s = BatchScheduler(n_slots=1, max_seq=64)
        s.submit(Request(rid=0, prompt_len=10, max_new_tokens=3))
        s.admit()
        positions = [s.tick().get(0) for _ in range(3)]
        assert positions == [10, 11, 12]

    def test_preemption_unblocks_starved_queue(self):
        s = BatchScheduler(n_slots=1, max_seq=100_000,
                           preempt_after=10, max_wait_steps=5)
        s.submit(Request(rid=0, prompt_len=4, max_new_tokens=50_000))
        s.admit()
        for _ in range(12):
            s.tick()
        s.submit(Request(rid=1, prompt_len=4, max_new_tokens=4))
        # run past the starvation window; the long request must be preempted
        for _ in range(40):
            s.admit()
            s.tick()
        assert s.preempted >= 1
        assert 1 in s.completed

    def test_no_preemption_below_token_threshold(self):
        """A request that has not yet generated preempt_after tokens is not
        a preemption victim, even with a starving queue — eviction would
        waste more recompute than it frees."""
        s = BatchScheduler(n_slots=1, max_seq=100_000,
                           preempt_after=500, max_wait_steps=5)
        s.submit(Request(rid=0, prompt_len=4, max_new_tokens=50_000))
        s.admit()
        s.submit(Request(rid=1, prompt_len=4, max_new_tokens=4))
        for _ in range(100):  # well past max_wait_steps, short of 500 tokens
            s.admit()
            s.tick()
        assert s.preempted == 0
        assert 1 not in s.completed

    def test_preempted_request_recomputes_from_zero(self):
        """Preemption discards generation state (deterministic recompute):
        the victim re-runs its full budget after re-admission and still
        completes."""
        s = BatchScheduler(n_slots=1, max_seq=100_000,
                           preempt_after=10, max_wait_steps=5)
        s.submit(Request(rid=0, prompt_len=4, max_new_tokens=30))
        s.admit()
        for _ in range(15):
            s.tick()
        s.submit(Request(rid=1, prompt_len=4, max_new_tokens=2))
        for _ in range(200):
            if 0 in s.completed:
                break
            s.admit()
            s.tick()
        assert s.preempted >= 1
        assert sorted(s.completed) == [0, 1]

    def test_cohort_reports_positions_post_admission(self):
        """tick() returns {slot: position} for every active slot; two
        same-length prompts admitted together batch at the same position."""
        s = BatchScheduler(n_slots=2, max_seq=64)
        s.submit(Request(rid=0, prompt_len=6, max_new_tokens=4))
        s.submit(Request(rid=1, prompt_len=6, max_new_tokens=4))
        s.admit()
        cohort = s.tick()
        assert sorted(cohort) == [0, 1]
        assert cohort[0] == cohort[1] == 6
