"""Serving engine (DESIGN.md §14): pad-to-bucket bitwise parity, FIFO
admission/fairness, plan+jit cache accounting (hits/misses/evictions and
zero replans/retraces on a repeated wave), typed admission rejections that
never stall the queue, the batch-aware costing knobs, and the host-staging
serving cost model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cycle_model import (
    HOST_BYTES_PER_CYCLE,
    host_staging_cycles,
    serve_stream_cycles,
)
from repro.net import runner
from repro.net.graph import lenet5
from repro.net.partition import (
    auto_partition,
    clear_partition_cache,
    partition_cache_info,
)
from repro.net.runner import (
    init_network_params,
    prepare_network_params,
    run_network,
)
from repro.net.serve import (
    Request,
    ServeConfig,
    ServingEngine,
    bucket_for,
    pad_to_bucket,
)
from repro.robust.errors import NumericError, PreflightError

KEY = jax.random.PRNGKey(0)
GRAPH = lenet5()
PARAMS = init_network_params(GRAPH, KEY)
CFG = ServeConfig(buckets=(1, 2, 4))


def _images(rows: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal(
        (rows, GRAPH.input_size, GRAPH.input_size, GRAPH.in_channels)
    ).astype(np.float32)


def _engine(**overrides) -> ServingEngine:
    cfg = ServeConfig(**{"buckets": (1, 2, 4), **overrides})
    return ServingEngine(GRAPH, PARAMS, cfg)


# ---------------------------------------------------------------------------
# bucketing helpers
# ---------------------------------------------------------------------------


class TestBucketing:
    def test_bucket_for_picks_smallest_fit(self):
        assert bucket_for(1, (1, 2, 4, 8)) == 1
        assert bucket_for(3, (1, 2, 4, 8)) == 4
        assert bucket_for(8, (1, 2, 4, 8)) == 8
        # unsorted config still resolves smallest-fit
        assert bucket_for(3, (8, 4, 2, 1)) == 4

    def test_bucket_for_overflow_is_typed(self):
        with pytest.raises(PreflightError):
            bucket_for(9, (1, 2, 4, 8))

    def test_pad_to_bucket_shapes(self):
        x = _images(3)
        padded = pad_to_bucket(x, 4)
        assert padded.shape[0] == 4
        assert np.array_equal(padded[:3], x)
        assert not padded[3:].any()
        assert pad_to_bucket(x, 3) is not None  # exact fit: unchanged
        assert np.array_equal(pad_to_bucket(x, 3), x)
        with pytest.raises(PreflightError):
            pad_to_bucket(x, 2)

    def test_config_rejects_bad_buckets(self):
        with pytest.raises(PreflightError):
            ServeConfig(buckets=(4, 2))
        with pytest.raises(PreflightError):
            ServeConfig(buckets=())


# ---------------------------------------------------------------------------
# pad-to-bucket bitwise parity
# ---------------------------------------------------------------------------


class TestPadParity:
    """The property the whole engine rests on: a padded batch's real rows
    are bit-identical to the unpadded run under the same bucket plan."""

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_padded_rows_bit_identical(self, dtype):
        rows, bucket = 3, 4
        x = _images(rows, seed=7)
        plan = auto_partition(GRAPH, batch=bucket, compute_dtype=dtype)
        prepared = prepare_network_params(plan, PARAMS)
        full, _ = run_network(
            jnp.asarray(pad_to_bucket(x, bucket)), prepared, plan=plan
        )
        part, _ = run_network(jnp.asarray(x), prepared, plan=plan)
        assert np.array_equal(np.asarray(full)[:rows], np.asarray(part))

    def test_neighbor_content_does_not_leak(self):
        """Row i's logits depend only on row i: swapping the *other* rows
        of the bucket leaves it bitwise unchanged."""
        bucket = 4
        a, b = _images(1, seed=1), _images(bucket - 1, seed=2)
        c = _images(bucket - 1, seed=3)
        plan = auto_partition(GRAPH, batch=bucket)
        prepared = prepare_network_params(plan, PARAMS)
        with_b, _ = run_network(
            jnp.asarray(np.concatenate([a, b])), prepared, plan=plan
        )
        with_c, _ = run_network(
            jnp.asarray(np.concatenate([a, c])), prepared, plan=plan
        )
        assert np.array_equal(np.asarray(with_b)[0], np.asarray(with_c)[0])

    def test_engine_matches_manual_padded_run(self):
        """The engine's packed bucket (two requests + zero pad) returns
        exactly the rows a hand-built padded ``run_network`` produces."""
        x1, x2 = _images(2, seed=4), _images(1, seed=5)
        eng = _engine()
        r1, r2 = eng.serve([x1, x2])
        assert r1.ok and r2.ok and r1.bucket == r2.bucket == 4
        plan = auto_partition(GRAPH, batch=4)
        prepared = prepare_network_params(plan, PARAMS)
        manual, _ = run_network(
            jnp.asarray(pad_to_bucket(np.concatenate([x1, x2]), 4)),
            prepared, plan=plan,
        )
        manual = np.asarray(manual)
        assert np.array_equal(r1.logits, manual[:2])
        assert np.array_equal(r2.logits, manual[2:3])


# ---------------------------------------------------------------------------
# admission order / fairness
# ---------------------------------------------------------------------------


class TestAdmission:
    def test_results_in_submission_order(self):
        eng = _engine()
        sizes = [1, 4, 2, 1, 3]
        results = eng.serve([_images(r, seed=r) for r in sizes])
        assert [r.rows for r in results] == sizes
        assert [r.id for r in results] == sorted(r.id for r in results)
        assert all(r.ok for r in results)

    def test_large_request_not_starved(self):
        """A 4-row request at the head is dispatched in the first batch —
        FIFO packing never skips the head to fill with later singles."""
        eng = _engine()
        eng.submit_many([_images(4, seed=0)] + [_images(1, seed=i)
                                                for i in range(1, 5)])
        first = eng._form_batch()
        assert [r.rows for r in first] == [4]

    @given(st.lists(st.integers(min_value=1, max_value=4), min_size=1,
                    max_size=12))
    @settings(max_examples=25, deadline=None)
    def test_packing_properties(self, sizes):
        """FIFO packing invariants, checked without executing kernels:
        batches preserve admission order exactly, each batch fits the
        largest bucket, and every batch is the *greedy* prefix (the next
        request would not have fit)."""
        eng = _engine()
        for i, r in enumerate(sizes):
            eng.queue.append(
                Request(id=i, x=np.zeros((r, 1, 1, 1)), rows=r, enqueue_s=0.0)
            )
        limit = max(eng.config.buckets)
        seen = []
        while True:
            batch = eng._form_batch()
            if batch is None:
                break
            rows = sum(r.rows for r in batch)
            assert rows <= limit
            if eng.queue:  # greedy: the next head would overflow the bucket
                assert rows + eng.queue[0].rows > limit
            seen.extend(r.id for r in batch)
        assert seen == list(range(len(sizes)))


# ---------------------------------------------------------------------------
# rejection path
# ---------------------------------------------------------------------------


class TestRejection:
    def test_nonfinite_request_rejected_not_raised(self):
        eng = _engine()
        bad = _images(1)
        bad[0, 0, 0, 0] = np.nan
        rid = eng.submit(bad)
        res = eng.results[rid]
        assert not res.ok and isinstance(res.error, NumericError)
        assert not eng.queue  # never enqueued

    def test_bad_shape_and_oversize_rejected(self):
        eng = _engine()
        r1 = eng.results[eng.submit(np.zeros((1, 8, 8, 1), np.float32))]
        assert isinstance(r1.error, PreflightError)
        r2 = eng.results[eng.submit(_images(5))]  # > max bucket (4)
        assert isinstance(r2.error, PreflightError)
        assert eng.rejected == 2

    def test_rejection_does_not_stall_queue(self):
        eng = _engine()
        good1 = eng.submit(_images(1, seed=1))
        bad = _images(1)
        bad[0] = np.inf
        bad_id = eng.submit(bad)
        good2 = eng.submit(_images(1, seed=2))
        eng.drain()
        assert eng.results[good1].ok and eng.results[good2].ok
        assert not eng.results[bad_id].ok
        summary = eng.summary()
        assert summary["completed"] == 2 and summary["rejected"] == 1

    def test_queue_backpressure(self):
        eng = _engine(max_queue=1)
        eng.submit(_images(1))
        res = eng.results[eng.submit(_images(1))]
        assert isinstance(res.error, PreflightError)
        eng.drain()
        assert eng.results[0].ok


# ---------------------------------------------------------------------------
# plan + jit cache accounting
# ---------------------------------------------------------------------------


class TestPlanCache:
    def test_second_wave_zero_replans_zero_retraces(self):
        """The acceptance criterion: wave 2 of the same bucket mix performs
        zero partition replans and zero jit retraces, visible in
        ``partition_cache_info()`` and the engine counters."""
        clear_partition_cache()
        eng = _engine()
        # one serve call per size so each drains as its own bucket
        # (a single FIFO drain would coalesce them all into bucket 4)
        wave = [[_images(r, seed=r)] for r in (1, 2, 3)]

        for w in wave:
            eng.serve(w)
        part1 = partition_cache_info()
        traces1 = runner.jit_trace_count()
        misses1 = eng.cache_counters["misses"]
        assert misses1 == 3  # buckets 1, 2, 4 (3 rounds up)

        for w in wave:
            eng.serve([x.copy() for x in w])
        part2 = partition_cache_info()
        assert eng.cache_counters["misses"] == misses1  # zero replans
        assert eng.cache_counters["hits"] >= 3
        assert part2.misses == part1.misses
        assert runner.jit_trace_count() == traces1  # zero recompiles

    def test_second_engine_reuses_partition_and_jit_caches(self):
        """Plan reuse crosses engine instances: the memoized auto_partition
        returns the *same plan object*, so jax's executable cache hits on
        identical (plan, shape) keys."""
        eng1 = _engine()
        eng1.serve([_images(2, seed=0)])
        part = partition_cache_info()
        traces = runner.jit_trace_count()
        eng2 = _engine()
        eng2.serve([_images(2, seed=9)])
        assert partition_cache_info().hits == part.hits + 1
        assert partition_cache_info().misses == part.misses
        assert runner.jit_trace_count() == traces

    def test_eviction_counter(self):
        eng = _engine(plan_cache_size=1, buckets=(1, 2))
        eng.serve([_images(1, seed=0)])
        eng.serve([_images(2, seed=1)])  # evicts bucket-1 entry
        eng.serve([_images(1, seed=2)])  # evicts bucket-2 entry
        info = eng.cache_info()
        assert info["evictions"] == 2
        assert info["currsize"] == 1
        assert info["misses"] == 3

    def test_partition_cache_info_has_eviction_field(self):
        clear_partition_cache()
        info = partition_cache_info()
        assert info.evictions == 0
        auto_partition(GRAPH)
        assert partition_cache_info().evictions == 0  # plenty of room
        clear_partition_cache()
        assert partition_cache_info() == partition_cache_info()._replace(
            hits=0, misses=0, evictions=0, currsize=0
        )


class TestJitRetrace:
    def test_distinct_batch_sizes_retrace_same_plan(self):
        """The failure mode bucketing amortizes: one plan, two batch sizes,
        two jit traces — then replaying either shape adds none."""
        plan = auto_partition(GRAPH, batch=1)
        prepared = prepare_network_params(plan, PARAMS)
        runner.reset_jit_trace_count()
        for rows in (3, 5, 3, 5):
            out, _ = run_network(
                jnp.asarray(_images(rows)), prepared, plan=plan
            )
            jax.block_until_ready(out)
        assert runner.jit_trace_count() == 2
        runner.reset_jit_trace_count()
        out, _ = run_network(jnp.asarray(_images(3)), prepared, plan=plan)
        jax.block_until_ready(out)
        assert runner.jit_trace_count() == 0  # reset counts, cache survives


# ---------------------------------------------------------------------------
# SLO / summary / renderer
# ---------------------------------------------------------------------------


class TestSummary:
    def test_bucket_rows_publish_slo_and_measured(self):
        eng = _engine()
        eng.serve([_images(r, seed=r) for r in (1, 2, 4)])
        summary = eng.summary()
        assert summary["model"] == "lenet"
        assert summary["buckets"], "no bucket rows"
        for row in summary["buckets"]:
            assert row["slo_us"] > 0
            assert row["steady_us"] > 0
            assert row["steady_us"] <= row["slo_us"]
            assert row["p50_ms"] > 0 and row["p95_ms"] >= row["p50_ms"]
            assert row["imgs_per_s"] > 0
            assert row["modeled_cycles"] > 0
        assert summary["cache"]["serve"]["misses"] == len(summary["buckets"])

    def test_slo_scales_with_bucket(self):
        """A bigger bucket models strictly more work: SLO is monotone in
        bucket for the same model/dtype."""
        eng = _engine()
        e1, e4 = eng._entry(1), eng._entry(4)
        assert e4.compute_cycles > e1.compute_cycles
        assert e4.staging_cycles > e1.staging_cycles
        assert e4.slo_us > e1.slo_us

    def test_serve_table_renders(self):
        from repro.obs.explain import serve_table

        eng = _engine()
        eng.serve([_images(2, seed=0)])
        summary = eng.summary()
        summary["waves"] = [
            {"serve_hits": 0, "serve_misses": 1, "partition_hits": 0,
             "partition_misses": 1, "jit_traces": 1, "wall_s": 0.5},
        ]
        lines = []
        serve_table(summary, out=lines.append)
        text = "\n".join(lines)
        assert "slo_us" in text and "p50_ms" in text
        assert "wave 1" in text and "jit traces" in text

    def test_guarded_engine_completes(self):
        eng = _engine(guarded=True)
        res = eng.serve([_images(1, seed=3)])
        assert all(r.ok for r in res)
        # guarded (launch-by-launch) and unguarded (whole-graph jit) paths
        # agree to the runner's documented f32 closeness — XLA fuses the
        # two graphs differently, so bitwise equality is not the contract
        ref = _engine().serve([_images(1, seed=3)])
        np.testing.assert_allclose(
            res[0].logits, ref[0].logits, atol=1e-4
        )


# ---------------------------------------------------------------------------
# batch-aware costing + serving cost model
# ---------------------------------------------------------------------------


class TestBatchAwareCosting:
    def test_plan_launch_accepts_batch(self):
        from repro.core.cnn_models import LENET5_FUSION
        from repro.core.program import plan_launch

        p1 = plan_launch(LENET5_FUSION)
        p8 = plan_launch(LENET5_FUSION, batch=8)
        # the ladder is cost-monotone in batch: same rung either way
        assert p1.regime == p8.regime
        assert p8.modeled_cycles(8) == 8 * p8.modeled_cycles(1)

    def test_modeled_us_matches_cycles(self):
        from repro.core.cycle_model import DEFAULT_PARAMS

        plan = auto_partition(GRAPH, batch=4)
        lp = plan.pyramids[0].launch
        assert lp.modeled_us(4) == pytest.approx(
            lp.modeled_cycles(4) / DEFAULT_PARAMS.freq_mhz
        )
        assert plan.modeled_us() == pytest.approx(
            plan.modeled_cycles() / DEFAULT_PARAMS.freq_mhz
        )

    def test_partition_shifts_with_batch(self):
        """The reason batch-aware costing matters: streamed re-reads scale
        with batch while resident loads amortize, so the resnet18 cut
        points differ between batch 1 and batch 8."""
        from repro.net.graph import resnet18

        g = resnet18()
        p1 = auto_partition(g, batch=1)
        p8 = auto_partition(g, batch=8)
        assert [p.launch.regime for p in p1.pyramids] != [
            p.launch.regime for p in p8.pyramids
        ]


class TestServeCycleModel:
    def test_host_staging_cycles_ceil(self):
        assert host_staging_cycles(0) == 0
        assert host_staging_cycles(1) == 1
        assert host_staging_cycles(HOST_BYTES_PER_CYCLE) == 1
        assert host_staging_cycles(HOST_BYTES_PER_CYCLE + 1) == 2

    def test_serve_stream_cycles_shapes(self):
        c, s = 100, 30
        assert serve_stream_cycles(0, c, s, double_buffered=True) == 0
        assert serve_stream_cycles(1, c, s, double_buffered=True) == c + s
        # serial pays staging+compute per batch
        assert serve_stream_cycles(3, c, s, double_buffered=False) == 3 * (c + s)
        # double-buffered hides staging behind compute after the first
        assert serve_stream_cycles(3, c, s, double_buffered=True) == (
            s + c + 2 * max(c, s)
        )

    @given(st.integers(1, 32), st.integers(1, 10**6), st.integers(1, 10**6))
    @settings(max_examples=50, deadline=None)
    def test_double_buffering_never_worse(self, batches, compute, staging):
        db = serve_stream_cycles(
            batches, compute, staging, double_buffered=True
        )
        serial = serve_stream_cycles(
            batches, compute, staging, double_buffered=False
        )
        assert db <= serial
        # and never better than the compute/staging lower bounds
        assert db >= batches * max(compute, staging)
