"""Serving chaos suite (DESIGN.md §15): every serving fault class must
terminate with typed per-request results while subsequent requests keep
being served.

The engine-level counterpart of ``tests/test_chaos.py``: where that suite
proves the *ladder* absorbs launch faults, this one proves the *service*
around it — blown deadlines, stuck launches, repeated kernel failure,
queue overflow, staging failure, poisoned outputs, and drain-loop stalls
— never hangs a wave, never loses or duplicates a request, and surfaces
every transition (watchdog, breaker, sentinel, shed/expiry) as typed
results, counters, and trace events.  Also home of the breaker unit
tests (fake clock), the deadline/EDF admission tests, the overload
shedding acceptance (EDF+shedding vs FIFO under the same injected slow
launches), the multi-threaded frontend hammer, and the PR 9 equivalence
guarantee (all resilience knobs off == the plain engine).
"""

import threading
import time

import jax
import numpy as np
import pytest

from repro.net.frontend import ServingFrontend
from repro.net.graph import lenet5
from repro.net.runner import init_network_params, reference_network
from repro.net.serve import (
    Request,
    ServeConfig,
    ServingEngine,
)
from repro.obs import tracing
from repro.obs.stats import percentile
from repro.robust.breaker import CLOSED, HALF_OPEN, OPEN, CircuitBreaker
from repro.robust.errors import (
    DeadlineExceeded,
    FaultInjected,
    NumericError,
    PreflightError,
)
from repro.robust.faults import FaultInjector, inject

KEY = jax.random.PRNGKey(0)
GRAPH = lenet5()
PARAMS = init_network_params(GRAPH, KEY)


def _images(rows: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal(
        (rows, GRAPH.input_size, GRAPH.input_size, GRAPH.in_channels)
    ).astype(np.float32)


def _engine(**overrides) -> ServingEngine:
    cfg = ServeConfig(**{"buckets": (1, 2, 4), **overrides})
    return ServingEngine(GRAPH, PARAMS, cfg)


def _events(collector, name):
    return [e for e in collector.events if e.name == name]


# ---------------------------------------------------------------------------
# circuit breaker unit tests (fake clock — no sleeping)
# ---------------------------------------------------------------------------


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


class TestCircuitBreaker:
    def test_opens_after_threshold_consecutive_failures(self):
        br = CircuitBreaker(threshold=3, cooldown_s=5.0, clock=FakeClock())
        for _ in range(2):
            br.record_failure()
            assert br.state == CLOSED and br.allow()
        br.record_failure()
        assert br.state == OPEN and not br.allow()
        assert br.opens == 1
        assert br.transitions[-1]["why"] == "3 consecutive failures"

    def test_success_resets_consecutive_count(self):
        br = CircuitBreaker(threshold=2, clock=FakeClock())
        br.record_failure()
        br.record_success()
        br.record_failure()
        assert br.state == CLOSED  # never two *consecutive* failures

    def test_cooldown_grants_one_half_open_probe(self):
        clock = FakeClock()
        br = CircuitBreaker(threshold=1, cooldown_s=5.0, clock=clock)
        br.record_failure(rung="interpret")
        assert br.state == OPEN and br.pinned_rung == "interpret"
        assert not br.allow()  # cooldown not elapsed
        clock.t = 5.0
        assert br.allow()  # the probe
        assert br.state == HALF_OPEN
        assert not br.allow()  # only one probe outstanding

    def test_probe_success_closes_and_unpins(self):
        clock = FakeClock()
        br = CircuitBreaker(threshold=1, cooldown_s=1.0, clock=clock)
        br.record_failure(rung="reference")
        clock.t = 1.0
        assert br.allow()
        br.record_success()
        assert br.state == CLOSED and br.pinned_rung is None
        states = [(t["from"], t["to"]) for t in br.transitions]
        assert states == [
            (CLOSED, OPEN), (OPEN, HALF_OPEN), (HALF_OPEN, CLOSED),
        ]

    def test_probe_failure_reopens_and_restarts_cooldown(self):
        clock = FakeClock()
        br = CircuitBreaker(threshold=1, cooldown_s=2.0, clock=clock)
        br.record_failure()
        clock.t = 2.0
        assert br.allow()
        br.record_failure(rung="reference")
        assert br.state == OPEN and br.opens == 2
        clock.t = 3.0  # only 1s since reopen: still open
        assert not br.allow()
        clock.t = 4.0
        assert br.allow() and br.state == HALF_OPEN

    def test_snapshot_and_validation(self):
        br = CircuitBreaker(threshold=2, clock=FakeClock())
        br.record_failure()
        snap = br.snapshot()
        assert snap.state == CLOSED and snap.failures == 1
        assert snap.threshold == 2 and snap.opens == 0
        with pytest.raises(ValueError):
            CircuitBreaker(threshold=0)
        with pytest.raises(ValueError):
            CircuitBreaker(cooldown_s=-1.0)


# ---------------------------------------------------------------------------
# deadlines: expiry, shedding, EDF order
# ---------------------------------------------------------------------------


class TestDeadlines:
    def test_expired_request_completes_typed_never_launches(self):
        eng = _engine(deadline_aware=True)
        # generous vs the modeled ETA (so admission passes), tiny vs the
        # wall clock (so it blows while queued before the drain)
        deadline_us = 20 * eng._entry(1).slo_us
        with tracing() as col:
            dead = eng.submit(_images(1, seed=1), deadline_us=deadline_us)
            live = eng.submit(_images(1, seed=2))
            time.sleep(deadline_us * 1e-6 + 0.01)
            eng.drain()
        res = eng.results[dead]
        assert not res.ok and isinstance(res.error, DeadlineExceeded)
        assert res.error.context["late_us"] > 0
        assert res.bucket is None  # never occupied a launch
        assert eng.results[live].ok
        assert eng.resilience["expired"] == 1
        assert len(_events(col, "serve_expired")) == 1

    def test_admission_shed_is_typed_and_counted(self):
        # a margin this large makes any finite deadline unmeetable, so the
        # request is shed at the door — no queue entry, no launch
        eng = _engine(deadline_aware=True, shed_margin=1e12)
        with tracing() as col:
            rid = eng.submit(_images(1), deadline_us=1e6)
        res = eng.results[rid]
        assert not res.ok and isinstance(res.error, DeadlineExceeded)
        assert res.error.context["eta_us"] > 0
        assert res.error.context["deadline_us"] == 1e6
        assert not eng.queue
        assert eng.resilience["shed"] == 1 and eng.rejected == 1
        assert len(_events(col, "serve_shed")) == 1

    def test_no_deadline_requests_never_shed_or_expire(self):
        eng = _engine(deadline_aware=True, shed_margin=1e12)
        res = eng.serve([_images(1, seed=s) for s in range(3)])
        assert all(r.ok for r in res)
        assert eng.resilience["shed"] == eng.resilience["expired"] == 0

    def test_edf_order_priority_then_deadline(self):
        eng = _engine(deadline_aware=True)
        now = time.perf_counter()
        specs = [  # (priority, deadline_s offset or None)
            (0, 10.0), (0, 1.0), (1, 10.0), (0, None),
        ]
        for i, (prio, off) in enumerate(specs):
            eng.queue.append(Request(
                id=i, x=np.zeros((1, 1, 1, 1)), rows=1, enqueue_s=now,
                deadline_us=None if off is None else off * 1e6,
                deadline_s=None if off is None else now + off,
                priority=prio,
            ))
        batch = eng._form_batch()
        # priority desc first, then nearest deadline, deadline-less last
        assert [r.id for r in batch] == [2, 1, 0, 3]

    def test_fifo_engine_ignores_deadlines(self):
        # PR 9 equivalence: without deadline_aware, a deadline rides along
        # inert — no shed, no expiry, strict FIFO formation
        eng = _engine()
        rid = eng.submit(_images(1), deadline_us=1.0)
        time.sleep(0.002)
        eng.drain()
        assert eng.results[rid].ok
        assert eng.resilience["shed"] == eng.resilience["expired"] == 0


class TestOverloadShedding:
    """The acceptance: under overload, deadline-aware admission sheds what
    cannot meet its deadline and what it admits completes on time, while
    the FIFO engine serves everything late.  Injected slow launches make
    the batch wall ~60ms, dwarfing scheduler noise."""

    DELAY_S = 0.06

    def _slow(self):
        inj = FaultInjector(seed=0)
        inj.slow_launch(self.DELAY_S, times=999)
        return inj

    def _warmed(self, **overrides):
        eng = _engine(**overrides)
        # clean pass first: jit compiles land outside the measured-walls
        # median, then two injected passes per bucket put the p50 batch
        # wall at the ~60ms injected delay — calibration now maps the
        # modeled us-scale SLO into the wall-clock domain
        for r in (1, 2, 4):
            eng.serve([_images(r, seed=r)])
        with inject(injector=self._slow()):
            for rep in range(2):
                for r in (1, 2, 4):
                    eng.serve([_images(r, seed=10 * rep + r)])
        for b in (1, 2, 4):
            p50 = percentile(eng._stats[b].batch_walls_ms, 50)
            assert p50 >= self.DELAY_S * 1e3
        return eng

    def test_edf_sheds_and_admitted_meet_deadlines(self):
        # shed_margin > 1 keeps admission conservative: what the engine
        # lets in, it is confident it can finish before the deadline
        eng = self._warmed(deadline_aware=True, shed_margin=1.6)
        deadline_us = 2.6 * self.DELAY_S * 1e6  # room for ~2 slow batches
        with inject(injector=self._slow()):
            ids = [
                eng.submit(_images(1, seed=s), deadline_us=deadline_us)
                for s in range(20)
            ]
            eng.drain()
        results = [eng.results[i] for i in ids]
        completed = [r for r in results if r.ok]
        typed = [
            r for r in results
            if not r.ok and isinstance(r.error, DeadlineExceeded)
        ]
        shed = [r for r in typed if "eta_us" in r.error.context]
        assert len(completed) + len(typed) == 20  # every request typed
        assert completed and shed  # overload actually shed load
        on_time = [
            r for r in completed if r.latency_ms * 1e3 <= deadline_us
        ]
        assert len(on_time) / len(completed) >= 0.95

    def test_fifo_baseline_misses_deadlines(self):
        eng = self._warmed()
        deadline_us = 2.6 * self.DELAY_S * 1e6
        with inject(injector=self._slow()):
            ids = [
                eng.submit(_images(1, seed=s), deadline_us=deadline_us)
                for s in range(20)
            ]
            eng.drain()
        results = [eng.results[i] for i in ids]
        assert all(r.ok for r in results)  # FIFO serves everything...
        late = [r for r in results if r.latency_ms * 1e3 > deadline_us]
        # ...but 20 rows over bucket-4 batches at ~60ms each puts the
        # tail far past the deadline: most of the stream is late
        assert len(late) >= len(results) // 2


# ---------------------------------------------------------------------------
# serving fault classes
# ---------------------------------------------------------------------------


class TestStagingFailure:
    def test_staging_fault_fails_batch_typed_queue_drains(self):
        eng = _engine()
        inj = FaultInjector(seed=0)
        inj.raise_at("stage", times=2, message="injected device_put failure")
        with tracing() as col, inject(injector=inj):
            res = eng.serve([_images(4, seed=s) for s in range(3)])
        assert [r.ok for r in res] == [False, False, True]
        for r in res[:2]:
            assert isinstance(r.error, FaultInjected)
            assert r.error.context["stage"] == "stage"
            assert r.bucket == 4
        assert eng.resilience["failed"] == 2
        assert len(_events(col, "serve_batch_error")) == 2
        # the engine is healthy afterwards, not wedged
        after = eng.serve([_images(1, seed=7)])
        assert after[0].ok


class TestStuckLaunch:
    def test_watchdog_trips_and_breaker_cycles(self):
        eng = _engine(watchdog_factor=3.0, breaker_threshold=1,
                      breaker_cooldown_s=0.0)
        eng.serve([_images(4, seed=0)])  # clean wall calibrates the watchdog
        inj = FaultInjector(seed=0)
        inj.slow_launch(0.25, times=1)
        with tracing() as col, inject(injector=inj):
            stuck = eng.serve([_images(4, seed=1)])
        assert stuck[0].ok  # slow, not wrong: the result still lands
        assert eng.resilience["watchdog_trips"] == 1
        wd = _events(col, "serve_watchdog")
        assert len(wd) == 1 and wd[0].args["wall_ms"] >= 250
        # breaker_threshold=1: the trip opened the breaker
        snap = eng.summary()["resilience"]["breakers"]["4"]
        assert snap["opens"] == 1 and snap["state"] == "open"
        # cooldown 0: the next launch is the half-open probe; clean run
        # closes the breaker — the full open -> half_open -> closed cycle
        with tracing() as col2:
            probe = eng.serve([_images(4, seed=2)])
        assert probe[0].ok
        trans = [
            (e.args["from_state"], e.args["to_state"])
            for e in _events(col2, "serve_breaker")
        ]
        assert trans == [("open", "half_open"), ("half_open", "closed")]
        snap = eng.summary()["resilience"]["breakers"]["4"]
        assert snap["state"] == "closed" and snap["pinned_rung"] is None

    def test_tripped_wall_not_used_for_calibration(self):
        eng = _engine(watchdog_factor=3.0)
        eng.serve([_images(4, seed=0)])
        clean_walls = list(eng._stats[4].batch_walls_ms)
        inj = FaultInjector(seed=0)
        inj.slow_launch(0.25, times=1)
        with inject(injector=inj):
            eng.serve([_images(4, seed=1)])
        assert eng.resilience["watchdog_trips"] == 1
        # the 250ms wall is excluded: a stall cannot raise its own bar
        assert eng._stats[4].batch_walls_ms == clean_walls


class TestRepeatedKernelFailure:
    def test_degraded_launches_open_breaker_and_pin_rung(self):
        # every guarded fused attempt hits the injected run fault and
        # degrades; two such launches open the breaker, which pins the
        # bucket to the gentlest rung that worked (interpret) for the
        # whole cooldown — no more failed fused attempts per batch
        eng = _engine(guarded=True, breaker_threshold=2,
                      breaker_cooldown_s=600.0)
        ref = np.asarray(
            reference_network(_images(4, seed=3), GRAPH, PARAMS)
        )
        inj = FaultInjector(seed=0)
        with tracing() as col, inject(injector=inj):
            # one run fault per batch: each fused attempt fails once and
            # the ladder lands on the interpret rung (a repeated fault,
            # not a permanent one — the breaker is what stops paying the
            # failed fused attempt per batch)
            inj.raise_at("run", times=1)
            r1 = eng.serve([_images(4, seed=1)])
            inj.raise_at("run", times=1)
            r2 = eng.serve([_images(4, seed=2)])
            r3 = eng.serve([_images(4, seed=3)])
        assert all(r[0].ok for r in (r1, r2, r3))
        snap = eng.summary()["resilience"]["breakers"]["4"]
        assert snap["state"] == "open"
        assert snap["pinned_rung"] == "interpret"
        opens = [
            e for e in _events(col, "serve_breaker")
            if e.args["to_state"] == "open"
        ]
        assert len(opens) == 1 and opens[0].args["bucket"] == 4
        # the third batch rode the pinned rung, not another fused attempt
        routes = [e.args["route"] for e in _events(col, "serve_batch")]
        assert routes[-1] == "interpret"
        np.testing.assert_allclose(r3[0].logits, ref, atol=1e-4)


class TestPoisonedOutput:
    def test_sentinel_reserves_from_reference(self):
        eng = _engine(output_sentinel=True, breaker_threshold=1,
                      breaker_cooldown_s=600.0)
        x = _images(2, seed=5)
        ref = np.asarray(reference_network(x, GRAPH, PARAMS))
        inj = FaultInjector(seed=0)
        inj.poison_output(times=1)
        with tracing() as col, inject(injector=inj):
            res = eng.serve([x])
        assert res[0].ok  # degraded-but-correct, never silent garbage
        assert np.isfinite(res[0].logits).all()
        np.testing.assert_allclose(res[0].logits, ref, atol=1e-4)
        assert eng.resilience["sentinel_trips"] == 1
        sent = _events(col, "serve_sentinel")
        assert len(sent) == 1
        assert sent[0].args["action"] == "reference_retry"
        # a sentinel trip is a fused-path failure: breaker opens pinned
        # to the reference walk
        snap = eng.summary()["resilience"]["breakers"]["2"]
        assert snap["state"] == "open"
        assert snap["pinned_rung"] == "reference"
        # while open, traffic serves from the pin and stays correct
        with tracing() as col2:
            res2 = eng.serve([x.copy()])
        assert res2[0].ok
        routes = [e.args["route"] for e in _events(col2, "serve_batch")]
        assert routes == ["reference"]
        np.testing.assert_allclose(res2[0].logits, ref, atol=1e-4)


class TestQueueOverflow:
    def test_overflow_rejects_typed_then_recovers(self):
        eng = _engine(max_queue=2)
        ids = [eng.submit(_images(1, seed=s)) for s in range(3)]
        res = eng.results[ids[2]]
        assert not res.ok and isinstance(res.error, PreflightError)
        assert res.error.context["field"] == "queue"
        eng.drain()
        assert eng.results[ids[0]].ok and eng.results[ids[1]].ok
        # capacity freed: the queue admits again
        after = eng.serve([_images(1, seed=9)])
        assert after[0].ok


class TestQueueStall:
    def test_stalls_delay_but_never_drop(self):
        eng = _engine()
        inj = FaultInjector(seed=0)
        inj.stall_queue(2)
        with tracing() as col, inject(injector=inj):
            res = eng.serve([_images(1, seed=s) for s in range(3)])
        assert all(r.ok for r in res)
        assert eng.resilience["stalls"] == 2
        assert len(_events(col, "serve_stall")) == 2
        assert inj.fired.count(("stall", "<queue>", "skip")) == 2


# ---------------------------------------------------------------------------
# concurrent frontend: hammer + handle semantics
# ---------------------------------------------------------------------------


class TestFrontend:
    def test_handle_resolves_with_result(self):
        eng = _engine()
        with ServingFrontend(eng) as fe:
            h = fe.submit(_images(2, seed=1))
            res = h.result(timeout=60.0)
        assert res.ok and res.id == h.id and h.done()

    def test_rejection_resolves_immediately(self):
        eng = _engine()
        fe = ServingFrontend(eng)  # not even started: rejection is sync
        h = fe.submit(np.zeros((1, 8, 8, 1), np.float32))
        res = h.result(timeout=1.0)
        assert not res.ok and isinstance(res.error, PreflightError)

    def test_multithreaded_hammer_no_lost_no_duplicate(self):
        eng = _engine()
        eng.serve([_images(4, seed=0)])  # pre-warm: hammer reuses the plan
        misses_before = eng.cache_counters["misses"]
        n_threads, per_thread = 6, 8
        results: dict[int, list] = {}
        res_lock = threading.Lock()
        errors: list = []

        def producer(tid: int) -> None:
            try:
                for i in range(per_thread):
                    h = fe.submit(_images(1, seed=tid * 100 + i))
                    r = h.result(timeout=120.0)
                    with res_lock:
                        results.setdefault(r.id, []).append(r)
            except Exception as e:  # pragma: no cover - surfaced below
                errors.append(e)

        with ServingFrontend(eng) as fe:
            threads = [
                threading.Thread(target=producer, args=(t,))
                for t in range(n_threads)
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join(timeout=120.0)
        assert not errors, errors
        # no lost, no duplicated results
        assert len(results) == n_threads * per_thread
        assert all(len(v) == 1 for v in results.values())
        assert all(v[0].ok for v in results.values())
        # cache counters stayed stable: the hammer added zero plan misses
        # (1-row traffic packs into already-planned buckets)
        assert eng.cache_counters["misses"] <= misses_before + 2
        assert eng.cache_counters["evictions"] == 0


# ---------------------------------------------------------------------------
# PR 9 equivalence: all resilience knobs off == the plain engine
# ---------------------------------------------------------------------------


class TestDefaultConfigEquivalence:
    def test_default_engine_is_the_plain_engine(self):
        """With every new knob at its default, nothing new runs: no
        breakers, no watchdog, no sentinel, no shed/expiry — and the
        logits are bit-identical between two default engines."""
        xs = [_images(r, seed=r) for r in (1, 4, 2)]
        eng_a = _engine()
        eng_b = _engine()
        res_a = eng_a.serve(xs)
        res_b = eng_b.serve([x.copy() for x in xs])
        for a, b in zip(res_a, res_b):
            assert a.ok and b.ok and a.bucket == b.bucket
            assert np.array_equal(a.logits, b.logits)
        summary = eng_a.summary()
        assert all(
            v == 0 for k, v in summary["resilience"].items()
            if k != "breakers"
        )
        assert summary["resilience"]["breakers"] == {}
        assert eng_a._breakers == {}

    def test_config_validation(self):
        with pytest.raises(PreflightError):
            ServeConfig(shed_margin=0.0)
        with pytest.raises(PreflightError):
            ServeConfig(breaker_threshold=0)
        with pytest.raises(PreflightError):
            ServeConfig(watchdog_factor=1.0)


# ---------------------------------------------------------------------------
# admission hardening: check_request edge cases (satellite of §15)
# ---------------------------------------------------------------------------


class TestAdmissionHardening:
    def _field(self, exc_info) -> str:
        return exc_info.value.context["field"]

    def test_non_contiguous_view_accepted(self):
        from repro.robust.validate import check_request

        base = _images(8, seed=1)
        view = base[::2]  # stride trick: valid shape, not contiguous
        assert not view.flags["C_CONTIGUOUS"]
        check_request(view, GRAPH)  # does not raise
        eng = _engine()
        res = eng.serve([view])
        assert res[0].ok and res[0].rows == 4

    def test_f64_finite_accepted_f64_overflow_rejected(self):
        from repro.robust.validate import check_request

        ok64 = _images(1).astype(np.float64)
        check_request(ok64, GRAPH)  # finite f64 casts cleanly: admitted
        big = ok64.copy()
        big[0, 0, 0, 0] = 1e200  # finite in f64, Inf after the f32 cast
        with pytest.raises(NumericError) as ei:
            check_request(big, GRAPH)
        assert self._field(ei) == "range"

    def test_f64_nan_named_values_not_range(self):
        from repro.robust.validate import check_request

        bad = _images(1).astype(np.float64)
        bad[0, 1, 1, 0] = np.nan
        with pytest.raises(NumericError) as ei:
            check_request(bad, GRAPH)
        assert self._field(ei) == "values"

    def test_zero_row_batch_rejected(self):
        from repro.robust.validate import check_request

        empty = np.zeros(
            (0, GRAPH.input_size, GRAPH.input_size, GRAPH.in_channels),
            np.float32,
        )
        with pytest.raises(PreflightError) as ei:
            check_request(empty, GRAPH)
        assert self._field(ei) == "batch"

    def test_rejection_fields_name_the_offender(self):
        from repro.robust.validate import check_request

        cases = [
            (np.zeros((32, 32, 1), np.float32), "rank"),
            (np.zeros((1, 8, 8, 1), np.float32), "spatial"),
            (np.zeros((1, 32, 32, 3), np.float32), "channels"),
            (np.array([[[["x"]]]], dtype=object), None),  # dtype below
        ]
        for x, field in cases[:3]:
            with pytest.raises(PreflightError) as ei:
                check_request(x, GRAPH)
            assert self._field(ei) == field
        bad_dtype = np.empty(
            (1, GRAPH.input_size, GRAPH.input_size, GRAPH.in_channels),
            dtype=object,
        )
        with pytest.raises(PreflightError) as ei:
            check_request(bad_dtype, GRAPH)
        assert self._field(ei) == "dtype"

    def test_engine_rejection_carries_field_context(self):
        eng = _engine()
        rid = eng.submit(np.zeros((1, 8, 8, 1), np.float32))
        res = eng.results[rid]
        assert not res.ok
        assert res.error.context["field"] == "spatial"
