"""SSD chunk-scan Pallas kernel vs the sequential-recurrence oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

RNG = np.random.default_rng(11)


def _inputs(b, S, H, P, N, dtype=jnp.float32):
    x = jnp.asarray(RNG.normal(0, 1, (b, S, H, P)), dtype)
    dt = jax.nn.softplus(jnp.asarray(RNG.normal(0, 1, (b, S, H)), jnp.float32))
    A = -jnp.exp(jnp.asarray(RNG.normal(0, 0.5, (H,)), jnp.float32))
    B = jnp.asarray(RNG.normal(0, 1, (b, S, N)), dtype)
    C = jnp.asarray(RNG.normal(0, 1, (b, S, N)), dtype)
    D = jnp.asarray(RNG.normal(0, 1, (H,)), jnp.float32)
    return x, dt, A, B, C, D


class TestSsdScanKernel:
    @pytest.mark.parametrize("chunk", [8, 16, 32])
    def test_matches_sequential_oracle(self, chunk):
        args = _inputs(2, 64, 4, 8, 16)
        yr, sr = ssd_ref(*args)
        yk, sk = ssd_scan(*args, chunk=chunk)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=5e-4)
        np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), atol=5e-4)

    @pytest.mark.parametrize("shape", [(1, 24, 2, 4, 8), (3, 40, 5, 16, 32)])
    def test_shape_sweep(self, shape):
        args = _inputs(*shape)
        yr, _ = ssd_ref(*args)
        yk, _ = ssd_scan(*args, chunk=8)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=5e-4)

    def test_ragged_length_padded(self):
        args = _inputs(2, 37, 3, 8, 8)  # 37 % 8 != 0: trailing pad path
        yr, _ = ssd_ref(*args)
        yk, _ = ssd_scan(*args, chunk=8)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=5e-4)

    def test_bf16_inputs(self):
        args = _inputs(1, 32, 2, 8, 8, dtype=jnp.bfloat16)
        f32_args = tuple(a.astype(jnp.float32) for a in args)
        yr, _ = ssd_ref(*f32_args)
        yk, _ = ssd_scan(*args, chunk=16)
        scale = float(np.abs(np.asarray(yr)).max())
        err = float(np.abs(np.asarray(yk, np.float32) - np.asarray(yr)).max())
        assert err < 0.05 * scale  # bf16 inputs, f32 state: ~2-3 digits

    def test_agrees_with_model_ssd(self):
        """The kernel and the model-side pure-JAX chunked SSD agree — the
        swap-in contract for mamba2_mixer."""
        from repro.models.ssm import ssd_chunked

        args = _inputs(2, 64, 4, 8, 16)
        ym, sm = ssd_chunked(*args, chunk=16)
        yk, sk = ssd_scan(*args, chunk=16)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(ym), atol=5e-4)
        np.testing.assert_allclose(np.asarray(sk), np.asarray(sm), atol=5e-4)
